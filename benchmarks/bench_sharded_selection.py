"""Per-device selection overhead: the shard_map data-parallel GRAFT path vs
the single-device reference and the vmapped multi-batch path.

The interesting number is the ratio ``sharded / single``: each shard's local
work is one K_local-row Fast MaxVol (identical to the single-device call),
so anything above 1.0 is the price of the psum'd rank statistics. Run
standalone (``python benchmarks/bench_sharded_selection.py`` or
``run.py --suite sharded``) this module forces 8 host CPU devices; when jax
is already initialized (``--suite all``) it degrades to the real device
count — on one device the mesh is (1, 1) and the ratio isolates the
shard_map machinery itself.
"""
from __future__ import annotations

import os
import sys
from typing import List

_FORCE_DEVICES = 8
if ("jax" not in sys.modules
        and "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", "")):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                               f" --xla_force_host_platform_device_count={_FORCE_DEVICES}").strip()

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row, time_call
from repro.distributed import sharding as sh
from repro.selection import GraftConfig, engine


def run() -> List[str]:
    rng = np.random.default_rng(0)
    rows: List[str] = []
    n = len(jax.devices())
    K_local, d, R = 128, 512, 32
    cfg = GraftConfig(rset=(8, 16, 32), eps=0.25)

    def batch(k):
        V = jnp.asarray(rng.normal(size=(k, R)).astype(np.float32))
        G = jnp.asarray(rng.normal(size=(d, k)).astype(np.float32))
        return V, G, jnp.mean(G, axis=1)

    # single-device reference: one K_local-row selection
    V1, G1, gb1 = batch(K_local)
    t_single = time_call(
        lambda v, g, gb: engine.select_batch(cfg, "graft", v, g, gb),
        V1, G1, gb1)
    rows.append(csv_row(f"select_single_K{K_local}", t_single, "reference"))

    # vmapped multi-batch: n microbatches under one jit on one device
    Vs, Gs, gbs = (jnp.stack(x) for x in zip(*(batch(K_local) for _ in range(n))))
    t_vmap = time_call(
        lambda v, g, gb: engine.select_multi_batch(cfg, "graft", v, g, gb),
        Vs, Gs, gbs)
    rows.append(csv_row(f"select_vmap_B{n}_K{K_local}", t_vmap,
                        f"per_batch_us={t_vmap / n:.1f}"))

    # shard_map data-parallel: n shards of K_local rows each
    mesh = jax.make_mesh((n, 1), ("data", "model"))
    Vg, Gg, _ = batch(n * K_local)
    Vg = jax.device_put(Vg, sh.named_sharding(mesh, ("act_batch", None)))
    Gg = jax.device_put(Gg, sh.named_sharding(mesh, (None, "act_batch")))
    selector = engine.make_sharded_selector(cfg, mesh)
    t_shard = time_call(lambda v, g: selector(v, g, jnp.int32(0)), Vg, Gg)
    rows.append(csv_row(
        f"select_sharded_n{n}_Kglobal{n * K_local}", t_shard,
        f"per_device_overhead={t_shard / max(t_single, 1e-9):.2f}x"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
