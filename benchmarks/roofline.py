"""Roofline analysis from the dry-run artifacts (EXPERIMENTS.md §Roofline).

Reads experiments/dryrun/*.json (written by repro.launch.dryrun), derives the
three roofline terms per (arch × shape × variant) on the single-pod mesh:

    T_compute = HLO_FLOPs   / (chips · 197e12 FLOP/s bf16)
    T_memory  = HLO_bytes   / (chips · 819e9 B/s HBM)
    T_coll    = coll_bytes  / (chips · 50e9 B/s ICI link)

FLOPs/bytes/coll_bytes use the L=p vs L=2p unrolled deltas scaled to full
depth (scan bodies are counted once by XLA cost analysis — see dryrun.py).
MODEL_FLOPS = 6·N_active·D_tokens for train, 2·N_active·D for forward-only.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # B/s / chip
ICI_BW = 50e9                # B/s / link (conservative single-link figure)

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments", "dryrun")

_SHAPE_TOKENS = {
    "train_4k": 4096 * 256,
    "prefill_32k": 32768 * 32,
    "decode_32k": 128,            # one token per sequence
    "long_500k": 1,
}


def param_counts(arch: str) -> Dict[str, float]:
    """Total + active parameter counts from the registered config."""
    from repro import configs
    from repro.models.model import ModelConfig
    cfg = configs.get_config(arch)
    D, F, V, L = cfg.d_model, cfg.d_ff, cfg.vocab_size, cfg.num_layers
    H, Hkv, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    attn = D * H * Dh + 2 * D * Hkv * Dh + H * Dh * D
    total = active = 0.0
    for i in range(L):
        if cfg.family == "ssm":
            blk = 5 * D * D + D * D          # rwkv time (r,k,v,g,o) + lora-ish
            blk += D * F + F * D + D * D     # channel mix
            total += blk; active += blk
        elif cfg.family == "hybrid":
            ssm = 2 * D * D + 2 * D * H * cfg.ssm_state + D * H
            blk = attn + ssm + 3 * D * F
            total += blk; active += blk
        elif cfg.family == "moe" and i >= cfg.first_k_dense:
            e_blk = 3 * D * F
            total += attn + cfg.num_experts * e_blk
            active += attn + cfg.num_experts_per_tok * e_blk
        elif cfg.family == "moe":
            blk = attn + 3 * D * cfg.d_ff_dense
            total += blk; active += blk
        else:
            blk = attn + 3 * D * F
            total += blk; active += blk
    emb = V * D * (1 if cfg.tie_embeddings else 2)
    total += emb
    active += emb
    return {"total": total, "active": active}


def load_cells(mesh: str = "single", include_perf_variants: bool = False) -> List[Dict]:
    out = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, f"*__{mesh}__*.json"))):
        tag = os.path.basename(path).split("__")[-1][:-5]
        if not include_perf_variants and (
                "@" in tag or tag in ("subset", "select")):
            continue                      # §Perf hillclimb artifacts
        with open(path) as f:
            d = json.load(f)
        if d.get("ok"):
            out.append(d)
    return out


def roofline_row(d: Dict) -> Optional[Dict]:
    mesh_shape = d.get("mesh_shape", {})
    chips = 1
    for v in mesh_shape.values():
        chips *= v
    src = d.get("scaled") or {
        "flops": d["full"]["flops"],
        "bytes_accessed": d["full"]["bytes_accessed"],
        "collective_bytes": d["full"]["collectives"]["total_bytes"],
    }
    # XLA cost_analysis reports PER-PARTITION numbers (the compiled module is
    # the per-device program — verified: ×chips ≈ 1.8·6ND for dense trains,
    # the expected remat+attention overhead). Collective operand bytes parsed
    # from the partitioned HLO are also per-device.
    # Guard: L2−L1 deltas can go slightly negative from fusion differences;
    # never report below the L1 measurement.
    flops = max(src["flops"], d.get("unrolled_p1", d["full"])["flops"])
    bytes_acc = max(src["bytes_accessed"],
                    d.get("unrolled_p1", d["full"])["bytes_accessed"])
    coll = max(src["collective_bytes"] if "collective_bytes" in src else 0.0,
               0.0)
    t_comp = flops / PEAK_FLOPS
    t_mem = bytes_acc / HBM_BW
    t_coll = coll / ICI_BW
    src = {"flops": flops * chips, "bytes_accessed": bytes_acc * chips,
           "collective_bytes": coll * chips}
    dom = max(("compute", t_comp), ("memory", t_mem),
              ("collective", t_coll), key=lambda kv: kv[1])
    counts = param_counts(d["arch"])
    tokens = _SHAPE_TOKENS[d["shape"]]
    mult = 6.0 if d["shape"] == "train_4k" else 2.0
    graft_note = ""
    if d["variant"] == "graft":
        # selection fwd (2·N·D) + subset train (6·N·D·R/K with R=K/2 max rank)
        model_flops = 2.0 * counts["active"] * tokens + \
            6.0 * counts["active"] * tokens * 0.5
        graft_note = "graft(R=K/2)"
    else:
        model_flops = mult * counts["active"] * tokens
    useful = model_flops / src["flops"] if src["flops"] else 0.0
    mem = d["full"]["memory"]
    return {
        "arch": d["arch"], "shape": d["shape"], "variant": d["variant"],
        "chips": chips,
        "t_compute_s": t_comp, "t_memory_s": t_mem, "t_collective_s": t_coll,
        "dominant": dom[0], "bound_s": dom[1],
        "model_flops": model_flops, "hlo_flops": src["flops"],
        "useful_ratio": useful, "note": graft_note,
        "temp_gib": mem.get("temp_size_in_bytes", 0) / 2 ** 30,
        "args_gib": mem.get("argument_size_in_bytes", 0) / 2 ** 30,
    }


def format_table(rows: List[Dict]) -> str:
    hdr = (f"{'arch':24s} {'shape':12s} {'variant':8s} {'T_comp(ms)':>10s} "
           f"{'T_mem(ms)':>10s} {'T_coll(ms)':>10s} {'dominant':>10s} "
           f"{'useful':>7s} {'temp GiB':>9s}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        lines.append(
            f"{r['arch']:24s} {r['shape']:12s} {r['variant']:8s} "
            f"{r['t_compute_s']*1e3:10.2f} {r['t_memory_s']*1e3:10.2f} "
            f"{r['t_collective_s']*1e3:10.2f} {r['dominant']:>10s} "
            f"{r['useful_ratio']:7.3f} {r['temp_gib']:9.2f}")
    return "\n".join(lines)


def run() -> List[str]:
    rows = [roofline_row(d) for d in load_cells("single")]
    rows = [r for r in rows if r]
    out = []
    for r in rows:
        out.append(
            f"roofline_{r['arch']}_{r['shape']}_{r['variant']},0.0,"
            f"Tc={r['t_compute_s']*1e3:.2f}ms;Tm={r['t_memory_s']*1e3:.2f}ms;"
            f"Tcoll={r['t_collective_s']*1e3:.2f}ms;dom={r['dominant']};"
            f"useful={r['useful_ratio']:.3f}")
    return out


def main():
    rows = [roofline_row(d) for d in load_cells("single")]
    rows = [r for r in rows if r]
    rows.sort(key=lambda r: (r["arch"], r["shape"], r["variant"]))
    print(format_table(rows))


if __name__ == "__main__":
    main()
