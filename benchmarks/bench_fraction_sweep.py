"""Paper Tables 8/9/12/14 + Fig 3: accuracy vs data fraction f for GRAFT,
GRAFT-Warm, Random, GradMatch, CRAIG, EL2N on the classification analog.

Emissions are reported as accounted training FLOPs (DESIGN.md §3: E ∝ FLOPs
at fixed hardware). The exponential gain fit E(x) = E0 + (H−E0)(1−e^{−λx})
reproduces the paper's λ comparison (GRAFT's λ should exceed baselines')."""
from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (accuracy, csv_row, init_mlp, mlp_loss,
                               mlp_per_example_loss, sgd_step,
                               train_flops_per_example)
from repro.core import baselines as bl
from repro.core import graft
from repro.core.features import svd_features
from repro.core.grad_features import per_sample_grads_full
from repro.data import SyntheticClassification

FRACTIONS = (0.05, 0.15, 0.25, 0.35)
DIM, HIDDEN, CLASSES = 64, 48, 30
BATCH, STEPS, LR = 200, 100, 0.2
REFRESH = 25                                  # paper's S (selection period)


def _select(method: str, key, params, xb, yb, r: int, warm_params=None):
    """Return (pivots, weights) of size r for one batch."""
    if method == "random":
        return bl.random_subset(key, xb.shape[0], r)
    if method in ("gradmatch", "craig", "el2n", "glister", "graft",
                  "graft_warm"):
        probe = warm_params if method == "graft_warm" and warm_params else params

        def ex_loss(p, ex):
            x1, y1 = ex
            return mlp_loss(p, x1[None], y1[None])

        G, gbar = per_sample_grads_full(ex_loss, probe, (xb, yb))
        if method == "gradmatch":
            piv, w = bl.gradmatch_omp(G, gbar, r)
            w = w / (jnp.sum(w) + 1e-9)
            return piv, w
        if method == "craig":
            return bl.craig_greedy(G, r)
        if method == "el2n":
            return bl.el2n_topk(G, r)
        if method == "glister":
            # validation gradient proxied by the batch-mean gradient of the
            # CURRENT model (held-out val grads are host-side in production)
            return bl.glister_greedy(G, gbar, r)
        # GRAFT: features from the raw batch (cold) or model grads (warm)
        from repro.core.maxvol import fast_maxvol
        src = G.T if method == "graft_warm" else xb
        r_feat = min(r, src.shape[1], src.shape[0])
        V = svd_features(src, r_feat)
        piv, _ = fast_maxvol(V, r_feat)
        if r > r_feat:
            # rank beyond the feature dimension: MaxVol pivots first, then
            # uniform fill from the unselected pool (paper's regime is r ≪ dim)
            rest = jnp.setdiff1d(jnp.arange(xb.shape[0]), piv,
                                 size=xb.shape[0] - r_feat, fill_value=-1)
            extra = jax.random.permutation(key, rest)[: r - r_feat]
            piv = jnp.concatenate([piv, extra.astype(jnp.int32)])
        w = jnp.full((r,), 1.0 / r)
        return piv, w
    raise KeyError(method)


def _run_method(method: str, frac: float, xtr, ytr, xte, yte,
                warm_params=None, seed: int = 0) -> Dict[str, float]:
    key = jax.random.PRNGKey(seed)
    params = init_mlp(key, DIM, HIDDEN, CLASSES)
    r = max(2, int(BATCH * frac))
    flops_ex = train_flops_per_example(DIM, HIDDEN, CLASSES)
    total_flops = 0.0
    g = np.random.default_rng(seed)
    piv = w = None

    @jax.jit
    def train_step(p, xs, ys, ws):
        def loss(p):
            pel = mlp_per_example_loss(p, xs, ys)
            return jnp.sum(pel * ws)
        return sgd_step(p, jax.grad(loss)(p), LR)

    for step in range(STEPS):
        idx = g.choice(len(ytr), BATCH, replace=False)
        xb, yb = jnp.asarray(xtr[idx]), jnp.asarray(ytr[idx])
        if step % REFRESH == 0 or piv is None:
            piv, w = _select(method, jax.random.fold_in(key, step), params,
                             xb, yb, r, warm_params)
            # selection cost: one per-sample grad pass over the batch
            if method not in ("random",):
                total_flops += flops_ex * BATCH / 3.0      # fwd-only ≈ 1/3
        xs, ys = xb[piv], yb[piv]
        params = train_step(params, xs, ys, w)
        total_flops += flops_ex * r
    return {"acc": accuracy(params, jnp.asarray(xte), jnp.asarray(yte)),
            "flops": total_flops}


def fit_exponential_gain(xs: np.ndarray, ys: np.ndarray):
    """Fit E(x) = E0 + (H−E0)(1−exp(−λ x/x_max)) by grid+least squares."""
    x = xs / xs.max()
    best = None
    for lam in np.linspace(0.2, 12.0, 60):
        basis = 1 - np.exp(-lam * x)
        A = np.stack([np.ones_like(x), basis], 1)
        coef, res, *_ = np.linalg.lstsq(A, ys, rcond=None)
        sse = float(res[0]) if len(res) else float(
            np.sum((A @ coef - ys) ** 2))
        if best is None or sse < best[0]:
            best = (sse, lam, coef)
    sse, lam, coef = best
    ss_tot = float(np.sum((ys - ys.mean()) ** 2)) + 1e-12
    return {"lambda": lam, "E0": float(coef[0]),
            "H": float(coef[0] + coef[1]), "r2": 1 - sse / ss_tot}


def run(full_steps: int = STEPS) -> List[str]:
    # imbalanced + noisy: the regime the paper targets (random subsets miss
    # rare classes; diversity-seeking selection keeps them — Fig 2c)
    ds = SyntheticClassification(n=4096, dim=DIM, num_classes=CLASSES,
                                 seed=0, noise=3.0, label_noise=0.05,
                                 imbalance=1.0)
    (xtr, ytr), (xte, yte) = ds.split(0.2)

    # full-data reference + warm-start params (paper's GRAFT Warm uses
    # full-data representations for selection)
    key = jax.random.PRNGKey(42)
    full_params = init_mlp(key, DIM, HIDDEN, CLASSES)

    @jax.jit
    def full_step(p, xs, ys):
        return sgd_step(p, jax.grad(mlp_loss)(p, xs, ys), LR)

    g = np.random.default_rng(1)
    flops_ex = train_flops_per_example(DIM, HIDDEN, CLASSES)
    full_flops = 0.0
    for _ in range(STEPS):
        idx = g.choice(len(ytr), BATCH, replace=False)
        full_params = full_step(full_params, jnp.asarray(xtr[idx]),
                                jnp.asarray(ytr[idx]))
        full_flops += flops_ex * BATCH
    full_acc = accuracy(full_params, jnp.asarray(xte), jnp.asarray(yte))

    rows = [csv_row("fraction_full", 0.0,
                    f"acc={full_acc:.4f};flops={full_flops:.3e}")]
    methods = ("graft", "graft_warm", "random", "gradmatch", "craig",
               "glister", "el2n")
    accs: Dict[str, List[float]] = {m: [] for m in methods}
    flops: Dict[str, List[float]] = {m: [] for m in methods}
    for m in methods:
        for f in FRACTIONS:
            out = _run_method(m, f, xtr, ytr, xte, yte,
                              warm_params=full_params)
            accs[m].append(out["acc"])
            flops[m].append(out["flops"])
            rows.append(csv_row(
                f"fraction_{m}_f{int(f*100):02d}", 0.0,
                f"acc={out['acc']:.4f};flops={out['flops']:.3e};"
                f"psi={out['acc']/full_acc:.4f}"))
        fit = fit_exponential_gain(np.asarray(flops[m]), np.asarray(accs[m]))
        rows.append(csv_row(
            f"fraction_{m}_fit", 0.0,
            f"lambda={fit['lambda']:.2f};E0={fit['E0']:.3f};"
            f"H={fit['H']:.3f};r2={fit['r2']:.3f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
