"""Perf-regression gate: diff a freshly-measured BENCH_selection.json
against the committed repo-root baseline and fail on regression.

Hardware-independent fields only:

  * ``dispatch_per_refresh`` — kernel launches / gathers per selection
    refresh must never INCREASE (the fused-dispatch win is the repo's
    headline perf property);
  * compiled FLOPs (``features_*`` and ``scaling`` entries) — must not grow
    beyond ``--tol`` relative, and the sketch-vs-svd ``flops_ratio`` must
    not shrink below it;
  * ``host_stall.dispatch_ahead_steps`` — the async train loop's
    dispatch-ahead depth (steps issued while the previous step's metrics
    were still device futures) must never DECREASE: it is a deterministic
    counter for the bench's fixed flush cadence, and a drop means a
    host↔device sync crept back onto the per-step path. Likewise
    ``host_stall.device_timed_steps`` (DeviceClock coverage) must never
    decrease;
  * ``attention`` — the ``attn_backend=flash`` forward/train-step
    ``pallas_call`` counts must never increase (one launch per layer is
    the invariant), and the compiled flash train-step FLOPs are
    tolerance-gated like the other FLOPs fields.

Wall-clock fields (including ``host_stall.blocked_ms_per_step``) are
deliberately ignored (CI machines are noisy).

Prints a markdown delta table; when ``$GITHUB_STEP_SUMMARY`` is set (or
``--summary PATH`` given) the table is appended there so the delta shows up
in the job summary. Exit code 1 on any regression.

Usage::

    PYTHONPATH=src:. python benchmarks/check_bench_regression.py \
        BENCH_selection.json BENCH_current.json
"""
from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Any, Dict, List, Tuple

from repro.analysis.jaxpr_audit import monotone_count_rows

Row = Tuple[str, float, float, bool]   # metric, baseline, current, regressed


def _fmt(v: float) -> str:
    if v == int(v) and abs(v) < 1e6:
        return str(int(v))
    return f"{v:.4g}"


def compare(baseline: Dict[str, Any], current: Dict[str, Any],
            tol: float) -> Tuple[List[Row], List[str]]:
    rows: List[Row] = []
    problems: List[str] = []

    def check(name: str, b: float, c: float, bad: bool, why: str) -> None:
        rows.append((name, b, c, bad))
        if bad:
            problems.append(f"{name}: {why} (baseline {_fmt(b)}, "
                            f"current {_fmt(c)})")

    # --- dispatch shape: exact counters, monotone gate (the differ is the
    # auditor's, shared with bench collection — one accounting, no drift) --
    for path, entry in sorted(baseline.get("dispatch_per_refresh", {}).items()):
        cur = current.get("dispatch_per_refresh", {}).get(path)
        if cur is None:
            problems.append(f"dispatch_per_refresh['{path}'] missing from "
                            "the current report")
            continue
        r, p = monotone_count_rows(f"dispatch.{path}", entry, cur,
                                   ("pallas_call", "gather"),
                                   "dispatch count increased")
        rows.extend(r)
        problems.extend(p)

    # --- compiled FLOPs: tolerance gate ---------------------------------
    for key in sorted(baseline):
        if not key.startswith("features_"):
            continue
        base_f, cur_f = baseline[key], current.get(key)
        if cur_f is None:
            problems.append(f"'{key}' missing from the current report")
            continue
        for name in ("svd", "sketch_svd"):
            b = float(base_f[name]["flops"])
            c = float(cur_f[name]["flops"])
            check(f"{key}.{name}.flops", b, c, c > b * (1 + tol),
                  f"compiled FLOPs grew > {tol:.0%}")
        b, c = float(base_f["flops_ratio"]), float(cur_f["flops_ratio"])
        check(f"{key}.flops_ratio", b, c, c < b * (1 - tol),
              f"sketch_svd FLOPs win shrank > {tol:.0%}")

    # --- host-stall: dispatch-ahead depth, monotone gate -----------------
    base_stall = baseline.get("host_stall")
    if base_stall is not None:
        cur_stall = current.get("host_stall")
        if cur_stall is None:
            problems.append("host_stall missing from the current report")
        else:
            b = float(base_stall["dispatch_ahead_steps"])
            c = float(cur_stall["dispatch_ahead_steps"])
            check("host_stall.dispatch_ahead_steps", b, c, c < b,
                  "async-loop dispatch-ahead depth decreased (a per-step "
                  "host sync crept back in)")
            if "device_timed_steps" in base_stall:
                b = float(base_stall["device_timed_steps"])
                c = float(cur_stall.get("device_timed_steps", 0))
                check("host_stall.device_timed_steps", b, c, c < b,
                      "DeviceClock coverage decreased (completion stamps "
                      "are being dropped)")

    # --- attention hot path: launches exact, train-step FLOPs tol-gated --
    base_attn = baseline.get("attention")
    if base_attn is not None:
        cur_attn = current.get("attention")
        if cur_attn is None:
            problems.append("attention missing from the current report")
        else:
            r, p = monotone_count_rows(
                "attention", base_attn, cur_attn,
                ("forward_pallas_call", "train_step_pallas_call"),
                "flash-attention kernel launch count increased")
            rows.extend(r)
            problems.extend(p)
            b = float(base_attn["train_step_flops"]["flash"])
            c = float(cur_attn["train_step_flops"]["flash"])
            check("attention.train_step_flops.flash", b, c,
                  c > b * (1 + tol), f"compiled FLOPs grew > {tol:.0%}")

    # --- streaming reservoir: launches exact, update FLOPs tol-gated -----
    base_stream = baseline.get("streaming")
    if base_stream is not None:
        cur_stream = current.get("streaming")
        if cur_stream is None:
            problems.append("streaming missing from the current report")
        else:
            for path in sorted(base_stream.get("dispatch", {})):
                cur = cur_stream.get("dispatch", {}).get(path)
                if cur is None:
                    problems.append(f"streaming.dispatch['{path}'] missing "
                                    "from the current report")
                    continue
                r, p = monotone_count_rows(
                    f"streaming.{path}", base_stream["dispatch"][path], cur,
                    ("pallas_call", "gather"),
                    "streaming refresh dispatch count increased")
                rows.extend(r)
                problems.extend(p)
            b = float(base_stream["flops"]["reservoir_update"])
            c = float(cur_stream.get("flops", {}).get("reservoir_update", 0))
            check("streaming.flops.reservoir_update", b, c,
                  c > b * (1 + tol),
                  f"reservoir-update FLOPs grew > {tol:.0%}")

    cur_scaling = {e["name"]: e for e in current.get("scaling", [])}
    for entry in baseline.get("scaling", []):
        cur = cur_scaling.get(entry["name"])
        if cur is None:
            problems.append(f"scaling entry '{entry['name']}' missing from "
                            "the current report")
            continue
        b, c = float(entry["flops"]), float(cur["flops"])
        check(f"scaling.{entry['name']}.flops", b, c, c > b * (1 + tol),
              f"compiled FLOPs grew > {tol:.0%}")
    return rows, problems


def markdown_table(rows: List[Row]) -> str:
    lines = ["| metric | baseline | current | Δ | |",
             "|---|---:|---:|---:|---|"]
    for name, b, c, bad in rows:
        delta = "0" if b == c else (f"{(c - b) / b:+.1%}" if b else f"+{_fmt(c)}")
        lines.append(f"| `{name}` | {_fmt(b)} | {_fmt(c)} | {delta} | "
                     f"{'❌ REGRESSION' if bad else '✅'} |")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("baseline", help="committed BENCH_selection.json")
    ap.add_argument("current", help="freshly-measured report")
    ap.add_argument("--tol", type=float, default=0.05,
                    help="relative tolerance for FLOPs fields "
                         "(dispatch counts are exact)")
    ap.add_argument("--summary", default=None,
                    help="markdown summary path "
                         "(default: $GITHUB_STEP_SUMMARY when set)")
    args = ap.parse_args(argv)

    with open(args.baseline) as f:
        baseline = json.load(f)
    with open(args.current) as f:
        current = json.load(f)
    rows, problems = compare(baseline, current, args.tol)

    table = markdown_table(rows)
    title = ("## selection perf gate — REGRESSION" if problems
             else "## selection perf gate — OK")
    body = title + "\n\n" + table + "\n"
    if problems:
        body += "\n" + "\n".join(f"- **{p}**" for p in problems) + "\n"
    print(body)
    summary_path = args.summary or os.environ.get("GITHUB_STEP_SUMMARY")
    if summary_path:
        with open(summary_path, "a") as f:
            f.write(body + "\n")
    for p in problems:
        print(f"PERF REGRESSION: {p}", file=sys.stderr)
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main())
