"""Shared benchmark utilities: timing, CSV rows, tiny-model training."""
from __future__ import annotations

import time
from typing import Callable, Dict, List

import jax
import jax.numpy as jnp
import numpy as np


def time_call(fn: Callable, *args, repeats: int = 20, warmup: int = 3) -> float:
    """Median wall-time (µs) of a jitted call (block_until_ready)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(times))


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"


# --------------------------------------------------------------------------
# tiny MLP classifier used by the paper-analog accuracy benchmarks
# --------------------------------------------------------------------------

def init_mlp(key, dim: int, hidden: int, classes: int):
    k1, k2 = jax.random.split(key)
    return {
        "w1": jax.random.normal(k1, (dim, hidden)) * (dim ** -0.5),
        "b1": jnp.zeros((hidden,)),
        "w2": jax.random.normal(k2, (hidden, classes)) * (hidden ** -0.5),
        "b2": jnp.zeros((classes,)),
    }


def mlp_logits(params, x):
    h = jnp.tanh(x @ params["w1"] + params["b1"])
    return h @ params["w2"] + params["b2"]


def mlp_loss(params, x, y):
    logits = mlp_logits(params, x)
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))


def mlp_per_example_loss(params, x, y):
    logits = mlp_logits(params, x)
    logp = jax.nn.log_softmax(logits)
    return -jnp.take_along_axis(logp, y[:, None], axis=1)[:, 0]


def accuracy(params, x, y) -> float:
    pred = jnp.argmax(mlp_logits(params, x), axis=1)
    return float(jnp.mean((pred == y).astype(jnp.float32)))


def sgd_step(params, grads, lr):
    return jax.tree_util.tree_map(lambda p, g: p - lr * g, params, grads)


def train_flops_per_example(dim: int, hidden: int, classes: int) -> float:
    """fwd+bwd ≈ 3× fwd matmul FLOPs (the CO₂/emissions proxy)."""
    fwd = 2 * (dim * hidden + hidden * classes)
    return 3.0 * fwd
