"""Paper Table 4 + Fig 4(right): Fast MaxVol vs Cross-2D (CrossMaxVol) —
subspace similarity and execution time; classic MaxVol included."""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row, time_call
from repro.core.features import svd_features
from repro.core.maxvol import cross2d_maxvol, fast_maxvol, maxvol_classic


def subspace_similarity(A: np.ndarray, rows: np.ndarray, R: int) -> float:
    """Σ cos²(principal angles) between selected-row span and top-R row space."""
    sub = A[rows]
    q, _ = np.linalg.qr(sub.T)
    opt = np.linalg.svd(A.T, full_matrices=False)[0][:, :R]
    s = np.linalg.svd(q[:, :R].T @ opt)[1]
    return float(np.sum(s ** 2))


def run() -> List[str]:
    rng = np.random.default_rng(0)
    rows_out: List[str] = []
    # Iris-like regime (paper uses Iris: 150×4) + a feature-scale regime
    for K, M, R, tag in [(150, 4, 4, "iris_like"), (512, 64, 16, "feature_scale")]:
        sims_f, sims_c, sims_cl = [], [], []
        for t in range(5):
            g = np.random.default_rng(t)
            A = (g.normal(size=(K, max(R, M // 4))) @
                 g.normal(size=(max(R, M // 4), M)) +
                 0.2 * g.normal(size=(K, M))).astype(np.float32)
            V = svd_features(jnp.asarray(A), R)
            piv_f, _ = fast_maxvol(V, R)
            piv_cl = maxvol_classic(V, R)
            rows_c, _ = cross2d_maxvol(jnp.asarray(A), R)
            sims_f.append(subspace_similarity(A, np.asarray(piv_f), R))
            sims_cl.append(subspace_similarity(A, np.asarray(piv_cl), R))
            sims_c.append(subspace_similarity(A, np.asarray(rows_c), R))
        A = jnp.asarray(rng.normal(size=(K, M)).astype(np.float32))
        V = svd_features(A, R)
        t_fast = time_call(jax.jit(lambda v: fast_maxvol(v, R)), V)
        t_classic = time_call(jax.jit(lambda v: maxvol_classic(v, R)), V)
        t_cross = time_call(jax.jit(lambda a: cross2d_maxvol(a, R)), A)
        rows_out.append(csv_row(
            f"maxvol_fast_{tag}", t_fast,
            f"similarity={np.mean(sims_f):.4f}"))
        rows_out.append(csv_row(
            f"maxvol_classic_{tag}", t_classic,
            f"similarity={np.mean(sims_cl):.4f}"))
        rows_out.append(csv_row(
            f"maxvol_cross2d_{tag}", t_cross,
            f"similarity={np.mean(sims_c):.4f};fast_speedup={t_cross / t_fast:.1f}x"))
    return rows_out


if __name__ == "__main__":
    for r in run():
        print(r)
