"""Paper Fig 2: gradient alignment cos θ and dynamic rank R* trajectories
during GRAFT training of a small LM (alignment should rise, permitting
smaller ranks at fixed ε)."""
from __future__ import annotations

from typing import List

import numpy as np

from benchmarks.common import csv_row
from repro.api import ExperimentConfig, GraftConfig, TrainConfig, Trainer


def run() -> List[str]:
    cfg = ExperimentConfig(
        train=TrainConfig(steps=60, batch=16, seq=32, log_every=0),
        graft=GraftConfig(rset=(2, 4, 8), eps=0.35, refresh_every=4),
    ).apply_overrides(["optimizer.learning_rate=3e-3"])
    report = Trainer(cfg).fit()
    hist = report["history"]
    aligns = np.asarray([h["alignment"] for h in hist])
    ranks = np.asarray([h["rank"] for h in hist])
    losses = np.asarray([h["loss"] for h in hist])
    first, last = aligns[:10].mean(), aligns[-10:].mean()
    rows = [
        csv_row("alignment_early", 0.0, f"cos={first:.4f}"),
        csv_row("alignment_late", 0.0, f"cos={last:.4f}"),
        csv_row("alignment_mean_std", 0.0,
                f"mu={aligns.mean():.3f};sigma={aligns.std():.3f}"),
        csv_row("rank_mean_earlylate", 0.0,
                f"early={ranks[:10].mean():.1f};late={ranks[-10:].mean():.1f}"),
        csv_row("alignment_loss_drop", 0.0,
                f"loss0={losses[0]:.3f};lossN={losses[-1]:.3f}"),
    ]
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
