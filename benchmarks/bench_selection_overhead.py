"""Paper Table 7 / §3.3: empirical complexity of the selection machinery —
Fast MaxVol must scale O(K·R²), the projection sweep O(R·d); wall-clock and
compiled-FLOP scaling are both reported. A third section times every
registered sampler through the selection engine on identical inputs, so
strategy overheads are directly comparable."""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row, time_call
from repro.compat import cost_analysis_dict
from repro.core.maxvol import fast_maxvol
from repro.core.projection import prefix_projection_errors
from repro.selection import GraftConfig, engine, registry


def _flops(fn, *args) -> float:
    compiled = jax.jit(fn).lower(*args).compile()
    return cost_analysis_dict(compiled).get("flops", 0.0)


def run() -> List[str]:
    rng = np.random.default_rng(0)
    rows: List[str] = []

    # K scaling at fixed R (expect ~linear)
    R = 16
    for K in (128, 256, 512, 1024):
        V = jnp.asarray(rng.normal(size=(K, R)).astype(np.float32))
        t = time_call(jax.jit(lambda v: fast_maxvol(v, R)), V)
        f = _flops(lambda v: fast_maxvol(v, R), V)
        rows.append(csv_row(f"maxvol_K{K}_R{R}", t, f"flops={f:.3e}"))

    # R scaling at fixed K (expect ~quadratic)
    K = 512
    for R_ in (8, 16, 32, 64):
        V = jnp.asarray(rng.normal(size=(K, R_)).astype(np.float32))
        t = time_call(jax.jit(lambda v, r=R_: fast_maxvol(v, r)), V)
        f = _flops(lambda v, r=R_: fast_maxvol(v, r), V)
        rows.append(csv_row(f"maxvol_K{K}_R{R_}", t, f"flops={f:.3e}"))

    # projection sweep: d scaling (expect ~linear in d at fixed R)
    R_ = 32
    for d in (256, 1024, 4096):
        G = jnp.asarray(rng.normal(size=(d, R_)).astype(np.float32))
        g = jnp.asarray(rng.normal(size=(d,)).astype(np.float32))
        t = time_call(jax.jit(prefix_projection_errors), G, g)
        f = _flops(prefix_projection_errors, G, g)
        rows.append(csv_row(f"projsweep_d{d}_R{R_}", t, f"flops={f:.3e}"))

    # every registered sampler through the engine on identical inputs
    K, d, R_ = 256, 1024, 32
    cfg = GraftConfig(rset=(8, 16, 32), eps=0.25)
    V = jnp.asarray(rng.normal(size=(K, R_)).astype(np.float32))
    G = jnp.asarray(rng.normal(size=(d, K)).astype(np.float32))
    g_bar = jnp.mean(G, axis=1)
    scores = jnp.asarray(rng.random(K).astype(np.float32))
    for name in registry.available():
        def call(v, g, gb, sc, n=name):
            return engine.select_batch(cfg, n, v, g, gb, scores=sc)
        t = time_call(call, V, G, g_bar, scores)
        rows.append(csv_row(f"sampler_{name}_K{K}_d{d}", t, "registry-engine"))

    # derived scaling exponents (log-log slope)
    def slope(names, var_vals):
        ts = []
        for n in names:
            for r in rows:
                if r.startswith(n + ","):
                    ts.append(float(r.split(",")[1]))
                    break                      # first match (names can repeat)
        ts = np.asarray(ts)
        return float(np.polyfit(np.log(var_vals), np.log(ts), 1)[0])

    k_slope = slope([f"maxvol_K{k}_R16" for k in (128, 256, 512, 1024)],
                    np.asarray([128, 256, 512, 1024]))
    r_slope = slope([f"maxvol_K512_R{r}" for r in (8, 16, 32, 64)],
                    np.asarray([8, 16, 32, 64]))
    rows.append(csv_row("maxvol_scaling_exponents", 0.0,
                        f"K_slope={k_slope:.2f};R_slope={r_slope:.2f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
