"""Paper Table 7 / §3.3: empirical complexity of the selection machinery —
Fast MaxVol must scale O(K·R²), the projection sweep O(R·d); wall-clock and
compiled-FLOP scaling are both reported, plus a sweep of every registered
sampler through the selection engine on identical inputs.

This suite is also the repo's perf gate for the selection hot path:

  * dispatch accounting — the fused Pallas refresh
    (``kernels/graft_select.py``) must trace to ONE ``pallas_call`` (and no
    gather), vs 2 ``pallas_call`` + 1 gather for the unfused chain, and the
    batched variant must keep ONE launch for a whole microbatch stack;
  * ``sketch_svd`` vs ``svd`` compiled FLOPs at K=1024, M=4096, R=64 — the
    sketch path must win by ≥ 5×;
  * host-stall accounting — the async train loop (deferred ``MetricsFuture``
    drain + side-stream eval) must keep dispatching ahead of the device
    queue: under ``graft.overlap`` with ``eval_every`` set, step N+1 is
    issued while step N's metrics are still device futures. The counter is
    deterministic for a fixed config (materialization happens only at flush
    boundaries), so it is gated like the dispatch counts; ``blocked_ms`` is
    wall clock and recorded but not gated. The same probe now also gates
    DeviceClock coverage (every step but the first gets a device-time
    stamp) and device-sourced ``mfu`` in the flushed metrics;
  * attention hot path — with ``attn_backend=flash`` the model forward must
    trace to exactly ONE ``pallas_call`` per layer (layers unrolled so the
    count is per-layer, not per scan body); compiled train-step FLOPs
    (flash vs dense jnp path) and the analytic ``train_step_flops``
    estimate ride along for the regression diff.

Run standalone to emit machine-readable results (tracked across PRs by the
``perf-smoke`` CI job)::

    PYTHONPATH=src:. python benchmarks/bench_selection_overhead.py \
        --quick --json BENCH_selection.json
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row, time_call
from repro.analysis import jaxpr_audit
from repro.compat import cost_analysis_dict
from repro.core.features import sketch_svd_features, svd_features
from repro.core.maxvol import fast_maxvol
from repro.core.projection import prefix_projection_errors
from repro.kernels import ops as kernel_ops
from repro.selection import GraftConfig, engine, registry
from repro.selection import graft as graft_lib

_DEFAULT_JSON = os.path.join(os.path.dirname(__file__), "..",
                             "BENCH_selection.json")

# acceptance configs shared by collect() and the --check gate
_B = 8                                   # batched-fused microbatch stack
_KF, _MF, _RF = 1024, 4096, 64           # feature-path FLOPs comparison
_MIN_FLOPS_RATIO = 5.0                   # sketch_svd must beat svd by this


def _flops(fn, *args) -> float:
    compiled = jax.jit(fn).lower(*args).compile()
    return cost_analysis_dict(compiled).get("flops", 0.0)


# jaxpr walking lives in repro.analysis.jaxpr_audit — one implementation
# feeding the bench entries, the regression gate, and `python -m
# repro.analysis`, so measured and gated counts cannot drift apart
_count_primitives = jaxpr_audit.count_primitives
_dispatch_entry = jaxpr_audit.dispatch_summary


_HOST_STALL_STEPS = 12                   # async-loop probe config (must stay
_HOST_STALL_FLUSH = 4                    # fixed: the gate is deterministic
                                         # only for a fixed cadence)


def _host_stall_entry() -> Dict[str, Any]:
    """Drive the REAL async Trainer loop (overlap + side-stream eval +
    deferred metrics) and report the dispatch-ahead depth: how many steps
    were issued while the previous step's metrics were still device
    futures. Drains happen only at metrics flush boundaries, so for this
    fixed config the counter is deterministic (steps − flush drains − 1).
    Also reports the DeviceClock coverage: every step but the first must
    get a device-time stamp, and the JSONL ``mfu`` must be device-sourced."""
    import tempfile

    from repro.api import ExperimentConfig, Trainer
    from repro.launch.metrics import read_metrics

    with tempfile.TemporaryDirectory() as td:
        cfg = ExperimentConfig().apply_overrides([
            f"train.steps={_HOST_STALL_STEPS}", "train.batch=8",
            "train.seq=16", "train.log_every=0", "train.eval_every=4",
            f"train.metrics_path={td}/m.jsonl",
            f"train.metrics_flush_every={_HOST_STALL_FLUSH}",
            "graft.rset=[2,4]", "graft.refresh_every=3",
            "graft.overlap=true",
        ])
        report = Trainer(cfg).fit()
        mrows = read_metrics(f"{td}/m.jsonl")
    h = report["host_loop"]
    dev_rows = [r for r in mrows if r.get("mfu_source") == "device"]
    return {
        "steps": h["steps"],
        "dispatch_ahead_steps": h["dispatched_ahead"],
        "blocked_ms_per_step": (1e3 * h.get("metrics_drain_s", 0.0)
                                / max(h["steps"], 1)),
        "device_timed_steps": h.get("device_timed_steps", 0),
        "device_time_s": h.get("device_time_s", 0.0),
        "mfu_source": "device" if dev_rows else "dispatch",
        "mfu_device_rows": len(dev_rows),
        "mfu": dev_rows[-1]["mfu"] if dev_rows else None,
    }


_ATTN_LAYERS = 2                         # attention-gate probe model (fixed:
_ATTN_B, _ATTN_S = 4, 64                 # dispatch counts are exact gates)


def _attention_entry() -> Dict[str, Any]:
    """Model-hot-path accounting for ``attn_backend=flash``: the forward
    jaxpr must dispatch exactly ONE ``pallas_call`` per layer (the layers
    are unrolled here so per-layer really means per layer, not per scan
    body), and the compiled train-step FLOPs ride along for the regression
    diff (flash vs the dense jnp path on the same shapes)."""
    from repro.launch.metrics import train_step_flops
    from repro.models import model as model_lib

    rng = np.random.default_rng(0)

    def mk(backend: str):
        return model_lib.ModelConfig(
            family="dense", num_layers=_ATTN_LAYERS, d_model=64, num_heads=4,
            num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256,
            param_dtype="float32", scan_layers=False, attn_backend=backend)

    cfg_f, cfg_d = mk("flash"), mk("dense")
    params = model_lib.init_params(cfg_f, jax.random.PRNGKey(0))
    batch = {
        "tokens": jnp.asarray(
            rng.integers(0, 256, (_ATTN_B, _ATTN_S)).astype(np.int32)),
        "labels": jnp.asarray(
            rng.integers(0, 256, (_ATTN_B, _ATTN_S)).astype(np.int32)),
    }

    def fwd(p, b):
        return model_lib.loss_fn(cfg_f, p, b)[0]

    def step(cfg):
        def f(p, b):
            return jax.grad(lambda pp: model_lib.loss_fn(cfg, pp, b)[0])(p)
        return f

    fwd_counts = _count_primitives(fwd, params, batch)
    step_counts = _count_primitives(step(cfg_f), params, batch)
    num_params = sum(int(np.prod(l.shape))
                     for l in jax.tree_util.tree_leaves(params))
    tokens = _ATTN_B * _ATTN_S
    return {
        "layers": _ATTN_LAYERS,
        "forward_pallas_call": fwd_counts.get("pallas_call", 0),
        "train_step_pallas_call": step_counts.get("pallas_call", 0),
        "train_step_flops": {
            "flash": _flops(step(cfg_f), params, batch),
            "dense": _flops(step(cfg_d), params, batch),
        },
        "analytic_train_flops": {
            "param_only": train_step_flops(num_params, tokens),
            "with_attention": train_step_flops(num_params, tokens,
                                               mcfg=cfg_f, seq=_ATTN_S),
        },
    }


def collect(quick: bool = False) -> Tuple[List[str], Dict[str, Any]]:
    rng = np.random.default_rng(0)
    rows: List[str] = []
    report: Dict[str, Any] = {
        "meta": {"backend": jax.default_backend(), "quick": quick,
                 "interpret_mode": jax.default_backend() != "tpu"},
    }
    repeats = 5 if quick else 20
    warmup = 1 if quick else 3

    def timed(fn, *args):
        return time_call(fn, *args, repeats=repeats, warmup=warmup)

    # ------------------------------------------------------------------
    # dispatch accounting: fused refresh vs the unfused 3-op chain
    # ------------------------------------------------------------------
    K, d, R = 256, 1024, 32
    cfg_p = GraftConfig(rset=(8, 16, 32), eps=0.25, use_pallas=True)
    V = jnp.asarray(rng.normal(size=(K, R)).astype(np.float32))
    G = jnp.asarray(rng.normal(size=(d, K)).astype(np.float32))
    g_bar = jnp.mean(G, axis=1)

    def fused(v, g, gb):
        return graft_lib.pivot_and_sweep(cfg_p, v, g, gb)

    def unfused(v, g, gb):
        piv = kernel_ops.fast_maxvol(v, cfg_p.r_max)
        g_sel = jnp.take(g, piv, axis=1)
        return piv, kernel_ops.projection_sweep(g_sel, gb), g_sel

    B = _B
    Vs = jnp.asarray(rng.normal(size=(B, K, R)).astype(np.float32))
    Gs = jnp.asarray(rng.normal(size=(B, d, K)).astype(np.float32))
    gbs = jnp.mean(Gs, axis=2)

    def batched_fused(vs, gs, gbss):
        # the refresh chain for a whole stack (apples-to-apples with
        # fused/unfused above, which also exclude the rank-decision epilogue)
        return kernel_ops.fused_graft_select_batched(vs, gs, gbss,
                                                     cfg_p.r_max)

    report["dispatch_per_refresh"] = {
        "fused": _dispatch_entry(_count_primitives(fused, V, G, g_bar)),
        "unfused": _dispatch_entry(_count_primitives(unfused, V, G, g_bar)),
        f"batched_fused_B{B}": _dispatch_entry(
            _count_primitives(batched_fused, Vs, Gs, gbs)),
    }
    report["refresh_wall_us"] = {
        "fused": timed(jax.jit(fused), V, G, g_bar),
        "unfused": timed(jax.jit(unfused), V, G, g_bar),
    }
    for name, entry in report["dispatch_per_refresh"].items():
        rows.append(csv_row(
            f"dispatch_{name}", 0.0,
            f"pallas_calls={entry['pallas_call']};gathers={entry['gather']}"))

    # ------------------------------------------------------------------
    # feature path: sketch_svd vs svd at the acceptance config
    # ------------------------------------------------------------------
    Kf, Mf, Rf = _KF, _MF, _RF
    A = jnp.asarray(rng.normal(size=(Kf, Mf)).astype(np.float32))
    feats: Dict[str, Any] = {}
    for name, fn in (("svd", lambda a: svd_features(a, Rf)),
                     ("sketch_svd", lambda a: sketch_svd_features(a, Rf))):
        f = _flops(fn, A)
        t = timed(jax.jit(fn), A)
        feats[name] = {"flops": f, "wall_us": t}
        rows.append(csv_row(f"features_{name}_K{Kf}_M{Mf}_R{Rf}", t,
                            f"flops={f:.3e}"))
    feats["flops_ratio"] = (feats["svd"]["flops"] /
                            max(feats["sketch_svd"]["flops"], 1.0))
    report[f"features_K{Kf}_M{Mf}_R{Rf}"] = feats
    rows.append(csv_row("features_flops_ratio", 0.0,
                        f"svd/sketch_svd={feats['flops_ratio']:.2f}"))

    # ------------------------------------------------------------------
    # scaling: K at fixed R (expect ~linear), R at fixed K (~quadratic),
    # projection sweep d (~linear)
    # ------------------------------------------------------------------
    scaling: List[Dict[str, Any]] = []
    R_ = 16
    for K_ in (128, 256, 512, 1024):
        Vk = jnp.asarray(rng.normal(size=(K_, R_)).astype(np.float32))
        t = timed(jax.jit(lambda v: fast_maxvol(v, R_)), Vk)
        f = _flops(lambda v: fast_maxvol(v, R_), Vk)
        scaling.append({"name": f"maxvol_K{K_}_R{R_}", "wall_us": t,
                        "flops": f})
        rows.append(csv_row(f"maxvol_K{K_}_R{R_}", t, f"flops={f:.3e}"))

    K_ = 512
    for Rv in (8, 16, 32, 64):
        Vk = jnp.asarray(rng.normal(size=(K_, Rv)).astype(np.float32))
        t = timed(jax.jit(lambda v, r=Rv: fast_maxvol(v, r)), Vk)
        f = _flops(lambda v, r=Rv: fast_maxvol(v, r), Vk)
        scaling.append({"name": f"maxvol_K{K_}_R{Rv}", "wall_us": t,
                        "flops": f})
        rows.append(csv_row(f"maxvol_K{K_}_R{Rv}", t, f"flops={f:.3e}"))

    Rv = 32
    for dv in (256, 1024, 4096):
        Gd = jnp.asarray(rng.normal(size=(dv, Rv)).astype(np.float32))
        gd = jnp.asarray(rng.normal(size=(dv,)).astype(np.float32))
        t = timed(jax.jit(prefix_projection_errors), Gd, gd)
        f = _flops(prefix_projection_errors, Gd, gd)
        scaling.append({"name": f"projsweep_d{dv}_R{Rv}", "wall_us": t,
                        "flops": f})
        rows.append(csv_row(f"projsweep_d{dv}_R{Rv}", t, f"flops={f:.3e}"))
    report["scaling"] = scaling

    # ------------------------------------------------------------------
    # host-stall: the async train loop must run ahead of the device queue
    # ------------------------------------------------------------------
    stall = _host_stall_entry()
    report["host_stall"] = stall
    rows.append(csv_row(
        "host_stall", stall["blocked_ms_per_step"] * 1e3,
        f"dispatch_ahead={stall['dispatch_ahead_steps']}/{stall['steps']}"
        f";blocked_ms_per_step={stall['blocked_ms_per_step']:.3f}"))

    # ------------------------------------------------------------------
    # model hot path: flash attention dispatch + train-step FLOPs
    # ------------------------------------------------------------------
    attn = _attention_entry()
    report["attention"] = attn
    rows.append(csv_row(
        "attention_dispatch", 0.0,
        f"forward_pallas_calls={attn['forward_pallas_call']}"
        f"/{attn['layers']}layers"
        f";train_step_pallas_calls={attn['train_step_pallas_call']}"))
    rows.append(csv_row(
        "attention_train_flops", 0.0,
        f"flash={attn['train_step_flops']['flash']:.3e}"
        f";dense={attn['train_step_flops']['dense']:.3e}"
        f";analytic={attn['analytic_train_flops']['with_attention']:.3e}"))

    # ------------------------------------------------------------------
    # every registered sampler through the engine on identical inputs
    # ------------------------------------------------------------------
    K, dv, Rv = 256, 1024, 32
    cfg = GraftConfig(rset=(8, 16, 32), eps=0.25)
    V = jnp.asarray(rng.normal(size=(K, Rv)).astype(np.float32))
    G = jnp.asarray(rng.normal(size=(dv, K)).astype(np.float32))
    g_bar = jnp.mean(G, axis=1)
    scores = jnp.asarray(rng.random(K).astype(np.float32))
    samplers: Dict[str, float] = {}
    for name in registry.available():
        def call(v, g, gb, sc, n=name):
            return engine.select_batch(cfg, n, v, g, gb, scores=sc)
        t = timed(call, V, G, g_bar, scores)
        samplers[name] = t
        rows.append(csv_row(f"sampler_{name}_K{K}_d{dv}", t, "registry-engine"))
    report["samplers_wall_us"] = samplers

    # ------------------------------------------------------------------
    # streaming reservoir: the cross-batch sketch refresh must keep the
    # single-dispatch contract (ONE pallas_call, no extra gathers) and its
    # reservoir-update overhead (FD eigh + EMA blend) is tracked as a
    # compiled-FLOPs delta over the per-batch GRAFT refresh
    # ------------------------------------------------------------------
    from repro.selection import CarrySpec, SelectionInputs

    smp_stream = registry.get_sampler("streaming_graft")
    smp_graft = registry.get_sampler("graft")
    cfg_sp = GraftConfig(rset=(8, 16, 32), eps=0.25, use_pallas=True,
                         streaming=True)
    carry0 = smp_stream.init_carry(cfg_sp,
                                   CarrySpec(batch_size=K, grad_dim=dv))

    def stream_refresh(v, g, gb, c):
        return smp_stream.select_fn(cfg_sp, SelectionInputs(v, g, gb), c,
                                    jnp.int32(0))

    def batch_refresh(v, g, gb):
        return smp_graft.fn(cfg_sp, SelectionInputs(v, g, gb), jnp.int32(0))

    stream_disp = _dispatch_entry(
        _count_primitives(stream_refresh, V, G, g_bar, carry0))
    batch_disp = _dispatch_entry(
        _count_primitives(batch_refresh, V, G, g_bar))
    f_stream = _flops(stream_refresh, V, G, g_bar, carry0)
    f_batch = _flops(batch_refresh, V, G, g_bar)
    report["streaming"] = {
        "sketch_rows": cfg_sp.sketch_rows,
        "dispatch": {"streaming": stream_disp, "per_batch": batch_disp},
        "flops": {"streaming": f_stream, "per_batch": f_batch,
                  "reservoir_update": f_stream - f_batch},
        "wall_us": {
            "streaming": timed(jax.jit(stream_refresh), V, G, g_bar, carry0),
            "per_batch": timed(jax.jit(batch_refresh), V, G, g_bar)},
    }
    rows.append(csv_row(
        "streaming_dispatch", 0.0,
        f"pallas_calls={stream_disp['pallas_call']}"
        f";gathers={stream_disp['gather']}"
        f";per_batch_pallas_calls={batch_disp['pallas_call']}"))
    rows.append(csv_row(
        "streaming_reservoir_flops",
        report["streaming"]["wall_us"]["streaming"],
        f"update={f_stream - f_batch:.3e}"
        f";streaming={f_stream:.3e};per_batch={f_batch:.3e}"))

    # derived scaling exponents (log-log slope)
    def slope(prefixes, var_vals):
        ts = [next(e["wall_us"] for e in scaling if e["name"] == p)
              for p in prefixes]
        return float(np.polyfit(np.log(var_vals), np.log(ts), 1)[0])

    k_slope = slope([f"maxvol_K{k}_R16" for k in (128, 256, 512, 1024)],
                    np.asarray([128, 256, 512, 1024]))
    r_slope = slope([f"maxvol_K512_R{r}" for r in (8, 16, 32, 64)],
                    np.asarray([8, 16, 32, 64]))
    report["scaling_exponents"] = {"K_slope": k_slope, "R_slope": r_slope}
    rows.append(csv_row("maxvol_scaling_exponents", 0.0,
                        f"K_slope={k_slope:.2f};R_slope={r_slope:.2f}"))
    return rows, report


def run() -> List[str]:
    rows, _ = collect()
    return rows


def check(report: Dict[str, Any]) -> List[str]:
    """The perf gate: dispatch shape and FLOPs wins that must not regress.
    Returns a list of violations (empty = pass)."""
    problems: List[str] = []
    disp = report["dispatch_per_refresh"]
    if disp["fused"] != {"pallas_call": 1, "gather": 0}:
        problems.append(f"fused refresh is not 1 pallas_call / 0 gathers: "
                        f"{disp['fused']}")
    if disp[f"batched_fused_B{_B}"]["pallas_call"] != 1:
        problems.append(f"batched fused refresh is not ONE launch: "
                        f"{disp[f'batched_fused_B{_B}']}")
    ratio = report[f"features_K{_KF}_M{_MF}_R{_RF}"]["flops_ratio"]
    if ratio < _MIN_FLOPS_RATIO:
        problems.append(f"sketch_svd FLOPs win {ratio:.2f}x < "
                        f"{_MIN_FLOPS_RATIO}x vs svd")
    stall = report["host_stall"]
    if stall["dispatch_ahead_steps"] < 1:
        problems.append(
            "async host loop never dispatched ahead of metrics "
            f"materialization: {stall} — a float()/sync crept back onto "
            "the per-step path")
    if stall.get("device_timed_steps", 0) != stall["steps"] - 1:
        problems.append(
            f"DeviceClock timed {stall.get('device_timed_steps')} steps, "
            f"expected {stall['steps'] - 1} (every step but the first) — "
            "completion stamps are being dropped")
    if stall.get("mfu_source") != "device":
        problems.append(
            f"flushed metrics mfu_source={stall.get('mfu_source')!r}, "
            "expected 'device' — mfu fell back to the dispatch clock")
    stream = report.get("streaming", {})
    sdisp = stream.get("dispatch", {}).get("streaming", {})
    bdisp = stream.get("dispatch", {}).get("per_batch", {})
    if sdisp.get("pallas_call") != 1:
        problems.append(
            f"streaming refresh dispatches {sdisp.get('pallas_call')} "
            "pallas_call — the reservoir update broke the single-dispatch "
            "contract (must stay ONE fused launch)")
    if sdisp.get("gather", 0) > bdisp.get("gather", 0):
        problems.append(
            f"streaming refresh adds gathers over per-batch GRAFT "
            f"({sdisp.get('gather')} vs {bdisp.get('gather')}) — the "
            "sketch update must stay gather-free")
    if stream.get("flops", {}).get("reservoir_update", 0.0) <= 0.0:
        problems.append(
            "streaming reservoir-update FLOPs delta is non-positive — the "
            "bench is no longer measuring the FD update")
    attn = report.get("attention", {})
    if attn.get("forward_pallas_call") != attn.get("layers"):
        problems.append(
            f"flash forward dispatches {attn.get('forward_pallas_call')} "
            f"pallas_call for {attn.get('layers')} layers — must be exactly "
            "one kernel launch per layer")
    return problems


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="fewer timing repeats (CI smoke mode)")
    ap.add_argument("--json", nargs="?", const=_DEFAULT_JSON, default=None,
                    help="write the machine-readable report "
                         "(default: BENCH_selection.json at the repo root)")
    ap.add_argument("--check", action="store_true",
                    help="exit non-zero if the perf gate regresses (fused "
                         "refresh != 1 pallas_call, batched != 1 launch, "
                         f"sketch_svd FLOPs win < {_MIN_FLOPS_RATIO}x, "
                         "the async host loop never dispatches ahead, "
                         "flash attention != 1 pallas_call per layer, or "
                         "DeviceClock coverage/mfu sourcing slips)")
    args = ap.parse_args(argv)
    rows, report = collect(quick=args.quick)
    for r in rows:
        print(r)
    if args.json:
        path = os.path.abspath(args.json)
        with open(path, "w") as f:
            json.dump(report, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"# wrote {path}")
    if args.check:
        problems = check(report)
        for p in problems:
            print(f"# PERF GATE FAILED: {p}")
        return 1 if problems else 0
    return 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
