"""Benchmark driver — one suite per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (us_per_call = 0.0 for accuracy-only
rows). Suites: maxvol (Table 4 / Fig 4R), features (Table 3 / Fig 4L),
fraction sweep (Tables 8/9/12/14 / Fig 3), alignment (Fig 2), selection
overhead (Table 7), roofline (dry-run §Roofline, if artifacts exist).
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--suite", default="all",
                    choices=["all", "maxvol", "features", "fraction",
                             "alignment", "overhead", "sharded", "roofline"])
    args = ap.parse_args(argv)

    suites = []
    if args.suite in ("all", "maxvol"):
        from benchmarks import bench_maxvol
        suites.append(("maxvol", bench_maxvol.run))
    if args.suite in ("all", "features"):
        from benchmarks import bench_features
        suites.append(("features", bench_features.run))
    if args.suite in ("all", "fraction"):
        from benchmarks import bench_fraction_sweep
        suites.append(("fraction", bench_fraction_sweep.run))
    if args.suite in ("all", "alignment"):
        from benchmarks import bench_alignment
        suites.append(("alignment", bench_alignment.run))
    if args.suite in ("all", "overhead"):
        from benchmarks import bench_selection_overhead
        suites.append(("overhead", bench_selection_overhead.run))
    if args.suite in ("all", "sharded"):
        # import first thing (before other suites pull in jax) to get the
        # forced multi-device CPU topology when run standalone
        from benchmarks import bench_sharded_selection
        suites.append(("sharded", bench_sharded_selection.run))
    if args.suite in ("all", "roofline"):
        from benchmarks import roofline
        suites.append(("roofline", roofline.run))

    print("name,us_per_call,derived")
    failures = 0
    for name, fn in suites:
        t0 = time.time()
        try:
            for row in fn():
                print(row, flush=True)
            print(f"suite_{name}_wall,{(time.time()-t0)*1e6:.0f},ok", flush=True)
        except Exception:
            failures += 1
            traceback.print_exc()
            print(f"suite_{name}_wall,{(time.time()-t0)*1e6:.0f},FAILED",
                  flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
