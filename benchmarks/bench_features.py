"""Paper Table 3 + Fig 4(left): feature-extractor ablation (SVD vs AE vs
ICA) — linear-probe accuracy of GRAFT-selected subsets + time per batch."""
from __future__ import annotations

from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (accuracy, csv_row, init_mlp, mlp_loss,
                               sgd_step, time_call)
from repro.core.features import ica_features, pca_features, svd_features
from repro.core.maxvol import fast_maxvol
from repro.data import SyntheticClassification


def _ae_features(A: jnp.ndarray, R: int, steps: int = 60) -> jnp.ndarray:
    """Shallow linear-tanh autoencoder trained on the batch (paper's AE)."""
    K, M = A.shape
    key = jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)
    params = {"enc": jax.random.normal(k1, (M, R)) * (M ** -0.5),
              "dec": jax.random.normal(k2, (R, M)) * (R ** -0.5)}

    @jax.jit
    def step(p):
        def loss(p):
            z = jnp.tanh(A @ p["enc"])
            return jnp.mean((z @ p["dec"] - A) ** 2)
        g = jax.grad(loss)(p)
        return sgd_step(p, g, 0.05)

    for _ in range(steps):
        params = step(params)
    z = jnp.tanh(A @ params["enc"])
    # order columns by variance (relevance ordering precondition)
    order = jnp.argsort(-jnp.var(z, axis=0))
    return z[:, order]


def _probe_accuracy(x_sel, y_sel, x_te, y_te, steps=150) -> float:
    params = init_mlp(jax.random.PRNGKey(1), x_sel.shape[1], 32,
                      int(y_te.max()) + 1)

    @jax.jit
    def step(p):
        return sgd_step(p, jax.grad(mlp_loss)(p, x_sel, y_sel), 0.3)

    for _ in range(steps):
        params = step(params)
    return accuracy(params, x_te, y_te)


def run() -> List[str]:
    # noisier data than the fraction sweep so extractor quality differentiates
    ds = SyntheticClassification(n=2048, dim=64, num_classes=10, seed=0,
                                 noise=2.0, label_noise=0.05)
    (xtr, ytr), (xte, yte) = ds.split(0.2)
    K, R = 256, 24
    batch = jnp.asarray(xtr[:K])
    ybatch = jnp.asarray(ytr[:K])
    xte_j, yte_j = jnp.asarray(xte), jnp.asarray(yte)

    extractors = {
        "svd": lambda A: svd_features(A, R),
        "pca": lambda A: pca_features(A, R),
        "ica": lambda A: ica_features(A, R),
        "ae": lambda A: _ae_features(A, R),
    }
    rows: List[str] = []
    for name, fn in extractors.items():
        V = fn(batch)
        piv, _ = fast_maxvol(V, R)
        acc = _probe_accuracy(batch[np.asarray(piv)], ybatch[np.asarray(piv)],
                              xte_j, yte_j)
        t = time_call(jax.jit(fn) if name != "ae" else fn, batch,
                      repeats=5 if name == "ae" else 20,
                      warmup=1 if name == "ae" else 3)
        rows.append(csv_row(f"features_{name}", t, f"probe_acc={acc:.4f}"))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
