"""The full analysis battery — what ``python -m repro.analysis`` and the
CI ``analysis`` job run.

Four sections, each returning findings in the shared report format:

  * **lint**   — the AST rules over every module under ``src/repro``;
  * **jaxpr**  — trace the fused selection refresh, the streaming
    (sketch-reservoir) refresh, and the flash-attention model against the
    declarative contracts (1 ``pallas_call`` per fused refresh with no
    gather — streaming included, 1 per attention layer, no host callbacks
    or f64 ops in either step function), plus the SP001 sweep: no
    registered sampler may close over mutable Python state;
  * **vmem**   — static footprint/divisibility for the production kernel
    configurations, with headroom notes;
  * **runtime** — a short REAL ``Trainer.fit`` on the probe config with
    ``train.audit=true``: the strict SyncGuard + RecompileWatcher wrap the
    live step loop; a sync outside a sanctioned site or a step-signature
    drift fails the run. Skippable with ``--no-runtime`` (it trains for a
    few seconds).

Exit code 1 on any error-severity finding.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import jaxpr_audit, lint, vmem
from repro.analysis.report import Finding, Report, rule_table
from repro.analysis.sync_guard import SyncGuardError

# probe shapes — the bench acceptance configs (benchmarks/
# bench_selection_overhead.py), so the CLI audits what the bench measures
_SEL_K, _SEL_D, _SEL_R = 256, 1024, 32
_ATTN_LAYERS, _ATTN_B, _ATTN_S = 2, 4, 64


def check_lint() -> Report:
    return lint.lint_tree()


def check_fused_selection() -> Report:
    """PR 3's contract on the real refresh entry point."""
    from repro.selection import GraftConfig
    from repro.selection import graft as graft_lib

    rng = np.random.default_rng(0)
    cfg = GraftConfig(rset=(8, 16, 32), eps=0.25, use_pallas=True)
    V = jnp.asarray(rng.normal(size=(_SEL_K, _SEL_R)).astype(np.float32))
    G = jnp.asarray(rng.normal(size=(_SEL_D, _SEL_K)).astype(np.float32))
    g_bar = jnp.mean(G, axis=1)

    def fused(v, g, gb):
        return graft_lib.pivot_and_sweep(cfg, v, g, gb)

    return jaxpr_audit.audit_step(
        fused, (V, G, g_bar), label="fused_selection_refresh",
        extra_rules=jaxpr_audit.fused_selection_rules())


def check_streaming_selection() -> Report:
    """PR 9's contract: the streaming (sketch-reservoir) refresh reuses the
    fused dispatch — ONE ``pallas_call`` for the whole select, and the
    reservoir update adds no gathers beyond the per-batch GRAFT epilogue
    (``select_rank``'s candidate lookup, shared by both paths)."""
    from repro.selection import (CarrySpec, GraftConfig, SelectionInputs,
                                 get_sampler)

    rng = np.random.default_rng(0)
    cfg = GraftConfig(rset=(8, 16, 32), eps=0.25, use_pallas=True,
                      streaming=True)
    V = jnp.asarray(rng.normal(size=(_SEL_K, _SEL_R)).astype(np.float32))
    G = jnp.asarray(rng.normal(size=(_SEL_D, _SEL_K)).astype(np.float32))
    g_bar = jnp.mean(G, axis=1)
    smp = get_sampler("streaming_graft")
    carry = smp.init_carry(cfg, CarrySpec(batch_size=_SEL_K,
                                          grad_dim=_SEL_D))

    def streaming(v, g, gb, c):
        return smp.select_fn(cfg, SelectionInputs(v, g, gb), c, jnp.int32(0))

    def per_batch(v, g, gb):
        return get_sampler("graft").fn(cfg, SelectionInputs(v, g, gb),
                                       jnp.int32(0))

    gather_budget = jaxpr_audit.count_primitives(
        per_batch, V, G, g_bar).get("gather", 0)
    rules = [
        jaxpr_audit.PrimitiveRule(
            "pallas_call", exact=1, rule="JX003",
            why="the streaming refresh (sketch update + blended-target "
                "select) must stay a single fused kernel launch",
            fix_hint="keep streaming_select_fn on graft.pivot_and_sweep — "
                     "do not add a second dispatch for the reservoir"),
        jaxpr_audit.PrimitiveRule(
            "gather", max_count=gather_budget, rule="JX004",
            why=f"the reservoir update must add no gathers over the "
                f"per-batch GRAFT select (budget {gather_budget} from the "
                f"shared rank-decision epilogue)",
            fix_hint="express the FD sketch update with slices/matmuls, "
                     "not fancy indexing"),
    ]
    return jaxpr_audit.audit_step(
        streaming, (V, G, g_bar, carry), label="streaming_selection_refresh",
        extra_rules=rules)


def check_sampler_closures() -> Report:
    """SP001: no registered sampler may smuggle cross-step state through a
    closed-over mutable (list/dict/set/bytearray) — under jit it would be
    baked at trace time, and rollback/resume could never restore it. The
    Sampler-v2 carry is the only sanctioned channel."""
    from repro.selection import available, get_sampler

    mutable = (list, dict, set, bytearray)
    report = Report()
    for name in available():
        smp = get_sampler(name)
        for attr in ("fn", "select_fn", "init_carry_fn"):
            fn = getattr(smp, attr)
            cells = getattr(fn, "__closure__", None) or ()
            for cell in cells:
                try:
                    value = cell.cell_contents
                except ValueError:       # empty cell
                    continue
                if isinstance(value, mutable):
                    report.add(Finding(
                        rule="SP001", location=f"sampler '{name}'.{attr}",
                        message=f"closes over mutable "
                                f"{type(value).__name__}: {value!r:.80}",
                        fix_hint="thread the state through init_carry_fn/"
                                 "select_fn (Sampler-v2 carry) so it rides "
                                 "the train state and checkpoints"))
    if report.ok:
        report.add(Finding(
            rule="SP001", severity="info", location="selection.registry",
            message=f"no mutable closures across "
                    f"{len(available())} registered samplers"))
    return report


def check_attention() -> Report:
    """PR 6's contract on the bench probe model: one launch per layer in
    the forward, and a callback/f64-free train step."""
    from repro.models import model as model_lib

    rng = np.random.default_rng(0)
    mcfg = model_lib.ModelConfig(
        family="dense", num_layers=_ATTN_LAYERS, d_model=64, num_heads=4,
        num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=256,
        param_dtype="float32", scan_layers=False, attn_backend="flash")
    params = model_lib.init_params(mcfg, jax.random.PRNGKey(0))
    batch = {
        "tokens": jnp.asarray(rng.integers(
            0, 256, (_ATTN_B, _ATTN_S)).astype(np.int32)),
        "labels": jnp.asarray(rng.integers(
            0, 256, (_ATTN_B, _ATTN_S)).astype(np.int32)),
    }

    def fwd(p, b):
        return model_lib.loss_fn(mcfg, p, b)[0]

    def step(p, b):
        return jax.grad(lambda pp: model_lib.loss_fn(mcfg, pp, b)[0])(p)

    report = jaxpr_audit.audit_step(
        fwd, (params, batch), label="flash_forward",
        extra_rules=jaxpr_audit.attention_rules(_ATTN_LAYERS))
    report.extend(jaxpr_audit.audit_step(
        step, (params, batch), label="flash_train_step"))
    return report


def check_vmem() -> Report:
    """The production kernel configurations + headroom notes (the
    blockwise-KV groundwork: how far T can grow before flash must tile)."""
    report = Report()
    # probe model shape, and a TPU-production shape for the headroom note
    report.extend(vmem.flash_attention_report(
        S=_ATTN_S, T=_ATTN_S, head_dim=16, block_q=64, block_k=64))
    report.extend(vmem.flash_attention_report(
        S=2048, T=2048, head_dim=64, block_q=128, block_k=128))
    report.extend(vmem.fused_select_vmem(
        _SEL_K, _SEL_R, _SEL_D, _SEL_R).report())
    report.extend(vmem.fast_maxvol_vmem(1024, 64).report())
    return report


def probe_overrides(tmpdir: str) -> List[str]:
    """The host-stall probe config (bench's async-loop gate) with the
    audit knob on — the clean-pass configuration CI certifies."""
    return [
        "train.steps=8", "train.batch=8", "train.seq=16",
        "train.log_every=4", "train.eval_every=4",
        f"train.metrics_path={tmpdir}/metrics.jsonl",
        "train.metrics_flush_every=4",
        f"train.checkpoint_dir={tmpdir}/ckpt", "train.checkpoint_every=4",
        "graft.rset=[2,4]", "graft.refresh_every=3", "graft.overlap=true",
        "train.audit=true",
    ]


def check_runtime(overrides: Sequence[str] = ()) -> Report:
    """Run the REAL Trainer under ``train.audit`` on the probe config."""
    import tempfile

    from repro.api import ExperimentConfig, Trainer

    report = Report()
    with tempfile.TemporaryDirectory() as td:
        cfg = ExperimentConfig().apply_overrides(
            probe_overrides(td) + list(overrides))
        try:
            run_report = Trainer(cfg).fit()
        except SyncGuardError as e:
            report.add(Finding(
                rule="SY001", location="train.audit", message=str(e)))
            return report
        except RuntimeError as e:
            if "[train.audit]" not in str(e):
                raise
            report.add(Finding(
                rule="RC001", location="train.audit", message=str(e)))
            return report
    audit = run_report.get("audit", {})
    sites = ", ".join(f"{k}={v}" for k, v
                      in audit.get("sync_sites", {}).items()) or "none"
    report.add(Finding(
        rule="SY001", severity="info", location="train.audit",
        message=f"clean audited run: {audit.get('sync_events', 0)} "
                f"sanctioned sync(s) [{sites}], 0 unsanctioned, "
                f"{audit.get('recompiles', 0)} re-trace(s)"))
    return report


def run_all(runtime: bool = True,
            overrides: Sequence[str] = ()) -> Report:
    report = Report()
    report.extend(check_lint())
    report.extend(check_fused_selection())
    report.extend(check_streaming_selection())
    report.extend(check_sampler_closures())
    report.extend(check_attention())
    report.extend(check_vmem())
    if runtime:
        report.extend(check_runtime(overrides))
    return report


def main(argv: Optional[Sequence[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="static + runtime audit of the training hot-path "
                    "contracts (lint, jaxpr, VMEM, sync/recompile)")
    ap.add_argument("--json", nargs="?", const="-", default=None,
                    help="emit the report as JSON (to PATH, or stdout "
                         "with no argument)")
    ap.add_argument("--rules", action="store_true",
                    help="print the rule table and exit")
    ap.add_argument("--no-runtime", action="store_true",
                    help="skip the audited Trainer probe run")
    ap.add_argument("--quiet", action="store_true",
                    help="hide info-severity findings")
    ap.add_argument("--set", action="append", default=[], metavar="KEY=VAL",
                    dest="overrides",
                    help="extra ExperimentConfig override for the runtime "
                         "probe (repeatable)")
    args = ap.parse_args(argv)
    if args.rules:
        print(rule_table())
        return 0
    report = run_all(runtime=not args.no_runtime, overrides=args.overrides)
    if args.json:
        blob = report.to_json(indent=1)
        if args.json == "-":
            print(blob)
        else:
            with open(args.json, "w") as f:
                f.write(blob + "\n")
    print(report.format(show_info=not args.quiet))
    return 0 if report.ok else 1


if __name__ == "__main__":
    sys.exit(main())
