"""AST lint rules ruff can't express — repo-specific hot-path hygiene.

Three rules, each scoped to the modules where the pattern is actually a
bug (the same call is fine elsewhere):

* **LN001** — ``float()`` / ``np.asarray`` / ``np.array`` /
  ``jax.device_get`` / ``block_until_ready`` in the launch/api hot-path
  modules. Every one of these is a host sync; on the async host loop they
  belong only at sanctioned drain points.
* **LN002** — ``time.time()`` / ``time.perf_counter()`` in step/selection/
  kernel code, where timing must come from the dispatch clock
  (``DeviceClock``): a wall clock there measures the python host, not the
  device, and reintroduces the dispatch-queue stall PR 5 removed.
* **LN003** — ``pallas_call`` outside ``kernels/``: kernel launches live
  behind the kernels API (budget checks, interpret-mode routing, VJP
  definitions); a stray direct launch bypasses all three.
* **LN004** — ``jax.distributed.*`` / mesh construction (``jax.make_mesh``
  or a ``Mesh(...)`` ctor) / ``jax.process_index``/``jax.process_count``
  outside ``backend/`` + ``launch/mesh.py``: device/process topology is the
  execution backend's monopoly — a stray mesh or process query hardwires
  single-process assumptions back into code the backend refactor freed.

Whitelisting is inline and local: put ``lint: allow`` in a comment on the
flagged line (or the line above). The sanctioned drain points in
``launch/metrics.py`` etc. carry the marker next to their
``sync_allowed(...)`` wrapper, so the static whitelist and the runtime
whitelist sit on the same lines.
"""
from __future__ import annotations

import ast
import pathlib
from typing import Callable, List, Optional, Sequence, Tuple

from repro.analysis.report import Finding, Report

ALLOW_MARKER = "lint: allow"

# modules where a host sync outside a sanctioned site is a hot-path bug
HOT_PATH_MODULES = (
    "launch/steps.py",
    "launch/metrics.py",
    "launch/evaluate.py",
    "resilience/guard.py",
    "api/trainer.py",
    "api/callbacks.py",
    "selection/overlap.py",
)

# modules where timing must come from the dispatch clock
DISPATCH_CLOCK_SCOPES = ("launch/steps.py", "selection/", "kernels/")

_SYNC_CALLS = {"float", "np.asarray", "numpy.asarray", "np.array",
               "numpy.array"}
_SYNC_TAILS = {"device_get", "block_until_ready"}
_WALL_CLOCK = {"time.time", "time.perf_counter", "time.monotonic",
               "perf_counter"}

# topology is the backend's monopoly (LN004)
_TOPOLOGY_SCOPES = ("backend/", "launch/mesh.py")
_TOPOLOGY_CALLS = {"jax.make_mesh", "jax.process_index", "jax.process_count"}
_MESH_CTORS = {"Mesh", "jax.sharding.Mesh", "sharding.Mesh"}


def _dotted(node: ast.AST) -> str:
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = _dotted(node.value)
        return f"{base}.{node.attr}" if base else node.attr
    return ""


def _in_scope(relpath: str, scopes: Sequence[str]) -> bool:
    return any(relpath == s or (s.endswith("/") and relpath.startswith(s))
               for s in scopes)


def _allowed(lines: Sequence[str], lineno: int) -> bool:
    """``lint: allow`` on the flagged line or the one above."""
    for ln in (lineno, lineno - 1):
        if 1 <= ln <= len(lines) and ALLOW_MARKER in lines[ln - 1]:
            return True
    return False


def _call_findings(relpath: str, name: str, lineno: int) -> List[Finding]:
    tail = name.rsplit(".", 1)[-1]
    out: List[Finding] = []
    loc = f"{relpath}:{lineno}"
    if _in_scope(relpath, HOT_PATH_MODULES) and (
            name in _SYNC_CALLS or tail in _SYNC_TAILS):
        out.append(Finding(
            rule="LN001", location=loc,
            message=f"host-sync call '{name}()' in a hot-path module",
            fix_hint="drain at a flush boundary under sync_allowed(...), "
                     "then mark the line '# lint: allow <why>'"))
    if _in_scope(relpath, DISPATCH_CLOCK_SCOPES) and name in _WALL_CLOCK:
        out.append(Finding(
            rule="LN002", location=loc,
            message=f"wall clock '{name}()' where the dispatch clock is "
                    "required",
            fix_hint="use launch/metrics.py:DeviceClock (device-ordered "
                     "timing) or hoist the timing out of the step path"))
    if tail == "pallas_call" and not relpath.startswith("kernels/"):
        out.append(Finding(
            rule="LN003", location=loc,
            message="direct pallas_call outside kernels/",
            fix_hint="wrap the launch in a kernels/ entry point (budget "
                     "check + interpret routing + custom_vjp live there)"))
    if not _in_scope(relpath, _TOPOLOGY_SCOPES) and (
            name in _TOPOLOGY_CALLS or name in _MESH_CTORS
            or name.startswith("jax.distributed.")):
        out.append(Finding(
            rule="LN004", location=loc,
            message=f"topology call '{name}(...)' outside the execution "
                    "backend",
            fix_hint="route through repro.backend (Backend.mesh()/"
                     "process_index/setup()) or launch/mesh.py — or mark "
                     "'# lint: allow <why>' for a deliberate exception"))
    return out


def lint_source(src: str, relpath: str) -> List[Finding]:
    """Lint one module's source. ``relpath`` is the path relative to
    ``src/repro`` with forward slashes (drives the rule scopes)."""
    try:
        tree = ast.parse(src)
    except SyntaxError as e:
        return [Finding(rule="LN001", location=f"{relpath}:{e.lineno or 0}",
                        message=f"unparseable module: {e.msg}")]
    lines = src.splitlines()
    findings: List[Finding] = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        name = _dotted(node.func)
        if not name:
            continue
        for f in _call_findings(relpath, name, node.lineno):
            if not _allowed(lines, node.lineno):
                findings.append(f)
    return findings


def lint_file(path: pathlib.Path, root: pathlib.Path) -> List[Finding]:
    relpath = path.relative_to(root).as_posix()
    return lint_source(path.read_text(), relpath)


def lint_tree(root: Optional[pathlib.Path] = None,
              predicate: Optional[Callable[[str], bool]] = None) -> Report:
    """Lint every module under ``src/repro`` (default: the installed
    package's own directory)."""
    if root is None:
        root = pathlib.Path(__file__).resolve().parent.parent
    report = Report()
    for path in sorted(root.rglob("*.py")):
        rel = path.relative_to(root).as_posix()
        if rel.startswith("analysis/"):
            continue                 # the linter's own sources
        if predicate is not None and not predicate(rel):
            continue
        report.extend(lint_file(path, root))
    return report
