"""Declarative primitive accounting over traced jaxprs.

Generalizes the dispatch-shape evidence that ``benchmarks/
bench_selection_overhead.py`` used to hand-roll: trace a function, walk
every equation (recursing into pjit bodies, cond branches, scan bodies,
custom-vjp calls), and check the primitive counts against declarative
rules. The same counters feed three consumers:

  * the bench's ``dispatch_per_refresh`` / ``attention`` entries
    (:func:`count_primitives`, :func:`dispatch_summary`);
  * ``check_bench_regression``'s monotone launch-count gates
    (:func:`monotone_count_rows` — one implementation, so the measured and
    the gated counts can never drift apart);
  * the contract audits in ``python -m repro.analysis``
    (:func:`audit_step`, :func:`fused_selection_rules`, …).

Host-callback primitives are the jaxpr-visible evidence of a device→host
sync compiled INTO a step (``pure_callback`` and friends) — a step function
containing one stalls the dispatch queue every step, reverting the async
host loop (PR 5). f64 ops are audited from the equation output avals: a
single ``float64`` constant silently doubles bandwidth on the whole
downstream chain.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Iterator, List, Mapping, Optional, \
    Sequence, Tuple

import jax

from repro.analysis.report import Finding, Report

# jaxpr primitives that call back into the host — any of these inside a
# train/selection step is a per-dispatch host sync
HOST_CALLBACK_PRIMITIVES = (
    "pure_callback", "io_callback", "debug_callback", "callback",
    "outside_call", "host_callback_call",
)

_WIDE_DTYPES = ("float64", "complex128")


# ---------------------------------------------------------------------------
# traversal
# ---------------------------------------------------------------------------

def _subjaxprs(v) -> Iterator[Any]:
    if isinstance(v, jax.core.ClosedJaxpr):
        yield v.jaxpr
    elif isinstance(v, jax.core.Jaxpr):
        yield v
    elif isinstance(v, (list, tuple)):
        for item in v:
            yield from _subjaxprs(item)


def iter_eqns(jaxpr) -> Iterator[Any]:
    """Every equation in ``jaxpr``, recursing into sub-jaxprs (pjit bodies,
    cond branches, scans, custom-vjp calls)."""
    for eqn in jaxpr.eqns:
        yield eqn
        for v in eqn.params.values():
            for sub in _subjaxprs(v):
                yield from iter_eqns(sub)


def trace_jaxpr(fn: Callable, *args, **kwargs):
    """The traced (unlowered) jaxpr of ``fn(*args, **kwargs)``."""
    return jax.make_jaxpr(lambda *a: fn(*a, **kwargs))(*args).jaxpr


def eqn_location(eqn) -> str:
    """Best-effort user source location of one equation."""
    try:
        from jax._src import source_info_util
        return source_info_util.summarize(eqn.source_info)
    except Exception:
        return "<unknown>"


def count_primitives(fn: Callable, *args, **kwargs) -> Dict[str, int]:
    """Primitive → occurrence count in the traced jaxpr of ``fn`` —
    ``pallas_call`` entries are kernel launches per dispatch."""
    counts: Dict[str, int] = {}
    for eqn in iter_eqns(trace_jaxpr(fn, *args, **kwargs)):
        counts[eqn.primitive.name] = counts.get(eqn.primitive.name, 0) + 1
    return counts


def dispatch_summary(counts: Mapping[str, int],
                     keys: Sequence[str] = ("pallas_call", "gather"),
                     ) -> Dict[str, int]:
    """The dispatch-shape entry the bench reports and the gate diffs."""
    return {k: int(counts.get(k, 0)) for k in keys}


# ---------------------------------------------------------------------------
# declarative rules
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PrimitiveRule:
    """Bound on one primitive's count in a traced function.

    ``exact``/``max_count``/``min_count`` — any subset; unset bounds are
    not checked. ``rule`` is the report rule id the violation carries.
    """
    primitive: str
    exact: Optional[int] = None
    max_count: Optional[int] = None
    min_count: Optional[int] = None
    rule: str = "JX003"
    why: str = ""
    fix_hint: str = ""

    def check(self, counts: Mapping[str, int], label: str) -> List[Finding]:
        n = counts.get(self.primitive, 0)
        problems = []
        if self.exact is not None and n != self.exact:
            problems.append(f"expected exactly {self.exact}")
        if self.max_count is not None and n > self.max_count:
            problems.append(f"expected at most {self.max_count}")
        if self.min_count is not None and n < self.min_count:
            problems.append(f"expected at least {self.min_count}")
        if not problems:
            return []
        why = f" — {self.why}" if self.why else ""
        return [Finding(
            rule=self.rule, location=label,
            message=f"{n}× '{self.primitive}' in the traced jaxpr "
                    f"({'; '.join(problems)}){why}",
            fix_hint=self.fix_hint)]


def no_host_callback_rules() -> List[PrimitiveRule]:
    """Forbid every host-callback primitive (JX001) — the jaxpr evidence of
    a device→host transfer compiled into the step."""
    return [PrimitiveRule(
        p, max_count=0, rule="JX001",
        why="a host callback inside a jitted step syncs the dispatch "
            "queue every step",
        fix_hint="move the host computation out of the jitted function "
                 "(drain it at a flush boundary instead)")
        for p in HOST_CALLBACK_PRIMITIVES]


def fused_selection_rules() -> List[PrimitiveRule]:
    """PR 3's single-dispatch contract: ONE ``pallas_call``, NO gather."""
    return [
        PrimitiveRule(
            "pallas_call", exact=1, rule="JX003",
            why="the fused selection refresh must be a single kernel launch",
            fix_hint="route through kernels/graft_select.py "
                     "(GraftConfig.use_pallas) instead of the unfused chain"),
        PrimitiveRule(
            "gather", max_count=0, rule="JX004",
            why="the fused path gathers pivot columns inside the kernel "
                "(one-hot matmul); a jaxpr-level gather means an HBM "
                "round-trip crept back in",
            fix_hint="keep the G-gather inside the fused kernel "
                     "(no jnp.take on the fused path)"),
    ]


def attention_rules(layers: int) -> List[PrimitiveRule]:
    """PR 6's contract: exactly one kernel launch per attention layer."""
    return [PrimitiveRule(
        "pallas_call", exact=layers, rule="JX003",
        why=f"flash attention must launch exactly one kernel per layer "
            f"({layers} layers)",
        fix_hint="check resolve_attn_backend routing and the kernel "
                 "factory cache key")]


# ---------------------------------------------------------------------------
# audits
# ---------------------------------------------------------------------------

def audit_dtypes(fn: Callable, *args, label: str = "fn",
                 forbidden: Sequence[str] = _WIDE_DTYPES,
                 **kwargs) -> Report:
    """JX002: flag equations whose outputs are f64/c128 — one wide constant
    poisons the dtype of the whole downstream chain."""
    report = Report()
    seen: Dict[Tuple[str, str], int] = {}
    for eqn in iter_eqns(trace_jaxpr(fn, *args, **kwargs)):
        for var in eqn.outvars:
            aval = getattr(var, "aval", None)
            dtype = str(getattr(aval, "dtype", ""))
            if dtype in forbidden:
                key = (eqn.primitive.name, dtype)
                seen[key] = seen.get(key, 0) + 1
                if seen[key] == 1:       # one finding per (primitive, dtype)
                    report.add(Finding(
                        rule="JX002", location=f"{label} @ {eqn_location(eqn)}",
                        message=f"'{eqn.primitive.name}' produces {dtype} "
                                "inside a step function",
                        fix_hint="cast to float32 (or audit for a stray "
                                 "float64 constant / np scalar); x64 does "
                                 "not belong on the train hot path"))
    for (prim, dtype), n in seen.items():
        if n > 1:
            report.add(Finding(
                rule="JX002", severity="info", location=label,
                message=f"'{prim}' → {dtype} occurs {n}× in total"))
    return report


def audit_counts(fn: Callable, args: Sequence[Any],
                 rules: Sequence[PrimitiveRule],
                 label: str = "fn") -> Report:
    """Check declarative primitive-count rules against ``fn``'s jaxpr."""
    counts = count_primitives(fn, *args)
    report = Report()
    for rule in rules:
        report.extend(rule.check(counts, label))
    return report


def audit_step(fn: Callable, args: Sequence[Any], *, label: str = "step",
               extra_rules: Sequence[PrimitiveRule] = (),
               check_dtypes: bool = True) -> Report:
    """The standard train/selection-step audit: no host callbacks (JX001),
    no f64 ops (JX002), plus any caller-specific count rules."""
    rules = list(no_host_callback_rules()) + list(extra_rules)
    report = audit_counts(fn, args, rules, label=label)
    if check_dtypes:
        report.extend(audit_dtypes(fn, *args, label=label))
    return report


# ---------------------------------------------------------------------------
# regression-gate helper (shared with benchmarks/check_bench_regression.py)
# ---------------------------------------------------------------------------

def monotone_count_rows(prefix: str, baseline: Mapping[str, Any],
                        current: Mapping[str, Any],
                        keys: Sequence[str], why: str,
                        ) -> Tuple[List[Tuple[str, float, float, bool]],
                                   List[str]]:
    """Diff integer counters that must never INCREASE (launch/dispatch
    counts). Returns ``(rows, problems)`` in the regression gate's row
    format: ``(metric, baseline, current, regressed)``."""
    rows: List[Tuple[str, float, float, bool]] = []
    problems: List[str] = []
    for k in keys:
        b = float(baseline.get(k, 0))
        c = float(current.get(k, 0))
        bad = c > b
        rows.append((f"{prefix}.{k}", b, c, bad))
        if bad:
            problems.append(f"{prefix}.{k}: {why} "
                            f"(baseline {int(b)}, current {int(c)})")
    return rows, problems
