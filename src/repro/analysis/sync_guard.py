"""Runtime guard recording every host↔device sync with a stack summary.

PR 5's async host loop promises: between metric flushes the train loop
never blocks on the device — no ``float(arr)``, no ``device_get``, no
``block_until_ready`` outside the sanctioned drain points. This module
pins that promise at runtime: :class:`SyncGuard` instruments the sync
entry points (``jax.block_until_ready``, ``jax.device_get``, and the
concrete Array's ``__float__``/``__int__``/``__bool__``/``__array__``)
and records every hit in the guarded thread; ``strict=True`` raises
:class:`SyncGuardError` at the offending call site.

Sanctioned sites mark themselves with :func:`sync_allowed`::

    with sync_allowed("metrics_flush"):
        vals = [float(v) for v in pending]     # recorded, but sanctioned

JAX's own transfer guard (``jax.transfer_guard_device_to_host``) is NOT
used: on the CPU backend arrays are host-resident, so ``"disallow"``
never fires — instrumentation is the only portable detector, and it also
works in CI.

Scope is **thread-local**: only threads that entered a guard are audited.
The ``DeviceClock`` marker thread and ``SideStream`` waiter may block
freely — blocking off-thread is exactly the design.
"""
from __future__ import annotations

import contextlib
import dataclasses
import sys
import threading
from typing import Callable, Dict, List, Optional, Tuple

import jax

from repro.analysis.report import Finding, Report

_tls = threading.local()


class SyncGuardError(RuntimeError):
    """A host↔device sync occurred outside every sanctioned site."""


@dataclasses.dataclass(frozen=True)
class SyncEvent:
    """One observed sync: what kind, which sanctioned site (if any), and
    the user stack frame it came from."""
    kind: str                       # "__float__", "device_get", ...
    site: Optional[str]             # sanctioned site name, None = violation
    where: str                      # "file.py:42 in flush"

    @property
    def sanctioned(self) -> bool:
        return self.site is not None


def _origin() -> Optional[str]:
    """The frame that triggered the sync, or ``None`` when the trigger sits
    inside jax/jaxlib itself.

    Internal triggers are NOT user syncs: jit tracing/lowering legitimately
    materializes captured device constants (``__array__`` during constant
    folding), and attributing those to the step loop would fail audited
    runs at compile time. A sync the user wrote always surfaces through a
    non-jax frame (their ``float(...)`` / ``np.asarray`` call site), which
    is what gets reported. Frames in numpy are walked through — a
    ``np.mean(device_array)`` in user code is a user sync."""
    f = sys._getframe(2)
    while f is not None:
        fn = f.f_code.co_filename.replace("\\", "/")
        if "sync_guard" in fn or "/numpy/" in fn:
            f = f.f_back
            continue
        if "/jax/" in fn or "/jaxlib/" in fn:
            return None
        return f"{fn.rsplit('/', 1)[-1]}:{f.f_lineno} in {f.f_code.co_name}"
    return None


def _allowed_site() -> Optional[str]:
    stack = getattr(_tls, "allowed", None)
    return stack[-1] if stack else None


@contextlib.contextmanager
def sync_allowed(site: str):
    """Mark the enclosed block as a sanctioned sync site named ``site``.

    Cheap no-op when no guard is active in this thread; safe to leave in
    production code permanently (that's the point — the whitelist lives at
    the drain sites themselves, not in a separate config).
    """
    stack = getattr(_tls, "allowed", None)
    if stack is None:
        stack = _tls.allowed = []
    stack.append(site)
    try:
        yield
    finally:
        stack.pop()


def _array_impl_class():
    """The concrete on-device Array class whose dunders we instrument."""
    try:
        from jax._src.array import ArrayImpl
        return ArrayImpl
    except Exception:
        return type(jax.numpy.zeros((), jax.numpy.float32))


class SyncGuard:
    """Context manager auditing host↔device syncs in the entering thread.

    ``strict=True`` raises :class:`SyncGuardError` at the first
    unsanctioned sync; ``strict=False`` only records, for post-hoc
    :meth:`report`. Events (sanctioned included) accumulate in
    :attr:`events`. Reentrant patches are refcounted so nested guards and
    concurrent guarded threads compose.
    """

    _lock = threading.Lock()
    _install_count = 0
    _saved: Dict[str, Callable] = {}

    def __init__(self, strict: bool = False, label: str = "sync_guard"):
        self.strict = strict
        self.label = label
        self.events: List[SyncEvent] = []

    # -- patch plumbing ----------------------------------------------------

    @classmethod
    def _install(cls) -> None:
        with cls._lock:
            cls._install_count += 1
            if cls._install_count > 1:
                return
            arr = _array_impl_class()
            cls._saved = {
                "block_until_ready": jax.block_until_ready,
                "device_get": jax.device_get,
                "__float__": arr.__float__,
                "__int__": arr.__int__,
                "__bool__": arr.__bool__,
                "__array__": arr.__array__,
            }

            def wrap(kind: str, orig: Callable) -> Callable:
                def hook(*args, **kwargs):
                    _record(kind)
                    return orig(*args, **kwargs)
                return hook

            jax.block_until_ready = wrap(
                "block_until_ready", cls._saved["block_until_ready"])
            jax.device_get = wrap("device_get", cls._saved["device_get"])
            for dunder in ("__float__", "__int__", "__bool__", "__array__"):
                setattr(arr, dunder, wrap(dunder, cls._saved[dunder]))

    @classmethod
    def _uninstall(cls) -> None:
        with cls._lock:
            cls._install_count -= 1
            if cls._install_count > 0:
                return
            arr = _array_impl_class()
            jax.block_until_ready = cls._saved["block_until_ready"]
            jax.device_get = cls._saved["device_get"]
            for dunder in ("__float__", "__int__", "__bool__", "__array__"):
                setattr(arr, dunder, cls._saved[dunder])
            cls._saved = {}

    # -- context manager ---------------------------------------------------

    def __enter__(self) -> "SyncGuard":
        if getattr(_tls, "guard", None) is not None:
            raise RuntimeError("SyncGuard is not reentrant within a thread")
        self._install()
        _tls.guard = self
        return self

    def __exit__(self, *exc) -> None:
        _tls.guard = None
        self._uninstall()

    # -- results -----------------------------------------------------------

    def on_event(self, event: SyncEvent) -> None:
        self.events.append(event)
        if self.strict and not event.sanctioned:
            raise SyncGuardError(
                f"[{self.label}] unsanctioned host sync: {event.kind} at "
                f"{event.where} — wrap the drain point in "
                f"sync_allowed(\"<site>\") if this sync is intentional")

    @property
    def violations(self) -> List[SyncEvent]:
        return [e for e in self.events if not e.sanctioned]

    def site_counts(self) -> Dict[Tuple[str, str], int]:
        out: Dict[Tuple[str, str], int] = {}
        for e in self.events:
            key = (e.site or "UNSANCTIONED", e.kind)
            out[key] = out.get(key, 0) + 1
        return out

    def report(self) -> Report:
        """SY001 per distinct violating call site; sanctioned totals as an
        info note (the sync budget the run actually spent)."""
        rep = Report()
        seen: Dict[Tuple[str, str], int] = {}
        for e in self.violations:
            seen[(e.kind, e.where)] = seen.get((e.kind, e.where), 0) + 1
        for (kind, where), n in seen.items():
            times = f" ({n}×)" if n > 1 else ""
            rep.add(Finding(
                rule="SY001", location=where,
                message=f"unsanctioned host sync via {kind}{times} while "
                        f"[{self.label}] was active",
                fix_hint="move the sync to a flush boundary, or wrap the "
                         "site in repro.analysis.sync_allowed(...) with a "
                         "named site"))
        sanctioned = [e for e in self.events if e.sanctioned]
        if sanctioned:
            by_site: Dict[str, int] = {}
            for e in sanctioned:
                by_site[e.site] = by_site.get(e.site, 0) + 1
            detail = ", ".join(f"{s}={n}" for s, n in sorted(by_site.items()))
            rep.add(Finding(
                rule="SY001", severity="info", location=self.label,
                message=f"{len(sanctioned)} sanctioned sync(s): {detail}"))
        return rep


def _record(kind: str) -> None:
    guard: Optional[SyncGuard] = getattr(_tls, "guard", None)
    if guard is None:
        return                       # unguarded thread (DeviceClock, ...)
    if getattr(_tls, "in_hook", False):
        return                       # device_get → __array__ reentry
    _tls.in_hook = True
    try:
        where = _origin()
        if where is None:
            return                   # jax-internal trigger (compile path)
        guard.on_event(SyncEvent(kind=kind, site=_allowed_site(),
                                 where=where))
    finally:
        _tls.in_hook = False
