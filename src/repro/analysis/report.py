"""One report format for every checker: rule id, severity, location,
message, fix hint.

Rule ids are stable strings (``JX*`` jaxpr, ``SY*`` sync, ``RC*``
recompile, ``VM*`` VMEM, ``LN*`` lint) so CI logs, tests, and whitelists
can reference a rule without parsing prose. ``RULES`` is the registry the
CLI prints as the rule table.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Dict, Iterable, List, Optional

SEVERITIES = ("error", "warning", "info")

# rule id → (default severity, one-line description)
RULES: Dict[str, tuple] = {
    "JX001": ("error", "host-callback primitive traced into a jitted hot "
                       "path (a device→host sync every dispatch)"),
    "JX002": ("error", "float64/complex128 op inside a step function "
                       "(silent 2× bandwidth + matmul off the MXU path)"),
    "JX003": ("error", "pallas_call launch count differs from the "
                       "single-dispatch contract"),
    "JX004": ("error", "stray gather primitive on the fused selection path"),
    "SY001": ("error", "host↔device sync outside a sanctioned site"),
    "RC001": ("error", "step function re-traced: call signature "
                       "(shape/dtype/static arg) drifted between steps"),
    "VM001": ("error", "kernel's resident blocks exceed the per-program "
                       "VMEM budget"),
    "VM002": ("error", "block size does not divide the array extent "
                       "(grid would drop or pad elements)"),
    "VM003": ("info", "VMEM headroom report for a kernel configuration"),
    "LN001": ("error", "float()/np.asarray/jax.device_get in a hot-path "
                       "module outside a whitelisted site"),
    "LN002": ("error", "wall clock (time.time/perf_counter) where the "
                       "dispatch/device clock is required"),
    "LN003": ("error", "pallas_call outside kernels/ (kernel launches must "
                       "live behind the kernels API)"),
    "LN004": ("error", "jax.distributed/mesh construction/process queries "
                       "outside repro/backend/ + launch/mesh.py (topology "
                       "is the execution backend's monopoly)"),
    "SP001": ("error", "registered sampler closes over mutable Python state "
                       "(cross-step state must flow through the Sampler-v2 "
                       "carry, or rollback/resume silently desyncs)"),
}


@dataclasses.dataclass(frozen=True)
class Finding:
    """One violation (or info note) from any checker."""
    rule: str                       # registry id, e.g. "JX003"
    location: str                   # "file.py:42", "train_step", "flash fwd"
    message: str                    # what is wrong, with the observed values
    fix_hint: str = ""              # how to fix or whitelist it
    severity: str = ""              # defaults to the rule's registered one

    def __post_init__(self):
        if not self.severity:
            object.__setattr__(
                self, "severity", RULES.get(self.rule, ("error",))[0])
        if self.severity not in SEVERITIES:
            raise ValueError(f"severity {self.severity!r} not in {SEVERITIES}")

    def format(self) -> str:
        line = f"{self.severity.upper():7s} {self.rule} {self.location}: " \
               f"{self.message}"
        if self.fix_hint:
            line += f"\n        fix: {self.fix_hint}"
        return line

    def to_dict(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)


class Report:
    """An ordered collection of findings with error/ok accounting."""

    def __init__(self, findings: Optional[Iterable[Finding]] = None):
        self.findings: List[Finding] = list(findings or [])

    def add(self, finding: Finding) -> None:
        self.findings.append(finding)

    def extend(self, other: "Report | Iterable[Finding]") -> None:
        self.findings.extend(
            other.findings if isinstance(other, Report) else other)

    @property
    def errors(self) -> List[Finding]:
        return [f for f in self.findings if f.severity == "error"]

    @property
    def ok(self) -> bool:
        return not self.errors

    def by_rule(self, rule: str) -> List[Finding]:
        return [f for f in self.findings if f.rule == rule]

    def format(self, show_info: bool = True) -> str:
        shown = [f for f in self.findings
                 if show_info or f.severity != "info"]
        if not shown:
            return "analysis: clean (no findings)"
        lines = [f.format() for f in shown]
        lines.append(f"analysis: {len(self.errors)} error(s), "
                     f"{len(self.findings) - len(self.errors)} note(s)")
        return "\n".join(lines)

    def to_json(self, indent: Optional[int] = 1) -> str:
        return json.dumps({"ok": self.ok,
                           "findings": [f.to_dict() for f in self.findings]},
                          indent=indent, sort_keys=True)

    def __len__(self) -> int:
        return len(self.findings)

    def __iter__(self):
        return iter(self.findings)


def rule_table() -> str:
    """The rule registry as a markdown table (CLI ``--rules``)."""
    lines = ["| rule | severity | description |", "|---|---|---|"]
    for rid, (sev, desc) in sorted(RULES.items()):
        lines.append(f"| {rid} | {sev} | {desc} |")
    return "\n".join(lines)
