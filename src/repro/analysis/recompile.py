"""Re-trace detection for step functions across training steps.

A jitted step re-traces when its call signature drifts — a batch shape
changed, a dtype widened, a static argument took a new value, a python
scalar leaked into the args. Each re-trace costs a full trace+lower+compile
(seconds) in the middle of training and usually repeats every step; it is
the single most common way the async host loop's throughput silently
collapses.

:class:`RecompileWatcher` does two independent checks:

* **signature drift** — :meth:`observe` snapshots the (shape, dtype)
  spec of every argument leaf per call and diffs it against the previous
  call, emitting RC001 naming exactly the key path that changed
  (``batch['x']: f32[8,16] → f32[8,32]``). This catches the *cause*
  before jit even re-traces.
* **cache growth** — :meth:`watch` registers a jitted function;
  :meth:`check_caches` reads its compile-cache size and emits RC001 when
  the cache grew past the expected number of specializations. This
  catches re-traces whose cause is outside the observed args (closure
  drift, weak-type promotion).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Tuple

import jax

from repro.analysis.report import Finding, Report


def leaf_spec(leaf: Any) -> str:
    """Stable signature of one argument leaf: aval spec for arrays,
    ``repr`` for static python values (both re-trace keys)."""
    shape = getattr(leaf, "shape", None)
    dtype = getattr(leaf, "dtype", None)
    if shape is not None and dtype is not None:
        weak = "~" if getattr(leaf, "weak_type", False) else ""
        return f"{dtype}[{','.join(map(str, shape))}]{weak}"
    if isinstance(leaf, (bool, int, float, str, bytes, type(None))):
        r = repr(leaf)
        return r if len(r) <= 64 else r[:61] + "..."
    # exotic leaf: type identity only — repr could walk device arrays
    return f"<{type(leaf).__name__}>"


def signature_of(**named_args) -> Dict[str, str]:
    """Key path → leaf spec over every named argument pytree."""
    out: Dict[str, str] = {}
    for name, tree in named_args.items():
        leaves, _ = jax.tree_util.tree_flatten_with_path(tree)
        if not leaves:
            out[name] = repr(tree)
        for path, leaf in leaves:
            out[name + jax.tree_util.keystr(path)] = leaf_spec(leaf)
    return out


def _cache_size(fn: Callable) -> Optional[int]:
    probe = getattr(fn, "_cache_size", None)
    if probe is None:
        return None
    try:
        return int(probe())
    except Exception:
        return None


class RecompileWatcher:
    """Accumulates RC001 findings over a sequence of step calls."""

    def __init__(self, label: str = "step"):
        self.label = label
        self.findings: List[Finding] = []
        self._prev: Optional[Dict[str, str]] = None
        self._prev_step: Optional[int] = None
        self._watched: List[Tuple[str, Callable, Optional[int]]] = []

    # -- signature drift ---------------------------------------------------

    def observe(self, step: Optional[int] = None,
                **named_args) -> List[Finding]:
        """Snapshot this call's argument signature; diff vs the previous
        call. Returns the NEW findings from this observation."""
        sig = signature_of(**named_args)
        new: List[Finding] = []
        if self._prev is not None:
            at = f"{self.label}" + (f" step {step}" if step is not None
                                    else "")
            for key in sorted(set(self._prev) | set(sig)):
                before, after = self._prev.get(key), sig.get(key)
                if before == after:
                    continue
                if before is None:
                    msg = f"argument '{key}' appeared ({after})"
                elif after is None:
                    msg = f"argument '{key}' disappeared (was {before})"
                else:
                    msg = f"argument '{key}' changed: {before} → {after}"
                new.append(Finding(
                    rule="RC001", location=at,
                    message=msg + " — jit will re-trace on this call",
                    fix_hint="pin the shape/dtype (pad the batch, cast at "
                             "the loader) or mark the argument static once "
                             "at construction"))
        self._prev, self._prev_step = sig, step
        self.findings.extend(new)
        return new

    # -- compile-cache growth ---------------------------------------------

    def watch(self, name: str, fn: Callable,
              expected_specializations: int = 1) -> None:
        """Register a jitted function whose compile cache must not exceed
        ``expected_specializations`` entries."""
        self._watched.append((name, fn, expected_specializations))

    def check_caches(self) -> List[Finding]:
        new: List[Finding] = []
        for name, fn, expected in self._watched:
            size = _cache_size(fn)
            if size is not None and expected is not None and size > expected:
                new.append(Finding(
                    rule="RC001", location=f"{self.label}:{name}",
                    message=f"compile cache holds {size} specializations "
                            f"(expected ≤ {expected}) — the step function "
                            "re-traced during the run",
                    fix_hint="diff the argument signatures (observe()) or "
                             "check for closure/static-arg drift"))
        self.findings.extend(new)
        return new

    # -- results -----------------------------------------------------------

    def report(self) -> Report:
        return Report(self.findings)

    @property
    def ok(self) -> bool:
        return not self.findings
