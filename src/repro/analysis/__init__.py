"""Static + runtime analysis of the training hot path.

The repo's efficiency story is a set of *contracts* — single-dispatch fused
selection (PR 3), an async host loop that never syncs per step (PR 5), one
``pallas_call`` per attention layer (PR 6), kernels that fit the
per-program VMEM budget — and this package is what enforces them on every
PR instead of a human re-reading bench JSON:

  * :mod:`repro.analysis.jaxpr_audit` — declarative primitive accounting
    over traced jaxprs (launch counts, forbidden host callbacks, f64 ops,
    stray gathers);
  * :mod:`repro.analysis.sync_guard`  — a runtime guard that records every
    host↔device sync with a stack summary and fails on syncs outside
    sanctioned sites (``train.audit``);
  * :mod:`repro.analysis.recompile`   — re-trace detection across step
    calls, naming the argument whose shape/dtype drifted;
  * :mod:`repro.analysis.vmem`        — static VMEM footprint + grid/block
    divisibility for the Pallas kernels (the single budget the kernel
    wrappers and backend routing consult);
  * :mod:`repro.analysis.lint`        — AST rules ruff can't express
    (host-sync calls in hot-path modules, wall-clock where the dispatch
    clock is required, ``pallas_call`` outside ``kernels/``).

All checkers emit :class:`repro.analysis.report.Finding`s — one format:
rule id, severity, location, message, fix hint. ``python -m repro.analysis``
runs the full battery over a probe config (the CI ``analysis`` job).
"""
from repro.analysis.report import Finding, Report, RULES
from repro.analysis.sync_guard import (SyncGuard, SyncGuardError,
                                       sync_allowed)

__all__ = [
    "Finding",
    "Report",
    "RULES",
    "SyncGuard",
    "SyncGuardError",
    "sync_allowed",
]
