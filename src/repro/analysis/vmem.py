"""Static VMEM footprint + grid/block feasibility for the Pallas kernels.

One audited estimator instead of three inline mirrors: the flash-attention
wrapper's guard (``kernels/flash_attention.py``), the backend router's
feasibility probe (``models/layers.py:_flash_feasible``), and the fused
selection budget (``kernels/graft_select.py:_check_budget``) all consult
the formulas here, so the number the router plans with is the number the
kernel enforces.

The budget is the per-program share of TPU VMEM a single kernel instance
may keep resident (~12 MB of the ~16 MB/core arena, leaving headroom for
semaphores/compiler spill). Footprints are computed from BlockSpec block
shapes and dtypes — what the Pallas runtime actually keeps resident per
grid program — NOT from the full operand shapes.

Headroom reports (VM003, info) are the groundwork for the ROADMAP's
blockwise-KV item: they say how far ``T`` can grow before flash attention
must tile the KV stream.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

from repro.analysis.report import Finding, Report

# per-program resident budget (f32 words * 4 bytes accounting everywhere)
VMEM_BUDGET_BYTES = 12 * 1024 * 1024


@dataclasses.dataclass(frozen=True)
class VmemEstimate:
    """Per-program VMEM residency of one kernel configuration."""
    kernel: str                     # "flash_attention(T=512, Dh=64, bq=128)"
    parts: Dict[str, int]           # resident block → bytes
    budget: int = VMEM_BUDGET_BYTES

    @property
    def total(self) -> int:
        return sum(self.parts.values())

    @property
    def headroom(self) -> int:
        return self.budget - self.total

    @property
    def fits(self) -> bool:
        return self.total <= self.budget

    def describe(self) -> str:
        parts = ", ".join(f"{k}={v / 2**20:.2f}MB"
                          for k, v in sorted(self.parts.items()))
        return (f"{self.kernel}: {self.total / 2**20:.2f}MB resident "
                f"({parts}); headroom {self.headroom / 2**20:.2f}MB")

    def report(self, location: str = "") -> Report:
        """VM001 on overflow, VM003 headroom note otherwise."""
        rep = Report()
        loc = location or self.kernel
        if not self.fits:
            rep.add(Finding(
                rule="VM001", location=loc,
                message=f"resident blocks {self.total / 2**20:.2f}MB exceed "
                        f"the {self.budget / 2**20:.0f}MB per-program budget "
                        f"({self.describe()})",
                fix_hint="shrink the block sizes / KV length, or route this "
                         "shape to the chunked jnp path"))
        else:
            rep.add(Finding(rule="VM003", location=loc,
                            message=self.describe()))
        return rep


def check_divisible(extent: int, block: int, axis: str,
                    location: str) -> Optional[Finding]:
    """VM002: a block that does not divide its extent drops or pads rows."""
    if block <= 0 or extent % block:
        return Finding(
            rule="VM002", location=location,
            message=f"block size {block} does not divide {axis}={extent}",
            fix_hint="pick a block from the divisor ladder "
                     "(models/layers.py:_FLASH_BLOCKS) or pad the operand")
    return None


# ---------------------------------------------------------------------------
# per-kernel footprints (formulas bit-exact with the kernel wrappers)
# ---------------------------------------------------------------------------

def flash_forward_vmem(T: int, head_dim: int, block_q: int,
                       itemsize: int = 4) -> VmemEstimate:
    """Flash-attention forward, one grid program: the full K and V streams
    (kv BlockSpec ``(1, T, Dh)``) plus 3 q-sized tiles (q block, acc block,
    out block), matching the wrapper guard
    ``(2*T*Dh + 3*block_q*Dh) * 4 <= budget``."""
    return VmemEstimate(
        kernel=f"flash_attention(T={T}, Dh={head_dim}, bq={block_q})",
        parts={"kv_stream": 2 * T * head_dim * itemsize,
               "q_tiles": 3 * block_q * head_dim * itemsize})


def flash_attention_report(S: int, T: int, head_dim: int,
                           block_q: int, block_k: int) -> Report:
    """Full feasibility check for one flash shape: divisibility + VMEM."""
    rep = Report()
    loc = f"flash_attention(S={S}, T={T}, Dh={head_dim})"
    for extent, block, axis in ((S, block_q, "Sq"), (T, block_k, "T")):
        f = check_divisible(extent, block, axis, loc)
        if f:
            rep.add(f)
    rep.extend(flash_forward_vmem(T, head_dim, block_q).report(loc))
    return rep


def flash_feasible(S: int, T: int, head_dim: int,
                   block_q: int, block_k: int) -> bool:
    """The router's go/no-go: blocks divide AND the footprint fits."""
    return flash_attention_report(S, T, head_dim, block_q, block_k).ok


def fused_select_vmem(K: int, R: int, d: int, rank: int,
                      itemsize: int = 4) -> VmemEstimate:
    """Fused GRAFT selection, single program: V (K,R), G (d,K), the
    selected-columns output G_sel (d,rank), the MGS basis Q (d,rank), and
    the K×rank one-hot — matching ``graft_select.py:_check_budget``'s
    ``words = K*R + d*K + 2*d*rank + K*rank``."""
    return VmemEstimate(
        kernel=f"graft_select(K={K}, R={R}, d={d}, rank={rank})",
        parts={"V": K * R * itemsize,
               "G": d * K * itemsize,
               "G_sel+Q": 2 * d * rank * itemsize,
               "one_hot": K * rank * itemsize})


def fast_maxvol_vmem(K: int, R: int, itemsize: int = 4) -> VmemEstimate:
    """Standalone Fast MaxVol: the whole K×R feature matrix stays resident
    through the R-step pivot loop, plus one K-vector of scores and one
    R-row workspace for the rank-1 update."""
    return VmemEstimate(
        kernel=f"fast_maxvol(K={K}, R={R})",
        parts={"V": K * R * itemsize,
               "scores": K * itemsize,
               "row_ws": R * itemsize})
