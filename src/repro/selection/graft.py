"""GRAFT selector — the paper's Algorithm 1 as a jit-able JAX module.

Pipeline per refresh step (every ``S`` iterations):
  1. features: V = f(batch) ∈ R^{K×R_max}, relevance-ordered columns
  2. Fast MaxVol: pivot order p (prefixes = candidate subsets for every rank)
  3. gradient matrix G[:, j] = grad-embedding of sample p_j; ḡ = batch mean
  4. prefix projection errors d_r; R* = smallest candidate rank with d ≤ ε
  5. emit (pivots, R*, weights) — weights mask pivots beyond R* so downstream
     train steps keep a static shape (R_max) while training on R* samples.

Between refreshes the previous selection is reused (Alg. 1 'else' branch).

This module is the real implementation; ``repro.core.graft`` re-exports it
for backwards compatibility.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import features as feat_lib
from repro.core import maxvol as maxvol_lib
from repro.core import projection as proj_lib
from repro.selection.base import GraftConfig, SelectionInputs, SelectionState, init_state

# the paper's names, kept as the canonical aliases
GraftState = SelectionState


def pivot_and_sweep(cfg: GraftConfig, V: jax.Array, G: jax.Array,
                    g_bar: jax.Array):
    """Stages 2-4 of the refresh: ``(pivots, prefix errors, G_sel)``.

    With ``cfg.use_pallas`` this is ONE fused Pallas dispatch
    (``kernels/graft_select.py``: MaxVol + gather + MGS sweep, everything
    VMEM-resident); otherwise the jnp three-op reference chain.
    """
    if cfg.use_pallas:
        from repro.kernels import ops as kernel_ops
        return kernel_ops.fused_graft_select(V, G, g_bar, cfg.r_max)
    pivots, _ = maxvol_lib.fast_maxvol(V, cfg.r_max)
    G_sel = jnp.take(G, pivots, axis=1)                    # (d, R_max)
    errors = proj_lib.prefix_projection_errors(G_sel, g_bar)
    return pivots, errors, G_sel


def _finalize(cfg: GraftConfig, pivots: jax.Array, errors: jax.Array,
              G_sel: jax.Array, g_bar: jax.Array,
              step: jax.Array) -> SelectionState:
    """Rank decision + weights + diagnostics — the cheap jnp epilogue shared
    by the single, batched and sharded refresh paths."""
    rank, err = proj_lib.select_rank(errors, cfg.rset, cfg.eps)
    active = (jnp.arange(cfg.r_max) < rank).astype(jnp.float32)
    weights = active / jnp.maximum(jnp.sum(active), 1.0)
    g_sub = G_sel @ weights                                # subset mean gradient
    align = proj_lib.cosine_alignment(g_sub, g_bar)
    return SelectionState(pivots=pivots, weights=weights, rank=rank,
                          last_error=err, alignment=align, step=step)


@functools.partial(jax.jit, static_argnames=("cfg",))
def graft_select(cfg: GraftConfig, V: jax.Array, G: jax.Array,
                 g_bar: jax.Array, step: jax.Array) -> SelectionState:
    """One selection refresh. V: (K, R_max) features (relevance-ordered);
    G: (d, K) per-sample grad embeddings; ḡ: (d,). Returns new state."""
    pivots, errors, G_sel = pivot_and_sweep(cfg, V, G, g_bar)
    return _finalize(cfg, pivots, errors, G_sel, g_bar, step)


@functools.partial(jax.jit, static_argnames=("cfg",))
def graft_select_batched(cfg: GraftConfig, V: jax.Array, G: jax.Array,
                         g_bar: jax.Array, step: jax.Array) -> SelectionState:
    """A whole microbatch stack of refreshes: V (B, K, R_max), G (B, d, K),
    ḡ (B, d). Semantically ``vmap(graft_select)`` — but with
    ``cfg.use_pallas`` the stack runs as ONE ``grid=(B,)`` kernel launch
    (vmap cannot lower a ``grid=()`` ``pallas_call`` on TPU)."""
    if cfg.use_pallas:
        from repro.kernels import ops as kernel_ops
        pivots, errors, G_sel = kernel_ops.fused_graft_select_batched(
            V, G, g_bar, cfg.r_max)
        return jax.vmap(
            lambda p, e, gs, gb: _finalize(cfg, p, e, gs, gb, step)
        )(pivots, errors, G_sel, g_bar)
    return jax.vmap(lambda v, g, gb: graft_select(cfg, v, g, gb, step)
                    )(V, G, g_bar)


def graft_sampler_fn(cfg: GraftConfig, inputs: SelectionInputs,
                     step: jax.Array) -> SelectionState:
    """Registry adapter: the ``Sampler.fn`` signature over ``graft_select``."""
    return graft_select(cfg, inputs.V, inputs.G, inputs.g_bar, step)


def maybe_refresh(cfg: GraftConfig, state: SelectionState, step: jax.Array,
                  V: jax.Array, G: jax.Array, g_bar: jax.Array) -> SelectionState:
    """Alg. 1 outer branch: refresh every S steps, else carry the old subset."""
    def do_refresh(_):
        return graft_select(cfg, V, G, g_bar, step)

    def keep(_):
        return state._replace(step=step)

    return jax.lax.cond(step % cfg.refresh_every == 0, do_refresh, keep, None)


# ---------------------------------------------------------------------------
# convenience: full selection from a raw batch matrix (paper's CNN/MLP path)
# ---------------------------------------------------------------------------

def select_from_batch(cfg: GraftConfig, batch_matrix: jax.Array,
                      loss_fn=None, params=None,
                      grad_fn_outputs: Optional[Tuple[jax.Array, jax.Array]] = None,
                      step: int = 0) -> SelectionState:
    """End-to-end selection when the batch is a plain (K, M) matrix.

    ``grad_fn_outputs``: optional precomputed (G (d,K), ḡ (d,)). If absent and
    ``loss_fn``/``params`` given, exact per-sample grads are used (small
    models). Features always from ``cfg.feature_mode`` on the raw batch.
    """
    from repro.core import grad_features as gf
    V = feat_lib.extract(cfg.feature_mode, batch_matrix, cfg.r_max)
    if grad_fn_outputs is not None:
        G, g_bar = grad_fn_outputs
    else:
        if loss_fn is None or params is None:
            raise ValueError("need loss_fn+params or grad_fn_outputs")
        G, g_bar = gf.per_sample_grads_full(loss_fn, params, batch_matrix)
    return graft_select(cfg, V, G, g_bar, jnp.int32(step))


__all__ = ["GraftConfig", "GraftState", "SelectionState", "init_state",
           "graft_select", "graft_select_batched", "graft_sampler_fn",
           "maybe_refresh", "pivot_and_sweep", "select_from_batch"]
