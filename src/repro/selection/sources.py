"""Feature-extractor / gradient-source registries for the selection inputs.

Mirrors ``selection/registry.py``: the two halves of GRAFT's selection
forward — how per-example *features* (the ``V`` matrix MaxVol pivots on)
and per-example *gradient embeddings* (the ``G`` matrix the rank sweep
projects) are produced — are named, registered strategies instead of code
baked into the train step. ``launch/steps.py:selection_inputs`` resolves
them from ``GraftConfig.feature_mode`` / ``GraftConfig.grad_mode``, so an
experiment switches feature paths declaratively (``--graft.feature_mode=
pca_sketch``) with no loop edits.

Built-in feature extractors (``(K, M) array, rank → (K, rank)``, columns
relevance-ordered as Fast MaxVol requires):

  * ``svd``         — relevance-ordered SVD of the pooled hiddens (the
                      paper's encoder/'Warm' path; default)
  * ``sketch_svd``  — randomized range-finder SVD (SAGE-style): O(K·M·L)
                      matmuls with only an L×L eigh, replacing the K×K Gram
                      eigendecomposition on the selection hot path
  * ``pca_sketch``  — Gaussian sketch to O(rank) columns, then PCA: the
                      sketch-based feature path whose cost is independent
                      of d_model
  * ``pooled_raw``  — raw pooled hiddens, columns ordered by energy; no
                      factorization at all (the cheapest baseline)
  * ``ica``         — FastICA on the whitened pooled hiddens, components
                      re-ordered by descending excess kurtosis
                      (non-Gaussianity = relevance; paper §13 ablation)

Built-in gradient sources (``GradSourceInputs → (K, E) embeddings``):

  * ``probe``       — loss-scaled, error-norm-weighted pooled hiddens from
                      the softmax error signal (no extra backward; default)
  * ``logit_embed`` — exact per-example head-input gradient Wᵀ(p − y)
                      averaged over probe positions (one extra matmul with
                      the unembedding, still no backward pass)
  * ``full``        — EXACT per-sample gradients of the whole parameter
                      pytree via ``vmap(grad)`` over the raw batch
                      (``core/grad_features.py:per_sample_grads_full``).
                      E = |Θ|: Alg. 1 verbatim — the oracle for small-model
                      runs, not a production path.

Remaining gap (see ROADMAP): ``encoder`` features (model-based AE
embeddings need a second encoder's params plumbed in).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple, Union

import jax
import jax.numpy as jnp

from repro.core import features as features_lib
from repro.core.grad_features import (logit_error_embeddings,
                                      per_sample_grads_full)
from repro.registry import Registry


class GradSourceInputs(NamedTuple):
    """Everything a gradient source may read. ``logits``/``labels``/
    ``hiddens`` are probe-position slices (K, S', ·); ``mcfg``/``params``
    give head-aware sources access to the unembedding; ``batch`` is the RAW
    model batch (leaves with leading K) for sources that re-run the model
    per example (``full``)."""
    logits: jax.Array            # (K, S', V) probe-position logits
    labels: jax.Array            # (K, S') probe-position labels
    hiddens: jax.Array           # (K, S', E) probe-position hiddens
    mcfg: Any = None             # model config (static)
    params: Any = None           # model params pytree
    batch: Any = None            # raw batch pytree (leading K leaves)
    mask: Any = None             # (K, S') loss mask at probe positions;
                                 # None = every position is labeled


@dataclasses.dataclass(frozen=True)
class FeatureExtractor:
    """A registered feature path: ``fn(A, rank) → V`` with ``A`` the pooled
    per-example matrix (K, M) and ``V`` (K, rank) relevance-ordered. Must be
    jit/vmap-traceable for a static ``rank``."""
    name: str
    fn: Callable[[jax.Array, int], jax.Array]

    def __call__(self, A: jax.Array, rank: int) -> jax.Array:
        return self.fn(A, rank)


@dataclasses.dataclass(frozen=True)
class GradSource:
    """A registered gradient-embedding path: ``fn(inputs) → (K, E)``."""
    name: str
    fn: Callable[[GradSourceInputs], jax.Array]
    needs_params: bool = False   # reads inputs.params/mcfg (head weights)
    needs_batch: bool = False    # reads inputs.batch (re-runs the model)
    embed_dim_of: Optional[Callable[[Any, Any], int]] = None
    # ^ (mcfg, params) → E, the embedding width this source emits. Stateful
    # samplers size their carry (the sketch reservoir is (L, E)) before any
    # batch exists; None means "hidden width" (mcfg.d_model).

    def embed_dim(self, mcfg: Any, params: Any) -> int:
        if self.embed_dim_of is not None:
            return int(self.embed_dim_of(mcfg, params))
        return int(mcfg.d_model)

    def __call__(self, inputs: GradSourceInputs) -> jax.Array:
        if self.needs_params and inputs.params is None:
            raise ValueError(
                f"grad source '{self.name}' requires GradSourceInputs.params")
        if self.needs_batch and inputs.batch is None:
            raise ValueError(
                f"grad source '{self.name}' requires GradSourceInputs.batch")
        return self.fn(inputs)


# generic registries (repro.registry) — shared register/get/available
# semantics with the sampler and data-source registries
_FEATURES: Registry = Registry("feature extractor")
_GRAD_SOURCES: Registry = Registry("grad source")


def register_features(extractor: FeatureExtractor, *,
                      overwrite: bool = False) -> FeatureExtractor:
    return _FEATURES.register(extractor.name, extractor, overwrite=overwrite)


def register_grad_source(source: GradSource, *,
                         overwrite: bool = False) -> GradSource:
    return _GRAD_SOURCES.register(source.name, source, overwrite=overwrite)


def resolve_features(name: Union[str, FeatureExtractor]) -> FeatureExtractor:
    if isinstance(name, FeatureExtractor):
        return name
    return _FEATURES.get(name)


def resolve_grad_source(name: Union[str, GradSource]) -> GradSource:
    if isinstance(name, GradSource):
        return name
    return _GRAD_SOURCES.get(name)


def available_features() -> Tuple[str, ...]:
    return _FEATURES.available()


def available_grad_sources() -> Tuple[str, ...]:
    return _GRAD_SOURCES.available()


# ---------------------------------------------------------------------------
# built-in feature extractors
# ---------------------------------------------------------------------------

_SKETCH_SEED = 0x5A6E


def pca_sketch_features(A: jax.Array, rank: int) -> jax.Array:
    """Gaussian sketch to O(rank) columns, then PCA.

    The sketch matrix is a fixed function of (M, width) — deterministic
    across steps, so the feature basis is stable between refreshes — and the
    downstream eigendecomposition works on a (K, width) matrix whose width
    is independent of d_model.
    """
    A = A.reshape(A.shape[0], -1).astype(jnp.float32)
    M = A.shape[1]
    width = min(M, max(4 * rank, rank + 8))
    if M > width:
        S = jax.random.normal(jax.random.PRNGKey(_SKETCH_SEED),
                              (M, width), dtype=jnp.float32)
        A = A @ (S / jnp.sqrt(jnp.float32(width)))
    return features_lib.pca_features(A, rank)


def pooled_raw_features(A: jax.Array, rank: int) -> jax.Array:
    """Raw pooled matrix, columns energy-ordered and truncated to ``rank``.

    No factorization — the relevance ordering precondition is approximated
    by descending column energy. Zero-pads when the source has fewer than
    ``rank`` columns so downstream shapes stay static.
    """
    A = A.reshape(A.shape[0], -1).astype(jnp.float32)
    K, M = A.shape
    cols = min(rank, M)
    energy = jnp.sum(A * A, axis=0)
    order = jnp.argsort(-energy)[:cols]
    V = jnp.take(A, order, axis=1)
    if cols < rank:
        V = jnp.concatenate(
            [V, jnp.zeros((K, rank - cols), jnp.float32)], axis=1)
    return V


SVD = register_features(FeatureExtractor("svd", features_lib.svd_features))
SKETCH_SVD = register_features(
    FeatureExtractor("sketch_svd", features_lib.sketch_svd_features))
PCA_SKETCH = register_features(FeatureExtractor("pca_sketch", pca_sketch_features))
POOLED_RAW = register_features(FeatureExtractor("pooled_raw", pooled_raw_features))
ICA = register_features(FeatureExtractor("ica", features_lib.ica_features))


# ---------------------------------------------------------------------------
# built-in gradient sources
# ---------------------------------------------------------------------------

def probe_grad_source(inp: GradSourceInputs) -> jax.Array:
    """Probe-gradient surrogate from the softmax error signal (no backward):
    loss-scaled, error-norm-weighted pooled hiddens over LABELED positions.
    See ``core/grad_features.py:logit_error_embeddings``."""
    return logit_error_embeddings(inp.logits, inp.labels, inp.hiddens,
                                  mask=inp.mask)


def logit_embed_grad_source(inp: GradSourceInputs) -> jax.Array:
    """Exact per-example gradient of the probe CE w.r.t. the head input,
    ``Wᵀ(p − y)`` averaged over LABELED probe positions — one extra matmul
    with the unembedding, still no backward pass. Returns (K, d_model)."""
    mcfg, params = inp.mcfg, inp.params
    if mcfg is not None and getattr(mcfg, "tie_embeddings", False):
        head = params["embed"].T                       # (D, V)
    elif "lm_head" in params:
        head = params["lm_head"]
    elif "embed" in params:
        head = params["embed"].T
    else:
        raise ValueError("logit_embed grad source needs an unembedding "
                         "('lm_head' or tied 'embed') in params")
    logp = jax.nn.log_softmax(inp.logits.astype(jnp.float32), axis=-1)
    p = jnp.exp(logp)
    onehot = jax.nn.one_hot(inp.labels, inp.logits.shape[-1], dtype=jnp.float32)
    err = p - onehot                                   # (K, S', V)
    if inp.mask is not None:
        m = inp.mask.astype(jnp.float32)
        err = err * m[..., None]
        count = jnp.maximum(jnp.sum(m, axis=-1, keepdims=True), 1.0)
    else:
        count = jnp.float32(err.shape[1])
    emb = jnp.einsum("ksv,dv->kd", err, head.astype(jnp.float32))
    return emb / count


def full_grad_source(inp: GradSourceInputs) -> jax.Array:
    """EXACT per-sample gradients of the WHOLE parameter pytree — Alg. 1
    without the last-layer approximation, via ``vmap(grad)`` over the raw
    batch. Returns (K, |Θ|): the oracle for small-model runs (E = |Θ| makes
    this O(K·|Θ|) memory — never the production path)."""
    from repro.models import model as model_lib

    def one_example_loss(params, example):
        b = jax.tree_util.tree_map(lambda x: x[None], example)
        loss, _ = model_lib.loss_fn(inp.mcfg, params, b)
        return loss

    G, _ = per_sample_grads_full(one_example_loss, inp.params, inp.batch)
    return G.T                                         # (K, |Θ|) f32


def _param_count(mcfg: Any, params: Any) -> int:
    import math
    return sum(math.prod(leaf.shape)
               for leaf in jax.tree_util.tree_leaves(params))


PROBE = register_grad_source(GradSource("probe", probe_grad_source))
LOGIT_EMBED = register_grad_source(
    GradSource("logit_embed", logit_embed_grad_source, needs_params=True))
FULL = register_grad_source(
    GradSource("full", full_grad_source, needs_params=True, needs_batch=True,
               embed_dim_of=_param_count))
