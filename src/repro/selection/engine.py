"""Selection execution engines: single-batch, vmapped multi-batch, and
shard_map data-parallel.

Three ways to run one sampler (all speak the Sampler-v2 protocol: every
path threads the sampler's *carry* — its cross-step state pytree — and
returns ``(SelectionState, carry')``; stateless samplers carry ``{}``
untouched, so their numerics are bit-identical to the pre-v2 engine):

  * :func:`select_batch` — one (K, R_max) batch on one device (the seed
    repo's only path, now sampler-generic).
  * :func:`select_multi_batch` — a stack of B per-device microbatches
    selected under ONE jit via vmap; a stateful sampler's carry gets a
    leading B axis (B independent streams).
  * :func:`make_sharded_selector` — selection over the data-parallel mesh
    axes. V/G are sharded along K by the ``act_batch`` logical rule from
    ``distributed/sharding.py``; each shard runs the sampler on its local
    rows. For GRAFT (the default) the prefix projection-error statistics
    are psum'd so every shard applies the same globally-decided rank R*;
    generic samplers run shard-locally against the pmean'd global ḡ, and a
    stateful carry is kept replicated by cross-shard averaging after each
    update.

Engines cache one jitted callable per (cfg, sampler) pair, so repeated calls
from a training loop never re-trace. ``carry=None`` means "initialize a
fresh carry from the input shapes" — one-shot call sites never have to
touch :meth:`Sampler.init_carry` themselves.
"""
from __future__ import annotations

import functools
from typing import Optional, Union

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map
from repro.core import projection as proj_lib
from repro.distributed import sharding as sh
from repro.selection import graft as graft_lib
from repro.selection import registry
from repro.selection.base import (Carry, CarrySpec, GraftConfig, Sampler,
                                  SelectionInputs, SelectionState,
                                  default_select_key)

SamplerLike = Union[str, Sampler]


# shared step-folded derivation — kept under the old name for engine-internal
# call sites
_default_key = default_select_key


def _resolve(cfg: GraftConfig, sampler: SamplerLike, scores) -> Sampler:
    smp = registry.get_sampler(sampler)
    if smp.needs_scores and scores is None:
        # same actionable error as Sampler.select: the engine auto-derives a
        # key for stochastic samplers but NEVER invents scores
        raise ValueError(
            f"sampler '{smp.name}' requires SelectionInputs.scores — "
            f"pass scores=... (engine paths fill defaults only for "
            f"samplers that do not declare needs_scores)")
    return smp


def _fresh_carry(smp: Sampler, cfg: GraftConfig, V: jax.Array,
                 G: jax.Array) -> Carry:
    return smp.init_carry(cfg, CarrySpec(batch_size=int(V.shape[-2]),
                                         grad_dim=int(G.shape[-2])))


# ---------------------------------------------------------------------------
# single batch
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=64)
def _single_batch_compiled(cfg: GraftConfig, smp: Sampler):
    # keyed on the Sampler VALUE (frozen dataclass), not its name, so a
    # re-registration under the same name gets its own compiled entry
    def fn(V, G, g_bar, scores, key, carry, step):
        return smp.select(cfg, SelectionInputs(V, G, g_bar, scores, key),
                          carry, step)

    return jax.jit(fn)


def select_batch(cfg: GraftConfig, sampler: SamplerLike, V: jax.Array,
                 G: jax.Array, g_bar: jax.Array, *,
                 scores: Optional[jax.Array] = None,
                 key: Optional[jax.Array] = None,
                 carry: Carry = None, step=0):
    """Run ``sampler`` on one (K, R_max) batch. Registry-resolved, jit-cached.

    Returns ``(SelectionState, carry')``; feed ``carry'`` back in to stream
    across calls (stateless samplers return ``{}`` unchanged).
    """
    smp = _resolve(cfg, sampler, scores)
    if scores is None:
        scores = jnp.zeros((V.shape[0],), jnp.float32)
    if key is None:
        key = _default_key(step)
    if carry is None:
        carry = _fresh_carry(smp, cfg, V, G)
    return _single_batch_compiled(cfg, smp)(
        V, G, g_bar, scores, key, carry, jnp.int32(step))


# ---------------------------------------------------------------------------
# vmapped multi-batch
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=64)
def _multi_batch_compiled(cfg: GraftConfig, smp: Sampler):
    if cfg.use_pallas and smp.fn is graft_lib.graft_sampler_fn:
        # vmap over a grid=() pallas_call has no Mosaic lowering — the GRAFT
        # fast path dispatches the whole stack as ONE grid=(B,) fused launch
        def fn(V, G, g_bar, scores, keys, carry, step):
            return graft_lib.graft_select_batched(cfg, V, G, g_bar, step), carry
        return jax.jit(fn)

    def fn(V, G, g_bar, scores, keys, carry, step):
        def one(v, g, gb, sc, k, c):
            return smp.select(cfg, SelectionInputs(v, g, gb, sc, k), c, step)
        return jax.vmap(one)(V, G, g_bar, scores, keys, carry)

    return jax.jit(fn)


def select_multi_batch(cfg: GraftConfig, sampler: SamplerLike, V: jax.Array,
                       G: jax.Array, g_bar: jax.Array, *,
                       scores: Optional[jax.Array] = None,
                       keys: Optional[jax.Array] = None,
                       carry: Carry = None, step=0):
    """Select for a STACK of microbatches under one jit.

    ``V``: (B, K, R_max); ``G``: (B, d, K); ``g_bar``: (B, d); optional
    ``scores``: (B, K) and ``keys``: (B, 2) per-microbatch PRNG keys.
    Returns ``(SelectionState, carry')`` whose leaves carry a leading B
    axis — semantically identical to a Python loop of :func:`select_batch`
    calls, but compiled once and batched on-device. A stateful sampler's
    carry is B-stacked: each microbatch lane streams independently
    (``carry=None`` broadcasts one fresh carry across the stack).
    """
    smp = _resolve(cfg, sampler, scores)
    B = V.shape[0]
    if scores is None:
        scores = jnp.zeros(V.shape[:2], jnp.float32)
    if keys is None:
        keys = jax.random.split(_default_key(step), B)
    if carry is None:
        carry = jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x, (B,) + x.shape),
            _fresh_carry(smp, cfg, V, G))
    return _multi_batch_compiled(cfg, smp)(
        V, G, g_bar, scores, keys, carry, jnp.int32(step))


# ---------------------------------------------------------------------------
# shard_map data-parallel selection
# ---------------------------------------------------------------------------

def _as_mesh(mesh_or_backend) -> Mesh:
    """Accept a ``repro.backend.Backend`` anywhere a mesh is expected —
    callers holding a backend shouldn't have to know it owns a mesh."""
    if isinstance(mesh_or_backend, Mesh):
        return mesh_or_backend
    getter = getattr(mesh_or_backend, "mesh", None)
    return getter() if callable(getter) else mesh_or_backend


def _batch_axes(mesh: Mesh, batch_logical: str, rules):
    """Mesh axis names the logical rule table maps ``batch_logical`` to."""
    entry = tuple(sh.logical_to_spec((batch_logical,), mesh, rules))[0]
    if entry is None:
        raise ValueError(
            f"logical axis '{batch_logical}' maps to no axis of mesh "
            f"{mesh.axis_names}; nothing to shard selection over")
    axes = (entry,) if isinstance(entry, str) else tuple(entry)
    return entry, axes


def make_sharded_selector(cfg: GraftConfig, mesh: Mesh, *,
                          sampler: SamplerLike = "graft",
                          batch_logical: str = "act_batch", rules=None):
    """Build (or fetch the cached) jitted data-parallel selector.

    Returns ``fn(V, G, step=0, *, scores=None, carry=None) ->
    (SelectionState, carry')`` where V (K, R_max) and G (d, K) are sharded
    along K over the mesh axes assigned to ``batch_logical`` (n_shards
    ways). Per shard: the sampler runs on the local K/n rows against the
    pmean'd global ḡ. For GRAFT (the default) the prefix projection errors
    are additionally pmean'd so the rank decision R* is identical on every
    shard. The returned state concatenates the shards — pivots/weights have
    shape (n_shards·R_max,) with GLOBAL row indices and weights summing to
    1 over the active entries; ``rank`` is the per-shard R*. A stateful
    carry stays replicated: every shard's update is averaged (float leaves)
    or pmax'd (integer leaves) across the mesh.
    """
    smp = registry.get_sampler(sampler)
    rules_key = tuple(sorted(rules.items())) if rules else None
    return _sharded_selector_cached(cfg, smp, _as_mesh(mesh), batch_logical,
                                    rules_key)


@functools.lru_cache(maxsize=64)
def _sharded_selector_cached(cfg: GraftConfig, smp: Sampler, mesh: Mesh,
                             batch_logical: str, rules_key):
    rules = dict(rules_key) if rules_key else None
    entry, axes = _batch_axes(mesh, batch_logical, rules)
    n_shards = 1
    for a in axes:
        n_shards *= mesh.shape[a]
    r_max = cfg.r_max

    def _shard_index(K_local):
        shard = jnp.int32(0)
        for a in axes:              # global shard index, first axis major
            shard = shard * mesh.shape[a] + jax.lax.axis_index(a)
        return shard

    def _sync_carry(carry):
        # keep the carry replicated across the mesh: shard-local updates are
        # averaged (float leaves) / pmax'd (integer leaves) — stateless {}
        # passes through untouched
        def sync(leaf):
            if jnp.issubdtype(leaf.dtype, jnp.inexact):
                return jax.lax.pmean(leaf, axes)
            return jax.lax.pmax(leaf, axes)
        return jax.tree_util.tree_map(sync, carry)

    if smp.fn is graft_lib.graft_sampler_fn:
        # the specialized GRAFT path: globally-synchronized rank decision,
        # bit-identical to the pre-v2 sharded selector
        def shard_fn(V_s, G_s, scores_s, carry, step):
            K_local = V_s.shape[0]
            g_bar = jax.lax.pmean(jnp.mean(G_s, axis=1), axes)      # global ḡ
            # local refresh: ONE fused Pallas dispatch under cfg.use_pallas,
            # else the jnp chain — then the error statistics are pmean'd so
            # the rank decision R* is identical on every shard
            pivots, local_errors, G_sel = graft_lib.pivot_and_sweep(
                cfg, V_s, G_s, g_bar)
            errors = jax.lax.pmean(local_errors, axes)
            rank, err = proj_lib.select_rank(errors, cfg.rset, cfg.eps)
            active = (jnp.arange(r_max) < rank).astype(jnp.float32)
            weights = active / jnp.maximum(n_shards * jnp.sum(active), 1.0)
            g_sub = jax.lax.psum(G_sel @ weights, axes)     # global subset ḡ
            align = proj_lib.cosine_alignment(g_sub, g_bar)
            pivots_global = pivots + _shard_index(K_local) * K_local
            state = SelectionState(pivots=pivots_global.astype(jnp.int32),
                                   weights=weights, rank=rank, last_error=err,
                                   alignment=align, step=jnp.int32(step))
            return state, carry
    else:
        def shard_fn(V_s, G_s, scores_s, carry, step):
            K_local = V_s.shape[0]
            g_bar = jax.lax.pmean(jnp.mean(G_s, axis=1), axes)      # global ḡ
            shard = _shard_index(K_local)
            # per-shard key so stochastic samplers draw independent rows
            key = jax.random.fold_in(_default_key(step), shard)
            state, carry = smp.select(
                cfg, SelectionInputs(V_s, G_s, g_bar, scores_s, key),
                carry, step)
            # local weights sum to 1 → global sum 1 across n_shards
            weights = state.weights / n_shards
            state = SelectionState(
                pivots=(state.pivots + shard * K_local).astype(jnp.int32),
                weights=weights,
                rank=jax.lax.pmax(state.rank, axes),
                last_error=jax.lax.pmean(state.last_error, axes),
                alignment=jax.lax.pmean(state.alignment, axes),
                step=jnp.int32(step))
            return state, _sync_carry(carry)

    # check_rep=False: the scan/fori_loop bodies inside MaxVol and the MGS
    # sweep defeat shard_map's conservative replication inference even though
    # every P() output is pmean/psum-replicated by construction.
    fn = shard_map(shard_fn, mesh=mesh,
                   in_specs=(P(entry, None), P(None, entry), P(entry),
                             P(), P()),
                   out_specs=(SelectionState(P(entry), P(entry), P(),
                                             P(), P(), P()),
                              P()),
                   check_rep=False)
    jitted = jax.jit(fn)

    def selector(V, G, step=0, *, scores=None, carry=None):
        if smp.needs_scores and scores is None:
            raise ValueError(
                f"sampler '{smp.name}' requires SelectionInputs.scores — "
                f"pass scores=... (engine paths fill defaults only for "
                f"samplers that do not declare needs_scores)")
        if scores is None:
            scores = jnp.zeros((V.shape[0],), jnp.float32)
        if carry is None:
            carry = _fresh_carry(smp, cfg, V, G)
        return jitted(V, G, scores, carry, jnp.int32(step))

    return selector


def select_sharded(cfg: GraftConfig, mesh: Mesh, V: jax.Array, G: jax.Array,
                   *, sampler: SamplerLike = "graft",
                   scores: Optional[jax.Array] = None, carry: Carry = None,
                   step=0, batch_logical: str = "act_batch", rules=None):
    """One-shot convenience over :func:`make_sharded_selector`."""
    mesh = _as_mesh(mesh)
    _, axes = _batch_axes(mesh, batch_logical, rules)
    n_shards = 1
    for a in axes:
        n_shards *= mesh.shape[a]
    K = V.shape[0]
    if K % n_shards:
        raise ValueError(f"batch {K} not divisible by {n_shards} shards")
    if K // n_shards < cfg.r_max:
        raise ValueError(f"per-shard batch {K // n_shards} < r_max {cfg.r_max}")
    return make_sharded_selector(cfg, mesh, sampler=sampler,
                                 batch_logical=batch_logical,
                                 rules=rules)(V, G, step,
                                              scores=scores, carry=carry)
