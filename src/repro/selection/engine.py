"""Selection execution engines: single-batch, vmapped multi-batch, and
shard_map data-parallel.

Three ways to run one sampler:

  * :func:`select_batch` — one (K, R_max) batch on one device (the seed
    repo's only path, now sampler-generic).
  * :func:`select_multi_batch` — a stack of B per-device microbatches
    selected under ONE jit via vmap: compile once, select everywhere.
  * :func:`make_sharded_selector` — GRAFT over the data-parallel mesh axes.
    V/G are sharded along K by the ``act_batch`` logical rule from
    ``distributed/sharding.py``; each shard runs Fast MaxVol on its local
    rows and the prefix projection-error statistics are psum'd so every
    shard applies the same globally-decided rank R*.

Engines cache one jitted callable per (cfg, sampler) pair, so repeated calls
from a training loop never re-trace.
"""
from __future__ import annotations

import functools
from typing import Optional, Union

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import shard_map
from repro.core import projection as proj_lib
from repro.distributed import sharding as sh
from repro.selection import graft as graft_lib
from repro.selection import registry
from repro.selection.base import (GraftConfig, Sampler, SelectionInputs,
                                  SelectionState, default_select_key)

SamplerLike = Union[str, Sampler]


# shared step-folded derivation — kept under the old name for engine-internal
# call sites
_default_key = default_select_key


def _resolve(cfg: GraftConfig, sampler: SamplerLike, scores) -> Sampler:
    smp = registry.get_sampler(sampler)
    if smp.needs_scores and scores is None:
        raise ValueError(f"sampler '{smp.name}' requires per-sample scores")
    return smp


# ---------------------------------------------------------------------------
# single batch
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=64)
def _single_batch_compiled(cfg: GraftConfig, smp: Sampler):
    # keyed on the Sampler VALUE (frozen dataclass), not its name, so a
    # re-registration under the same name gets its own compiled entry
    def fn(V, G, g_bar, scores, key, step):
        return smp.fn(cfg, SelectionInputs(V, G, g_bar, scores, key), step)

    return jax.jit(fn)


def select_batch(cfg: GraftConfig, sampler: SamplerLike, V: jax.Array,
                 G: jax.Array, g_bar: jax.Array, *,
                 scores: Optional[jax.Array] = None,
                 key: Optional[jax.Array] = None, step=0) -> SelectionState:
    """Run ``sampler`` on one (K, R_max) batch. Registry-resolved, jit-cached."""
    smp = _resolve(cfg, sampler, scores)
    if scores is None:
        scores = jnp.zeros((V.shape[0],), jnp.float32)
    if key is None:
        key = _default_key(step)
    return _single_batch_compiled(cfg, smp)(
        V, G, g_bar, scores, key, jnp.int32(step))


# ---------------------------------------------------------------------------
# vmapped multi-batch
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=64)
def _multi_batch_compiled(cfg: GraftConfig, smp: Sampler):
    if cfg.use_pallas and smp.fn is graft_lib.graft_sampler_fn:
        # vmap over a grid=() pallas_call has no Mosaic lowering — the GRAFT
        # fast path dispatches the whole stack as ONE grid=(B,) fused launch
        def fn(V, G, g_bar, scores, keys, step):
            return graft_lib.graft_select_batched(cfg, V, G, g_bar, step)
        return jax.jit(fn)

    def fn(V, G, g_bar, scores, keys, step):
        def one(v, g, gb, sc, k):
            return smp.fn(cfg, SelectionInputs(v, g, gb, sc, k), step)
        return jax.vmap(one)(V, G, g_bar, scores, keys)

    return jax.jit(fn)


def select_multi_batch(cfg: GraftConfig, sampler: SamplerLike, V: jax.Array,
                       G: jax.Array, g_bar: jax.Array, *,
                       scores: Optional[jax.Array] = None,
                       keys: Optional[jax.Array] = None,
                       step=0) -> SelectionState:
    """Select for a STACK of microbatches under one jit.

    ``V``: (B, K, R_max); ``G``: (B, d, K); ``g_bar``: (B, d); optional
    ``scores``: (B, K) and ``keys``: (B, 2) per-microbatch PRNG keys.
    Returns a :class:`SelectionState` whose fields carry a leading B axis —
    semantically identical to a Python loop of :func:`select_batch` calls,
    but compiled once and batched on-device.
    """
    smp = _resolve(cfg, sampler, scores)
    B = V.shape[0]
    if scores is None:
        scores = jnp.zeros(V.shape[:2], jnp.float32)
    if keys is None:
        keys = jax.random.split(_default_key(step), B)
    return _multi_batch_compiled(cfg, smp)(
        V, G, g_bar, scores, keys, jnp.int32(step))


# ---------------------------------------------------------------------------
# shard_map data-parallel GRAFT
# ---------------------------------------------------------------------------

def _batch_axes(mesh: Mesh, batch_logical: str, rules):
    """Mesh axis names the logical rule table maps ``batch_logical`` to."""
    entry = tuple(sh.logical_to_spec((batch_logical,), mesh, rules))[0]
    if entry is None:
        raise ValueError(
            f"logical axis '{batch_logical}' maps to no axis of mesh "
            f"{mesh.axis_names}; nothing to shard selection over")
    axes = (entry,) if isinstance(entry, str) else tuple(entry)
    return entry, axes


def make_sharded_selector(cfg: GraftConfig, mesh: Mesh, *,
                          batch_logical: str = "act_batch", rules=None):
    """Build (or fetch the cached) jitted data-parallel GRAFT selector.

    Returns ``fn(V, G, step) -> SelectionState`` where V (K, R_max) and
    G (d, K) are sharded along K over the mesh axes assigned to
    ``batch_logical`` (n_shards ways). Per shard: Fast MaxVol on the local
    K/n rows. Globally: ḡ and the prefix projection errors are averaged by
    psum so the rank decision R* is identical on every shard. The returned
    state concatenates the shards — pivots/weights have shape
    (n_shards·R_max,) with GLOBAL row indices and weights summing to 1 over
    the n_shards·R* active entries; ``rank`` is the per-shard R*.
    """
    rules_key = tuple(sorted(rules.items())) if rules else None
    return _sharded_selector_cached(cfg, mesh, batch_logical, rules_key)


@functools.lru_cache(maxsize=64)
def _sharded_selector_cached(cfg: GraftConfig, mesh: Mesh,
                             batch_logical: str, rules_key):
    rules = dict(rules_key) if rules_key else None
    entry, axes = _batch_axes(mesh, batch_logical, rules)
    n_shards = 1
    for a in axes:
        n_shards *= mesh.shape[a]
    r_max = cfg.r_max

    def shard_fn(V_s, G_s, step):
        K_local = V_s.shape[0]
        g_bar = jax.lax.pmean(jnp.mean(G_s, axis=1), axes)          # global ḡ
        # local refresh: ONE fused Pallas dispatch under cfg.use_pallas,
        # else the jnp chain — then the error statistics are pmean'd so the
        # rank decision R* is identical on every shard
        pivots, local_errors, G_sel = graft_lib.pivot_and_sweep(
            cfg, V_s, G_s, g_bar)
        errors = jax.lax.pmean(local_errors, axes)
        rank, err = proj_lib.select_rank(errors, cfg.rset, cfg.eps)
        active = (jnp.arange(r_max) < rank).astype(jnp.float32)
        weights = active / jnp.maximum(n_shards * jnp.sum(active), 1.0)
        g_sub = jax.lax.psum(G_sel @ weights, axes)                 # global subset ḡ
        align = proj_lib.cosine_alignment(g_sub, g_bar)
        shard = jnp.int32(0)
        for a in axes:              # global shard index, first axis major
            shard = shard * mesh.shape[a] + jax.lax.axis_index(a)
        pivots_global = pivots + shard * K_local
        return SelectionState(pivots=pivots_global.astype(jnp.int32),
                              weights=weights, rank=rank, last_error=err,
                              alignment=align, step=jnp.int32(step))

    # check_rep=False: the scan/fori_loop bodies inside MaxVol and the MGS
    # sweep defeat shard_map's conservative replication inference even though
    # every P() output is pmean/psum-replicated by construction.
    fn = shard_map(shard_fn, mesh=mesh,
                   in_specs=(P(entry, None), P(None, entry), P()),
                   out_specs=SelectionState(P(entry), P(entry), P(),
                                            P(), P(), P()),
                   check_rep=False)
    return jax.jit(fn)


def select_sharded(cfg: GraftConfig, mesh: Mesh, V: jax.Array, G: jax.Array,
                   *, step=0, batch_logical: str = "act_batch",
                   rules=None) -> SelectionState:
    """One-shot convenience over :func:`make_sharded_selector`."""
    _, axes = _batch_axes(mesh, batch_logical, rules)
    n_shards = 1
    for a in axes:
        n_shards *= mesh.shape[a]
    K = V.shape[0]
    if K % n_shards:
        raise ValueError(f"batch {K} not divisible by {n_shards} shards")
    if K // n_shards < cfg.r_max:
        raise ValueError(f"per-shard batch {K // n_shards} < r_max {cfg.r_max}")
    return make_sharded_selector(cfg, mesh, batch_logical=batch_logical,
                                 rules=rules)(V, G, jnp.int32(step))
