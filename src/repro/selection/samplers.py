"""Default sampler implementations behind the registry.

``graft`` is the paper's method (dynamic rank R* ≤ R_max); everything else
selects a fixed R_max-sample subset so fraction sweeps are apples-to-apples:

  * ``random``      — uniform R-of-K (needs ``inputs.key``)
  * ``loss_topk``   — highest per-sample score/loss (needs ``inputs.scores``)
  * ``full``        — first R_max samples (with R_max = K: no selection)
  * ``el2n``        — largest gradient-embedding norm
  * ``gradmatch``   — OMP matching of the mean gradient (weights re-normalized
                      to sum 1 for training use; raw OMP fit in baselines.py)
  * ``craig``       — facility-location greedy, cluster-share weights
  * ``glister``     — one-step validation-gain greedy (ḡ as the val gradient)

All return a :class:`SelectionState` with diagnostics filled by
``finalize_state`` so telemetry (rank / proj_error / alignment) is comparable
across strategies.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

import repro.core.baselines as baselines_lib
from repro.selection.base import (GraftConfig, Sampler, SelectionInputs,
                                  SelectionState, default_select_key,
                                  finalize_state)
from repro.selection.graft import graft_sampler_fn
from repro.selection.registry import register


def _key_for(inputs: SelectionInputs, step: jax.Array) -> jax.Array:
    if inputs.key is not None:
        return inputs.key
    return default_select_key(step)


def _uniform_weights(r_max: int) -> jax.Array:
    return jnp.full((r_max,), 1.0 / r_max, dtype=jnp.float32)


def random_fn(cfg: GraftConfig, inputs: SelectionInputs,
              step: jax.Array) -> SelectionState:
    K = inputs.V.shape[0]
    pivots, weights = baselines_lib.random_subset(_key_for(inputs, step),
                                                  K, cfg.r_max)
    return finalize_state(cfg, pivots, weights, cfg.r_max,
                          inputs.G, inputs.g_bar, step)


def loss_topk_fn(cfg: GraftConfig, inputs: SelectionInputs,
                 step: jax.Array) -> SelectionState:
    pivots = jnp.argsort(-inputs.scores)[:cfg.r_max].astype(jnp.int32)
    return finalize_state(cfg, pivots, _uniform_weights(cfg.r_max),
                          cfg.r_max, inputs.G, inputs.g_bar, step)


def full_fn(cfg: GraftConfig, inputs: SelectionInputs,
            step: jax.Array) -> SelectionState:
    pivots = jnp.arange(cfg.r_max, dtype=jnp.int32)
    return finalize_state(cfg, pivots, _uniform_weights(cfg.r_max),
                          cfg.r_max, inputs.G, inputs.g_bar, step)


def el2n_fn(cfg: GraftConfig, inputs: SelectionInputs,
            step: jax.Array) -> SelectionState:
    pivots, weights = baselines_lib.el2n_topk(inputs.G, cfg.r_max)
    return finalize_state(cfg, pivots, weights, cfg.r_max,
                          inputs.G, inputs.g_bar, step)


def gradmatch_fn(cfg: GraftConfig, inputs: SelectionInputs,
                 step: jax.Array) -> SelectionState:
    pivots, w = baselines_lib.gradmatch_omp(inputs.G, inputs.g_bar, cfg.r_max)
    total = jnp.sum(w)
    weights = jnp.where(total > 1e-12, w / jnp.maximum(total, 1e-12),
                        _uniform_weights(cfg.r_max))
    return finalize_state(cfg, pivots, weights, cfg.r_max,
                          inputs.G, inputs.g_bar, step)


def craig_fn(cfg: GraftConfig, inputs: SelectionInputs,
             step: jax.Array) -> SelectionState:
    pivots, weights = baselines_lib.craig_greedy(inputs.G, cfg.r_max)
    return finalize_state(cfg, pivots, weights, cfg.r_max,
                          inputs.G, inputs.g_bar, step)


def glister_fn(cfg: GraftConfig, inputs: SelectionInputs,
               step: jax.Array) -> SelectionState:
    pivots, weights = baselines_lib.glister_greedy(inputs.G, inputs.g_bar,
                                                   cfg.r_max)
    return finalize_state(cfg, pivots, weights, cfg.r_max,
                          inputs.G, inputs.g_bar, step)


GRAFT = register(Sampler("graft", graft_sampler_fn))
RANDOM = register(Sampler("random", random_fn, needs_key=True))
LOSS_TOPK = register(Sampler("loss_topk", loss_topk_fn, needs_scores=True))
FULL = register(Sampler("full", full_fn))
# el2n ranks loss-scaled gradient-embedding norms: score-less inputs mean the
# probe forward that scales G was skipped upstream, so the ranking would be
# silently wrong — declare the dependency and fail loudly instead
EL2N = register(Sampler("el2n", el2n_fn, needs_scores=True))
GRADMATCH = register(Sampler("gradmatch", gradmatch_fn))
CRAIG = register(Sampler("craig", craig_fn))
GLISTER = register(Sampler("glister", glister_fn))

# the streaming sketch sampler lives in its own module; importing it here
# keeps "import repro.selection.samplers" sufficient to populate the registry
from repro.selection import streaming as _streaming  # noqa: E402,F401
