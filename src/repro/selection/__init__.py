"""Subset-selection subsystem: sampler registry + execution engines.

Quick tour::

    from repro.selection import engine, registry
    from repro.selection.base import GraftConfig

    cfg = GraftConfig(rset=(4, 8, 16))
    state, carry = engine.select_batch(cfg, "graft", V, G, g_bar)  # one batch
    states, cs = engine.select_multi_batch(cfg, "graft", Vs, Gs, gbs)  # vmapped
    state, carry = engine.select_sharded(cfg, mesh, V, G)          # shard_map DP

Every engine path speaks the Sampler-v2 protocol — ``(SelectionState,
carry)`` pairs, where the carry is the sampler's cross-step state (``{}``
for stateless strategies, the sketch reservoir for ``streaming_graft``).
``registry.available()`` lists samplers; add your own with
``registry.register(Sampler(name, fn))``.

The selection *inputs* are pluggable too: ``sources.resolve_features`` /
``sources.resolve_grad_source`` pick the feature path (``svd`` |
``pca_sketch`` | ``pooled_raw``) and gradient-embedding path (``probe`` |
``logit_embed``) by the names in ``GraftConfig.feature_mode`` /
``GraftConfig.grad_mode``.
"""
from repro.selection import samplers as _samplers  # noqa: F401 (registers defaults)
from repro.selection import sources, streaming
from repro.selection.base import (Carry, CarrySpec, GraftConfig, Sampler,
                                  SamplerConfig, SelectionInputs,
                                  SelectionState, init_state)
from repro.selection.engine import (make_sharded_selector, select_batch,
                                    select_multi_batch, select_sharded)
from repro.selection.graft import (GraftState, graft_select,
                                   graft_select_batched, maybe_refresh,
                                   select_from_batch)
from repro.selection.overlap import OverlappedSelector
from repro.selection.registry import available, get_sampler, register
from repro.selection.sources import (FeatureExtractor, GradSource,
                                     GradSourceInputs, available_features,
                                     available_grad_sources,
                                     register_features, register_grad_source,
                                     resolve_features, resolve_grad_source)

__all__ = [
    "GraftConfig", "SamplerConfig", "Sampler", "SelectionInputs",
    "SelectionState", "GraftState", "Carry", "CarrySpec", "init_state",
    "streaming",
    "graft_select", "graft_select_batched", "maybe_refresh",
    "select_from_batch",
    "select_batch", "select_multi_batch", "select_sharded",
    "make_sharded_selector", "OverlappedSelector",
    "available", "get_sampler", "register",
    "sources", "FeatureExtractor", "GradSource", "GradSourceInputs",
    "resolve_features", "resolve_grad_source", "register_features",
    "register_grad_source", "available_features", "available_grad_sources",
]
