"""Subset-selection subsystem: sampler registry + execution engines.

Quick tour::

    from repro.selection import engine, registry
    from repro.selection.base import GraftConfig

    cfg = GraftConfig(rset=(4, 8, 16))
    state = engine.select_batch(cfg, "graft", V, G, g_bar)       # one batch
    states = engine.select_multi_batch(cfg, "graft", Vs, Gs, gbs)  # vmapped
    state = engine.select_sharded(cfg, mesh, V, G)               # shard_map DP

``registry.available()`` lists samplers; add your own with
``registry.register(Sampler(name, fn))``.
"""
from repro.selection import samplers as _samplers  # noqa: F401 (registers defaults)
from repro.selection.base import (GraftConfig, Sampler, SamplerConfig,
                                  SelectionInputs, SelectionState, init_state)
from repro.selection.engine import (make_sharded_selector, select_batch,
                                    select_multi_batch, select_sharded)
from repro.selection.graft import (GraftState, graft_select, maybe_refresh,
                                   select_from_batch)
from repro.selection.registry import available, get_sampler, register

__all__ = [
    "GraftConfig", "SamplerConfig", "Sampler", "SelectionInputs",
    "SelectionState", "GraftState", "init_state",
    "graft_select", "maybe_refresh", "select_from_batch",
    "select_batch", "select_multi_batch", "select_sharded",
    "make_sharded_selector",
    "available", "get_sampler", "register",
]
