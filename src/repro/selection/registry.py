"""Name → Sampler registry.

A thin skin over the generic :class:`repro.registry.Registry` (shared with
the feature/grad-source and data-source registries): default samplers
register themselves when ``repro.selection`` (or
``repro.selection.samplers``) is imported; external code can add strategies
with :func:`register` and every train step / engine path picks them up by
name — no call-site changes.
"""
from __future__ import annotations

from typing import Tuple, Union

from repro.registry import Registry
from repro.selection.base import Sampler


def _load_defaults() -> None:
    # default samplers live in sibling modules; make bare-registry imports
    # (and an emptied-then-queried registry) resolve them lazily
    from repro.selection import samplers as _  # noqa: F401
    from repro.selection import streaming as _s  # noqa: F401


_REGISTRY: Registry = Registry("sampler", ensure_defaults=_load_defaults)


def register(sampler: Sampler, *, overwrite: bool = False) -> Sampler:
    return _REGISTRY.register(sampler.name, sampler, overwrite=overwrite)


def get_sampler(name_or_sampler: Union[str, Sampler]) -> Sampler:
    if isinstance(name_or_sampler, Sampler):
        return name_or_sampler
    return _REGISTRY.get(name_or_sampler)


def available() -> Tuple[str, ...]:
    return _REGISTRY.available()
