"""Name → Sampler registry.

Default samplers register themselves when ``repro.selection`` (or
``repro.selection.samplers``) is imported; external code can add strategies
with :func:`register` and every train step / engine path picks them up by
name — no call-site changes.
"""
from __future__ import annotations

from typing import Dict, Tuple, Union

from repro.selection.base import Sampler

_REGISTRY: Dict[str, Sampler] = {}


def register(sampler: Sampler, *, overwrite: bool = False) -> Sampler:
    if not overwrite and sampler.name in _REGISTRY:
        raise ValueError(f"sampler '{sampler.name}' already registered")
    _REGISTRY[sampler.name] = sampler
    return sampler


def get_sampler(name_or_sampler: Union[str, Sampler]) -> Sampler:
    if isinstance(name_or_sampler, Sampler):
        return name_or_sampler
    # default samplers live in a sibling module; make bare-registry imports work
    if not _REGISTRY:
        from repro.selection import samplers as _  # noqa: F401
    if name_or_sampler not in _REGISTRY:
        raise KeyError(f"unknown sampler '{name_or_sampler}'; "
                       f"available: {available()}")
    return _REGISTRY[name_or_sampler]


def available() -> Tuple[str, ...]:
    if not _REGISTRY:
        from repro.selection import samplers as _  # noqa: F401
    return tuple(sorted(_REGISTRY))
