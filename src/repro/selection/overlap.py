"""Selection/train overlap: hide the refresh behind the train step.

The sequential ``graft_train_step`` embeds the selection refresh in the SAME
jitted program as the subset train step (a ``lax.cond``), so a refresh step
is one long serial dispatch — features → MaxVol → rank sweep → fwd/bwd —
and every steady-state step still carries the compiled selection branch.

The :class:`OverlappedSelector` splits them into two programs and leans on
JAX async dispatch:

  * at a refresh boundary the selection forward is ENQUEUED first and the
    subset train step immediately after; the refresh result is a
    ``SelectionState`` of device futures that the train dispatch consumes
    WITHOUT any host sync, so the host keeps issuing work (while the device
    drains train steps t..t+S−1 the host is already at step t+S issuing the
    next refresh);
  * between refreshes the step program is ``subset_train_step`` alone — no
    selection branch compiled in at all.

Trajectory equivalence: the refresh consumes exactly the ``(params, batch,
step)`` triple the sequential path's ``lax.cond`` would — selection for
step ``t`` is issued at step ``t``, never from stale params — so pivots,
weights, and the loss trajectory are identical to ``graft_train_step``
(asserted step-by-step in ``tests/test_train_integration.py``). Enable it
declaratively with ``ExperimentConfig.graft.overlap = True`` (excluded from
``config_hash``: it changes the dispatch schedule, not the experiment).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.analysis.sync_guard import sync_allowed


class SideStream:
    """At-most-one-in-flight side dispatch against soon-to-be-donated
    buffers — the double-buffer discipline shared by the
    :class:`OverlappedSelector` refresh and the ``EvalCallback``'s deferred
    held-out eval.

    The rule both obey: a side computation that reads ``state['params']``
    must be ENQUEUED before the next donating train dispatch is issued.
    PjRt usage events then order the side reads ahead of the buffer reuse,
    so the side stream consumes the live params with no host copy and no
    sync. Holding at most ONE pending handle is the double buffer: a new
    ``launch`` first drains (blocks on) the previous handle, bounding both
    device memory and how far results can trail their dispatch step.
    """

    def __init__(self):
        self._tag: Any = None
        self._handle: Any = None

    @property
    def pending(self) -> bool:
        return self._handle is not None

    def launch(self, tag: Any, handle: Any) -> Optional[Tuple[Any, Any]]:
        """Register a freshly-dispatched handle; returns the drained
        ``(tag, handle)`` of the previous launch (or ``None``)."""
        prev = self.drain()
        self._tag, self._handle = tag, handle
        return prev

    def drain(self, block: bool = True) -> Optional[Tuple[Any, Any]]:
        """Hand back the pending ``(tag, handle)``, blocking until its
        device work is done (it almost always already is — a full
        inter-boundary window of train steps has been dispatched since)."""
        if self._handle is None:
            return None
        tag, handle = self._tag, self._handle
        self._tag = self._handle = None
        if block:
            with sync_allowed("side_stream"):
                jax.block_until_ready(handle)              # lint: allow
        return tag, handle


class OverlappedSelector:
    """Refresh scheduler over host-side step control.

    ``step(state, batch, step)`` takes the HOST step index (the trainer's
    loop variable, which mirrors ``state['step']``) so refresh scheduling
    never syncs on the device.
    """

    def __init__(self, mcfg, tcfg, donate: bool = True):
        # lazy import: launch.steps imports repro.selection at module scope
        from repro.launch import steps as steps_lib
        if not tcfg.use_graft:
            raise ValueError("OverlappedSelector requires TrainConfig.graft")
        self.refresh_every = tcfg.graft.refresh_every
        self._refresh = jax.jit(steps_lib.make_selection_refresh(mcfg, tcfg))
        # make_train_step (not subset_train_step directly) so the divergence
        # sentinel wraps this path exactly like the sequential one
        self._train = jax.jit(
            steps_lib.make_train_step(mcfg, tcfg, kind="subset"),
            donate_argnums=(0,) if donate else ())

    def step(self, state: Dict[str, Any], batch,
             step: int) -> Tuple[Dict[str, Any], Dict[str, Any]]:
        """One train step; refreshes the subset first when ``step`` is a
        refresh boundary. Returns ``(new_state, metrics)`` with the same
        metrics keys as ``graft_train_step``."""
        if step % self.refresh_every == 0:
            # enqueue the refresh and move on: the result is a bundle of
            # device futures the train dispatch consumes without host sync.
            # PjRt usage events order it before the donated train step, so
            # the donation of state['params'] cannot clobber its inputs.
            # The sampler carry rides the same dispatch: refreshed here,
            # passed through the subset train step untouched (linear
            # state_t → state_t+1 aliasing, same as params).
            sel, carry = self._refresh(
                state["params"], batch, state.get("sampler_carry", {}),
                jnp.int32(step))
            state = dict(state, graft=sel)
            if "sampler_carry" in state:
                state["sampler_carry"] = carry
        new_state, metrics = self._train(state, batch)
        g = new_state["graft"]
        return new_state, dict(metrics, rank=g.rank, proj_error=g.last_error,
                               alignment=g.alignment)


__all__ = ["OverlappedSelector", "SideStream"]
