"""Sampler protocol + shared state for the selection engine.

Every subset sampler (GRAFT, random, loss-topk, the coreset baselines, the
streaming sketch sampler) implements one v2 signature —

    ``select(cfg, inputs, carry, step) -> (SelectionState, Carry)``

so the train step, the vmapped multi-batch path and the shard_map
data-parallel path in ``engine.py`` are sampler-agnostic. The *carry* is
the sampler's cross-step state: an arbitrary pytree created once by
``init_carry(cfg, spec)``, threaded through every ``select`` call, stored
in the train state, and checkpointed with it — it is the ONLY sanctioned
state channel (samplers must not close over mutable Python state; the
analysis suite enforces this). Stateless samplers carry the empty pytree
``{}`` and return it unchanged, so the legacy per-batch strategies are
bit-identical under v2.

The config object is the paper's ``GraftConfig``: non-GRAFT samplers read
only ``r_max`` (subset size budget) and ``use_pallas`` from it, so one
config drives every strategy in a sweep.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class GraftConfig:
    """Static selection hyper-parameters (hashable; safe as a jit static arg)."""
    rset: Tuple[int, ...] = (8, 16, 32, 64)   # candidate ranks, ascending
    eps: float = 0.25                          # projection-error threshold
    refresh_every: int = 20                    # S in the paper (20–50)
    feature_mode: str = "svd"                 # svd | sketch_svd | pca_sketch
                                              #   | pooled_raw | ica
    grad_mode: str = "probe"                  # probe | logit_embed | full
                                              # (registries: selection/sources.py)
    use_pallas: bool = False                   # TPU kernels vs jnp reference
    overlap: bool = False                      # double-buffered refresh/train
                                              # overlap (selection/overlap.py);
                                              # dispatch schedule only — same
                                              # trajectory, excluded from
                                              # config_hash
    # -- streaming (selection/streaming.py) ----------------------------------
    # knobs for the cross-batch sketch reservoir; inert (and excluded from
    # config_hash) unless the streaming_graft sampler is selected
    streaming: bool = False                    # upgrade 'graft' → 'streaming_graft'
    sketch_rows: int = 64                      # L — reservoir rows, (L, d) footprint
    sketch_decay: float = 0.99                 # per-refresh reservoir/EMA decay
    stream_mix: float = 0.5                    # β cap on the stream-target blend

    def __post_init__(self):
        if tuple(sorted(self.rset)) != tuple(self.rset):
            raise ValueError("rset must be ascending")
        if self.sketch_rows < 1:
            raise ValueError("sketch_rows must be >= 1")
        if not 0.0 <= self.sketch_decay <= 1.0:
            raise ValueError("sketch_decay must be in [0, 1]")
        if not 0.0 <= self.stream_mix <= 1.0:
            raise ValueError("stream_mix must be in [0, 1]")

    @property
    def r_max(self) -> int:
        return self.rset[-1]


# alias for sampler-generic call sites (the config is not GRAFT-specific)
SamplerConfig = GraftConfig


class SelectionState(NamedTuple):
    """Carried across training steps (replicated; tiny)."""
    pivots: jax.Array        # (R_max,) int32 — current subset, pivot order
    weights: jax.Array       # (R_max,) f32 — sum 1 over active, 0 inactive
    rank: jax.Array          # () int32 — current R*
    last_error: jax.Array    # () f32 — projection error at R*
    alignment: jax.Array     # () f32 — cos(subset ḡ, batch ḡ) diagnostic
    step: jax.Array          # () int32


class SelectionInputs(NamedTuple):
    """Per-batch selection inputs. ``V``/``G``/``g_bar`` as in the paper;
    ``scores`` are per-sample scalars (e.g. loss) for score-ranked samplers;
    ``key`` drives stochastic samplers. Optional fields may be ``None`` for
    samplers that don't read them (``None`` is pytree-transparent, so the
    vmapped/sharded engines can still map over the tuple)."""
    V: jax.Array                       # (K, R_max) relevance-ordered features
    G: jax.Array                       # (d, K) per-sample grad embeddings
    g_bar: jax.Array                   # (d,) batch mean gradient
    scores: Optional[jax.Array] = None  # (K,) per-sample scores
    key: Optional[jax.Array] = None     # PRNG key


def default_select_key(step) -> jax.Array:
    """Step-folded PRNG key for stochastic samplers when the caller supplies
    none — the ONE derivation shared by the engine paths and the in-step
    selection path, so they sample identically."""
    return jax.random.fold_in(jax.random.PRNGKey(0), jnp.int32(step))


def init_state(cfg: GraftConfig, batch_size: int) -> SelectionState:
    r = cfg.r_max
    if r > batch_size:
        raise ValueError(f"r_max {r} > batch size {batch_size}")
    return SelectionState(
        pivots=jnp.arange(r, dtype=jnp.int32),
        weights=jnp.full((r,), 1.0 / r, dtype=jnp.float32),
        rank=jnp.int32(r),
        last_error=jnp.float32(1.0),
        alignment=jnp.float32(0.0),
        step=jnp.int32(0),
    )


def finalize_state(cfg: GraftConfig, pivots: jax.Array, weights: jax.Array,
                   rank: jax.Array, G: jax.Array, g_bar: jax.Array,
                   step: jax.Array) -> SelectionState:
    """Fill the diagnostic fields every sampler shares: the projection error
    of the active selected gradients and the subset/batch alignment."""
    from repro.core import projection as proj_lib
    G_sel = jnp.take(G, pivots, axis=1)                 # (d, R_max)
    active = (weights > 0).astype(jnp.float32)
    # error over ONLY the active columns: the MGS sweep skips zeroed columns
    # (zero captured energy), whereas a QR of the masked matrix would invent
    # orthonormal completion directions for them and under-report the error
    err = proj_lib.prefix_projection_errors(G_sel * active[None, :], g_bar)[-1]
    g_sub = G_sel @ weights
    align = proj_lib.cosine_alignment(g_sub, g_bar)
    return SelectionState(pivots=pivots.astype(jnp.int32), weights=weights,
                          rank=jnp.int32(rank), last_error=err,
                          alignment=align, step=jnp.int32(step))


class CarrySpec(NamedTuple):
    """Static shape info a sampler needs to size its carry before the first
    batch exists (``init_carry`` runs at train-state init, not at trace
    time). ``batch_size`` is K (rows of ``V``), ``grad_dim`` is d (rows of
    ``G`` — the gradient-embedding width)."""
    batch_size: int
    grad_dim: int

    @classmethod
    def from_inputs(cls, inputs: "SelectionInputs") -> "CarrySpec":
        return cls(batch_size=int(inputs.V.shape[0]),
                   grad_dim=int(inputs.G.shape[0]))


# the stateless carry: a leafless pytree, invisible to jit/vmap/checkpoint
EMPTY_CARRY: dict = {}

# Carry is any pytree; a bare alias keeps signatures readable
Carry = Any


@dataclasses.dataclass(frozen=True)
class Sampler:
    """A registered selection strategy (v2 protocol).

    Stateless strategies provide ``fn(cfg, inputs, step) -> SelectionState``
    — the pre-v2 signature — and the protocol wraps it: their carry is the
    empty pytree, returned unchanged, and numerics are bit-identical to the
    direct ``fn`` call. Stateful strategies (the streaming reservoir)
    provide ``select_fn(cfg, inputs, carry, step) -> (SelectionState,
    carry')`` plus ``init_carry_fn(cfg, spec) -> carry``. Either callable
    must be jit/vmap-traceable for a fixed ``cfg``.

    ``needs_scores``/``needs_key`` document which optional inputs the
    strategy reads; both are validated symmetrically by :meth:`select` (and
    pre-validated by the engine paths) with the same actionable error.
    """
    name: str
    fn: Optional[Callable[[GraftConfig, SelectionInputs, jax.Array],
                          SelectionState]] = None
    needs_scores: bool = False
    needs_key: bool = False
    select_fn: Optional[Callable[..., Tuple[SelectionState, Carry]]] = None
    init_carry_fn: Optional[Callable[[GraftConfig, CarrySpec], Carry]] = None

    def __post_init__(self):
        if (self.fn is None) == (self.select_fn is None):
            raise ValueError(
                f"sampler '{self.name}' must define exactly one of fn "
                f"(stateless) or select_fn (stateful)")

    @property
    def stateful(self) -> bool:
        return self.select_fn is not None

    def _require(self, field: str, value) -> None:
        if value is None:
            raise ValueError(
                f"sampler '{self.name}' requires SelectionInputs.{field} — "
                f"pass {field}=... (engine paths fill defaults only for "
                f"samplers that do not declare needs_{field.split('_')[0]})")

    def init_carry(self, cfg: GraftConfig, spec: CarrySpec) -> Carry:
        """The sampler's initial cross-step state; ``{}`` when stateless."""
        if self.init_carry_fn is not None:
            return self.init_carry_fn(cfg, spec)
        return EMPTY_CARRY

    def select(self, cfg: GraftConfig, inputs: SelectionInputs,
               carry: Carry = None, step=0) -> Tuple[SelectionState, Carry]:
        """Run one selection: ``(state, carry')``. ``carry=None`` initializes
        a fresh carry from the input shapes (one-shot call sites)."""
        if self.needs_scores and inputs.scores is None:
            self._require("scores", inputs.scores)
        if self.needs_key and inputs.key is None:
            self._require("key", inputs.key)
        if carry is None:
            carry = self.init_carry(cfg, CarrySpec.from_inputs(inputs))
        if self.select_fn is not None:
            return self.select_fn(cfg, inputs, carry, jnp.int32(step))
        return self.fn(cfg, inputs, jnp.int32(step)), carry

    def init_state(self, cfg: GraftConfig, batch_size: int) -> SelectionState:
        return init_state(cfg, batch_size)
