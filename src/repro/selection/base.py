"""Sampler protocol + shared state for the selection engine.

Every subset sampler (GRAFT, random, loss-topk, the coreset baselines)
implements one signature — ``fn(cfg, inputs, step) -> SelectionState`` — so
the train step, the vmapped multi-batch path and the shard_map data-parallel
path in ``engine.py`` are sampler-agnostic. The config object is the paper's
``GraftConfig``: non-GRAFT samplers read only ``r_max`` (subset size budget)
and ``use_pallas`` from it, so one config drives every strategy in a sweep.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class GraftConfig:
    """Static selection hyper-parameters (hashable; safe as a jit static arg)."""
    rset: Tuple[int, ...] = (8, 16, 32, 64)   # candidate ranks, ascending
    eps: float = 0.25                          # projection-error threshold
    refresh_every: int = 20                    # S in the paper (20–50)
    feature_mode: str = "svd"                 # svd | sketch_svd | pca_sketch
                                              #   | pooled_raw | ica
    grad_mode: str = "probe"                  # probe | logit_embed | full
                                              # (registries: selection/sources.py)
    use_pallas: bool = False                   # TPU kernels vs jnp reference
    overlap: bool = False                      # double-buffered refresh/train
                                              # overlap (selection/overlap.py);
                                              # dispatch schedule only — same
                                              # trajectory, excluded from
                                              # config_hash

    def __post_init__(self):
        if tuple(sorted(self.rset)) != tuple(self.rset):
            raise ValueError("rset must be ascending")

    @property
    def r_max(self) -> int:
        return self.rset[-1]


# alias for sampler-generic call sites (the config is not GRAFT-specific)
SamplerConfig = GraftConfig


class SelectionState(NamedTuple):
    """Carried across training steps (replicated; tiny)."""
    pivots: jax.Array        # (R_max,) int32 — current subset, pivot order
    weights: jax.Array       # (R_max,) f32 — sum 1 over active, 0 inactive
    rank: jax.Array          # () int32 — current R*
    last_error: jax.Array    # () f32 — projection error at R*
    alignment: jax.Array     # () f32 — cos(subset ḡ, batch ḡ) diagnostic
    step: jax.Array          # () int32


class SelectionInputs(NamedTuple):
    """Per-batch selection inputs. ``V``/``G``/``g_bar`` as in the paper;
    ``scores`` are per-sample scalars (e.g. loss) for score-ranked samplers;
    ``key`` drives stochastic samplers. Optional fields may be ``None`` for
    samplers that don't read them (``None`` is pytree-transparent, so the
    vmapped/sharded engines can still map over the tuple)."""
    V: jax.Array                       # (K, R_max) relevance-ordered features
    G: jax.Array                       # (d, K) per-sample grad embeddings
    g_bar: jax.Array                   # (d,) batch mean gradient
    scores: Optional[jax.Array] = None  # (K,) per-sample scores
    key: Optional[jax.Array] = None     # PRNG key


def default_select_key(step) -> jax.Array:
    """Step-folded PRNG key for stochastic samplers when the caller supplies
    none — the ONE derivation shared by the engine paths and the in-step
    selection path, so they sample identically."""
    return jax.random.fold_in(jax.random.PRNGKey(0), jnp.int32(step))


def init_state(cfg: GraftConfig, batch_size: int) -> SelectionState:
    r = cfg.r_max
    if r > batch_size:
        raise ValueError(f"r_max {r} > batch size {batch_size}")
    return SelectionState(
        pivots=jnp.arange(r, dtype=jnp.int32),
        weights=jnp.full((r,), 1.0 / r, dtype=jnp.float32),
        rank=jnp.int32(r),
        last_error=jnp.float32(1.0),
        alignment=jnp.float32(0.0),
        step=jnp.int32(0),
    )


def finalize_state(cfg: GraftConfig, pivots: jax.Array, weights: jax.Array,
                   rank: jax.Array, G: jax.Array, g_bar: jax.Array,
                   step: jax.Array) -> SelectionState:
    """Fill the diagnostic fields every sampler shares: the projection error
    of the active selected gradients and the subset/batch alignment."""
    from repro.core import projection as proj_lib
    G_sel = jnp.take(G, pivots, axis=1)                 # (d, R_max)
    active = (weights > 0).astype(jnp.float32)
    # error over ONLY the active columns: the MGS sweep skips zeroed columns
    # (zero captured energy), whereas a QR of the masked matrix would invent
    # orthonormal completion directions for them and under-report the error
    err = proj_lib.prefix_projection_errors(G_sel * active[None, :], g_bar)[-1]
    g_sub = G_sel @ weights
    align = proj_lib.cosine_alignment(g_sub, g_bar)
    return SelectionState(pivots=pivots.astype(jnp.int32), weights=weights,
                          rank=jnp.int32(rank), last_error=err,
                          alignment=align, step=jnp.int32(step))


@dataclasses.dataclass(frozen=True)
class Sampler:
    """A registered selection strategy.

    ``fn(cfg, inputs, step) -> SelectionState`` must be jit/vmap-traceable
    for a fixed ``cfg``. ``needs_scores``/``needs_key`` document (and let the
    engine validate) which optional inputs the strategy reads.
    """
    name: str
    fn: Callable[[GraftConfig, SelectionInputs, jax.Array], SelectionState]
    needs_scores: bool = False
    needs_key: bool = False

    def select(self, cfg: GraftConfig, inputs: SelectionInputs,
               step=0) -> SelectionState:
        if self.needs_scores and inputs.scores is None:
            raise ValueError(f"sampler '{self.name}' requires SelectionInputs.scores")
        if self.needs_key and inputs.key is None:
            raise ValueError(f"sampler '{self.name}' requires SelectionInputs.key")
        return self.fn(cfg, inputs, jnp.int32(step))

    def init_state(self, cfg: GraftConfig, batch_size: int) -> SelectionState:
        return init_state(cfg, batch_size)
