"""Streaming cross-batch selection: a SAGE-style gradient-sketch reservoir.

Every per-batch sampler scores within one (micro)batch stack; production
traffic is an unbounded stream. This module keeps a bounded on-device
memory of the stream's gradient geometry and biases each refresh toward
directions the stream has agreed on — selection quality that survives
distribution drift without ever holding the stream in memory.

The carry (``SketchCarry``, fixed footprint, checkpointed with the train
state) holds three pieces:

  * ``sketch`` — an (L, d) **frequent-directions** sketch of every gradient
    embedding matrix ``G`` the refreshes have seen (Liberty 2013): each
    update appends the new rows, takes the top-L singular directions of the
    combined matrix and shrinks their energy by the (L+1)-th eigenvalue, so
    ``‖AᵀA − BᵀB‖₂ ≤ ‖A‖_F²/L`` holds for the decayed stream. The sketch is
    the stream's dominant gradient *subspace* in O(L·d) memory.
  * ``g_ema`` — the decayed stream mean gradient (bias-corrected at use).
  * ``count`` / ``agreement`` — refresh count and the last batch↔stream
    agreement, both float so the sharded path can average carries.

Selection (``streaming_graft``) is **agreement-driven** in the SAGE sense:
the batch mean ``ḡ`` is compared against its projection onto the sketch
subspace; the cosine of that projection is the *agreement* ``a ∈ [0, 1]``.
The refresh then runs the UNMODIFIED fused Fast MaxVol + MGS sweep
(``graft.pivot_and_sweep`` — still ONE ``pallas_call``, contract JX003)
against the reservoir-augmented target

    ``g̃ = (1 − β_eff)·ḡ + β_eff·ĝ_stream,   β_eff = stream_mix · a``

so when the batch agrees with the stream history the rank decision and
weights anchor on the global gradient, and under drift (or on the very
first refresh, when the sketch is empty and ``a = 0``) selection falls
back to pure per-batch GRAFT. MaxVol pivots stay batch-local by
construction — candidates can only come from the batch — it is the
projection sweep's target subspace that the reservoir augments.

Memory: the carry is ``L·d + d + 2`` floats — for the default L=64 probe
path (d = d_model) a few hundred KB, independent of stream length.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import projection as proj_lib
from repro.selection import graft as graft_lib
from repro.selection.base import (CarrySpec, GraftConfig, Sampler,
                                  SelectionInputs, SelectionState)
from repro.selection.registry import register

_EPS = 1e-12


class SketchCarry(NamedTuple):
    """The streaming sampler's cross-step state (all-float32 so the sharded
    engine can keep it replicated by averaging)."""
    sketch: jax.Array      # (L, d) frequent-directions sketch rows
    g_ema: jax.Array       # (d,) decayed stream mean gradient (uncorrected)
    count: jax.Array       # () f32 — reservoir updates absorbed so far
    agreement: jax.Array   # () f32 — last cos(ḡ, P_sketch ḡ) diagnostic


def init_sketch_carry(cfg: GraftConfig, spec: CarrySpec) -> SketchCarry:
    d = int(spec.grad_dim)
    return SketchCarry(
        sketch=jnp.zeros((cfg.sketch_rows, d), dtype=jnp.float32),
        g_ema=jnp.zeros((d,), dtype=jnp.float32),
        count=jnp.float32(0.0),
        agreement=jnp.float32(0.0),
    )


def fd_update(cfg: GraftConfig, sketch: jax.Array, G: jax.Array) -> jax.Array:
    """One frequent-directions round: absorb the rows of ``Gᵀ`` (K, d) into
    the decayed (L, d) sketch at fixed footprint.

    Works entirely through the small (L+K, L+K) Gram eigendecomposition —
    never an SVD of a d-wide matrix — so the cost is O((L+K)²·d) matmul
    FLOPs plus an O((L+K)³) eigh, independent of stream length.
    """
    L = cfg.sketch_rows
    stacked = jnp.concatenate(
        [cfg.sketch_decay * sketch, G.astype(jnp.float32).T], axis=0)
    gram = stacked @ stacked.T                          # (L+K, L+K)
    evals, evecs = jnp.linalg.eigh(gram)                # ascending
    evals = evals[::-1]
    evecs = evecs[:, ::-1]
    # σᵢ·vᵢᵀ rows of the combined matrix, largest direction first
    rows = evecs[:, :L].T @ stacked                     # (L, d)
    # FD shrinkage: subtract the (L+1)-th eigenvalue from every kept energy
    delta = evals[L] if stacked.shape[0] > L else jnp.float32(0.0)
    shrunk = jnp.maximum(evals[:L] - delta, 0.0)
    scale = jnp.sqrt(shrunk / jnp.maximum(evals[:L], _EPS))
    return rows * scale[:, None]


def sketch_projection(sketch: jax.Array, g: jax.Array) -> jax.Array:
    """Project ``g`` onto the span of the sketch rows (rows are orthogonal
    by FD construction; zero rows contribute nothing)."""
    norms = jnp.linalg.norm(sketch, axis=1, keepdims=True)  # (L, 1)
    unit = jnp.where(norms > 1e-8, sketch / (norms + _EPS),
                     jnp.zeros_like(sketch))
    return unit.T @ (unit @ g.astype(jnp.float32))


def stream_agreement(sketch: jax.Array, g_bar: jax.Array) -> jax.Array:
    """cos(ḡ, P_sketch ḡ) ∈ [0, 1] — how much of the batch gradient lies in
    the stream's dominant subspace. 0 for an empty sketch."""
    proj = sketch_projection(sketch, g_bar)
    return jnp.clip(proj_lib.cosine_alignment(proj, g_bar), 0.0, 1.0)


def streaming_select_fn(cfg: GraftConfig, inputs: SelectionInputs,
                        carry: SketchCarry, step: jax.Array):
    """The ``Sampler.select_fn`` body: one agreement-driven refresh.

    Order matters for drift response: the agreement is measured against the
    sketch *before* this batch is absorbed (history vs now), then the
    reservoir absorbs the batch so the next refresh sees it.
    """
    g_bar = inputs.g_bar.astype(jnp.float32)
    agreement = stream_agreement(carry.sketch, g_bar)

    # advance the stream statistics
    decay = jnp.float32(cfg.sketch_decay)
    count = carry.count + 1.0
    g_ema = decay * carry.g_ema + (1.0 - decay) * g_bar
    sketch = fd_update(cfg, carry.sketch, inputs.G)

    # bias-corrected stream mean (Adam-style: the EMA of n terms has total
    # weight 1 − decay^n); refined toward the sketch's dominant subspace
    corr = jnp.maximum(1.0 - jnp.power(decay, count), _EPS)
    g_stream = g_ema / corr

    beta = jnp.float32(cfg.stream_mix) * agreement
    g_tilde = (1.0 - beta) * g_bar + beta * g_stream

    # the unmodified fused dispatch — ONE pallas_call under use_pallas
    pivots, errors, G_sel = graft_lib.pivot_and_sweep(
        cfg, inputs.V, inputs.G, g_tilde)

    # epilogue: GRAFT's rank decision on the blended target, then an
    # agreement-driven reweighting of the active pivots — selected examples
    # whose gradient embedding aligns with g̃ are upweighted by a masked
    # softmax, blended with the uniform weights by the same β. At β = 0
    # (empty sketch, or full disagreement) this is EXACTLY the per-batch
    # GRAFT epilogue — refresh #1 stays bit-identical to plain GRAFT.
    rank, err = proj_lib.select_rank(errors, cfg.rset, cfg.eps)
    active = (jnp.arange(cfg.r_max) < rank).astype(jnp.float32)
    uniform = active / jnp.maximum(jnp.sum(active), 1.0)
    col_norms = jnp.linalg.norm(G_sel, axis=0)              # (R_max,)
    cos = (G_sel.T @ g_tilde) / jnp.maximum(
        col_norms * jnp.linalg.norm(g_tilde), _EPS)
    soft = jax.nn.softmax(jnp.where(active > 0.0, cos, -jnp.inf))
    weights = (1.0 - beta) * uniform + beta * soft
    g_sub = G_sel @ weights
    state = SelectionState(
        pivots=pivots, weights=weights, rank=rank, last_error=err,
        alignment=proj_lib.cosine_alignment(g_sub, g_tilde), step=step)
    return state, SketchCarry(sketch=sketch, g_ema=g_ema, count=count,
                              agreement=agreement)


STREAMING_GRAFT = register(Sampler(
    "streaming_graft",
    select_fn=streaming_select_fn,
    init_carry_fn=init_sketch_carry,
))

__all__ = ["SketchCarry", "init_sketch_carry", "fd_update",
           "sketch_projection", "stream_agreement", "streaming_select_fn",
           "STREAMING_GRAFT"]
