"""Lifecycle callbacks — everything the training loop does besides stepping.

The ``Trainer`` loop is pure step-dispatch; checkpointing, held-out eval,
JSONL telemetry, straggler monitoring, console logging, and
preemption/early-stop are all ``Callback`` plugins dispatched at four hooks:

  * ``on_train_start(trainer)``             — after state init, BEFORE the
    data iterator is created (so a restore can rewind the pipeline)
  * ``on_step_end(trainer, step, metrics)`` — once per step, in ascending
    ``priority`` order; callbacks may mutate ``metrics`` in place (eval
    merges its numbers here) and call ``trainer.request_stop(reason)``
  * ``on_checkpoint(trainer, step, path)``  — after a checkpoint commits
  * ``on_train_end(trainer, report)``       — once, may enrich the report

Ordering contract (the ``priority`` numbers below): preemption decides stop
BEFORE eval/telemetry run, eval merges metrics BEFORE the JSONL logger
queues them, and the checkpointer runs LAST so a stop request is always
checkpointed before the loop exits (checkpoint-before-stop).

The ``metrics`` argument of ``on_step_end`` is a lazy
``MetricsFuture`` over device scalars: reading a VALUE (``metrics["loss"]``)
syncs the host on the device queue, so a callback on the per-step path
should only touch values at its own boundaries (print steps, checkpoint
saves, flush drains) — key-level checks (``"eval_loss" in metrics``) are
always free.
"""
from __future__ import annotations

import math
from typing import Any, Callable, Dict, Optional

from repro.analysis.sync_guard import sync_allowed
from repro.checkpoint import CheckpointManager, EmergencySaver
from repro.distributed.straggler import StragglerMonitor
from repro.launch.evaluate import make_eval_fn_for
from repro.launch.metrics import (MetricsLogger, format_step_line,
                                  materialize_metrics, sanitize_row,
                                  train_step_flops)
from repro.selection.overlap import SideStream


class Callback:
    """Base lifecycle plugin. Lower ``priority`` runs earlier in every hook.

    The default (50) sits between the stock telemetry plugins (10-40) and
    the checkpointer (90), so a user callback that calls
    ``trainer.request_stop()`` still gets its stop checkpointed in the same
    step — keep custom priorities below 90 to preserve that guarantee."""
    priority: int = 50

    def on_train_start(self, trainer) -> None:
        pass

    def on_step_end(self, trainer, step: int, metrics: Dict[str, Any]) -> None:
        pass

    def on_checkpoint(self, trainer, step: int, path: str) -> None:
        pass

    def on_train_end(self, trainer, report: Dict[str, Any]) -> None:
        pass

    def on_train_abort(self, trainer) -> None:
        """Fired (instead of ``on_train_end``) when ``fit()`` exits on an
        exception — release external resources here (signal handlers, open
        files, writer threads) so a crashed run can be restarted in the
        same process. Exceptions raised here are logged, not propagated."""


class PreemptionCallback(Callback):
    """SIGTERM/SIGINT emergency stop + ``stop_after`` simulated preemption.
    Runs first so the checkpointer (last) sees the stop request in the same
    step — the checkpoint-before-stop ordering guarantee."""
    priority = 10

    def __init__(self, stop_after: Optional[int] = None):
        self.stop_after = stop_after
        self.saver: Optional[EmergencySaver] = None

    def on_train_start(self, trainer) -> None:
        self.saver = EmergencySaver()

    def on_step_end(self, trainer, step, metrics) -> None:
        if self.saver is not None and self.saver.should_stop:
            trainer.request_stop("preempted")
        elif self.stop_after is not None and step + 1 >= self.stop_after:
            trainer.request_stop("stop_after")

    def on_train_end(self, trainer, report) -> None:
        if self.saver is not None:
            self.saver.restore_handlers()

    def on_train_abort(self, trainer) -> None:
        # a crashed fit() must not leave our handlers (and their stale
        # stop flag) installed for the next Trainer in this process
        if self.saver is not None:
            self.saver.restore_handlers()


class EvalCallback(Callback):
    """Held-out eval every N steps, OFF the critical path by default.

    At an eval boundary the jitted evals are DISPATCHED as a non-donated
    side stream against the live ``state["params"]`` (safe because the
    dispatch happens before the trainer issues the next donating step —
    the ``SideStream`` discipline shared with the ``OverlappedSelector``)
    and the device-scalar results merge into the step's ``MetricsFuture``
    immediately, tagged with the step they were dispatched at; the actual
    numbers are collected at the NEXT eval boundary (or ``on_train_end``),
    by which point the device finished them long ago. ``sync=True``
    restores blocking eval inside ``on_step_end`` (the escape hatch for
    tests) — both modes run the identical device computation, so the
    numbers are bit-identical."""
    priority = 20

    def __init__(self, every: int, num_batches: int = 4, sync: bool = False):
        self.every = every
        self.num_batches = num_batches
        self.sync = sync
        self.eval_fn = None
        self.stream = SideStream()

    def on_train_start(self, trainer) -> None:
        self.eval_fn = make_eval_fn_for(trainer.config, trainer.mcfg,
                                        num_batches=self.num_batches)

    def on_step_end(self, trainer, step, metrics) -> None:
        if not (self.every and (step + 1) % self.every == 0):
            return
        if self.sync:
            metrics.update(self.eval_fn(trainer.state["params"]))
            return
        handle = self.eval_fn.dispatch(trainer.state["params"])
        metrics.update(handle)          # row tagged with the dispatch step
        self.stream.launch(step, handle)  # collects the PREVIOUS boundary's

    def on_train_end(self, trainer, report) -> None:
        self.stream.drain()             # nothing in flight past the loop


class MetricsCallback(Callback):
    """JSONL telemetry stream + throughput/MFU tracking. Runs after eval so
    held-out numbers reach the stream (one row per step). Rows are queued
    lazily (device values and all) and the logger materializes + writes
    only every ``flush_every`` steps and on close, so ``on_step_end`` pays
    neither a device sync nor a write syscall per step. Step timing comes
    from the trainer's dispatch clock, not the gap between log calls —
    eval/checkpoint pauses land in ``host_overhead_s``, not in ``mfu``."""
    priority = 30

    def __init__(self, path: Optional[str] = None, flush_every: int = 20):
        self.path = path
        self.flush_every = flush_every
        self.logger: Optional[MetricsLogger] = None
        self._primed = False

    def on_train_start(self, trainer) -> None:
        tr = trainer.config.train
        self.logger = MetricsLogger(
            self.path, num_chips=trainer.backend.device_count(),
            flops_per_step=train_step_flops(
                trainer.num_params, tr.batch * tr.seq,
                remat=trainer.mcfg.remat != "none",
                mcfg=trainer.mcfg, seq=tr.seq),
            flush_every=self.flush_every,
            device_clock=trainer.device_clock)

    def on_step_end(self, trainer, step, metrics) -> None:
        tr = trainer.config.train
        tokens = tr.batch * tr.seq
        if not self._primed:
            # checkpoint resume hands this fresh logger a mid-run step
            # counter (start_step is only known after the checkpointer's
            # on_train_start): seed the cumulative token counter so
            # resumed runs don't report tokens_seen from zero
            if trainer.start_step:
                self.logger.tokens_seen = trainer.start_step * tokens
            self._primed = True
        self.logger.log(step, metrics, tokens=tokens,
                        step_time=trainer.last_step_time)

    def on_train_end(self, trainer, report) -> None:
        if self.logger is not None:
            self.logger.close()
            report.setdefault("host_loop", {})["metrics_drain_s"] = \
                self.logger.drain_s

    def on_train_abort(self, trainer) -> None:
        if self.logger is not None:
            self.logger.close()     # flush the buffered tail of the stream


class StragglerCallback(Callback):
    """Per-step time distribution; summary lands in the report. With the
    trainer's :class:`DeviceClock` active the monitor is fed DEVICE step
    times (completion-stamp deltas, drained as they land) — dispatch jitter
    on an async host loop says nothing about a slow device. Without the
    clock it falls back to the dispatch clock."""
    priority = 40

    def __init__(self):
        self.monitor = StragglerMonitor()
        self._source = "dispatch"

    def on_train_start(self, trainer) -> None:
        # per-process attribution: the fleet view (merge_summaries) names
        # the worst host, so each monitor's summary carries its rank
        self.monitor.process_index = trainer.backend.process_index

    def on_step_end(self, trainer, step, metrics) -> None:
        if trainer.device_clock is not None:
            self._source = "device"
            for _, dt in trainer.device_clock.poll():
                self.monitor.record(dt)
        else:
            self.monitor.record(trainer.last_step_time)

    def on_train_end(self, trainer, report) -> None:
        if trainer.device_clock is not None:
            trainer.device_clock.drain()
            for _, dt in trainer.device_clock.poll():
                self.monitor.record(dt)
        summary = self.monitor.summary()
        summary["source"] = self._source
        report["straggler"] = summary


class LegacyFunctionCallback(Callback):
    """Adapter for the pre-API ``train(run, callbacks=[fn])`` hook:
    ``fn(step, state, metrics)`` once per step."""
    priority = 55

    def __init__(self, fn: Callable[[int, Any, Dict[str, Any]], None]):
        self.fn = fn

    def on_step_end(self, trainer, step, metrics) -> None:
        self.fn(step, trainer.state, metrics)


class ConsoleCallback(Callback):
    """Progress lines every ``log_every`` steps (post-eval metrics). Only
    the rows actually printed are materialized — the cadence check is
    key-free, so non-print steps stay sync-free."""
    priority = 60

    def __init__(self, every: int = 10):
        self.every = every

    def on_step_end(self, trainer, step, metrics) -> None:
        if self.every and step % self.every == 0:
            with sync_allowed("console"):
                print(format_step_line(step, metrics, trainer.last_step_time,
                                       use_graft=trainer.tcfg.use_graft),
                      flush=True)


class CheckpointCallback(Callback):
    """Fault-tolerant checkpointing: auto-restore on start, periodic +
    final + stop-triggered saves, manifest embedding of the finalized
    ``ExperimentConfig`` so a resume needs nothing but the directory.

    Runs LAST in ``on_step_end`` so any stop requested earlier in the same
    step (preemption, ``stop_after``) is checkpointed before the loop exits.
    """
    priority = 90

    def __init__(self, directory: str, every: int = 50, keep_last_n: int = 2,
                 async_save: bool = True, restore: bool = True):
        self.directory = directory
        self.every = every
        self.restore = restore
        self.manager = CheckpointManager(directory, keep_last_n=keep_last_n,
                                         async_save=async_save)

    def on_train_start(self, trainer) -> None:
        trainer.checkpoint_manager = self.manager
        if not self.restore:
            return
        try:
            # newest checkpoint that verifies (checksums) AND is stamped
            # healthy — a bit-flipped or mid-crash dir is quarantined to
            # corrupt.<step> and the walk falls back to the previous one
            _, tree, manifest = self.manager.restore_latest_good(
                trainer.state, backend=trainer.backend)
        except FileNotFoundError:
            return                            # fresh run — nothing on disk
        trainer.state = tree
        # restore the full pipeline state from the manifest ONCE — the
        # trainer creates its iterator only after on_train_start, so
        # nothing can clobber this
        trainer.data.load_state_dict(manifest["extra"]["data"])
        trainer.start_step = int(manifest["extra"]["train_step"])
        saved_hash = manifest["extra"].get("config_hash")
        ours = trainer.config.config_hash()
        if saved_hash is not None and saved_hash != ours:
            print(f"[train] WARNING: resuming config {ours} from a "
                  f"checkpoint written by config {saved_hash}")
        print(f"[train] resumed from step {trainer.start_step}")

    def on_step_end(self, trainer, step, metrics) -> None:
        total = trainer.config.train.steps
        due = (step + 1) % self.every == 0
        if not (due or trainer.should_stop or step + 1 == total):
            return
        if trainer.sentinel_tripped:
            # the divergence guard tripped earlier in this hook pass: the
            # live state is poisoned — refusing to save means keep-last-N
            # can never rotate entirely onto bad states while the trainer
            # rolls back (and GC won't run either, since it runs in save)
            if trainer.backend.is_primary:
                print(f"[ckpt] sentinel tripped — refusing to save step "
                      f"{step + 1}", flush=True)
            return
        with sync_allowed("checkpoint"):
            # a checkpoint boundary is a legitimate sync point: the
            # manifest needs JSON floats, not device futures
            vals = materialize_metrics(metrics)
            healthy = (vals.get("healthy", 1.0) >= 0.5
                       and math.isfinite(vals.get("loss", 0.0)))
            # the state gather is a COLLECTIVE on multi-process backends
            # (sharded leaves allgather across ranks) — every process must
            # participate or the primary deadlocks waiting for peers that
            # already moved on. One writer per run: every process gathers
            # (and RESTOREs in on_train_start), only process 0 writes.
            host_state = trainer.backend.to_host(trainer.state)
            if not trainer.backend.is_primary:
                return
            path = self.manager.save(
                step + 1, host_state,
                topology=trainer.backend.topology(),
                extra={"train_step": step + 1,
                       "data": trainer.data_state(),
                       "metrics": sanitize_row(vals),
                       "health": {"healthy": bool(healthy),
                                  "bad_streak":
                                      int(vals.get("bad_streak", 0.0))},
                       "experiment": trainer.config.to_dict(),
                       "config_hash": trainer.config.config_hash()})
        listeners = [cb for cb in trainer.callbacks
                     if type(cb).on_checkpoint is not Callback.on_checkpoint]
        if listeners:
            # the hook contract is "after the checkpoint commits": an async
            # save returns before the tmp→final rename, so join the writer
            # before announcing. No listeners → keep the save fully async.
            self.manager.wait()
            for cb in listeners:
                cb.on_checkpoint(trainer, step, path)
        if trainer.should_stop:
            print("[train] emergency checkpoint written — exiting")

    def on_train_end(self, trainer, report) -> None:
        self.manager.wait()

    def on_train_abort(self, trainer) -> None:
        try:
            self.manager.wait()
        except Exception:       # noqa: BLE001 — a writer that died
            pass                # mid-save left its breadcrumbs on disk;
                                # _recover() rolls them back on restart


class HookRecorder(Callback):
    """Test/debug helper: records (hook, step) tuples in call order."""
    priority = 95

    def __init__(self):
        self.events = []

    def on_train_start(self, trainer) -> None:
        self.events.append(("on_train_start", None))

    def on_step_end(self, trainer, step, metrics) -> None:
        self.events.append(("on_step_end", step))

    def on_checkpoint(self, trainer, step, path) -> None:
        self.events.append(("on_checkpoint", step))

    def on_train_end(self, trainer, report) -> None:
        self.events.append(("on_train_end", None))


def default_callbacks(cfg) -> list:
    """The stock plugin set for an ``ExperimentConfig`` (mirrors what the
    legacy monolithic loop hardwired)."""
    tr = cfg.train
    cbs: list = [PreemptionCallback(tr.stop_after)]
    if tr.eval_every:
        cbs.append(EvalCallback(tr.eval_every, sync=tr.sync_eval))
    cbs.append(MetricsCallback(tr.metrics_path,
                               flush_every=tr.metrics_flush_every))
    cbs.append(StragglerCallback())
    if tr.sentinel:
        # lazy: repro.resilience.guard imports this module
        from repro.resilience.guard import DivergenceGuardCallback
        cbs.append(DivergenceGuardCallback(
            patience=tr.bad_step_patience,
            check_every=max(1, tr.metrics_flush_every)))
    if tr.log_every:
        cbs.append(ConsoleCallback(tr.log_every))
    if tr.checkpoint_dir:
        cbs.append(CheckpointCallback(tr.checkpoint_dir,
                                      every=tr.checkpoint_every))
    return cbs
