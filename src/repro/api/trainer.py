"""The ``Trainer``: a pure step-dispatch loop over a declarative
``ExperimentConfig``, with every side effect (checkpointing, eval,
telemetry, monitoring, early stop) delegated to ``Callback`` plugins.

Typical use::

    from repro.api import ExperimentConfig, Trainer

    cfg = ExperimentConfig().apply_overrides(["train.steps=40"])
    report = Trainer(cfg).fit()

Resume needs nothing but the checkpoint directory — the finalized config
rides in the manifest::

    report = Trainer.from_checkpoint("/ckpts/run1").fit()
"""
from __future__ import annotations

import time
from typing import Any, Dict, Iterable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import callbacks as cb_lib
from repro.api.config import ExperimentConfig
from repro.distributed import sharding as sh
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_host_mesh


class Trainer:
    """Runs one experiment. ``callbacks`` are appended to the stock set
    derived from the config; pass ``use_default_callbacks=False`` to take
    full control of the plugin list."""

    def __init__(self, config: ExperimentConfig,
                 callbacks: Optional[Iterable[cb_lib.Callback]] = None,
                 use_default_callbacks: bool = True):
        self.config = config.finalized()
        cbs = list(cb_lib.default_callbacks(self.config)
                   if use_default_callbacks else [])
        if callbacks:
            cbs.extend(callbacks)
        self.callbacks = sorted(cbs, key=lambda c: c.priority)

        # populated by fit(); callbacks read these
        self.mcfg = None
        self.tcfg: Optional[steps_lib.TrainConfig] = None
        self.data = None
        self.state = None
        self.start_step: int = 0
        self.num_params: int = 0
        self.last_step_time: float = 0.0
        self.should_stop: bool = False
        self.stop_reason: Optional[str] = None
        self.checkpoint_manager = None

    # ------------------------------------------------------------------
    @classmethod
    def from_checkpoint(cls, directory: str,
                        callbacks: Optional[Iterable[cb_lib.Callback]] = None,
                        use_default_callbacks: bool = True) -> "Trainer":
        """Reconstruct the exact experiment from a checkpoint directory
        alone: the manifest-embedded ``ExperimentConfig`` is reloaded,
        ``stop_after`` (a one-shot simulated preemption, already consumed)
        is cleared, and ``checkpoint_dir`` is pointed at ``directory`` so
        the run restores and keeps checkpointing in place."""
        from repro.checkpoint import load_experiment
        import dataclasses
        cfg = load_experiment(directory)
        cfg = dataclasses.replace(cfg, train=dataclasses.replace(
            cfg.train, stop_after=None, checkpoint_dir=directory))
        return cls(cfg, callbacks=callbacks,
                   use_default_callbacks=use_default_callbacks)

    # ------------------------------------------------------------------
    def request_stop(self, reason: str = "requested") -> None:
        """Ask the loop to exit after this step's callbacks finish. The
        checkpointer runs after stop-requesting callbacks (priority order),
        so the stop is checkpointed before the loop breaks."""
        self.should_stop = True
        if self.stop_reason is None:
            self.stop_reason = reason

    def _fire(self, hook: str, *args) -> None:
        for cb in self.callbacks:
            getattr(cb, hook)(self, *args)

    # ------------------------------------------------------------------
    def fit(self) -> Dict[str, Any]:
        cfg = self.config
        tr = cfg.train
        self.mcfg, self.tcfg, self.data = cfg.build()
        mesh = make_host_mesh()
        if self.tcfg.use_graft and self.tcfg.graft.overlap:
            # refresh and train step as separate dispatches: the selection
            # forward pipelines with the train stream (same trajectory)
            from repro.selection.overlap import OverlappedSelector
            run_step = OverlappedSelector(self.mcfg, self.tcfg).step
        else:
            step_fn = steps_lib.make_train_step(self.mcfg, self.tcfg)
            jitted = jax.jit(step_fn, donate_argnums=(0,))

            def run_step(state, batch, step):
                return jitted(state, batch)

        history = []
        with sh.sharding_rules(mesh):
            self.state = steps_lib.init_train_state(
                self.mcfg, self.tcfg, jax.random.PRNGKey(tr.seed), tr.batch)
            self.num_params = sum(
                int(np.prod(l.shape)) for l in
                jax.tree_util.tree_leaves(self.state["params"]))
            self.start_step = 0
            # hooks may restore state + data-pipeline position (checkpoint
            # resume); the iterator is created only afterwards
            self._fire("on_train_start")
            it = iter(self.data)
            t_start = time.time()
            for step in range(self.start_step, tr.steps):
                batch_np = next(it)
                batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
                t0 = time.time()
                self.state, metrics = run_step(self.state, batch, step)
                metrics = {k: float(v) for k, v in metrics.items()}
                self.last_step_time = time.time() - t0
                self._fire("on_step_end", step, metrics)
                history.append(metrics)
                if self.should_stop:
                    break
            wall = time.time() - t_start
            report: Dict[str, Any] = {
                "final_loss": history[-1]["loss"] if history else None,
                "history": history,
                "wall_s": wall,
                "config_hash": cfg.config_hash(),
            }
            if self.stop_reason is not None:
                report["stopped"] = self.stop_reason
            self._fire("on_train_end", report)
        return report
