"""The ``Trainer``: a pure step-dispatch loop over a declarative
``ExperimentConfig``, with every side effect (checkpointing, eval,
telemetry, monitoring, early stop) delegated to ``Callback`` plugins.

The loop is ASYNC with respect to the device queue: a step's metrics leave
``run_step`` as device scalars and flow through ``on_step_end`` wrapped in
a lazy :class:`~repro.launch.metrics.MetricsFuture` — nothing on the step
path calls ``float()``, so the host keeps dispatching ahead (under
``graft.overlap`` the next refresh too) while the device drains earlier
steps. Materialization happens in bulk at flush boundaries (the
``MetricsCallback`` logger), at console/checkpoint boundaries, and when the
final report is assembled. ``last_step_time`` therefore times the step
DISPATCH, not device execution — the honest host-side number; the logger
reports the host-side gap on top of it as ``host_overhead_s``.

Typical use::

    from repro.api import ExperimentConfig, Trainer

    cfg = ExperimentConfig().apply_overrides(["train.steps=40"])
    report = Trainer(cfg).fit()

Resume needs nothing but the checkpoint directory — the finalized config
rides in the manifest::

    report = Trainer.from_checkpoint("/ckpts/run1").fit()
"""
from __future__ import annotations

import collections
import contextlib
import time
from typing import Any, Dict, Iterable, List, Optional

import jax
import numpy as np

from repro import backend as backend_lib
from repro.analysis.sync_guard import sync_allowed
from repro.api import callbacks as cb_lib
from repro.api.config import ExperimentConfig
from repro.distributed import sharding as sh
from repro.distributed.pipeline import BatchStager
from repro.launch import steps as steps_lib
from repro.launch.metrics import (DeviceClock, MetricsFuture,
                                  materialize_metrics)


class HistoryBuffer:
    """Bounded per-step history: with ``cap > 0`` keeps the FIRST row plus
    a tail window of the last ``cap`` rows (dropping the middle), so a
    million-step run doesn't hold every row — and every retained
    ``MetricsFuture`` — in host memory. ``cap == 0`` keeps everything
    (the historical behavior)."""

    def __init__(self, cap: int = 0):
        self.cap = cap
        self._first: Optional[Any] = None
        self._tail: collections.deque = collections.deque(
            maxlen=cap if cap > 0 else None)
        self.total = 0

    def append(self, row) -> None:
        # rows falling off the tail window are dropped UNMATERIALIZED —
        # a device future nobody will read again costs no sync
        if self.total == 0 and self.cap > 0:
            self._first = row
        else:
            self._tail.append(row)
        self.total += 1

    @property
    def last(self):
        if self._tail:
            return self._tail[-1]
        return self._first

    @property
    def dropped(self) -> int:
        return self.total - len(self._tail) - \
            (1 if self._first is not None else 0)

    def rows(self) -> List[Dict[str, float]]:
        """Materialized retained rows, oldest first."""
        out = ([self._first] if self._first is not None else []) + \
            list(self._tail)
        return [materialize_metrics(r) for r in out]


class Trainer:
    """Runs one experiment. ``callbacks`` are appended to the stock set
    derived from the config; pass ``use_default_callbacks=False`` to take
    full control of the plugin list."""

    def __init__(self, config: ExperimentConfig,
                 callbacks: Optional[Iterable[cb_lib.Callback]] = None,
                 use_default_callbacks: bool = True,
                 backend: Optional[backend_lib.Backend] = None):
        self.config = config.finalized()
        # how this run touches devices; ``None`` resolves the config's
        # tagged ``backend`` section (local when absent). The trainer
        # itself never constructs meshes or queries process topology —
        # lint rule LN004 enforces that boundary machine-wide.
        self.backend = (backend if backend is not None
                        else backend_lib.resolve(self.config.backend))
        cbs = list(cb_lib.default_callbacks(self.config)
                   if use_default_callbacks else [])
        if callbacks:
            cbs.extend(callbacks)
        self.callbacks = sorted(cbs, key=lambda c: c.priority)

        # populated by fit(); callbacks read these
        self.mcfg = None
        self.tcfg: Optional[steps_lib.TrainConfig] = None
        self.data = None
        self.state = None
        self.start_step: int = 0
        self.num_params: int = 0
        self.last_step_time: float = 0.0
        self.device_clock: Optional[DeviceClock] = None
        self.should_stop: bool = False
        self.stop_reason: Optional[str] = None
        self.checkpoint_manager = None
        # resilience: set by the DivergenceGuardCallback, consumed by the
        # loop (rollback) and the CheckpointCallback (save refusal)
        self.sentinel_tripped: bool = False
        self.rollbacks: List[Dict[str, Any]] = []
        self._rollback_reason: Optional[str] = None
        self._chaos = None
        self._stager: Optional[BatchStager] = None

    # ------------------------------------------------------------------
    @classmethod
    def from_checkpoint(cls, directory: str,
                        callbacks: Optional[Iterable[cb_lib.Callback]] = None,
                        use_default_callbacks: bool = True) -> "Trainer":
        """Reconstruct the exact experiment from a checkpoint directory
        alone: the manifest-embedded ``ExperimentConfig`` is reloaded,
        ``stop_after`` (a one-shot simulated preemption, already consumed)
        and ``fault_plan`` (injected faults must not replay into the
        recovered run) are cleared, and ``checkpoint_dir`` is pointed at
        ``directory`` so the run restores and keeps checkpointing in
        place. The embedded ``backend`` section is cleared too — resume is
        ELASTIC: the restart picks its own topology (local by default;
        pass ``backend=`` or re-launch with ``--backend.*`` overrides for
        multi-process), and ``restore`` reshards the state onto it."""
        from repro.checkpoint import load_experiment
        import dataclasses
        cfg = load_experiment(directory)
        cfg = dataclasses.replace(cfg, backend=None,
                                  train=dataclasses.replace(
            cfg.train, stop_after=None, fault_plan=None,
            checkpoint_dir=directory))
        return cls(cfg, callbacks=callbacks,
                   use_default_callbacks=use_default_callbacks)

    # ------------------------------------------------------------------
    def data_state(self) -> Dict[str, int]:
        """The data-pipeline state a checkpoint must record: the position
        of the last CONSUMED batch. With staging lookahead the live source
        runs ahead of the loop, so the stager's accounting is the truth."""
        if self._stager is not None:
            return self._stager.consumed_state()
        return self.data.state_dict()

    # ------------------------------------------------------------------
    def request_stop(self, reason: str = "requested") -> None:
        """Ask the loop to exit after this step's callbacks finish. The
        checkpointer runs after stop-requesting callbacks (priority order),
        so the stop is checkpointed before the loop breaks."""
        self.should_stop = True
        if self.stop_reason is None:
            self.stop_reason = reason

    def request_rollback(self, reason: str = "diverged") -> None:
        """Ask the loop to restore the last healthy checkpoint after this
        step's callbacks finish (the DivergenceGuardCallback's trip path).
        Without a checkpoint manager the run stops instead — continuing a
        diverged trajectory would only burn compute."""
        if self._rollback_reason is None:
            self._rollback_reason = reason

    def _fire(self, hook: str, *args) -> None:
        for cb in self.callbacks:
            getattr(cb, hook)(self, *args)

    def _fire_abort(self) -> None:
        """Best-effort cleanup when fit() is exiting on an exception and
        ``on_train_end`` will never run — each callback gets its shot even
        if an earlier one fails."""
        for cb in self.callbacks:
            try:
                cb.on_train_abort(self)
            except Exception as e:                      # noqa: BLE001
                print(f"[train] abort cleanup error in "
                      f"{type(cb).__name__}: {e}", flush=True)

    def _perform_rollback(self, at_step: int) -> Optional[int]:
        """Restore the newest checkpoint that verifies + is stamped healthy
        and rewind the data pipeline to it. Returns the step to resume from,
        or ``None`` (with a stop requested) when no rollback is possible."""
        reason = self._rollback_reason
        self._rollback_reason = None
        mgr = self.checkpoint_manager
        if mgr is None:
            print(f"[train] divergence ({reason}) with no checkpoint "
                  "manager — stopping", flush=True)
            self.request_stop("diverged")
            return None
        with sync_allowed("rollback"):
            mgr.wait()
            try:
                _, tree, manifest = mgr.restore_latest_good(
                    self.state, backend=self.backend)
            except FileNotFoundError:
                print(f"[train] divergence ({reason}) and no healthy "
                      "checkpoint to roll back to — stopping", flush=True)
                self.request_stop("diverged")
                return None
            self.state = tree
            self.data.load_state_dict(manifest["extra"]["data"])
            if self._stager is not None:
                # staged-ahead batches predate the rewind — drop them
                self._stager.reset()
        resume = int(manifest["extra"]["train_step"])
        self.sentinel_tripped = False
        self.rollbacks.append(
            {"at_step": at_step, "to_step": resume, "reason": reason})
        print(f"[train] ROLLBACK at step {at_step}: {reason} — resumed "
              f"from checkpoint step {resume}", flush=True)
        return resume

    # ------------------------------------------------------------------
    def fit(self) -> Dict[str, Any]:
        cfg = self.config
        tr = cfg.train
        from repro.resilience import chaos as chaos_lib
        self._chaos = chaos_lib.load_plan(tr.fault_plan)
        if self._chaos is not None:
            # module-global so the checkpoint writer (its own thread) sees
            # the crash points too
            chaos_lib.activate(self._chaos)
        # backend first: distributed bring-up must precede ANY device query
        # (mesh construction, data sharding, state init all depend on it)
        self.backend.setup()
        self.backend.check_consistent(cfg.config_hash())
        self.mcfg, self.tcfg, self.data = cfg.build(backend=self.backend)
        mesh = self.backend.mesh()
        run_step = steps_lib.make_run_step(self.mcfg, self.tcfg)

        history = HistoryBuffer(cap=tr.history_cap)
        dispatched_ahead = 0
        dispatch_s = 0.0
        prev_row: Optional[MetricsFuture] = None
        if tr.device_timing:
            self.device_clock = DeviceClock(
                stall_timeout_s=tr.device_timeout_s or None)
        audit_guard = watcher = None
        if tr.audit:
            # fail-fast enforcement of the async-loop contract: any host
            # sync outside a sync_allowed(...) site raises at the call
            # site; any step-signature drift (→ jit re-trace) raises too
            from repro.analysis.recompile import RecompileWatcher
            from repro.analysis.sync_guard import SyncGuard
            audit_guard = SyncGuard(strict=True, label="train.audit")
            watcher = RecompileWatcher(label="run_step")
        completed = False
        try:
            with sh.sharding_rules(mesh):
                self.state = steps_lib.init_train_state(
                    self.mcfg, self.tcfg, jax.random.PRNGKey(tr.seed),
                    tr.batch)
                # every process computes the identical init (same PRNGKey);
                # replicate makes it the backend's resident form (identity
                # on local — bit-identical to the pre-backend loop)
                self.state = self.backend.replicate(self.state)
                self.num_params = sum(
                    int(np.prod(l.shape)) for l in
                    jax.tree_util.tree_leaves(self.state["params"]))
                self.start_step = 0
                # hooks may restore state + data-pipeline position
                # (checkpoint resume); the iterator is created only after
                self._fire("on_train_start")
                it = None
                if self._chaos is not None:
                    # chaos corrupts HOST batches per step — keep the plain
                    # pull→corrupt→stage path (no lookahead) so injection
                    # sees the batch before it leaves the host
                    it = iter(self.data)
                else:
                    self._stager = BatchStager(
                        self.data, self.backend.shard_batch,
                        depth=self.backend.staging_depth)
                t_start = time.time()
                with contextlib.ExitStack() as audit_scope:
                    if audit_guard is not None:
                        # guard covers the step loop only — state init,
                        # restore hooks, and report assembly sync
                        # legitimately
                        audit_scope.enter_context(audit_guard)
                    step = self.start_step
                    while step < tr.steps:
                        if self._chaos is not None:
                            self._chaos.fire_signals(step)
                            batch_np = self._chaos.corrupt_batch(
                                step, next(it))
                            batch = self.backend.shard_batch(batch_np)
                        else:
                            batch = next(self._stager)
                        if watcher is not None:
                            drift = watcher.observe(step=step,
                                                    state=self.state,
                                                    batch=batch)
                            if drift:
                                raise RuntimeError(
                                    "[train.audit] " +
                                    "; ".join(f.message for f in drift))
                        t0 = time.time()
                        self.state, dev_metrics = run_step(self.state, batch,
                                                           step)
                        self.last_step_time = time.time() - t0
                        dispatch_s += self.last_step_time
                        if self.device_clock is not None and dev_metrics:
                            # metrics are detached (jnp.copy) — safe for
                            # the clock thread to hold while donated
                            # buffers are reused
                            marker = dev_metrics.get(
                                "loss", next(iter(dev_metrics.values())))
                            if self._chaos is not None:
                                marker = self._chaos.wrap_marker(step,
                                                                 marker)
                            self.device_clock.observe(step, marker)
                        # dispatch accounting: run_step returning means
                        # step N is ISSUED; if step N−1's metrics are
                        # still device futures at that point, the host ran
                        # ahead of the device queue
                        if prev_row is not None and not prev_row.materialized:
                            dispatched_ahead += 1
                        metrics = MetricsFuture(dev_metrics)
                        prev_row = metrics
                        self._fire("on_step_end", step, metrics)
                        history.append(metrics)
                        if self._rollback_reason is not None:
                            resumed = self._perform_rollback(step)
                            if resumed is not None:
                                step = resumed
                                prev_row = None
                                continue
                        if self.should_stop:
                            break
                        step += 1
                wall = time.time() - t_start
                last = history.last
                report: Dict[str, Any] = {
                    "final_loss": last["loss"] if last is not None else None,
                    "history": history.rows(),
                    "wall_s": wall,
                    "config_hash": cfg.config_hash(),
                    "host_loop": {
                        "steps": history.total,
                        "dispatched_ahead": dispatched_ahead,
                        "dispatch_s": dispatch_s,
                    },
                }
                if self.device_clock is not None:
                    self.device_clock.drain()
                    report["host_loop"]["device_timed_steps"] = \
                        self.device_clock.timed_steps
                    report["host_loop"]["device_time_s"] = \
                        self.device_clock.total_device_s
                    if self.device_clock.stalled:
                        report["host_loop"]["device_stalled"] = True
                if audit_guard is not None:
                    report["audit"] = {
                        "sync_events": len(audit_guard.events),
                        "unsanctioned": len(audit_guard.violations),
                        "sync_sites": {f"{site}:{kind}": n
                                       for (site, kind), n
                                       in sorted(audit_guard.site_counts()
                                                 .items())},
                        "recompiles": len(watcher.findings),
                    }
                if history.dropped:
                    report["history_dropped"] = history.dropped
                if self.stop_reason is not None:
                    report["stopped"] = self.stop_reason
                if self.rollbacks:
                    report["resilience"] = {"rollbacks": self.rollbacks}
                self._fire("on_train_end", report)
            completed = True
            return report
        finally:
            if not completed:
                # exiting on an exception: on_train_end never fires, but
                # signal handlers / open files / writer threads must still
                # be released (chaos crash tests restart in-process)
                self._fire_abort()
            if self._chaos is not None:
                chaos_lib.deactivate()
                self._chaos = None
            if self._stager is not None:
                self._stager.close()
                self._stager = None
            if self.device_clock is not None:
                self.device_clock.close()
            self.backend.teardown()
