"""`python -m repro.api` — declarative experiment launcher.

Three input sources, later ones winning:

  1. defaults (``ExperimentConfig()``)
  2. ``--config exp.json`` — a saved config file
  3. flat dotted overrides: ``--train.steps=5 --graft.eps=0.3``
     (``--graft=none`` disables selection; values are JSON, falling back
     to strings). ``--data.source=<name>`` swaps the training workload to
     any registered task/data source (``repro.data.sources``) — put
     model/train overrides BEFORE it, per-source ``--data.field=value``
     overrides after.

``--resume DIR`` ignores all of the above and reconstructs the experiment
from the manifest embedded in ``DIR``'s latest checkpoint.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import List, Optional

from repro.api.config import ExperimentConfig
from repro.api.trainer import Trainer


def _split_args(argv: List[str]):
    """Separate known flags from --section.field=value overrides."""
    ap = argparse.ArgumentParser(
        prog="python -m repro.api",
        description=__doc__, formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--config", default=None,
                    help="path to an ExperimentConfig JSON file")
    ap.add_argument("--resume", default=None, metavar="CKPT_DIR",
                    help="resume from a checkpoint directory's embedded config")
    ap.add_argument("--dump-config", action="store_true",
                    help="print the finalized config JSON and exit (no training)")
    args, rest = ap.parse_known_args(argv)
    overrides = []
    for tok in rest:
        if tok.startswith("--") and "=" in tok:
            overrides.append(tok[2:])
        else:
            ap.error(f"unrecognized argument '{tok}' "
                     "(overrides use --section.field=value)")
    return args, overrides


def main(argv: Optional[List[str]] = None) -> int:
    args, overrides = _split_args(sys.argv[1:] if argv is None else argv)

    if args.resume:
        if overrides or args.config:
            print("error: --resume reconstructs the experiment from the "
                  "manifest alone; drop the other flags", file=sys.stderr)
            return 2
        trainer = Trainer.from_checkpoint(args.resume)
        if args.dump_config:
            print(trainer.config.to_json(indent=1))
            return 0
    else:
        cfg = (ExperimentConfig.load(args.config) if args.config
               else ExperimentConfig())
        cfg = cfg.apply_overrides(overrides)
        if args.dump_config:
            print(cfg.finalized().to_json(indent=1))
            return 0
        trainer = Trainer(cfg)

    report = trainer.fit()
    print(json.dumps({k: v for k, v in report.items() if k != "history"},
                     indent=1))
    return 0
