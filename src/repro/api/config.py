"""Declarative experiment configuration — the single source of truth for a
training run.

``ExperimentConfig`` owns six subsections:

  * ``model``     — which architecture (registry id), smoke vs full, field
                    overrides (``repro.api.ModelConfig``)
  * ``train``     — loop-level knobs: steps, batch, seq, sampler, telemetry
                    and checkpoint cadence (``repro.api.TrainConfig``)
  * ``graft``     — the paper's selection hyper-parameters, or ``None`` for
                    the full-batch baseline (``repro.selection.GraftConfig``)
  * ``data``      — a TAGGED section: any config registered in the
                    task/data-source registry (``repro.data.sources``),
                    serialized with its ``source`` name. ``None`` derives
                    the default ``synthetic_lm`` section from model + train;
                    ``--data.source=synthetic_classification`` swaps the
                    workload (per-source fields then override on top)
  * ``optimizer`` — ``repro.optim.OptimizerConfig``; ``total_steps``/
                    ``warmup_steps`` of 0 mean "derive from train.steps"
  * ``backend``   — a TAGGED section like ``data``: any execution backend
                    registered in ``repro.backend`` (serialized with its
                    ``kind`` name). ``None`` means single-process local
                    execution; ``--backend.kind=multiprocess`` swaps it.
                    The section is HASH-NEUTRAL: where a run executes never
                    changes which experiment it is, so local and
                    multi-process runs of one config share a ``config_hash``
                    (which is what lets a checkpoint resume elastically on
                    a different topology)

Round-trips losslessly through JSON (``to_json``/``from_json``), accepts
flat dotted CLI overrides (``apply_overrides(["train.steps=5",
"graft.eps=0.3"])``), and hashes canonically (``config_hash()`` covers only
the fields that affect the training trajectory, so an interrupted run and
its uninterrupted twin agree). The finalized config is embedded in every
checkpoint manifest, which is what lets ``Trainer.from_checkpoint`` rebuild
the exact experiment from the directory alone.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Any, Dict, Iterable, Optional, Tuple

from repro import backend as backend_lib
from repro.data import DataConfig
from repro.data import sources as data_sources
from repro.optim import OptimizerConfig
from repro.selection.base import GraftConfig


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Declarative model selection: an architecture-registry id plus
    optional field overrides, resolved through ``repro.configs``."""
    arch: str = "minicpm-2b"
    smoke: bool = True                  # smoke (CPU-sized) vs published config
    overrides: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def build(self, extra_overrides: Optional[Dict[str, Any]] = None):
        """``extra_overrides`` are the task-pinned fields of the data
        source's adapter (vocab = class count, input frontend) — they win
        over user overrides, since a conflicting user value could only
        produce a mismatched head or frontend downstream."""
        from repro import configs as config_lib
        ov = dict(self.overrides)
        if extra_overrides:
            ov.update(extra_overrides)
        return (config_lib.get_smoke_config(self.arch, **ov) if self.smoke
                else config_lib.get_config(self.arch, **ov))


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    """Loop-level training knobs (the trajectory-shaping ones are hashed;
    paths/cadences/stop_after are run-environment and are not)."""
    steps: int = 100
    batch: int = 16
    seq: int = 64
    seed: int = 0
    sampler: str = "graft"              # any repro.selection registry name
    probe_positions: int = 0            # 0 = derive min(64, seq)
    microbatches: int = 1
    # --- run environment (excluded from config_hash) ---
    log_every: int = 10
    eval_every: int = 0                 # 0 = no held-out evaluation
    sync_eval: bool = False             # True: eval blocks inside the step
                                        # loop (tests); False: side-stream
                                        # dispatch, collected at the next
                                        # eval boundary (same numbers)
    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 50
    metrics_path: Optional[str] = None  # JSONL telemetry stream
    metrics_flush_every: int = 20       # rows per JSONL drain (host sync
                                        # cadence of the async metrics path)
    history_cap: int = 0                # >0: keep first + last N history
                                        # rows in the report (0 = all)
    stop_after: Optional[int] = None    # simulate preemption after N steps
    device_timing: bool = True          # DeviceClock completion stamps:
                                        # mfu/straggler see device time,
                                        # not dispatch jitter
    audit: bool = False                 # wrap the step loop in the
                                        # repro.analysis SyncGuard +
                                        # RecompileWatcher; fail on a host
                                        # sync outside sanctioned sites or
                                        # a step-function re-trace
    sentinel: bool = True               # on-device divergence sentinel:
                                        # fused health word + skip-update
                                        # (bit-neutral on healthy steps,
                                        # hence non-semantic)
    spike_z: float = 6.0                # loss-spike z-score vs the EMA in
                                        # train state (0 = finite-only)
    bad_step_patience: int = 10         # consecutive bad steps before the
                                        # guard rolls back to last-good
    device_timeout_s: float = 60.0      # DeviceClock stall watchdog; 0
                                        # disables it
    fault_plan: Optional[str] = None    # chaos harness: inline JSON or a
                                        # path (see repro.resilience.chaos);
                                        # REPRO_FAULT_PLAN env also works


# train fields that do not affect the optimization trajectory: two runs that
# differ only here are the same experiment (same config_hash). The
# resilience knobs qualify because the sentinel is bit-exact on healthy
# steps and a fault plan only perturbs a run that would otherwise be lost —
# an injected run and its clean twin must share a hash for resume to work.
_NONSEMANTIC_TRAIN_FIELDS = ("log_every", "eval_every", "sync_eval",
                             "checkpoint_dir", "checkpoint_every",
                             "metrics_path", "metrics_flush_every",
                             "history_cap", "stop_after", "device_timing",
                             "audit", "sentinel", "spike_z",
                             "bad_step_patience", "device_timeout_s",
                             "fault_plan")

_SECTION_TYPES = {
    "model": ModelConfig,
    "train": TrainConfig,
    "graft": GraftConfig,
    "data": DataConfig,      # the DEFAULT source; actual class is registry-tagged
    "optimizer": OptimizerConfig,
    "backend": backend_lib.LocalBackendConfig,  # registry-tagged like data
}
_OPTIONAL_SECTIONS = ("graft", "data", "backend")


@dataclasses.dataclass(frozen=True)
class ExperimentConfig:
    model: ModelConfig = ModelConfig()
    train: TrainConfig = TrainConfig()
    graft: Optional[GraftConfig] = GraftConfig(
        rset=(2, 4, 8), eps=0.25, refresh_every=5, grad_mode="probe")
    data: Optional[Any] = None          # any registered data-source config
    optimizer: OptimizerConfig = OptimizerConfig(
        name="adamw", learning_rate=3e-4, schedule="cosine",
        total_steps=0, warmup_steps=0)
    backend: Optional[Any] = None       # any registered backend config
                                        # (None = single-process local)

    # ------------------------------------------------------------------
    # derivation
    # ------------------------------------------------------------------
    def finalized(self) -> "ExperimentConfig":
        """Materialize every derived field so the config is self-contained
        (this is the form embedded in checkpoint manifests). Idempotent."""
        train = self.train
        if train.probe_positions <= 0:
            train = dataclasses.replace(
                train, probe_positions=min(64, train.seq))
        opt = self.optimizer
        if opt.total_steps <= 0:
            opt = dataclasses.replace(opt, total_steps=train.steps)
        if opt.warmup_steps <= 0:
            opt = dataclasses.replace(
                opt, warmup_steps=max(train.steps // 20, 1))
        data = self.data
        if data is None:
            data = data_sources.derive_config(
                "synthetic_lm", self.model.build(), batch=train.batch,
                seq=train.seq, seed=train.seed)
        elif data_sources.entry_for_config(data).task.finalize is not None:
            # explicit section with derivable sentinels (embed_dim /
            # global_batch of 0): fill them against model + train
            data = data_sources.finalize_config(
                data, self.model.build(), batch=train.batch, seq=train.seq,
                seed=train.seed)
        return dataclasses.replace(self, train=train, optimizer=opt, data=data)

    # ------------------------------------------------------------------
    # builders (the Trainer's inputs)
    # ------------------------------------------------------------------
    def build(self, backend: Optional[Any] = None):
        """→ (model config, step-level TrainConfig, data pipeline).

        Everything data-shaped resolves through the task/data-source
        registry: the adapter pins the model fields the task requires
        (vocab = class count, input frontend) and validates that an
        explicit ``data`` section agrees with model/train — a mismatched
        vocab silently NaNs the loss (out-of-range token ids clamp in
        gather), and a mismatched batch/embed-dim fails with an opaque jit
        shape error; both deserve a loud message instead.

        ``backend`` (a live ``repro.backend.Backend``) shards the data
        pipeline to this process's slice of every global batch. The shard
        is applied at build time only — the config section itself stays
        rank-agnostic so every process hashes/serializes identically."""
        from repro.launch import steps as steps_lib
        cfg = self.finalized()
        tr, d = cfg.train, cfg.data
        entry = data_sources.entry_for_config(d)
        mcfg = cfg.model.build(extra_overrides=entry.task.model_overrides(d))
        mismatches = entry.task.validate(d, mcfg, tr.batch, tr.seq)
        if mismatches:
            raise ValueError(
                f"data section ({entry.name}) disagrees with model/train: "
                + "; ".join(mismatches)
                + " — fix the fields, or re-derive by putting model/train "
                f"overrides BEFORE data.source={entry.name}")
        sampler = tr.sampler
        if cfg.graft is not None and cfg.graft.streaming and sampler == "graft":
            # graft.streaming=true is declarative shorthand for the
            # streaming sampler; an explicit non-default sampler wins
            sampler = "streaming_graft"
        tcfg = steps_lib.TrainConfig(
            optimizer=cfg.optimizer, graft=cfg.graft,
            sampler=sampler,
            probe_positions=tr.probe_positions,
            microbatches=tr.microbatches,
            sentinel=tr.sentinel, spike_z=tr.spike_z)
        if backend is not None:
            d = data_sources.shard_for_backend(d, backend)
        return mcfg, tcfg, entry.build(d)

    # ------------------------------------------------------------------
    # serialization
    # ------------------------------------------------------------------
    def to_dict(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {}
        for name in _SECTION_TYPES:
            section = getattr(self, name)
            out[name] = None if section is None else _section_to_dict(section)
        if out["data"] is not None:
            # tag the section with its registry name — except the default
            # LM source, which stays untagged so pre-registry configs keep
            # their config_hash (from_dict reads a missing tag as LM)
            name = data_sources.source_name_of(self.data)
            if name != "synthetic_lm":
                out["data"]["source"] = name
        if out["backend"] is not None:
            name = backend_lib.backend_name_of(self.backend)
            if name != "local":         # missing tag reads as local
                out["backend"]["kind"] = name
        return out

    def to_json(self, indent: Optional[int] = None) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True)

    @classmethod
    def from_dict(cls, d: Dict[str, Any]) -> "ExperimentConfig":
        kwargs: Dict[str, Any] = {}
        for name, typ in _SECTION_TYPES.items():
            raw = d.get(name)
            if raw is None:
                if name in _OPTIONAL_SECTIONS:
                    kwargs[name] = None
                    continue
                raise KeyError(f"experiment dict missing section '{name}'")
            if name == "data":
                kwargs[name] = _data_section_from_dict(raw)
            elif name == "backend":
                kwargs[name] = _backend_section_from_dict(raw)
            else:
                kwargs[name] = _section_from_dict(typ, raw)
        return cls(**kwargs)

    @classmethod
    def from_json(cls, s: str) -> "ExperimentConfig":
        return cls.from_dict(json.loads(s))

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json(indent=1) + "\n")

    @classmethod
    def load(cls, path: str) -> "ExperimentConfig":
        with open(path) as f:
            return cls.from_json(f.read())

    # ------------------------------------------------------------------
    # identity
    # ------------------------------------------------------------------
    def config_hash(self) -> str:
        """Stable 12-hex digest over the trajectory-shaping fields of the
        finalized config. Run-environment fields (paths, cadences,
        ``stop_after``) are excluded, so a preempted run, its resume, and an
        uninterrupted twin all share one hash."""
        d = self.finalized().to_dict()
        for f in _NONSEMANTIC_TRAIN_FIELDS:
            d["train"].pop(f, None)
        # WHERE a run executes never changes WHICH experiment it is: the
        # whole backend section is hash-neutral (elastic resume depends on
        # a multi-process resume matching its local-run checkpoint's hash)
        d.pop("backend", None)
        if d.get("graft"):
            # dispatch-schedule knobs: the overlapped and sequential paths
            # produce the same trajectory (tested), so they share a hash
            d["graft"].pop("overlap", None)
            # the streaming-reservoir knobs only shape the trajectory when
            # the streaming sampler is actually selected; popping them
            # otherwise keeps pre-streaming configs' hashes stable
            streaming_on = (d["graft"].get("streaming")
                            or d["train"].get("sampler") == "streaming_graft")
            if not streaming_on:
                for f in ("streaming", "sketch_rows", "sketch_decay",
                          "stream_mix"):
                    d["graft"].pop(f, None)
        blob = json.dumps(d, sort_keys=True).encode()
        return hashlib.sha256(blob).hexdigest()[:12]

    # ------------------------------------------------------------------
    # flat CLI overrides
    # ------------------------------------------------------------------
    def apply_overrides(self, pairs: Iterable[str]) -> "ExperimentConfig":
        """Apply flat ``section.field=value`` overrides (values parsed as
        JSON, falling back to string). ``graft=none`` / ``data=none`` clear
        an optional section; a ``graft.*`` override on a disabled section
        re-enables it from defaults first."""
        cfg = self
        for pair in pairs:
            if "=" not in pair:
                raise ValueError(f"override '{pair}' is not key=value")
            key, raw = pair.split("=", 1)
            cfg = _apply_one(cfg, key.strip(), raw.strip())
        return cfg


# ---------------------------------------------------------------------------
# (de)serialization helpers
# ---------------------------------------------------------------------------

def _section_to_dict(section) -> Dict[str, Any]:
    out = {}
    for f in dataclasses.fields(section):
        v = getattr(section, f.name)
        out[f.name] = list(v) if isinstance(v, tuple) else v
    return out


def _data_section_from_dict(raw: Dict[str, Any]):
    """The ``data`` section is tagged: ``{"source": <registry name>,
    **fields}``. A missing tag reads as ``synthetic_lm`` (pre-registry
    manifests)."""
    raw = dict(raw)
    name = raw.pop("source", "synthetic_lm")
    return _section_from_dict(data_sources.get_source(name).config_cls, raw)


def _backend_section_from_dict(raw: Dict[str, Any]):
    """The ``backend`` section is tagged: ``{"kind": <registry name>,
    **fields}``. A missing tag reads as ``local`` (pre-backend manifests
    serialized no section at all, which ``from_dict`` maps to ``None``)."""
    raw = dict(raw)
    name = raw.pop("kind", "local")
    return _section_from_dict(backend_lib.get_backend(name).config_cls, raw)


def _section_from_dict(typ, raw: Dict[str, Any]):
    defaults = typ()
    kwargs = {}
    names = {f.name for f in dataclasses.fields(typ)}
    unknown = set(raw) - names
    if unknown:
        raise KeyError(f"unknown {typ.__name__} field(s): {sorted(unknown)}")
    for name in raw:
        v = raw[name]
        if isinstance(v, list) and isinstance(getattr(defaults, name), tuple):
            v = tuple(v)
        kwargs[name] = v
    return typ(**kwargs)


def _parse_value(raw: str) -> Any:
    low = raw.lower()
    if low in ("none", "null"):
        return None
    try:
        return json.loads(raw)
    except (ValueError, json.JSONDecodeError):
        return raw


def _coerce(value: Any, current: Any) -> Any:
    if isinstance(current, tuple) and isinstance(value, list):
        return tuple(value)
    if isinstance(current, tuple) and isinstance(value, str):
        # "2,4,8" CLI shorthand for a JSON list
        return tuple(_parse_value(v) for v in value.split(",") if v)
    if isinstance(current, bool) and isinstance(value, int) \
            and not isinstance(value, bool):
        return bool(value)
    if isinstance(current, float) and isinstance(value, int) \
            and not isinstance(value, bool):
        return float(value)
    return value


def _derive_data(cfg: ExperimentConfig, source: str):
    """Fully-materialized default ``data`` section for ``source`` against
    ``cfg``'s model + train."""
    return data_sources.derive_config(
        source, cfg.model.build(), batch=cfg.train.batch, seq=cfg.train.seq,
        seed=cfg.train.seed)


def _apply_one(cfg: ExperimentConfig, key: str, raw: str) -> ExperimentConfig:
    value = _parse_value(raw)
    if "." not in key:                       # whole-section assignment
        if key not in _SECTION_TYPES:
            raise KeyError(f"unknown config section '{key}' "
                           f"(have {sorted(_SECTION_TYPES)})")
        if value is None:
            if key not in _OPTIONAL_SECTIONS:
                raise ValueError(f"section '{key}' cannot be disabled")
            return dataclasses.replace(cfg, **{key: None})
        if isinstance(value, dict):
            if key == "data":
                section = _data_section_from_dict(value)
            elif key == "backend":
                section = _backend_section_from_dict(value)
            else:
                section = _section_from_dict(_SECTION_TYPES[key], value)
            return dataclasses.replace(cfg, **{key: section})
        raise ValueError(f"override '{key}={raw}': expected none or a dict")

    section_name, field = key.split(".", 1)
    if section_name not in _SECTION_TYPES:
        raise KeyError(f"unknown config section '{section_name}' "
                       f"(have {sorted(_SECTION_TYPES)})")
    if (section_name, field) == ("data", "source"):
        # workload swap: a fresh section for the named source, derived from
        # model/train (per-source field overrides then apply on top)
        if not isinstance(value, str):
            raise ValueError(f"data.source expects a registry name "
                             f"(have {data_sources.available_sources()})")
        if cfg.data is not None and \
                data_sources.source_name_of(cfg.data) == value:
            return cfg
        return dataclasses.replace(cfg, data=_derive_data(cfg, value))
    if (section_name, field) == ("backend", "kind"):
        # execution swap: default config for the named backend; per-backend
        # field overrides (coordinator, num_processes…) then apply on top
        if not isinstance(value, str):
            raise ValueError(f"backend.kind expects a registry name "
                             f"(have {backend_lib.available_backends()})")
        if cfg.backend is not None and \
                backend_lib.backend_name_of(cfg.backend) == value:
            return cfg
        if value == "local" and cfg.backend is None:
            return cfg                       # None already means local
        return dataclasses.replace(
            cfg, backend=backend_lib.get_backend(value).config_cls())
    section = getattr(cfg, section_name)
    if section is None:                      # re-enable optional section
        if section_name == "graft":
            section = ExperimentConfig().graft
        elif section_name == "backend":
            # backend fields live on per-kind config classes; local (the
            # None default) has none, so a field override needs the kind
            # set first: --backend.kind=multiprocess --backend.field=...
            section = backend_lib.LocalBackendConfig()
        else:
            # data: derive from model/train so vocab/batch/seq agree —
            # raw DataConfig() defaults would silently mismatch the model
            section = cfg.finalized().data
    # data/backend sections' concrete classes are registry-tagged, not the
    # static table entry — fields resolve against the live section
    typ = type(section) if section_name in ("data", "backend") \
        else _SECTION_TYPES[section_name]
    names = {f.name for f in dataclasses.fields(typ)}
    if field not in names:
        raise KeyError(f"unknown field '{field}' in section "
                       f"'{section_name}' (have {sorted(names)})")
    value = _coerce(value, getattr(section, field))
    new_section = dataclasses.replace(section, **{field: value})
    new_cfg = dataclasses.replace(cfg, **{section_name: new_section})
    return _refresh_derived(cfg, new_cfg, section_name, field)


def _refresh_derived(old: ExperimentConfig, new: ExperimentConfig,
                     section_name: str, field: str) -> ExperimentConfig:
    """Overrides may land on a previously-``finalized()`` config (the form
    ``--dump-config`` emits and the manifest embeds). Any field that was
    DERIVED there — i.e. still equals the old config's derivation — is reset
    to its sentinel so ``finalized()`` re-derives it against the new values;
    explicitly-set fields are untouched, as is the section being overridden.
    Without this, ``--train.steps=500`` on a dumped 5-step config would keep
    a cosine horizon of 5 and train 495 steps at ~zero LR."""
    if section_name != "optimizer":
        opt, repl = new.optimizer, {}
        if opt.total_steps in (0, old.train.steps):
            repl["total_steps"] = 0
        if opt.warmup_steps in (0, max(old.train.steps // 20, 1)):
            repl["warmup_steps"] = 0
        if repl:
            new = dataclasses.replace(
                new, optimizer=dataclasses.replace(opt, **repl))
    if (section_name, field) != ("train", "probe_positions") \
            and new.train.probe_positions in (0, min(64, old.train.seq)):
        new = dataclasses.replace(new, train=dataclasses.replace(
            new.train, probe_positions=0))
    if section_name != "data" and new.data is not None:
        source = data_sources.source_name_of(new.data)
        if new.data == _derive_data(old, source):
            # the section was (still) fully derived: re-derive it for the
            # new model/train instead of keeping stale vocab/batch/dims.
            # For the default source the None sentinel keeps finalized()
            # as the single derivation point.
            new = dataclasses.replace(
                new, data=None if source == "synthetic_lm"
                else _derive_data(new, source))
    return new


# convenience alias used by the CLI and tests
def apply_overrides(cfg: ExperimentConfig,
                    pairs: Iterable[str]) -> ExperimentConfig:
    return cfg.apply_overrides(pairs)
