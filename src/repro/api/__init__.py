"""Unified Experiment API — the public entry point for training runs.

One declarative ``ExperimentConfig`` (JSON round-trip, flat CLI overrides,
stable ``config_hash``), one pluggable ``Trainer`` whose side effects are
``Callback`` plugins, and registries for every strategy axis: samplers
(``repro.selection.registry``), feature extractors and gradient sources
(``repro.selection.sources``), and task/data sources (``repro.data.sources``
— swap the workload with ``--data.source=synthetic_classification``).

Quickstart::

    from repro.api import ExperimentConfig, TrainConfig, Trainer

    cfg = ExperimentConfig(train=TrainConfig(steps=40, batch=16, seq=64))
    report = Trainer(cfg).fit()
    print(report["final_loss"], report["config_hash"])

Resume from a checkpoint directory alone (the config rides in the
manifest)::

    report = resume("/ckpts/run1")

CLI::

    python -m repro.api --model.arch=minicpm-2b --train.steps=5
    python -m repro.api --config exp.json --graft.feature_mode=pca_sketch
    python -m repro.api --data.source=synthetic_classification --train.steps=5
    python -m repro.api --resume /ckpts/run1
"""
from repro.api.callbacks import (Callback, CheckpointCallback,
                                 ConsoleCallback, EvalCallback, HookRecorder,
                                 MetricsCallback, PreemptionCallback,
                                 StragglerCallback, default_callbacks)
from repro.api.config import (DataConfig, ExperimentConfig, GraftConfig,
                              ModelConfig, OptimizerConfig, TrainConfig,
                              apply_overrides)
from repro.api.trainer import Trainer
from repro.data.sources import (ClassificationConfig, VisionConfig,
                                available_sources as available_data_sources)

__all__ = [
    "ExperimentConfig", "ModelConfig", "TrainConfig", "GraftConfig",
    "DataConfig", "ClassificationConfig", "VisionConfig",
    "available_data_sources", "OptimizerConfig", "apply_overrides",
    "Trainer", "run", "resume",
    "Callback", "default_callbacks", "PreemptionCallback", "EvalCallback",
    "MetricsCallback", "StragglerCallback", "ConsoleCallback",
    "CheckpointCallback", "HookRecorder",
]


def run(config: ExperimentConfig, callbacks=None):
    """Train ``config`` to completion; returns the report dict."""
    return Trainer(config, callbacks=callbacks).fit()


def resume(directory: str, callbacks=None):
    """Resume the experiment whose config is embedded in ``directory``'s
    latest checkpoint manifest; returns the report dict."""
    return Trainer.from_checkpoint(directory, callbacks=callbacks).fit()
