"""Per-sample gradient features for GRAFT's rank-selection stage.

Two modes (DESIGN.md §3 hardware adaptation):

* ``full``  — exact per-sample gradients of the whole parameter pytree via
  ``vmap(grad)``. Matches Alg. 1 literally; used for small models and as the
  oracle in tests.
* ``probe`` — per-sample gradients restricted to a small probe parameter set
  (classifier head / final norm), computed from one forward pass over frozen
  trunk hiddens + a vmapped head-only backward. O(K·d_model) instead of
  O(K·|Θ|); the standard last-layer approximation (GradMatch, CRAIG, BADGE).
"""
from __future__ import annotations

from typing import Callable, Tuple

import jax
import jax.numpy as jnp


def _flatten_pytree(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.concatenate([jnp.ravel(l).astype(jnp.float32) for l in leaves])


def per_sample_grads_full(loss_fn: Callable, params, batch) -> Tuple[jax.Array, jax.Array]:
    """Exact per-sample gradient matrix G ∈ R^{d×K} + batch mean ḡ ∈ R^d.

    ``loss_fn(params, example) → scalar``; ``batch`` is a pytree whose leaves
    have a leading K axis.
    """
    grad_fn = jax.grad(loss_fn)

    def one(example):
        return _flatten_pytree(grad_fn(params, example))

    G = jax.vmap(one)(batch)              # (K, d)
    g_bar = jnp.mean(G, axis=0)
    return G.T, g_bar


def per_sample_grads_probe(head_loss_fn: Callable, probe_params, hiddens,
                           labels) -> Tuple[jax.Array, jax.Array]:
    """Per-sample gradients w.r.t. probe params only.

    ``head_loss_fn(probe_params, hidden, label) → scalar`` for ONE example;
    ``hiddens``: (K, ...) frozen trunk outputs; ``labels``: (K, ...).
    Returns (G dxK, ḡ d).
    """
    grad_fn = jax.grad(head_loss_fn)

    def one(h, y):
        return _flatten_pytree(grad_fn(probe_params, h, y))

    G = jax.vmap(one)(hiddens, labels)    # (K, d_probe)
    g_bar = jnp.mean(G, axis=0)
    return G.T, g_bar


def logit_error_embeddings(logits: jax.Array, labels: jax.Array,
                           hiddens: jax.Array,
                           mask: jax.Array = None) -> jax.Array:
    """Cheap per-sample gradient embedding without any extra backward.

    For softmax-CE the per-sample gradient w.r.t. the head input is
    ``Wᵀ(p − y)``; we use the loss-weighted pooled hidden as a d_model-dim
    surrogate: ``e_k = ℓ_k · mean_s h_{k,s}`` with ℓ the per-sample loss and
    the residual error norm as the weight. Shapes: logits (K,S,V) or (K,V);
    labels (K,S) or (K,); hiddens (K,S,E) or (K,E). Returns (K,E).

    ``mask`` (K,S) restricts the error signal to labeled positions —
    frontends that prepend unlabeled patch/frame positions (vlm) would
    otherwise dominate the embedding with fake label-0 error. ``None``
    means all positions count (numerically identical to the unmasked
    form for all-ones masks).
    """
    if logits.ndim == 2:
        logits, labels, hiddens = logits[:, None, :], labels[:, None], hiddens[:, None, :]
        mask = None if mask is None else mask[:, None]
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    p = jnp.exp(logp)
    onehot = jax.nn.one_hot(labels, logits.shape[-1], dtype=jnp.float32)
    err = p - onehot                                       # (K,S,V)
    err_norm = jnp.sqrt(jnp.sum(err * err, axis=-1))       # (K,S)
    loss = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]  # (K,S)
    if mask is not None:
        m = mask.astype(jnp.float32)
        err_norm = err_norm * m
        scale = (jnp.sum(loss * m, axis=-1, keepdims=True) /
                 jnp.maximum(jnp.sum(m, axis=-1, keepdims=True), 1.0))
    else:
        scale = jnp.mean(loss, axis=-1, keepdims=True)
    w = err_norm / (jnp.sum(err_norm, axis=-1, keepdims=True) + 1e-9)
    pooled = jnp.einsum("ks,kse->ke", w, hiddens.astype(jnp.float32))
    return pooled * scale
