"""Shared numerical guards for the MaxVol family.

One definition of the degenerate-pivot guard, used by the jnp reference
(``core/maxvol.py``) and every Pallas kernel (``kernels/fast_maxvol.py``,
``kernels/graft_select.py``) — the pivot tie-break under rank deficiency
must be bit-identical across all implementations or the parity tests (and
the paper's prefix-consistency property) break.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# magnitude below which a pivot counts as a degenerate (eliminated) column
PIVOT_EPS = 1e-12


def safe_pivot(x: jax.Array) -> jax.Array:
    """Guard a pivot value away from exact zero, preserving its sign."""
    mag = jnp.abs(x)
    sign = jnp.where(x >= 0, 1.0, -1.0)
    return jnp.where(mag < PIVOT_EPS, sign * PIVOT_EPS, x)
