"""Compatibility shim — the GRAFT selector moved to ``repro.selection``.

The single-batch, single-device selector this module used to implement is
now one engine of the sampler-generic selection subsystem
(``repro.selection``): see ``selection/graft.py`` for the algorithm,
``selection/engine.py`` for the vmapped multi-batch and shard_map
data-parallel paths. Existing imports keep working; new code should import
from ``repro.selection``.
"""
from repro.selection.base import GraftConfig, SelectionState, init_state
from repro.selection.graft import (GraftState, graft_select,  # noqa: F401
                                   graft_select_batched, maybe_refresh,
                                   pivot_and_sweep, select_from_batch)

__all__ = ["GraftConfig", "GraftState", "SelectionState", "init_state",
           "graft_select", "maybe_refresh", "select_from_batch"]
