"""Gradient projection error + dynamic rank selection (paper §3.2).

Given the full-batch mean gradient ``ḡ`` and the per-sample gradient matrix
``G ∈ R^{d×R}`` of the MaxVol-ordered candidates, the projection error at
prefix rank ``r`` is ``d_r = ‖ḡ − P_r ḡ‖² / ‖ḡ‖²`` where ``P_r`` projects
onto span of the first ``r`` columns. Because Fast MaxVol pivots are
prefix-consistent, one modified-Gram-Schmidt sweep yields every candidate
rank's error (Lemma 1: errors are the residual energies, monotone in r).
"""
from __future__ import annotations

import functools
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp

_EPS = 1e-12


@jax.jit
def prefix_projection_errors(G: jax.Array, g_bar: jax.Array) -> jax.Array:
    """Normalized projection errors for every prefix rank 1..R.

    ``G``: (d, R) per-sample gradients in MaxVol pivot order.
    ``g_bar``: (d,) reference (full-batch mean) gradient.
    Returns ``err`` of shape (R,), ``err[r-1] = ‖ḡ − P_r ḡ‖²/‖ḡ‖²`` — by
    Lemma 1 equal to ``1 − ‖Q_rᵀ ĝ‖²`` with Q an orthonormal basis.
    Monotone non-increasing in r.
    """
    d, R = G.shape
    g_norm2 = jnp.sum(g_bar.astype(jnp.float32) ** 2)
    g_hat = g_bar.astype(jnp.float32) / jnp.sqrt(g_norm2 + _EPS)

    def body(carry, col):
        basis_proj_g, Q = carry                    # captured energy, basis so far (d, R)
        q = col
        # orthogonalize against existing basis (two MGS passes for stability)
        for _ in range(2):
            q = q - Q @ (Q.T @ q)
        nrm = jnp.sqrt(jnp.sum(q * q))
        q = jnp.where(nrm > 1e-8, q / (nrm + _EPS), jnp.zeros_like(q))
        Q = jnp.concatenate([Q[:, 1:], q[:, None]], axis=1)  # ring buffer append
        captured = basis_proj_g + jnp.sum(q * g_hat) ** 2
        err = 1.0 - captured
        return (captured, Q), err

    Q0 = jnp.zeros((d, R), dtype=jnp.float32)
    (_, _), errs = jax.lax.scan(body, (jnp.float32(0.0), Q0),
                                G.astype(jnp.float32).T)
    return jnp.clip(errs, 0.0, 1.0)


@functools.partial(jax.jit, static_argnames=("rset",))
def select_rank(errors: jax.Array, rset: Tuple[int, ...], eps: float) -> Tuple[jax.Array, jax.Array]:
    """Smallest candidate rank whose error ≤ eps (else fall back to R_max).

    ``errors``: prefix errors of shape (R_max,). ``rset``: static ascending
    candidate ranks. Returns ``(rank, err_at_rank)`` as traced scalars.
    When no candidate satisfies eps the largest candidate wins — by Lemma 1
    the errors are monotone non-increasing, so R_max is also the error
    minimizer, and an argmin tie-break must never pick a SMALLER rank (flat
    error plateaus would otherwise collapse the subset).
    """
    cand = jnp.asarray(rset, dtype=jnp.int32)
    cand_err = errors[cand - 1]
    ok = cand_err <= eps
    any_ok = jnp.any(ok)
    first_ok = jnp.argmax(ok)            # first True (0 if none — masked below)
    idx = jnp.where(any_ok, first_ok, len(rset) - 1)
    return cand[idx], cand_err[idx]


@jax.jit
def projection_error(G: jax.Array, g_bar: jax.Array) -> jax.Array:
    """Single-rank normalized projection error ‖ḡ − G G† ḡ‖²/‖ḡ‖² via QR."""
    Gf = G.astype(jnp.float32)
    q, _ = jnp.linalg.qr(Gf, mode="reduced")
    g = g_bar.astype(jnp.float32)
    g_norm2 = jnp.sum(g * g) + _EPS
    coeffs = q.T @ g
    return jnp.clip(1.0 - jnp.sum(coeffs * coeffs) / g_norm2, 0.0, 1.0)


@jax.jit
def cosine_alignment(g_sub: jax.Array, g_bar: jax.Array) -> jax.Array:
    """cos(subset mean gradient, full-batch mean gradient) — Fig. 2 metric."""
    a = g_sub.astype(jnp.float32)
    b = g_bar.astype(jnp.float32)
    return jnp.sum(a * b) / (jnp.linalg.norm(a) * jnp.linalg.norm(b) + _EPS)
