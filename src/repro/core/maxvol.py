"""MaxVol family: Fast MaxVol (the paper's sampler), classical MaxVol, Cross-2D.

All routines are jit-able (static ranks, ``jax.lax`` control flow) and operate
on a feature matrix ``V ∈ R^{K×R}`` whose columns are ordered by decreasing
relevance (see ``repro.core.features``).

Fast MaxVol (paper §3.1) is sequential pivoted elimination: step ``j`` picks
``p_j = argmax_i |r_j(i)|`` where ``r_j`` is column ``j`` of the residual
matrix after eliminating the previously selected pivot rows. By Sylvester's
determinant identity this greedily maximizes the volume of the selected
``j×j`` submatrix at every step. One elimination step is a rank-1 update, so
the total cost is ``O(K·R²)`` — linear in batch size K.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.numerics import PIVOT_EPS as _PIVOT_EPS
from repro.core.numerics import safe_pivot as _safe_pivot


@functools.partial(jax.jit, static_argnames=("rank",))
def fast_maxvol(V: jax.Array, rank: int) -> Tuple[jax.Array, jax.Array]:
    """Select ``rank`` rows of ``V`` (K×R) greedily maximizing submatrix volume.

    Returns ``(pivots, logvol)`` where ``pivots`` is an int32 vector of length
    ``rank`` (row indices, in selection order — prefixes of the result are the
    Fast MaxVol solutions for smaller ranks) and ``logvol`` is
    ``log |det V[pivots, :rank]|`` accumulated from the pivot magnitudes.
    """
    K, R = V.shape
    if rank > min(K, R):
        raise ValueError(f"rank {rank} exceeds feature matrix dims {V.shape}")
    W0 = V.astype(jnp.float32)
    avail0 = jnp.ones((K,), dtype=jnp.float32)

    def body(j, carry):
        W, avail, pivots, logvol = carry
        # residual column scores; already-selected rows can never win the argmax
        scores = jnp.where(avail > 0, jnp.abs(W[:, j]), -1.0)
        pj = jnp.argmax(scores)
        pivot_val = _safe_pivot(W[pj, j])
        # Eliminate: zero column j in every other row (rank-1 update). After
        # this, column j+1 of W restricted to available rows equals r_{j+1}.
        factor = W[:, j] / pivot_val               # (K,)
        pivot_row = W[pj, :]                       # (R,)
        W = W - factor[:, None] * pivot_row[None, :]
        W = W.at[pj, :].set(pivot_row)             # keep pivot row intact for later cols
        avail = avail.at[pj].set(0.0)
        pivots = pivots.at[j].set(pj.astype(jnp.int32))
        logvol = logvol + jnp.log(jnp.abs(pivot_val))
        return W, avail, pivots, logvol

    pivots0 = jnp.zeros((rank,), dtype=jnp.int32)
    _, _, pivots, logvol = jax.lax.fori_loop(
        0, rank, body, (W0, avail0, pivots0, jnp.float32(0.0)))
    return pivots, logvol


@functools.partial(jax.jit, static_argnames=("rank", "max_iters"))
def maxvol_classic(V: jax.Array, rank: int, tol: float = 1.05,
                   max_iters: int = 100) -> jax.Array:
    """Classical (Goreinov et al.) MaxVol with row swaps until |B|max ≤ tol.

    Seeded from Fast MaxVol. Returns the int32 pivot vector (length ``rank``).
    """
    K, R = V.shape
    Vr = V[:, :rank].astype(jnp.float32)
    pivots, _ = fast_maxvol(V[:, :rank], rank)

    def interp(p):
        # B = V · V[p]^{-1}  (K×rank interpolation matrix)
        sub = Vr[p, :]
        return jnp.linalg.solve(sub.T, Vr.T).T

    def cond(carry):
        p, it, done = carry
        return jnp.logical_and(it < max_iters, jnp.logical_not(done))

    def body(carry):
        p, it, _ = carry
        B = interp(p)
        flat = jnp.abs(B).reshape(-1)
        idx = jnp.argmax(flat)
        i, j = idx // rank, idx % rank
        maxval = flat[idx]
        p_new = jnp.where(maxval > tol, p.at[j].set(i.astype(jnp.int32)), p)
        return p_new, it + 1, maxval <= tol

    p, _, _ = jax.lax.while_loop(cond, body, (pivots, jnp.int32(0), jnp.bool_(False)))
    return p


@functools.partial(jax.jit, static_argnames=("rank", "sweeps"))
def cross2d_maxvol(X: jax.Array, rank: int, sweeps: int = 3) -> Tuple[jax.Array, jax.Array]:
    """Cross-2D baseline (Tyrtyshnikov): alternate row/column MaxVol on raw X.

    Returns ``(row_pivots, col_pivots)``. Used only as the paper's comparison
    baseline (Table 4) — GRAFT itself uses :func:`fast_maxvol` on features.
    """
    K, M = X.shape
    Xf = X.astype(jnp.float32)
    cols0 = jnp.arange(rank, dtype=jnp.int32)      # initial column guess

    def sweep(_, carry):
        rows, cols = carry
        rows_new, _ = fast_maxvol(Xf[:, cols], rank)
        cols_new, _ = fast_maxvol(Xf[rows_new, :].T, rank)
        return rows_new, cols_new

    rows0, _ = fast_maxvol(Xf[:, cols0], rank)
    rows, cols = jax.lax.fori_loop(0, sweeps, sweep, (rows0, cols0))
    return rows, cols


def submatrix_logvolume(V: jax.Array, pivots: jax.Array, rank: int) -> jax.Array:
    """log |det V[pivots, :rank]| via QR for numerical stability."""
    sub = V[pivots[:rank], :rank].astype(jnp.float32)
    r = jnp.linalg.qr(sub, mode="r")
    return jnp.sum(jnp.log(jnp.abs(jnp.diag(r)) + _PIVOT_EPS))
