"""Feature extraction for GRAFT (paper §3.1 Step 1 and §13).

Every extractor maps a batch matrix ``A ∈ R^{K×M}`` to ``V ∈ R^{K×R}`` with
columns ordered by descending relevance (singular value / variance /
non-Gaussianity), the precondition for Fast MaxVol's sequential pivoting.
"""
from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp


def _flatten_batch(A: jax.Array) -> jax.Array:
    return A.reshape(A.shape[0], -1).astype(jnp.float32)


@functools.partial(jax.jit, static_argnames=("rank",))
def svd_features(A: jax.Array, rank: int) -> jax.Array:
    """Top-``rank`` left singular vectors of A, scaled by singular values.

    Uses the K×K Gram eigendecomposition when M > K (cheaper, same U).
    Columns ordered by descending σ — satisfies Rel(1) ≥ … ≥ Rel(R).
    """
    A = _flatten_batch(A)
    K, M = A.shape
    if M >= K:
        gram = A @ A.T                                 # (K,K)
        evals, evecs = jnp.linalg.eigh(gram)           # ascending
        evals = jnp.flip(evals, -1)[:rank]
        U = jnp.flip(evecs, -1)[:, :rank]
        sigma = jnp.sqrt(jnp.clip(evals, 0.0))
    else:
        U, s, _ = jnp.linalg.svd(A, full_matrices=False)
        U, sigma = U[:, :rank], s[:rank]
    return U * sigma[None, :]


@functools.partial(jax.jit, static_argnames=("rank",))
def pca_features(A: jax.Array, rank: int) -> jax.Array:
    """PCA scores: mean-center then project onto top principal axes."""
    A = _flatten_batch(A)
    A = A - jnp.mean(A, axis=0, keepdims=True)
    return svd_features(A, rank)


_SKETCH_SVD_SEED = 0x51E7


@functools.partial(jax.jit, static_argnames=("rank", "oversample",
                                             "power_iters"))
def sketch_svd_features(A: jax.Array, rank: int, oversample: int = 8,
                        power_iters: int = 0) -> jax.Array:
    """Randomized range-finder SVD features (Halko et al.; SAGE-style).

    Sketch ``A (K, M)`` down to ``Y = A Ω (K, L)`` with a fixed Gaussian
    ``Ω (M, L)``, ``L = rank + oversample``, orthonormalize the range basis
    and diagonalize the tiny ``L×L`` Gram of ``B = QᵀA`` — the ONLY
    eigendecomposition. Total cost ``O(K·M·L)`` matmuls vs ``svd_features``'
    ``O(K²·M)`` Gram build + serial ``K×K`` eigh, the worst-scaling op on
    accelerators. Output matches ``svd_features`` (``U_r σ_r``, columns
    relevance-ordered) up to sketching error — principal-angle parity is
    asserted in tests. ``power_iters`` adds subspace-iteration passes for
    slowly-decaying spectra (each costs two more ``O(K·M·L)`` matmuls).

    The sketch matrix is a fixed function of (M, L): deterministic across
    steps, so the feature basis is stable between selection refreshes.
    """
    A = _flatten_batch(A)
    K, M = A.shape
    L = min(min(K, M), rank + oversample)
    omega = jax.random.normal(jax.random.PRNGKey(_SKETCH_SVD_SEED),
                              (M, L), dtype=jnp.float32)
    Y = A @ omega                                      # (K, L) range sample
    for _ in range(power_iters):
        Q, _ = jnp.linalg.qr(Y)                        # re-orthonormalize
        Y = A @ (A.T @ Q)
    Q, _ = jnp.linalg.qr(Y)                            # (K, L) range basis
    B = Q.T @ A                                        # (L, M) projected rows
    evals, evecs = jnp.linalg.eigh(B @ B.T)            # L×L — the only eigh
    evals = jnp.flip(evals, -1)[:rank]
    U_small = jnp.flip(evecs, -1)[:, :rank]
    sigma = jnp.sqrt(jnp.clip(evals, 0.0))
    return (Q @ U_small) * sigma[None, :]


@functools.partial(jax.jit, static_argnames=("rank", "iters"))
def ica_features(A: jax.Array, rank: int, iters: int = 64,
                 key: Optional[jax.Array] = None) -> jax.Array:
    """FastICA (parallel, tanh contrast) on the whitened batch.

    Components are re-ordered by descending excess kurtosis so that the
    Rel-ordering precondition holds. Deterministic for a fixed key.
    """
    A = _flatten_batch(A)
    K, _ = A.shape
    X = A - jnp.mean(A, axis=0, keepdims=True)
    # whiten via PCA in sample space
    gram = X @ X.T / X.shape[1]
    evals, evecs = jnp.linalg.eigh(gram)
    evals = jnp.flip(evals, -1)[:rank]
    E = jnp.flip(evecs, -1)[:, :rank]
    Z = (E / jnp.sqrt(jnp.clip(evals, 1e-12))[None, :]).T  # (rank, K) whitened comps

    if key is None:
        key = jax.random.PRNGKey(0)
    W0 = jax.random.normal(key, (rank, rank), dtype=jnp.float32)

    def sym_decorrelate(W):
        # W ← (W Wᵀ)^{-1/2} W
        s, u = jnp.linalg.eigh(W @ W.T)
        inv_sqrt = u @ jnp.diag(1.0 / jnp.sqrt(jnp.clip(s, 1e-12))) @ u.T
        return inv_sqrt @ W

    def body(_, W):
        Y = W @ Z                      # (rank, K) current sources
        g = jnp.tanh(Y)
        g_prime = 1.0 - g * g
        W_new = (g @ Z.T) / Z.shape[1] - jnp.mean(g_prime, axis=1)[:, None] * W
        return sym_decorrelate(W_new)

    W = jax.lax.fori_loop(0, iters, body, sym_decorrelate(W0))
    S = (W @ Z).T                      # (K, rank) sources
    # order by descending excess kurtosis (non-Gaussianity = relevance)
    kurt = jnp.mean(S ** 4, axis=0) / jnp.clip(jnp.mean(S ** 2, axis=0) ** 2, 1e-12) - 3.0
    order = jnp.argsort(-jnp.abs(kurt))
    return S[:, order]


def encoder_features(apply_fn: Callable[..., jax.Array], params,
                     batch, rank: int) -> jax.Array:
    """Model-based embeddings (paper's AE / 'GRAFT Warm' path).

    ``apply_fn(params, batch) → (K, E)`` pooled hiddens; we SVD-order them
    down to ``rank`` columns so downstream MaxVol sees relevance-ordered
    features regardless of the encoder's native basis.
    """
    H = apply_fn(params, batch)
    return svd_features(H, rank)


EXTRACTORS = {
    "svd": svd_features,
    "sketch_svd": sketch_svd_features,
    "pca": pca_features,
    "ica": ica_features,
}


def extract(mode: str, A: jax.Array, rank: int) -> jax.Array:
    if mode not in EXTRACTORS:
        raise KeyError(f"unknown feature extractor '{mode}' (have {list(EXTRACTORS)})")
    return EXTRACTORS[mode](A, rank)
