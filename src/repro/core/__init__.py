"""GRAFT core: Fast MaxVol sampling, feature extraction, gradient-aligned
dynamic rank selection (the paper's primary contribution)."""
from repro.core.graft import GraftConfig, GraftState, graft_select, init_state, maybe_refresh
from repro.core.maxvol import cross2d_maxvol, fast_maxvol, maxvol_classic
from repro.core.projection import (cosine_alignment, prefix_projection_errors,
                                   projection_error, select_rank)

__all__ = [
    "GraftConfig", "GraftState", "graft_select", "init_state", "maybe_refresh",
    "fast_maxvol", "maxvol_classic", "cross2d_maxvol",
    "prefix_projection_errors", "projection_error", "select_rank",
    "cosine_alignment",
]
