"""Subset-selection baselines the paper compares against (§2, §4).

All operate per-iteration-batch on the same inputs GRAFT sees, so the
fraction-sweep benchmark is apples-to-apples: Random, GradMatch (OMP),
CRAIG (facility-location greedy), EL2N pre-scoring.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("k", "r"))
def random_subset(key: jax.Array, k: int, r: int) -> Tuple[jax.Array, jax.Array]:
    """Uniform random R-of-K (the paper's Table 14 baseline)."""
    pivots = jax.random.permutation(key, k)[:r].astype(jnp.int32)
    weights = jnp.full((r,), 1.0 / r, dtype=jnp.float32)
    return pivots, weights


@functools.partial(jax.jit, static_argnames=("r",))
def gradmatch_omp(G: jax.Array, g_bar: jax.Array, r: int) -> Tuple[jax.Array, jax.Array]:
    """GradMatch: orthogonal matching pursuit minimizing ‖ḡ − G_S w‖₂.

    G: (d, K) per-sample gradients. Greedy: at each step add the column most
    correlated with the residual, then refit weights by least squares on the
    selected set. Returns (pivots (r,), weights (r,)).
    """
    d, K = G.shape
    Gf = G.astype(jnp.float32)
    g = g_bar.astype(jnp.float32)
    col_norms = jnp.linalg.norm(Gf, axis=0) + 1e-12
    Gn = Gf / col_norms[None, :]

    def body(j, carry):
        residual, pivots, selected = carry
        scores = jnp.abs(Gn.T @ residual)
        scores = jnp.where(selected > 0, -jnp.inf, scores)
        pj = jnp.argmax(scores).astype(jnp.int32)
        pivots = pivots.at[j].set(pj)
        selected = selected.at[pj].set(1.0)
        # refit on selected columns (mask trick keeps shapes static):
        mask = selected                                     # (K,)
        A = Gf * mask[None, :]                              # zero unselected cols
        # ridge-regularized normal equations (stable for j < r fits)
        gram = A.T @ A + 1e-6 * jnp.eye(K, dtype=jnp.float32)
        w = jnp.linalg.solve(gram, A.T @ g) * mask
        residual = g - A @ w
        return residual, pivots, selected

    pivots0 = jnp.zeros((r,), dtype=jnp.int32)
    residual, pivots, selected = jax.lax.fori_loop(
        0, r, body, (g, pivots0, jnp.zeros((K,), jnp.float32)))
    # final weights: non-negative least squares on the selected set. NOTE:
    # deliberately NOT normalized — OMP weights minimize ‖ḡ − G_S w‖ and
    # normalizing would destroy the fit; training-use normalizes separately.
    A = Gf * selected[None, :]
    gram = A.T @ A + 1e-6 * jnp.eye(K, dtype=jnp.float32)
    w_full = jnp.linalg.solve(gram, A.T @ g)
    w = jnp.clip(w_full[pivots], 0.0)
    return pivots, w


@functools.partial(jax.jit, static_argnames=("r",))
def craig_greedy(G: jax.Array, r: int) -> Tuple[jax.Array, jax.Array]:
    """CRAIG: facility-location greedy on gradient similarity.

    maximize F(S) = Σ_i max_{j∈S} sim(i, j); weights = cluster sizes / K.
    """
    d, K = G.shape
    Gf = G.astype(jnp.float32)
    norms = jnp.linalg.norm(Gf, axis=0) + 1e-12
    S = (Gf.T @ Gf) / (norms[:, None] * norms[None, :])     # (K,K) cosine sim

    def body(j, carry):
        best_sim, pivots, selected = carry                  # best_sim: (K,)
        gain = jnp.sum(jnp.maximum(S - best_sim[:, None], 0.0), axis=0)
        gain = jnp.where(selected > 0, -jnp.inf, gain)
        pj = jnp.argmax(gain).astype(jnp.int32)
        best_sim = jnp.maximum(best_sim, S[:, pj])
        return best_sim, pivots.at[j].set(pj), selected.at[pj].set(1.0)

    best_sim0 = jnp.full((K,), -jnp.inf, dtype=jnp.float32)
    _, pivots, selected = jax.lax.fori_loop(
        0, r, body, (best_sim0, jnp.zeros((r,), jnp.int32), jnp.zeros((K,), jnp.float32)))
    # weight each medoid by its cluster share
    sim_sel = S[:, pivots]                                   # (K, r)
    assign = jnp.argmax(sim_sel, axis=1)                     # nearest medoid
    counts = jnp.sum(jax.nn.one_hot(assign, r, dtype=jnp.float32), axis=0)
    w = counts / K
    return pivots, w


@functools.partial(jax.jit, static_argnames=("r",))
def glister_greedy(G: jax.Array, g_val: jax.Array, r: int,
                   eta: float = 0.1) -> Tuple[jax.Array, jax.Array]:
    """GLISTER-online (greedy, first-order): maximize the one-step Taylor
    approximation of validation log-likelihood gain.

    Gain of adding sample i given selected set S:
        ΔV(i | S) ≈ η · g_iᵀ (g_val − η · Σ_{j∈S} g_j)
    G: (d, K) per-sample train gradients; g_val: (d,) validation gradient.
    """
    d, K = G.shape
    Gf = G.astype(jnp.float32)
    gv = g_val.astype(jnp.float32)

    def body(j, carry):
        acc, pivots, selected = carry           # acc = Σ_{j∈S} g_j
        scores = Gf.T @ (gv - eta * acc)
        scores = jnp.where(selected > 0, -jnp.inf, scores)
        pj = jnp.argmax(scores).astype(jnp.int32)
        acc = acc + Gf[:, pj]
        return acc, pivots.at[j].set(pj), selected.at[pj].set(1.0)

    _, pivots, _ = jax.lax.fori_loop(
        0, r, body, (jnp.zeros((d,), jnp.float32),
                     jnp.zeros((r,), jnp.int32), jnp.zeros((K,), jnp.float32)))
    weights = jnp.full((r,), 1.0 / r, dtype=jnp.float32)
    return pivots, weights


@functools.partial(jax.jit, static_argnames=("r",))
def el2n_topk(G: jax.Array, r: int) -> Tuple[jax.Array, jax.Array]:
    """EL2N pre-scoring: keep the r samples with largest gradient norm."""
    norms = jnp.linalg.norm(G.astype(jnp.float32), axis=0)
    pivots = jnp.argsort(-norms)[:r].astype(jnp.int32)
    weights = jnp.full((r,), 1.0 / r, dtype=jnp.float32)
    return pivots, weights
