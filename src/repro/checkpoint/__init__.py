"""Atomic, resumable, elastic checkpointing with async writes."""
from repro.checkpoint.checkpoint import (CheckpointManager, EmergencySaver,
                                         load_experiment)

__all__ = ["CheckpointManager", "EmergencySaver", "load_experiment"]
