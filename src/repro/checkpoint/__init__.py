"""Atomic, resumable, elastic checkpointing with async writes."""
from repro.checkpoint.checkpoint import CheckpointManager, EmergencySaver

__all__ = ["CheckpointManager", "EmergencySaver"]
