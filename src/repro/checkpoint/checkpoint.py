"""Fault-tolerant checkpointing.

Production behaviors implemented:
  * atomic commits — write to ``<dir>/tmp.<step>`` then ``os.rename`` (POSIX
    atomic); re-saving an existing step renames the committed dir ASIDE
    (``step_X.old``) rather than deleting it first, so no crash instant
    loses the committed checkpoint (``_recover`` rolls a half-commit back);
  * manifest with per-leaf checksums (adler32) verified on load;
  * ``restore_latest_good`` — walk newest→oldest, verify checksums + the
    manifest health stamp, quarantine corrupt dirs to ``corrupt.<step>``
    (forensics, not deletion) and fall back to the previous step;
  * keep-last-N garbage collection that never rotates out the newest
    checkpoint stamped healthy — rollback always has somewhere to land;
  * async saves on a writer thread (training continues while the previous
    step serializes) with a join-on-next-save barrier;
  * emergency save on SIGTERM/SIGINT (preemption handler);
  * ELASTIC restore — arrays are stored unsharded (per-host gather of its
    addressable shards; single-process here), and ``restore`` re-shards onto
    whatever mesh/sharding the restart supplies, so the same checkpoint
    resumes on a different chip count.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import signal
import threading
import zlib
from typing import Any, Callable, Dict, Optional

import jax
import ml_dtypes
import numpy as np

from repro.resilience import chaos

PyTree = Any
_SEP = "/"
_BF16 = np.dtype(ml_dtypes.bfloat16)


def _flatten_with_paths(tree: PyTree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_path_str(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _path_str(entry) -> str:
    if hasattr(entry, "key"):
        return str(entry.key)
    if hasattr(entry, "idx"):
        return str(entry.idx)
    return str(entry)


def _checksum(a: np.ndarray) -> int:
    return zlib.adler32(np.ascontiguousarray(a).view(np.uint8).tobytes())


class CheckpointManager:
    def __init__(self, directory: str, keep_last_n: int = 3,
                 async_save: bool = False):
        self.directory = directory
        self.keep_last_n = keep_last_n
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        self._async_exc: Optional[BaseException] = None
        os.makedirs(directory, exist_ok=True)
        self._recover()

    def _recover(self) -> None:
        """Roll back half-finished commits from a crashed writer: stale
        ``tmp.*`` dirs are uncommitted (drop them); a ``step_X.old`` with no
        ``step_X`` means the crash hit between the two commit renames — the
        aside copy IS the committed checkpoint, so rename it back."""
        for name in sorted(os.listdir(self.directory)):
            path = os.path.join(self.directory, name)
            if name.startswith("tmp."):
                shutil.rmtree(path, ignore_errors=True)
            elif name.endswith(".old"):
                final = path[: -len(".old")]
                if os.path.exists(final):
                    shutil.rmtree(path, ignore_errors=True)
                else:
                    os.rename(path, final)

    # ------------------------------ save --------------------------------
    def save(self, step: int, tree: PyTree, extra: Optional[Dict] = None,
             topology: Optional[Dict] = None) -> str:
        """``topology`` (``Backend.topology()``: process/device counts +
        shard layout) is stamped into the manifest — what lets ``restore``
        detect a mismatched restart and reshard instead of mis-restoring."""
        self.wait()                               # one in-flight save max
        # materialize on host BEFORE handing to the writer thread
        flat = _flatten_with_paths(tree)
        if self.async_save:
            self._thread = threading.Thread(
                target=self._write_guarded,
                args=(step, flat, extra or {}, topology),
                daemon=False)
            self._thread.start()
            return os.path.join(self.directory, f"step_{step:08d}")
        return self._write(step, flat, extra or {}, topology)

    def _write_guarded(self, step, flat, extra, topology=None) -> None:
        """Writer-thread wrapper: a dead writer must not pass silently —
        its exception is re-raised from the next :meth:`wait`."""
        try:
            self._write(step, flat, extra, topology)
        except BaseException as e:          # noqa: BLE001 — surfaced later
            self._async_exc = e

    def _write(self, step: int, flat: Dict[str, np.ndarray], extra: Dict,
               topology: Optional[Dict] = None) -> str:
        final = os.path.join(self.directory, f"step_{step:08d}")
        tmp = os.path.join(self.directory, f"tmp.{step}.{os.getpid()}")
        os.makedirs(tmp, exist_ok=True)
        manifest = {"step": step, "extra": extra, "leaves": {}}
        if topology is not None:
            manifest["topology"] = topology
        for key, arr in flat.items():
            fname = re.sub(r"[^A-Za-z0-9_.-]", "_", key) + ".npy"
            logical_dtype = str(arr.dtype)
            if arr.dtype == _BF16:
                # numpy can't round-trip bfloat16 through .npy — store the
                # raw uint16 payload and record the logical dtype
                arr = arr.view(np.uint16)
            np.save(os.path.join(tmp, fname), arr)
            manifest["leaves"][key] = {
                "file": fname, "shape": list(arr.shape), "dtype": logical_dtype,
                "adler32": _checksum(arr)}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            # bare NaN/Infinity literals are invalid JSON — callers sanitize
            # non-finite metrics (sanitize_row) before they reach a manifest
            json.dump(manifest, f, allow_nan=False)
        chaos.crash_point("checkpoint.pre_commit")
        old = final + ".old"
        if os.path.exists(final):
            # re-saving an existing step (rollback replay, restarted run):
            # never a destructive window — the committed dir is renamed
            # aside, not deleted, until the new one is in place; a crash
            # between the renames leaves step_X.old for _recover()
            os.rename(final, old)
        chaos.crash_point("checkpoint.mid_commit")
        os.rename(tmp, final)                      # atomic commit
        chaos.crash_point("checkpoint.post_commit")
        if os.path.exists(old):
            shutil.rmtree(old)
        self._gc()
        return final

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._async_exc is not None:
            exc, self._async_exc = self._async_exc, None
            raise exc

    def _gc(self) -> None:
        if not self.keep_last_n:
            return
        steps = self.all_steps()
        keep = set(steps[-self.keep_last_n:])
        # never rotate out the newest step stamped healthy: if the sentinel
        # trips after keep_last_n poisoned-but-finite saves, rollback still
        # needs a good state to land on
        healthy = [s for s in steps if self._healthy(s)]
        if healthy:
            keep.add(healthy[-1])
        for s in steps:
            if s not in keep:
                shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                              ignore_errors=True)

    def _healthy(self, step: int) -> bool:
        """A checkpoint's manifest health stamp; unstamped (pre-sentinel or
        externally written) checkpoints count as healthy."""
        try:
            health = self.manifest(step).get("extra", {}).get("health")
        except (OSError, ValueError):
            return False
        return True if health is None else bool(health.get("healthy", True))

    # ----------------------------- restore ------------------------------
    def all_steps(self):
        out = []
        for name in os.listdir(self.directory):
            m = re.fullmatch(r"step_(\d{8})", name)
            if m and os.path.exists(os.path.join(self.directory, name, "manifest.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, target: PyTree,
                sharding_tree: Optional[PyTree] = None,
                verify: bool = True, backend: Optional[Any] = None) -> PyTree:
        """Load into the structure of ``target``; if ``sharding_tree`` given,
        device_put each leaf with its sharding (elastic re-shard on load).

        ``backend`` (a ``repro.backend.Backend``) makes the restore ELASTIC:
        leaves are placed with ``backend.device_put`` — arrays are stored
        unsharded, so a checkpoint written on N processes/devices restores
        onto M. A manifest topology stamp that disagrees with the live
        topology is resharded (one log line) when a backend is given, and
        raises an actionable error otherwise."""
        path = os.path.join(self.directory, f"step_{step:08d}")
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        leaves = manifest["leaves"]
        saved_topo = manifest.get("topology")
        if saved_topo is not None:
            live_topo = backend.topology() if backend is not None else None
            if live_topo is not None and live_topo != saved_topo:
                print(f"[ckpt] step {step} written on topology {saved_topo}, "
                      f"restoring onto {live_topo} — resharding", flush=True)
            elif live_topo is None and sharding_tree is None:
                import jax as _jax
                here = {"process_count": _jax.process_count(),   # lint: allow
                        "device_count": len(_jax.devices()),     # lint: allow
                        "shard_layout": saved_topo.get("shard_layout",
                                                       "replicated")}
                if here != saved_topo:
                    raise ValueError(
                        f"checkpoint step {step} was written on topology "
                        f"{saved_topo} but this process sees {here} — pass "
                        "backend=<trainer.backend> (or a sharding_tree) to "
                        "reshard elastically, or restart on the original "
                        "topology")

        flat_target, treedef = jax.tree_util.tree_flatten_with_path(target)
        flat_shardings = (jax.tree_util.tree_leaves(
            sharding_tree, is_leaf=lambda s: isinstance(s, jax.sharding.Sharding))
            if sharding_tree is not None else [None] * len(flat_target))
        out = []
        for (pth, leaf), shard in zip(flat_target, flat_shardings):
            key = _SEP.join(_path_str(p) for p in pth)
            if key not in leaves:
                if key.split(_SEP, 1)[0] in ("health", "sampler_carry"):
                    # state sections added after this checkpoint was written
                    # (the divergence sentinel, the Sampler-v2 carry): keep
                    # the freshly-initialized leaf instead of failing
                    fresh = np.asarray(leaf)
                    out.append(backend.device_put(fresh)
                               if backend is not None
                               else jax.device_put(fresh))
                    continue
                raise KeyError(f"checkpoint missing leaf '{key}'")
            meta = leaves[key]
            arr = np.load(os.path.join(path, meta["file"]))
            if verify and _checksum(arr) != meta["adler32"]:
                raise IOError(f"checksum mismatch for '{key}' — corrupt checkpoint")
            if meta["dtype"] == "bfloat16":
                arr = arr.view(_BF16)
            if list(arr.shape) != list(leaf.shape):
                raise ValueError(f"shape mismatch for '{key}': "
                                 f"ckpt {arr.shape} vs target {leaf.shape}")
            if shard is not None:
                out.append(jax.device_put(arr, shard))
            elif backend is not None:
                out.append(backend.device_put(arr))
            else:
                out.append(jax.device_put(arr))
        return jax.tree_util.tree_unflatten(treedef, out)

    def restore_latest_good(self, target: PyTree,
                            sharding_tree: Optional[PyTree] = None,
                            backend: Optional[Any] = None):
        """Restore the newest checkpoint that is both intact (checksums
        verify) and stamped healthy, walking newest→oldest. Corrupt dirs
        are quarantined to ``corrupt.<step>`` (kept for forensics, skipped
        by ``all_steps``); unhealthy-stamped ones are skipped in place.
        Returns ``(step, tree, manifest)``; raises ``FileNotFoundError``
        when no restorable checkpoint remains."""
        for step in reversed(self.all_steps()):
            try:
                manifest = self.manifest(step)
            except (OSError, ValueError):
                self._quarantine(step)
                continue
            health = manifest.get("extra", {}).get("health")
            if health is not None and not health.get("healthy", True):
                print(f"[ckpt] step {step} stamped unhealthy — skipping")
                continue
            try:
                tree = self.restore(step, target, sharding_tree, verify=True,
                                    backend=backend)
            except (OSError, ValueError, KeyError) as e:
                print(f"[ckpt] step {step} failed verification ({e}) — "
                      "quarantining")
                self._quarantine(step)
                continue
            return step, tree, manifest
        raise FileNotFoundError(
            f"no healthy checkpoint under '{self.directory}'")

    def _quarantine(self, step: int) -> None:
        src = os.path.join(self.directory, f"step_{step:08d}")
        dst = os.path.join(self.directory, f"corrupt.{step:08d}")
        if os.path.exists(dst):
            shutil.rmtree(dst)
        os.rename(src, dst)

    def manifest(self, step: int) -> Dict:
        path = os.path.join(self.directory, f"step_{step:08d}", "manifest.json")
        with open(path) as f:
            return json.load(f)

    def latest_manifest(self) -> Optional[Dict]:
        step = self.latest_step()
        return None if step is None else self.manifest(step)


def load_experiment(directory: str):
    """Reconstruct the ``repro.api.ExperimentConfig`` embedded in the latest
    manifest of ``directory`` — the resume path needs no re-specified flags.
    Raises if the directory has no checkpoint or predates config embedding.
    """
    from repro.api.config import ExperimentConfig  # lazy: avoids api↔ckpt cycle
    manifest = CheckpointManager(directory).latest_manifest()
    if manifest is None:
        raise FileNotFoundError(f"no checkpoint under '{directory}'")
    exp = manifest.get("extra", {}).get("experiment")
    if exp is None:
        raise KeyError(f"checkpoint in '{directory}' has no embedded "
                       "experiment config (written before the repro.api era?)")
    return ExperimentConfig.from_dict(exp)


class EmergencySaver:
    """SIGTERM/SIGINT preemption handler: request a final checkpoint.

    Usage::
        saver = EmergencySaver()
        for step in ...:
            ...
            if saver.should_stop:
                ckpt.save(step, state); break
    """

    def __init__(self, signals=(signal.SIGTERM, signal.SIGINT)):
        self.should_stop = False
        self._prev = {}
        for sig in signals:
            try:
                self._prev[sig] = signal.signal(sig, self._handler)
            except (ValueError, OSError):       # non-main thread
                pass

    def _handler(self, signum, frame):
        self.should_stop = True

    def restore_handlers(self):
        """Unwind the installed handlers (idempotent — ``_prev`` is cleared
        so a second call can't re-install a stale snapshot)."""
        prev, self._prev = self._prev, {}
        for sig, handler in prev.items():
            signal.signal(sig, handler)
