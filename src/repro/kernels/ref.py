"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.maxvol import fast_maxvol as _fast_maxvol_core
from repro.core.projection import prefix_projection_errors as _prefix_errors_core


def fast_maxvol_ref(V: jax.Array, rank: int):
    """Oracle = the core jnp implementation (itself validated against numpy
    brute-force volume maximization in tests/test_maxvol.py)."""
    return _fast_maxvol_core(V, rank)


def projection_sweep_ref(G: jax.Array, g_bar: jax.Array) -> jax.Array:
    return _prefix_errors_core(G, g_bar)


def rwkv_chunk_ref(r, k, v, w, u):
    """Oracle for the RWKV6 chunked-recurrence kernel: naive per-step scan.

    Shapes (single head): r,k: (T, D); v: (T, D); w: (T, D) per-step decay in
    (0,1); u: (D,) bonus. Returns (T, D) outputs. State S: (D, D).
    """
    T, D = r.shape

    def step(S, inputs):
        rt, kt, vt, wt = inputs
        kv = jnp.outer(kt, vt)                       # (D, D)
        out = rt @ (S + u[:, None] * kv)             # (D,)
        S = S * wt[:, None] + kv
        return S, out

    S0 = jnp.zeros((D, D), dtype=jnp.float32)
    _, outs = jax.lax.scan(step, S0, (r, k, v, w))
    return outs


def flash_attention_ref(q, k, v, causal=True, window=None, softcap=None):
    """Dense-softmax oracle for the flash attention kernel.

    q: (BH, Sq, Dh); k/v: (BH, T, Dh). Assumes queries align to the END of
    the KV stream when Sq < T (decode-style), matching the kernel's absolute
    positions q_pos = tile_offset + i.
    """
    import jax.numpy as jnp
    BH, Sq, Dh = q.shape
    T = k.shape[1]
    s = jnp.einsum("bqd,btd->bqt", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / (Dh ** 0.5)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    q_pos = jnp.arange(Sq)[:, None]
    k_pos = jnp.arange(T)[None, :]
    mask = jnp.ones((Sq, T), bool)
    if causal:
        mask = mask & (k_pos <= q_pos)
    if window is not None:
        mask = mask & (k_pos > q_pos - window)
    s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqt,btd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)
