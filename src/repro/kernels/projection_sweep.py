"""Pallas TPU kernel for the prefix projection-error sweep (paper §3.2).

Computes ``d_r = 1 − ‖Q_rᵀ ĝ‖²`` for every prefix rank r = 1..R of the
pivot-ordered gradient matrix G (d×R) in ONE modified-Gram-Schmidt pass —
the paper's rank sweep over Rset costs |Rset| separate pseudo-inverse
solves; here all candidate ranks fall out of a single kernel.

VMEM layout: G is streamed as (TILE_D, R) row-tiles when d is large; the
R×R MGS coefficient state and the R-vector of captured energies stay
resident. For GRAFT's regime (d = d_model ≤ 8192, R ≤ 128) the whole G is
≤ 4 MB and a single block suffices — we keep the single-block variant and
tile only the d axis via the grid when needed.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_EPS = 1e-12


def _projection_sweep_kernel(g_ref, gbar_ref, err_ref):
    """g_ref: (d, R) f32; gbar_ref: (d,) f32; err_ref: (R,) f32 out."""
    G = g_ref[...]
    g = gbar_ref[...]
    d, R = G.shape
    g_hat = g / jnp.sqrt(jnp.sum(g * g) + _EPS)

    def body(j, carry):
        Q, captured = carry                      # Q: (d, R) basis (cols < j valid)
        q = G[:, j]
        # two-pass MGS against the filled columns (zeros elsewhere are no-ops)
        q = q - Q @ (Q.T @ q)
        q = q - Q @ (Q.T @ q)
        nrm = jnp.sqrt(jnp.sum(q * q))
        q = jnp.where(nrm > 1e-8, q / (nrm + _EPS), jnp.zeros_like(q))
        Q = jnp.where((jax.lax.iota(jnp.int32, R) == j)[None, :], q[:, None], Q)
        captured = captured + jnp.sum(q * g_hat) ** 2
        err_ref[j] = jnp.clip(1.0 - captured, 0.0, 1.0)
        return Q, captured

    Q0 = jnp.zeros((d, R), dtype=jnp.float32)
    jax.lax.fori_loop(0, R, body, (Q0, jnp.float32(0.0)))


@functools.partial(jax.jit, static_argnames=("interpret",))
def projection_sweep_pallas(G: jax.Array, g_bar: jax.Array,
                            interpret: bool = False) -> jax.Array:
    """Prefix projection errors, shape (R,). G: (d, R); g_bar: (d,)."""
    d, R = G.shape
    if d * (2 * R + 1) * 4 > 12 * 1024 * 1024:
        raise ValueError("G exceeds the single-block VMEM budget; reduce d or R")
    return pl.pallas_call(
        _projection_sweep_kernel,
        out_shape=jax.ShapeDtypeStruct((R,), jnp.float32),
        in_specs=[pl.BlockSpec((d, R), lambda: (0, 0)),
                  pl.BlockSpec((d,), lambda: (0,))],
        out_specs=pl.BlockSpec((R,), lambda: (0,)),
        grid=(),
        interpret=interpret,
    )(G.astype(jnp.float32), g_bar.astype(jnp.float32))
