"""Jit'd public wrappers over the Pallas kernels.

On TPU these dispatch to the Mosaic-compiled kernels; on CPU (tests, the
dry-run container) they run in ``interpret=True`` mode, executing the same
kernel body in Python — bit-identical control flow, so the allclose tests
against ``ref.py`` validate the TPU target logic.
"""
from __future__ import annotations

import jax

from repro.kernels import fast_maxvol as _fm
from repro.kernels import graft_select as _gs
from repro.kernels import projection_sweep as _ps
from repro.kernels import rwkv_scan as _rw


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def fast_maxvol(V: jax.Array, rank: int) -> jax.Array:
    """Pivot indices (rank,) — Pallas fast MaxVol."""
    pivots, _ = _fm.fast_maxvol_pallas(V, rank, interpret=not _on_tpu())
    return pivots


def fast_maxvol_with_logvol(V: jax.Array, rank: int):
    return _fm.fast_maxvol_pallas(V, rank, interpret=not _on_tpu())


def projection_sweep(G: jax.Array, g_bar: jax.Array) -> jax.Array:
    """Prefix projection errors (R,) — Pallas MGS sweep."""
    return _ps.projection_sweep_pallas(G, g_bar, interpret=not _on_tpu())


def fused_graft_select(V: jax.Array, G: jax.Array, g_bar: jax.Array,
                       rank: int):
    """One GRAFT refresh (MaxVol + gather + MGS sweep) in ONE dispatch.
    Returns (pivots (rank,), errors (rank,), G_sel (d, rank))."""
    pivots, errors, _, gsel = _gs.fused_graft_select_pallas(
        V, G, g_bar, rank, interpret=not _on_tpu())
    return pivots, errors, gsel


def fused_graft_select_batched(V: jax.Array, G: jax.Array, g_bar: jax.Array,
                               rank: int):
    """A microbatch stack of refreshes in ONE grid=(B,) launch. Returns
    (pivots (B, rank), errors (B, rank), G_sel (B, d, rank))."""
    pivots, errors, _, gsel = _gs.fused_graft_select_batched_pallas(
        V, G, g_bar, rank, interpret=not _on_tpu())
    return pivots, errors, gsel


def rwkv_scan(r, k, v, w, u, chunk: int = 32) -> jax.Array:
    """Chunked RWKV6 recurrence (BH, T, D) — Pallas state-resident scan."""
    return _rw.rwkv_scan_pallas(r, k, v, w, u, chunk=chunk,
                                interpret=not _on_tpu())


def flash_attention(q, k, v, causal: bool = True, window=None, softcap=None,
                    block_q: int = 128, block_k: int = 128, group: int = 1,
                    scale=None):
    """Pallas flash attention q (B·H, S, Dh), k/v (B·Hkv, T, Dh) — the
    model hot path (differentiable; GQA via ``group``)."""
    from repro.kernels import flash_attention as _fa
    return _fa.flash_attention_pallas(q, k, v, block_q=block_q,
                                      block_k=block_k, causal=causal,
                                      window=window, softcap=softcap,
                                      group=group, scale=scale,
                                      interpret=not _on_tpu())
