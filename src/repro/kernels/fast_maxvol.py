"""Pallas TPU kernel for Fast MaxVol row selection (paper §3.1 Step 2).

TPU adaptation (DESIGN.md §3): the K×R feature matrix is tiny (K ≤ 1024,
R ≤ 128 ⇒ ≤ 512 KB fp32), so the WHOLE matrix lives in VMEM for the entire
R-step pivot loop — zero HBM round trips between steps, unlike the GPU
implementation's per-step kernel launches. Each step is a VPU-aligned
K-vector scan (argmax) + rank-1 FMA update; K is padded to the 8×128 lane
grid by the wrapper in ``ops.py``.

Grid: (1,) — selection is inherently sequential in R; parallelism is across
the K rows inside each step (lane dimension).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.numerics import safe_pivot


def _fast_maxvol_kernel(v_ref, pivots_ref, logvol_ref, *, rank: int):
    """One invocation selects all ``rank`` pivots.

    v_ref:      (K, R) f32 VMEM — feature matrix (mutated in place as the
                residual matrix; Pallas gives us a private copy).
    pivots_ref: (rank,) i32 VMEM out.
    logvol_ref: (1,) f32 VMEM out — accumulated log|det|.
    """
    K = v_ref.shape[0]
    W0 = v_ref[...]                                    # load full matrix to registers/VMEM
    avail0 = jnp.ones((K,), dtype=jnp.float32)

    def body(j, carry):
        W, avail, logvol = carry
        col = W[:, j]
        scores = jnp.where(avail > 0, jnp.abs(col), -1.0)
        pj = jnp.argmax(scores)
        pivot_val = safe_pivot(W[pj, j])
        factor = col / pivot_val                       # (K,)
        pivot_row = W[pj, :]                           # (R,)
        W_new = W - factor[:, None] * pivot_row[None, :]
        W_new = jnp.where((jax.lax.iota(jnp.int32, K) == pj)[:, None], W, W_new)
        avail = jnp.where(jax.lax.iota(jnp.int32, K) == pj, 0.0, avail)
        pivots_ref[j] = pj.astype(jnp.int32)
        return W_new, avail, logvol + jnp.log(jnp.abs(pivot_val))

    _, _, logvol = jax.lax.fori_loop(0, rank, body, (W0, avail0, jnp.float32(0.0)))
    logvol_ref[0] = logvol


@functools.partial(jax.jit, static_argnames=("rank", "interpret"))
def fast_maxvol_pallas(V: jax.Array, rank: int, interpret: bool = False):
    """Run the Fast MaxVol kernel. V: (K, R) — returns (pivots (rank,), logvol).

    BlockSpec: whole array resident in VMEM (K·R ≤ 128K fp32 elements by
    construction of GRAFT's K=batch, R=r_max regime — checked by the wrapper).
    """
    K, R = V.shape
    if rank > min(K, R):
        raise ValueError(f"rank {rank} > min{V.shape}")
    if K * R * 4 > 8 * 1024 * 1024:
        raise ValueError("feature matrix exceeds the VMEM budget; shrink K or R")
    kernel = functools.partial(_fast_maxvol_kernel, rank=rank)
    pivots, logvol = pl.pallas_call(
        kernel,
        out_shape=(jax.ShapeDtypeStruct((rank,), jnp.int32),
                   jax.ShapeDtypeStruct((1,), jnp.float32)),
        in_specs=[pl.BlockSpec((K, R), lambda: (0, 0))],
        out_specs=(pl.BlockSpec((rank,), lambda: (0,)),
                   pl.BlockSpec((1,), lambda: (0,))),
        grid=(),
        interpret=interpret,
    )(V.astype(jnp.float32))
    return pivots, logvol[0]
