"""Fused Pallas kernel for one whole GRAFT selection refresh (paper §3.1-3.2).

The unfused path is three device dispatches with an HBM round-trip between
each: ``fast_maxvol`` (pivots) → ``jnp.take`` (gather the pivot columns of
G) → ``projection_sweep`` (prefix errors). Here all three run in a single
``pallas_call`` with ``V (K, R)`` and ``G (d, K)`` resident in VMEM for the
whole refresh:

  1. Fast MaxVol pivot loop on V — identical control flow to
     ``kernels/fast_maxvol.py`` (same ``safe_pivot`` guard, same tie-break),
     so pivots are bit-identical to the unfused kernel.
  2. Column gather ``G_sel = G @ onehot(pivots)`` — a one-hot matmul rather
     than a dynamic gather: exact (one 1.0 per column) and MXU-friendly.
  3. MGS prefix projection-error sweep over ``G_sel`` against ``ḡ`` —
     identical arithmetic to ``kernels/projection_sweep.py``.

Two variants share one body:

  * ``fused_graft_select_pallas``          — ``grid=()``, one (K, R) batch.
  * ``fused_graft_select_batched_pallas``  — ``grid=(B,)``, a whole
    microbatch stack in ONE kernel launch (each grid step owns one batch's
    VMEM blocks). This is what ``engine.select_multi_batch`` dispatches
    instead of vmapping the ``grid=()`` kernel, which Mosaic cannot lower.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.numerics import safe_pivot

# MGS guard — must match kernels/projection_sweep.py for bit-identical errors
_EPS = 1e-12

# single-core VMEM budget for all resident blocks (f32 words, bytes)
# re-exported from the shared estimator (see repro.analysis.vmem, which
# owns the budget and the per-kernel footprint formulas)
from repro.analysis.vmem import VMEM_BUDGET_BYTES as _VMEM_BUDGET_BYTES


def _fused_body(V, G, g_bar, rank: int):
    """The whole refresh on loaded VMEM values.

    V: (K, R) f32; G: (d, K) f32; g_bar: (d,) f32. Returns
    (pivots (rank,) i32, errors (rank,) f32, logvol () f32,
    G_sel (d, rank) f32).
    """
    K = V.shape[0]

    # --- stage 1: Fast MaxVol (same arithmetic as _fast_maxvol_kernel) ---
    def mv_body(j, carry):
        W, avail, pivots, logvol = carry
        col = W[:, j]
        scores = jnp.where(avail > 0, jnp.abs(col), -1.0)
        pj = jnp.argmax(scores)
        pivot_val = safe_pivot(W[pj, j])
        factor = col / pivot_val                        # (K,)
        pivot_row = W[pj, :]                            # (R,)
        W_new = W - factor[:, None] * pivot_row[None, :]
        W_new = jnp.where((jax.lax.iota(jnp.int32, K) == pj)[:, None], W, W_new)
        avail = jnp.where(jax.lax.iota(jnp.int32, K) == pj, 0.0, avail)
        pivots = pivots.at[j].set(pj.astype(jnp.int32))
        return W_new, avail, pivots, logvol + jnp.log(jnp.abs(pivot_val))

    _, _, pivots, logvol = jax.lax.fori_loop(
        0, rank, mv_body,
        (V, jnp.ones((K,), jnp.float32),
         jnp.zeros((rank,), jnp.int32), jnp.float32(0.0)))

    # --- stage 2: gather the pivot columns of G as a one-hot matmul ---
    onehot = (jax.lax.broadcasted_iota(jnp.int32, (K, rank), 0)
              == pivots[None, :]).astype(jnp.float32)
    G_sel = G @ onehot                                  # (d, rank), exact

    # --- stage 3: MGS prefix sweep (same arithmetic as _projection_sweep_kernel) ---
    g_hat = g_bar / jnp.sqrt(jnp.sum(g_bar * g_bar) + _EPS)

    def mgs_body(j, carry):
        Q, captured, errs = carry                       # Q: (d, rank)
        q = G_sel[:, j]
        q = q - Q @ (Q.T @ q)
        q = q - Q @ (Q.T @ q)
        nrm = jnp.sqrt(jnp.sum(q * q))
        q = jnp.where(nrm > 1e-8, q / (nrm + _EPS), jnp.zeros_like(q))
        Q = jnp.where((jax.lax.iota(jnp.int32, rank) == j)[None, :],
                      q[:, None], Q)
        captured = captured + jnp.sum(q * g_hat) ** 2
        errs = errs.at[j].set(jnp.clip(1.0 - captured, 0.0, 1.0))
        return Q, captured, errs

    d = G.shape[0]
    _, _, errors = jax.lax.fori_loop(
        0, rank, mgs_body,
        (jnp.zeros((d, rank), jnp.float32), jnp.float32(0.0),
         jnp.zeros((rank,), jnp.float32)))
    return pivots, errors, logvol, G_sel


def _fused_kernel(v_ref, g_ref, gbar_ref,
                  piv_ref, err_ref, logvol_ref, gsel_ref, *, rank: int):
    pivots, errors, logvol, G_sel = _fused_body(
        v_ref[...], g_ref[...], gbar_ref[...], rank)
    piv_ref[...] = pivots
    err_ref[...] = errors
    logvol_ref[0] = logvol
    gsel_ref[...] = G_sel


def _fused_kernel_batched(v_ref, g_ref, gbar_ref,
                          piv_ref, err_ref, logvol_ref, gsel_ref, *,
                          rank: int):
    # every ref carries a leading block dim of 1 (one grid step = one batch)
    pivots, errors, logvol, G_sel = _fused_body(
        v_ref[0], g_ref[0], gbar_ref[0], rank)
    piv_ref[0] = pivots
    err_ref[0] = errors
    logvol_ref[0, 0] = logvol
    gsel_ref[0] = G_sel


def _check_budget(K: int, R: int, d: int, rank: int) -> None:
    # resident f32 blocks: V, G, G_sel, the MGS basis Q, and the one-hot —
    # accounted by the shared estimator (repro.analysis.vmem), so the
    # static checker and this runtime guard can never disagree
    from repro.analysis.vmem import fused_select_vmem
    est = fused_select_vmem(K, R, d, rank)
    if not est.fits:
        raise ValueError(
            f"fused selection blocks ({est.total / 2**20:.1f} MB) exceed "
            f"the VMEM budget; shrink K={K}, d={d} or rank={rank}")


@functools.partial(jax.jit, static_argnames=("rank", "interpret"))
def fused_graft_select_pallas(V: jax.Array, G: jax.Array, g_bar: jax.Array,
                              rank: int, interpret: bool = False):
    """One refresh, one dispatch. V: (K, R); G: (d, K); g_bar: (d,).

    Returns ``(pivots (rank,), errors (rank,), logvol (), G_sel (d, rank))``
    — pivots bit-identical to ``fast_maxvol_pallas``, errors bit-identical
    to ``projection_sweep_pallas`` on the gathered columns.
    """
    K, R = V.shape
    d, Kg = G.shape
    if Kg != K:
        raise ValueError(f"V rows {K} != G columns {Kg}")
    if g_bar.shape != (d,):
        raise ValueError(f"g_bar shape {g_bar.shape} != ({d},)")
    if rank > min(K, R):
        raise ValueError(f"rank {rank} > min{V.shape}")
    _check_budget(K, R, d, rank)
    kernel = functools.partial(_fused_kernel, rank=rank)
    pivots, errors, logvol, gsel = pl.pallas_call(
        kernel,
        out_shape=(jax.ShapeDtypeStruct((rank,), jnp.int32),
                   jax.ShapeDtypeStruct((rank,), jnp.float32),
                   jax.ShapeDtypeStruct((1,), jnp.float32),
                   jax.ShapeDtypeStruct((d, rank), jnp.float32)),
        in_specs=[pl.BlockSpec((K, R), lambda: (0, 0)),
                  pl.BlockSpec((d, K), lambda: (0, 0)),
                  pl.BlockSpec((d,), lambda: (0,))],
        out_specs=(pl.BlockSpec((rank,), lambda: (0,)),
                   pl.BlockSpec((rank,), lambda: (0,)),
                   pl.BlockSpec((1,), lambda: (0,)),
                   pl.BlockSpec((d, rank), lambda: (0, 0))),
        grid=(),
        interpret=interpret,
    )(V.astype(jnp.float32), G.astype(jnp.float32), g_bar.astype(jnp.float32))
    return pivots, errors, logvol[0], gsel


@functools.partial(jax.jit, static_argnames=("rank", "interpret"))
def fused_graft_select_batched_pallas(V: jax.Array, G: jax.Array,
                                      g_bar: jax.Array, rank: int,
                                      interpret: bool = False):
    """A whole microbatch stack in ONE launch (``grid=(B,)``).

    V: (B, K, R); G: (B, d, K); g_bar: (B, d). Returns per-batch
    ``(pivots (B, rank), errors (B, rank), logvol (B,), G_sel (B, d, rank))``
    — row ``b`` identical to ``fused_graft_select_pallas`` on batch ``b``.
    """
    B, K, R = V.shape
    _, d, Kg = G.shape
    if G.shape[0] != B or g_bar.shape != (B, d) or Kg != K:
        raise ValueError(f"inconsistent batch shapes V={V.shape} G={G.shape} "
                         f"g_bar={g_bar.shape}")
    if rank > min(K, R):
        raise ValueError(f"rank {rank} > min({K}, {R})")
    _check_budget(K, R, d, rank)
    kernel = functools.partial(_fused_kernel_batched, rank=rank)
    pivots, errors, logvol, gsel = pl.pallas_call(
        kernel,
        out_shape=(jax.ShapeDtypeStruct((B, rank), jnp.int32),
                   jax.ShapeDtypeStruct((B, rank), jnp.float32),
                   jax.ShapeDtypeStruct((B, 1), jnp.float32),
                   jax.ShapeDtypeStruct((B, d, rank), jnp.float32)),
        in_specs=[pl.BlockSpec((1, K, R), lambda b: (b, 0, 0)),
                  pl.BlockSpec((1, d, K), lambda b: (b, 0, 0)),
                  pl.BlockSpec((1, d), lambda b: (b, 0))],
        out_specs=(pl.BlockSpec((1, rank), lambda b: (b, 0)),
                   pl.BlockSpec((1, rank), lambda b: (b, 0)),
                   pl.BlockSpec((1, 1), lambda b: (b, 0)),
                   pl.BlockSpec((1, d, rank), lambda b: (b, 0, 0))),
        grid=(B,),
        interpret=interpret,
    )(V.astype(jnp.float32), G.astype(jnp.float32), g_bar.astype(jnp.float32))
    return pivots, errors, logvol[:, 0], gsel
