"""Pallas TPU flash attention (causal, optional sliding window + softcap).

The jnp chunked-attention path (models/layers._chunked_attention) is the
SPMD-dry-run reference; this kernel is the TPU fast path with the same
online-softmax schedule but explicit VMEM residency:

  grid = (BH, Sq // BLOCK_Q): each program owns one query tile. K/V for the
  (b,h) stream stay VMEM-resident across the program's KV loop (budget-
  guarded by the wrapper); scores exist only as a (BLOCK_Q, BLOCK_K) tile in
  registers/VMEM. m/l/acc run in f32 for numerical parity with the oracle.

GQA folds into the grid: q streams are (B·H) while k/v stay (B·Hkv); the
k/v BlockSpec index map divides the stream id by ``group`` so no repeated
K/V ever materializes. The sliding window rides along as a dynamic int32
scalar operand (w ≥ T disables it) so a traced per-layer ``is_local`` —
gemma2's scanned local/global pattern — selects the window without a
second kernel in the jaxpr.

Backward: custom_vjp with full recompute. Two kernels — dQ over the q grid
(same KV loop as forward) and dK/dV over the KV grid (loop over q tiles,
python-unrolled over the GQA group) — using the saved logsumexp residual
and the precomputed ``delta = Σ o·do`` row sums, so no (Sq × T) score
matrix ever materializes in either direction.

For KV streams too large for VMEM the wrapper refuses — the production
answer at 32k+ context is KV-tiling via a third grid axis, noted as future
work (the jnp path covers those cells today).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

# Mask fill value. A tile whose mask is ALL false leaves the running max at
# this sentinel; the online-softmax update must then suppress its
# contribution entirely (p = 0), not exp(0) = 1 — see _tile_probs.
_MASK = -1e30
_MASK_GUARD = -0.5e30


def _tile_mask(q_offset, k_offset, BQ: int, BK: int, causal: bool,
               use_window: bool, w) -> jax.Array:
    """(BQ, BK) validity mask for one score tile."""
    q_pos = q_offset + jax.lax.iota(jnp.int32, BQ)[:, None]
    k_pos = k_offset + jax.lax.iota(jnp.int32, BK)[None, :]
    mask = jnp.ones((BQ, BK), jnp.bool_)
    if causal:
        mask = jnp.logical_and(mask, k_pos <= q_pos)
    if use_window:
        mask = jnp.logical_and(mask, k_pos > q_pos - w)
    return mask


def _tile_scores(qs, k, mask, softcap: Optional[float]) -> jax.Array:
    """Masked (and optionally softcapped) scores for one tile; qs pre-scaled."""
    s = qs @ k.T
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    return jnp.where(mask, s, _MASK)


def _kv_bounds(q_offset, BQ: int, block_k: int, n_tiles: int, causal: bool,
               use_window: bool, w, bound_loop: bool):
    """[lo, hi) KV-tile range that can contain unmasked entries for this
    q tile. Causal bounds hi at ceil((q_offset + BQ) / block_k); the window
    bounds lo at the first tile reaching past ``q_offset - w``. With
    ``bound_loop=False`` the full range is scanned (the skipped tiles are
    all-masked, so with the _MASK_GUARD fix both variants are bit-equal —
    asserted in tests)."""
    lo: jax.Array | int = 0
    hi: jax.Array | int = n_tiles
    if bound_loop:
        if causal:
            hi = jnp.minimum(n_tiles, (q_offset + BQ + block_k - 1) // block_k)
        if use_window:
            lo = jnp.maximum(0, (q_offset - w + 1) // block_k)
    return lo, hi


def _flash_kernel(q_ref, k_ref, v_ref, w_ref, o_ref, lse_ref, *, block_k: int,
                  causal: bool, use_window: bool, softcap: Optional[float],
                  scale: float, bound_loop: bool):
    """Blocks: q (1, BQ, Dh); k/v (1, T, Dh); w (1,); o (1, BQ, Dh);
    lse (1, BQ) f32."""
    qs = q_ref[0].astype(jnp.float32) * scale          # (BQ, Dh)
    BQ = qs.shape[0]
    T = k_ref.shape[1]
    q_offset = pl.program_id(1) * BQ
    w = w_ref[0]

    m0 = jnp.full((BQ,), _MASK, jnp.float32)
    l0 = jnp.zeros((BQ,), jnp.float32)
    acc0 = jnp.zeros_like(qs)

    def body(i, carry):
        m, l, acc = carry
        k = k_ref[0, pl.dslice(i * block_k, block_k), :].astype(jnp.float32)
        v = v_ref[0, pl.dslice(i * block_k, block_k), :].astype(jnp.float32)
        mask = _tile_mask(q_offset, i * block_k, BQ, block_k,
                          causal, use_window, w)
        s = _tile_scores(qs, k, mask, softcap)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        alpha = jnp.exp(m - m_new)
        # A fully-masked row keeps m_new at the _MASK sentinel; without the
        # guard p = exp(s - m_new) = exp(0) = 1 there, silently averaging V.
        p = jnp.where(m_new[:, None] > _MASK_GUARD,
                      jnp.exp(s - m_new[:, None]), 0.0)
        l = l * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[:, None] + p @ v
        return m_new, l, acc

    lo, hi = _kv_bounds(q_offset, BQ, block_k, T // block_k,
                        causal, use_window, w, bound_loop)
    m, l, acc = jax.lax.fori_loop(lo, hi, body, (m0, l0, acc0))
    o_ref[0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)
    # logsumexp residual for the backward recompute; +inf marks rows whose
    # whole horizon is masked (output 0), so bwd p = exp(s - lse) = 0 there.
    lse_ref[0] = jnp.where(l > 0, m + jnp.log(jnp.maximum(l, 1e-30)), jnp.inf)


def _flash_dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, w_ref,
                     dq_ref, *, block_k: int, causal: bool, use_window: bool,
                     softcap: Optional[float], scale: float, bound_loop: bool):
    """dQ over the same (BH, Sq//BQ) grid / KV loop as the forward."""
    qs = q_ref[0].astype(jnp.float32) * scale
    do = do_ref[0].astype(jnp.float32)
    lse = lse_ref[0]                                    # (BQ,)
    delta = delta_ref[0]                                # (BQ,)
    BQ = qs.shape[0]
    T = k_ref.shape[1]
    q_offset = pl.program_id(1) * BQ
    w = w_ref[0]

    def body(i, dq):
        k = k_ref[0, pl.dslice(i * block_k, block_k), :].astype(jnp.float32)
        v = v_ref[0, pl.dslice(i * block_k, block_k), :].astype(jnp.float32)
        mask = _tile_mask(q_offset, i * block_k, BQ, block_k,
                          causal, use_window, w)
        s = _tile_scores(qs, k, mask, softcap)
        p = jnp.exp(s - lse[:, None])                   # normalized; 0 if masked
        dp = do @ v.T
        ds = p * (dp - delta[:, None])
        if softcap is not None:
            t = s / softcap                             # tanh(s_raw/cap) where unmasked
            ds = ds * jnp.where(mask, 1.0 - t * t, 0.0)
        return dq + ds @ k

    lo, hi = _kv_bounds(q_offset, BQ, block_k, T // block_k,
                        causal, use_window, w, bound_loop)
    dq = jax.lax.fori_loop(lo, hi, body, jnp.zeros_like(qs))
    dq_ref[0] = (dq * scale).astype(dq_ref.dtype)


def _flash_dkv_kernel(q_ref, do_ref, lse_ref, delta_ref, k_ref, v_ref, w_ref,
                      dk_ref, dv_ref, *, block_q: int, causal: bool,
                      use_window: bool, softcap: Optional[float], scale: float,
                      group: int, bound_loop: bool):
    """dK/dV over the (B·Hkv, T//BK) grid; loops q tiles, unrolls the GQA
    group (each kv stream serves ``group`` q streams). Blocks: q/do
    (group, Sq, Dh); lse/delta (group, Sq); k/v/dk/dv (1, BK, Dh)."""
    k = k_ref[0].astype(jnp.float32)                    # (BK, Dh)
    v = v_ref[0].astype(jnp.float32)
    BK = k.shape[0]
    Sq = q_ref.shape[1]
    k_offset = pl.program_id(1) * BK
    w = w_ref[0]
    n_q = Sq // block_q

    # q-tile range that can see this kv tile: causal needs q ≥ k_offset;
    # the window needs q < k_offset + BK - 1 + w.
    lo: jax.Array | int = 0
    hi: jax.Array | int = n_q
    if bound_loop:
        if causal:
            lo = k_offset // block_q
        if use_window:
            hi = jnp.minimum(n_q, (k_offset + BK + w + block_q - 2) // block_q)

    dk = jnp.zeros_like(k)
    dv = jnp.zeros_like(v)
    for g in range(group):
        def body(iq, carry, g=g):
            dk, dv = carry
            qs = q_ref[g, pl.dslice(iq * block_q, block_q), :].astype(
                jnp.float32) * scale
            do = do_ref[g, pl.dslice(iq * block_q, block_q), :].astype(
                jnp.float32)
            lse = lse_ref[g, pl.dslice(iq * block_q, block_q)]
            delta = delta_ref[g, pl.dslice(iq * block_q, block_q)]
            mask = _tile_mask(iq * block_q, k_offset, block_q, BK,
                              causal, use_window, w)
            s = _tile_scores(qs, k, mask, softcap)
            p = jnp.exp(s - lse[:, None])
            dp = do @ v.T
            ds = p * (dp - delta[:, None])
            if softcap is not None:
                t = s / softcap
                ds = ds * jnp.where(mask, 1.0 - t * t, 0.0)
            return dk + ds.T @ qs, dv + p.T @ do
        dk, dv = jax.lax.fori_loop(lo, hi, body, (dk, dv))
    dk_ref[0] = dk.astype(dk_ref.dtype)
    dv_ref[0] = dv.astype(dv_ref.dtype)


def _forward(q, k, v, w, *, block_q, block_k, causal, use_window, softcap,
             scale, group, bound_loop, interpret) -> Tuple[jax.Array, jax.Array]:
    BH, Sq, Dh = q.shape
    T = k.shape[1]
    kernel = functools.partial(
        _flash_kernel, block_k=block_k, causal=causal, use_window=use_window,
        softcap=softcap, scale=scale, bound_loop=bound_loop)
    kv_spec = pl.BlockSpec((1, T, Dh), lambda bh, iq: (bh // group, 0, 0))
    return pl.pallas_call(
        kernel,
        out_shape=(jax.ShapeDtypeStruct((BH, Sq, Dh), q.dtype),
                   jax.ShapeDtypeStruct((BH, Sq), jnp.float32)),
        in_specs=[pl.BlockSpec((1, block_q, Dh), lambda bh, iq: (bh, iq, 0)),
                  kv_spec, kv_spec,
                  pl.BlockSpec((1,), lambda bh, iq: (0,))],
        out_specs=(pl.BlockSpec((1, block_q, Dh), lambda bh, iq: (bh, iq, 0)),
                   pl.BlockSpec((1, block_q), lambda bh, iq: (bh, iq))),
        grid=(BH, Sq // block_q),
        interpret=interpret,
    )(q, k, v, w)


def _backward(q, k, v, w, o, lse, do, *, block_q, block_k, causal, use_window,
              softcap, scale, group, bound_loop, interpret):
    BH, Sq, Dh = q.shape
    BHkv, T = k.shape[0], k.shape[1]
    delta = jnp.sum(o.astype(jnp.float32) * do.astype(jnp.float32), axis=-1)

    dq_kernel = functools.partial(
        _flash_dq_kernel, block_k=block_k, causal=causal,
        use_window=use_window, softcap=softcap, scale=scale,
        bound_loop=bound_loop)
    kv_spec = pl.BlockSpec((1, T, Dh), lambda bh, iq: (bh // group, 0, 0))
    q_spec = pl.BlockSpec((1, block_q, Dh), lambda bh, iq: (bh, iq, 0))
    row_spec = pl.BlockSpec((1, block_q), lambda bh, iq: (bh, iq))
    dq = pl.pallas_call(
        dq_kernel,
        out_shape=jax.ShapeDtypeStruct((BH, Sq, Dh), q.dtype),
        in_specs=[q_spec, kv_spec, kv_spec, q_spec, row_spec, row_spec,
                  pl.BlockSpec((1,), lambda bh, iq: (0,))],
        out_specs=q_spec,
        grid=(BH, Sq // block_q),
        interpret=interpret,
    )(q, k, v, do, lse, delta, w)

    dkv_kernel = functools.partial(
        _flash_dkv_kernel, block_q=block_q, causal=causal,
        use_window=use_window, softcap=softcap, scale=scale, group=group,
        bound_loop=bound_loop)
    g_spec = pl.BlockSpec((group, Sq, Dh), lambda bkv, jk: (bkv, 0, 0))
    grow_spec = pl.BlockSpec((group, Sq), lambda bkv, jk: (bkv, 0))
    k_spec = pl.BlockSpec((1, block_k, Dh), lambda bkv, jk: (bkv, jk, 0))
    dk, dv = pl.pallas_call(
        dkv_kernel,
        out_shape=(jax.ShapeDtypeStruct((BHkv, T, Dh), k.dtype),
                   jax.ShapeDtypeStruct((BHkv, T, Dh), v.dtype)),
        in_specs=[g_spec, g_spec, grow_spec, grow_spec, k_spec, k_spec,
                  pl.BlockSpec((1,), lambda bkv, jk: (0,))],
        out_specs=(k_spec, k_spec),
        grid=(BHkv, T // block_k),
        interpret=interpret,
    )(q, do, lse, delta, k, v, w)
    return dq, dk, dv


@functools.lru_cache(maxsize=None)
def _make_flash_fn(block_q: int, block_k: int, causal: bool, use_window: bool,
                   softcap: Optional[float], scale: float, group: int,
                   bound_loop: bool, interpret: bool):
    """custom_vjp flash attention for one static config. The sliding window
    ``w`` is a (1,) int32 PRIMAL (it may be traced — gemma2's scanned
    is_local); its cotangent is float0."""
    opts = {"block_q": block_q, "block_k": block_k, "causal": causal,
            "use_window": use_window, "softcap": softcap, "scale": scale,
            "group": group, "bound_loop": bound_loop, "interpret": interpret}

    @jax.custom_vjp
    def fa(q, k, v, w):
        return _forward(q, k, v, w, **opts)[0]

    def fa_fwd(q, k, v, w):
        o, lse = _forward(q, k, v, w, **opts)
        return o, (q, k, v, w, o, lse)

    def fa_bwd(res, do):
        q, k, v, w, o, lse = res
        dq, dk, dv = _backward(q, k, v, w, o, lse, do, **opts)
        return dq, dk, dv, np.zeros((1,), jax.dtypes.float0)

    fa.defvjp(fa_fwd, fa_bwd)
    return fa


def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                           block_q: int = 128, block_k: int = 128,
                           causal: bool = True,
                           window=None,
                           softcap: Optional[float] = None,
                           interpret: bool = False,
                           group: int = 1,
                           scale: Optional[float] = None,
                           bound_loop: bool = True) -> jax.Array:
    """q: (B·H, Sq, Dh); k/v: (B·Hkv, T, Dh) with H = Hkv·group (streams
    ordered head-major so q stream i reads kv stream i // group). Returns
    (B·H, Sq, Dh) in q dtype. Differentiable (custom_vjp with recompute).

    ``scale`` defaults to 1/sqrt(Dh); pass 1.0 for pre-scaled queries.
    ``window`` may be a python int or a traced int scalar (dynamic per-layer
    sliding window); values ≥ T are a no-op. VMEM per program: 2·T·Dh f32
    (K,V) + 3 q-tiles ⇒ guard at ~12 MB.
    """
    BH, Sq, Dh = q.shape
    BHkv, T = k.shape[0], k.shape[1]
    if BHkv * group != BH or v.shape != k.shape:
        raise ValueError(f"GQA shapes: q {q.shape}, k {k.shape}, group={group}")
    if Sq % block_q or T % block_k:
        raise ValueError(f"Sq={Sq} % {block_q} or T={T} % {block_k} != 0")
    from repro.analysis.vmem import flash_forward_vmem
    est = flash_forward_vmem(T, Dh, block_q)
    if not est.fits:
        raise ValueError(
            f"KV stream exceeds the single-program VMEM budget "
            f"({est.describe()}); use the jnp chunked path (or KV grid "
            "tiling, TBD)")
    if scale is None:
        scale = 1.0 / (Dh ** 0.5)
    use_window = window is not None
    if window is None:
        w = jnp.full((1,), T, jnp.int32)
    else:
        w = jnp.reshape(jnp.asarray(window, jnp.int32), (1,))
    fa = _make_flash_fn(block_q, block_k, bool(causal), use_window,
                        None if softcap is None else float(softcap),
                        float(scale), int(group), bool(bound_loop),
                        bool(interpret))
    return fa(q, k, v, w)
