"""Pallas TPU flash attention (causal, optional sliding window + softcap).

The jnp chunked-attention path (models/layers._chunked_attention) is the
SPMD-dry-run reference; this kernel is the TPU fast path with the same
online-softmax schedule but explicit VMEM residency:

  grid = (BH, Sq // BLOCK_Q): each program owns one query tile. K/V for the
  (b,h) stream stay VMEM-resident across the program's KV loop (budget-
  guarded by the wrapper); scores exist only as a (BLOCK_Q, BLOCK_K) tile in
  registers/VMEM. m/l/acc run in f32 for numerical parity with the oracle.

For KV streams too large for VMEM the wrapper refuses — the production
answer at 32k+ context is KV-tiling via a third grid axis, noted as future
work (the jnp path covers those cells today).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, block_k: int,
                  causal: bool, window: Optional[int],
                  softcap: Optional[float], scale: float):
    """Blocks: q (1, BQ, Dh); k/v (1, T, Dh); o (1, BQ, Dh)."""
    q = q_ref[0].astype(jnp.float32) * scale          # (BQ, Dh)
    BQ = q.shape[0]
    T = k_ref.shape[1]
    q_offset = pl.program_id(1) * BQ

    m0 = jnp.full((BQ,), -1e30, jnp.float32)
    l0 = jnp.zeros((BQ,), jnp.float32)
    acc0 = jnp.zeros_like(q)

    def body(i, carry):
        m, l, acc = carry
        k = k_ref[0, pl.dslice(i * block_k, block_k), :].astype(jnp.float32)
        v = v_ref[0, pl.dslice(i * block_k, block_k), :].astype(jnp.float32)
        s = q @ k.T                                    # (BQ, BK)
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        q_pos = q_offset + jax.lax.iota(jnp.int32, BQ)[:, None]
        k_pos = i * block_k + jax.lax.iota(jnp.int32, block_k)[None, :]
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask = jnp.logical_and(mask, k_pos <= q_pos)
        if window is not None:
            mask = jnp.logical_and(mask, k_pos > q_pos - window)
        s = jnp.where(mask, s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[:, None])
        l = l * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[:, None] + p @ v
        return m_new, l, acc

    m, l, acc = jax.lax.fori_loop(0, T // block_k, body, (m0, l0, acc0))
    o_ref[0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "block_q", "block_k", "causal", "window", "softcap", "interpret"))
def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array,
                           block_q: int = 128, block_k: int = 128,
                           causal: bool = True,
                           window: Optional[int] = None,
                           softcap: Optional[float] = None,
                           interpret: bool = False) -> jax.Array:
    """q: (BH, Sq, Dh); k/v: (BH, T, Dh) → (BH, Sq, Dh) in q dtype.

    Pre-scaled by 1/sqrt(Dh). VMEM per program: 2·T·Dh f32 (K,V) +
    3 q-tiles ⇒ guard at ~12 MB.
    """
    BH, Sq, Dh = q.shape
    T = k.shape[1]
    if Sq % block_q or T % block_k:
        raise ValueError(f"Sq={Sq} % {block_q} or T={T} % {block_k} != 0")
    if (2 * T * Dh + 3 * block_q * Dh) * 4 > 12 * 1024 * 1024:
        raise ValueError("KV stream exceeds the single-program VMEM budget; "
                         "use the jnp chunked path (or KV grid tiling, TBD)")
    kernel = functools.partial(
        _flash_kernel, block_k=block_k, causal=causal, window=window,
        softcap=softcap, scale=1.0 / (Dh ** 0.5))
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct((BH, Sq, Dh), q.dtype),
        in_specs=[pl.BlockSpec((1, block_q, Dh), lambda bh, iq: (bh, iq, 0)),
                  pl.BlockSpec((1, T, Dh), lambda bh, iq: (bh, 0, 0)),
                  pl.BlockSpec((1, T, Dh), lambda bh, iq: (bh, 0, 0))],
        out_specs=pl.BlockSpec((1, block_q, Dh), lambda bh, iq: (bh, iq, 0)),
        grid=(BH, Sq // block_q),
        interpret=interpret,
    )(q, k, v)
