"""Pallas TPU kernel for the RWKV6 recurrence (assigned arch rwkv6-7b).

Beyond-paper optimization (DESIGN.md §5): the WKV recurrence
``S_t = diag(w_t)·S_{t-1} + k_t v_tᵀ;  o_t = r_t·(S_{t-1} + diag(u)·k_t v_tᵀ)``
is latency-bound when evaluated step-by-step from HBM. We tile time into
chunks: the (D×D) state lives in a VMEM scratch accumulator across the whole
sequence (grid iterates chunks sequentially on TPU), while r/k/v/w stream in
as (CHUNK, D) blocks — one HBM round-trip per chunk instead of per step.

Grid: (BH, T // CHUNK) — batch×head parallel dim first (TPU iterates the
trailing grid dim innermost, so the state scratch carries across chunks of
one (b,h) stream and resets when program_id(1) == 0).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rwkv_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, o_ref, state_ref):
    """Blocks: r/k/v/w (1, C, D); u (1, D); o (1, C, D); state (D, D) scratch."""
    chunk = pl.program_id(1)

    @pl.when(chunk == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    r = r_ref[0]                       # (C, D)
    k = k_ref[0]
    v = v_ref[0]
    w = w_ref[0]
    u = u_ref[0]                       # (D,)
    C, D = r.shape

    def step(t, S):
        kt, vt, rt, wt = k[t], v[t], r[t], w[t]
        kv = kt[:, None] * vt[None, :]                 # (D, D) outer product
        o_ref[0, t, :] = rt @ (S + u[:, None] * kv)
        return S * wt[:, None] + kv

    state_ref[...] = jax.lax.fori_loop(0, C, step, state_ref[...])


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def rwkv_scan_pallas(r: jax.Array, k: jax.Array, v: jax.Array, w: jax.Array,
                     u: jax.Array, chunk: int = 32,
                     interpret: bool = False) -> jax.Array:
    """r/k/v/w: (BH, T, D); u: (BH, D). Returns outputs (BH, T, D) f32.

    VMEM per program: 4·C·D (streams) + D² (state) + C·D (out) f32 —
    with C=32, D=64: ~57 KB. T must be divisible by ``chunk``.
    """
    BH, T, D = r.shape
    if T % chunk:
        raise ValueError(f"T={T} not divisible by chunk={chunk}")
    seq_spec = pl.BlockSpec((1, chunk, D), lambda bh, c: (bh, c, 0))
    return pl.pallas_call(
        _rwkv_kernel,
        out_shape=jax.ShapeDtypeStruct((BH, T, D), jnp.float32),
        in_specs=[seq_spec, seq_spec, seq_spec, seq_spec,
                  pl.BlockSpec((1, D), lambda bh, c: (bh, 0))],
        out_specs=seq_spec,
        scratch_shapes=[pltpu.VMEM((D, D), jnp.float32)],
        grid=(BH, T // chunk),
        interpret=interpret,
    )(r.astype(jnp.float32), k.astype(jnp.float32), v.astype(jnp.float32),
      w.astype(jnp.float32), u.astype(jnp.float32))
