"""Model zoo: unified decoder covering dense / MoE / RWKV6 / SSM-hybrid /
audio / VLM families (the 10 assigned architectures)."""
from repro.models.model import (ModelConfig, forward_hiddens, init_params,
                                logits_from_hiddens, loss_fn, params_logical,
                                per_example_loss, pooled_features)
from repro.models.decode import decode_step, init_cache, prefill

__all__ = [
    "ModelConfig", "init_params", "params_logical", "loss_fn",
    "per_example_loss", "pooled_features", "forward_hiddens",
    "logits_from_hiddens", "decode_step", "init_cache", "prefill",
]
