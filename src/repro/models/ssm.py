"""Recurrent blocks: RWKV6 ("Finch") time/channel mix and a Mamba-style
selective SSM head (used standalone for rwkv6-7b and inside Hymba's parallel
attn+SSM layers).

Train/prefill use ``lax.scan`` over time with the state resident (no T-sized
state materialization); decode is a single O(1) state update. The Pallas
``rwkv_scan`` kernel (repro/kernels) is the TPU fast path for the WKV
recurrence; the scan here is the jnp reference used by the SPMD dry-run.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain


# ---------------------------------------------------------------------------
# RWKV6 time mix
# ---------------------------------------------------------------------------

def _token_shift(x: jax.Array, last: Optional[jax.Array]) -> Tuple[jax.Array, jax.Array]:
    """Shift sequence right by one. ``last``: (B,1,D) carry for decode."""
    if last is None:
        last = jnp.zeros_like(x[:, :1])
    shifted = jnp.concatenate([last, x[:, :-1]], axis=1)
    return shifted, x[:, -1:]


def _lora_mix(x, shifted, mu, A, B_):
    """RWKV6 data-dependent lerp: x + (shifted - x) * (mu + tanh(xA)B)."""
    delta = shifted - x
    dyn = jnp.einsum("bsd,dr->bsr", x, A)
    dyn = jnp.einsum("bsr,rd->bsd", jnp.tanh(dyn), B_)
    return x + delta * (mu + dyn)


def rwkv_time_mix(cfg, p, x: jax.Array, state: Optional[dict] = None
                  ) -> Tuple[jax.Array, Optional[dict]]:
    """RWKV6 attention-free token mixing.

    x: (B, S, D). state (decode): {"shift": (B,1,D), "wkv": (B,H,Dh,Dh)}.
    Returns (out, new_state or None).
    """
    B, S, D = x.shape
    H = cfg.num_heads
    Dh = D // H

    shifted, new_shift = _token_shift(x, state["shift"] if state else None)
    xr = _lora_mix(x, shifted, p["mu_r"], p["lora_A"], p["lora_B_r"])
    xk = _lora_mix(x, shifted, p["mu_k"], p["lora_A"], p["lora_B_k"])
    xv = _lora_mix(x, shifted, p["mu_v"], p["lora_A"], p["lora_B_v"])
    xw = _lora_mix(x, shifted, p["mu_w"], p["lora_A"], p["lora_B_w"])
    xg = _lora_mix(x, shifted, p["mu_g"], p["lora_A"], p["lora_B_g"])

    r = jnp.einsum("bsd,de->bse", xr, p["wr"]).reshape(B, S, H, Dh)
    k = jnp.einsum("bsd,de->bse", xk, p["wk"]).reshape(B, S, H, Dh)
    v = jnp.einsum("bsd,de->bse", xv, p["wv"]).reshape(B, S, H, Dh)
    g = jax.nn.silu(jnp.einsum("bsd,de->bse", xg, p["wg"]))
    # data-dependent per-channel decay in (0, 1): w = exp(-exp(w0 + f(x)))
    wlog = p["w0"] + jnp.einsum("bsd,dr->bsr", jnp.tanh(
        jnp.einsum("bsd,dr->bsr", xw, p["decay_A"])), p["decay_B"])
    w = jnp.exp(-jnp.exp(wlog.astype(jnp.float32))).reshape(B, S, H, Dh)
    u = p["u"].reshape(H, Dh)

    r = constrain(r, ("act_batch", "act_seq", "act_heads", None))
    k = constrain(k, ("act_batch", "act_seq", "act_heads", None))
    v = constrain(v, ("act_batch", "act_seq", "act_heads", None))

    rf, kf, vf, wf = (t.astype(jnp.float32).transpose(0, 2, 1, 3)
                      for t in (r, k, v, w))  # (B,H,S,Dh)

    S0 = (state["wkv"] if state else
          jnp.zeros((B, H, Dh, Dh), dtype=jnp.float32))

    def step(carry, inputs):
        rt, kt, vt, wt = inputs                    # each (B,H,Dh)
        kv = kt[..., :, None] * vt[..., None, :]   # (B,H,Dh,Dh)
        out = jnp.einsum("bhk,bhkv->bhv", rt, carry + u[None, :, :, None] * kv)
        carry = carry * wt[..., :, None] + kv
        return carry, out

    xs = tuple(t.transpose(2, 0, 1, 3) for t in (rf, kf, vf, wf))  # (S,B,H,Dh)
    Sn, outs = jax.lax.scan(step, S0, xs)
    wkv = outs.transpose(1, 0, 2, 3).reshape(B, S, D)              # (B,S,D)

    # per-head group norm then gate
    wkv = wkv.reshape(B, S, H, Dh)
    mean = jnp.mean(wkv, axis=-1, keepdims=True)
    var = jnp.var(wkv, axis=-1, keepdims=True)
    wkv = (wkv - mean) * jax.lax.rsqrt(var + 1e-5)
    wkv = (wkv * p["ln_x_scale"].reshape(H, Dh)).reshape(B, S, D).astype(x.dtype)
    out = jnp.einsum("bsd,de->bse", wkv * g, p["wo"])
    out = constrain(out, ("act_batch", "act_res_seq", "act_embed"))

    new_state = {"shift": new_shift, "wkv": Sn} if state is not None else None
    return out, new_state


def init_rwkv_time_params(key, cfg, dtype) -> dict:
    D = cfg.d_model
    H = cfg.num_heads
    Dh = D // H
    lora_r = max(32, D // 64)
    ks = jax.random.split(key, 12)
    s = D ** -0.5

    def mat(k, shape, scale):
        return (jax.random.normal(k, shape) * scale).astype(dtype)

    p = {
        "wr": mat(ks[0], (D, D), s), "wk": mat(ks[1], (D, D), s),
        "wv": mat(ks[2], (D, D), s), "wg": mat(ks[3], (D, D), s),
        "wo": mat(ks[4], (D, D), s),
        "lora_A": mat(ks[5], (D, lora_r), s),
        "lora_B_r": jnp.zeros((lora_r, D), dtype),
        "lora_B_k": jnp.zeros((lora_r, D), dtype),
        "lora_B_v": jnp.zeros((lora_r, D), dtype),
        "lora_B_w": jnp.zeros((lora_r, D), dtype),
        "lora_B_g": jnp.zeros((lora_r, D), dtype),
        "mu_r": jnp.full((D,), 0.5, dtype), "mu_k": jnp.full((D,), 0.5, dtype),
        "mu_v": jnp.full((D,), 0.5, dtype), "mu_w": jnp.full((D,), 0.5, dtype),
        "mu_g": jnp.full((D,), 0.5, dtype),
        "decay_A": mat(ks[6], (D, lora_r), s),
        "decay_B": mat(ks[7], (lora_r, D), 0.01),
        "w0": jnp.full((D,), 0.5, jnp.float32),   # exp(-exp(0.5)) ≈ 0.19 decay
        "u": (jax.random.normal(ks[8], (D,)) * 0.1).astype(jnp.float32),
        "ln_x_scale": jnp.ones((D,), jnp.float32),
    }
    return p


def rwkv_channel_mix(cfg, p, x: jax.Array, state: Optional[dict] = None
                     ) -> Tuple[jax.Array, Optional[dict]]:
    """RWKV FFN with token shift and squared-ReLU."""
    shifted, new_shift = _token_shift(x, state["shift"] if state else None)
    xk = x + (shifted - x) * p["mu_k"]
    xr = x + (shifted - x) * p["mu_r"]
    k = jnp.einsum("bsd,df->bsf", xk, p["w_key"])
    k = jnp.square(jax.nn.relu(k))
    k = constrain(k, ("act_batch", "act_seq", "act_mlp"))
    kv = jnp.einsum("bsf,fd->bsd", k, p["w_value"])
    r = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, p["w_recept"]))
    out = constrain(r * kv, ("act_batch", "act_res_seq", "act_embed"))
    new_state = {"shift": new_shift} if state is not None else None
    return out, new_state


def init_rwkv_channel_params(key, cfg, dtype) -> dict:
    D, F = cfg.d_model, cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_key": (jax.random.normal(k1, (D, F)) * D ** -0.5).astype(dtype),
        "w_value": (jax.random.normal(k2, (F, D)) * F ** -0.5).astype(dtype),
        "w_recept": (jax.random.normal(k3, (D, D)) * D ** -0.5).astype(dtype),
        "mu_k": jnp.full((D,), 0.5, dtype),
        "mu_r": jnp.full((D,), 0.5, dtype),
    }


# ---------------------------------------------------------------------------
# Mamba-style selective SSM head (Hymba)
# ---------------------------------------------------------------------------

def ssm_heads(cfg, p, x: jax.Array, state: Optional[jax.Array] = None
              ) -> Tuple[jax.Array, Optional[jax.Array]]:
    """Selective SSM over H heads of dim Dh with diagonal state size N.

    h_t = exp(-softplus(Δ_t) A) ⊙ h_{t-1} + Δ_t · (x̃_t ⊗ B_t)
    y_t = (h_t · C_t) + D_skip ⊙ x̃_t
    x: (B,S,D) → y: (B,S,D). state: (B,H,Dh,N) decode carry.
    """
    B, S, D = x.shape
    H = cfg.num_heads
    Dh = D // H
    N = cfg.ssm_state

    xt = jnp.einsum("bsd,de->bse", x, p["w_in"]).reshape(B, S, H, Dh)
    Bm = jnp.einsum("bsd,dhn->bshn", x, p["w_B"])          # (B,S,H,N)
    Cm = jnp.einsum("bsd,dhn->bshn", x, p["w_C"])
    delta = jax.nn.softplus(
        jnp.einsum("bsd,dh->bsh", x, p["w_delta"]).astype(jnp.float32)
        + p["delta_bias"])                                  # (B,S,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))            # (H,N) negative
    decay = jnp.exp(delta[..., None] * A[None, None])       # (B,S,H,N)

    xt = constrain(xt, ("act_batch", "act_seq", "act_heads", None))
    xtf = xt.astype(jnp.float32)

    def step(h, inputs):
        dec_t, b_t, x_t, dl_t, c_t = inputs
        # h: (B,H,Dh,N)
        h = h * dec_t[:, :, None, :] + (dl_t[..., None, None] *
                                        x_t[..., :, None] * b_t[:, :, None, :])
        y = jnp.einsum("bhdn,bhn->bhd", h, c_t)
        return h, y

    h0 = state if state is not None else jnp.zeros((B, H, Dh, N), jnp.float32)
    xs = (decay.transpose(1, 0, 2, 3), Bm.astype(jnp.float32).transpose(1, 0, 2, 3),
          xtf.transpose(1, 0, 2, 3), delta.transpose(1, 0, 2),
          Cm.astype(jnp.float32).transpose(1, 0, 2, 3))
    hN, ys = jax.lax.scan(step, h0, xs)
    y = ys.transpose(1, 0, 2, 3)                            # (B,S,H,Dh)
    y = y + p["D_skip"].astype(jnp.float32)[None, None, :, None] * xtf
    y = y.reshape(B, S, D).astype(x.dtype)
    out = jnp.einsum("bsd,de->bse", y, p["w_out"])
    out = constrain(out, ("act_batch", "act_res_seq", "act_embed"))
    new_state = hN if state is not None else None
    return out, new_state


def init_ssm_params(key, cfg, dtype) -> dict:
    D = cfg.d_model
    H = cfg.num_heads
    N = cfg.ssm_state
    ks = jax.random.split(key, 6)
    s = D ** -0.5
    return {
        "w_in": (jax.random.normal(ks[0], (D, D)) * s).astype(dtype),
        "w_out": (jax.random.normal(ks[1], (D, D)) * s).astype(dtype),
        "w_B": (jax.random.normal(ks[2], (D, H, N)) * s).astype(dtype),
        "w_C": (jax.random.normal(ks[3], (D, H, N)) * s).astype(dtype),
        "w_delta": (jax.random.normal(ks[4], (D, H)) * s).astype(dtype),
        "delta_bias": jnp.zeros((H,), jnp.float32),
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, N + 1, dtype=jnp.float32), (H, N))),
        "D_skip": jnp.ones((H,), jnp.float32),
    }
