"""Unified decoder model covering all 10 assigned architecture families.

One ``ModelConfig`` + one parameter pytree + family-dispatched blocks:
  dense   — attn + gated MLP                     (stablelm/gemma2/qwen/minicpm)
  moe     — attn + capacity-factor MoE           (qwen3-moe, kimi-k2)
  ssm     — RWKV6 time mix + channel mix         (rwkv6)
  hybrid  — parallel attn∥SSM heads + MLP        (hymba)
  audio   — dense blocks over frame embeddings   (musicgen; frontend stub)
  vlm     — dense blocks over patch+text tokens  (internvl2; frontend stub)

Layers are scan-stacked (compile time O(1) in depth); per-layer binary
patterns (gemma2 local/global, hymba global islands) ride along as scan xs.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import constrain
from repro.models import layers as L
from repro.models import ssm as S


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"           # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int = 2
    d_model: int = 128
    num_heads: int = 4
    num_kv_heads: int = 4
    head_dim: int = 32
    d_ff: int = 512
    vocab_size: int = 1024
    # attention
    qkv_bias: bool = False
    rope_theta: float = 1e4
    sliding_window: Optional[int] = None
    layer_pattern: Tuple[str, ...] = ("global",)   # cycled; "local"|"global"
    global_layer_indices: Tuple[int, ...] = ()     # explicit global islands (hymba)
    attn_logit_softcap: Optional[float] = None
    final_logit_softcap: Optional[float] = None
    query_scale: Optional[float] = None
    post_block_norm: bool = False                  # gemma2 post-norms
    mlp_activation: str = "silu"
    # moe
    num_experts: int = 0
    num_experts_per_tok: int = 0
    moe_capacity_factor: float = 1.25
    moe_group_size: int = 512
    moe_local_groups: bool = False       # chunk-major SP-aligned routing (§Perf i6)
    first_k_dense: int = 0
    d_ff_dense: int = 0                            # dense-FFN width for first_k layers
    # ssm / hybrid
    ssm_state: int = 0
    # frontend stubs
    frontend: Optional[str] = None                 # audio_frames | vision_patches
    num_patches: int = 0
    # misc
    rms_eps: float = 1e-5
    tie_embeddings: bool = False
    param_dtype: str = "bfloat16"
    # perf knobs (hillclimb levers)
    remat: str = "full"                            # none | full | dots
    scan_layers: bool = True
    attn_backend: str = "auto"                     # auto | dense | chunked |
                                                   # flash (Pallas kernel;
                                                   # auto = flash on TPU,
                                                   # jnp paths elsewhere)
    attn_chunk: int = 0                            # 0 = dense scores; else
                                                   # flash-style KV chunking
    loss_chunk: int = 0                            # 0 = whole-seq CE; else
                                                   # seq-chunked CE (remat'd)

    @property
    def dtype(self):
        return jnp.dtype(self.param_dtype)

    def is_local_pattern(self) -> np.ndarray:
        """(L,) bool: which layers use the sliding window."""
        idx = np.arange(self.num_layers)
        if self.global_layer_indices:
            return ~np.isin(idx, np.asarray(self.global_layer_indices))
        pat = np.array([p == "local" for p in self.layer_pattern])
        return pat[idx % len(pat)]


# ---------------------------------------------------------------------------
# parameter init (+ matching logical-axis tree)
# ---------------------------------------------------------------------------

def _block_init(cfg: ModelConfig, key, dense_override: bool = False):
    dt = cfg.dtype
    D = cfg.d_model
    ks = jax.random.split(key, 8)
    norm = lambda: jnp.zeros((D,), jnp.float32)
    fam = "dense" if dense_override else cfg.family
    if fam in ("dense", "audio", "vlm"):
        p = {"ln1": norm(), "attn": L.init_attention_params(ks[0], cfg, dt),
             "ln2": norm(), "mlp": L.init_mlp_params(ks[1], cfg, dt)}
        if cfg.post_block_norm:
            p["ln1_post"], p["ln2_post"] = norm(), norm()
    elif fam == "moe":
        p = {"ln1": norm(), "attn": L.init_attention_params(ks[0], cfg, dt),
             "ln2": norm(), "moe": L.init_moe_params(ks[1], cfg, dt)}
    elif fam == "ssm":
        p = {"ln1": norm(), "time": S.init_rwkv_time_params(ks[0], cfg, dt),
             "ln2": norm(), "channel": S.init_rwkv_channel_params(ks[1], cfg, dt)}
    elif fam == "hybrid":
        p = {"ln1": norm(), "attn": L.init_attention_params(ks[0], cfg, dt),
             "ssm": S.init_ssm_params(ks[1], cfg, dt),
             "ln2": norm(), "mlp": L.init_mlp_params(ks[2], cfg, dt)}
    else:
        raise ValueError(cfg.family)
    if fam == "dense" and dense_override and cfg.d_ff_dense:
        p["mlp"] = L.init_mlp_params(ks[1], cfg, dt, d_ff=cfg.d_ff_dense)
    return p


_LOGICAL = {
    # attention
    "wq": ("embed", "heads", None), "wk": ("embed", "kv_heads", None),
    "wv": ("embed", "kv_heads", None), "wo": ("heads", None, "embed"),
    "bq": ("heads", None), "bk": ("kv_heads", None), "bv": ("kv_heads", None),
    # mlp
    "w_gate": ("embed", "mlp"), "w_up": ("embed", "mlp"), "w_down": ("mlp", "embed"),
    # moe (leaf names inside "moe" subtree get experts-first shapes)
    "router": ("embed", None),
    # rwkv
    "wr": ("embed", "ssm_inner"), "wg": ("embed", "ssm_inner"),
    "lora_A": ("embed", None), "decay_A": ("embed", None),
    "lora_B_r": (None, "embed"), "lora_B_k": (None, "embed"),
    "lora_B_v": (None, "embed"), "lora_B_w": (None, "embed"),
    "lora_B_g": (None, "embed"), "decay_B": (None, "embed"),
    # ssm heads
    "w_in": ("embed", "ssm_inner"), "w_out": ("ssm_inner", "embed"),
    "w_B": ("embed", "heads", None), "w_C": ("embed", "heads", None),
    "w_delta": ("embed", "heads"),
    # rwkv channel
    "w_key": ("embed", "mlp"), "w_value": ("mlp", "embed"),
    "w_recept": ("embed", "ssm_inner"),
}

_MOE_LOGICAL = {
    "w_gate": ("experts", "embed", "mlp"), "w_up": ("experts", "embed", "mlp"),
    "w_down": ("experts", "mlp", "embed"), "router": ("embed", None),
}


def _leaf_logical(path: Tuple[str, ...], leaf) -> Tuple[Optional[str], ...]:
    name = path[-1]
    # rwkv time-mix reuses attention-style names for D×D projections — must
    # dispatch on the subtree BEFORE the generic table
    if "time" in path:
        if name in ("wk", "wv"):
            return ("embed", "ssm_inner")
        if name == "wo":
            return ("ssm_inner", "embed")
    table = _MOE_LOGICAL if "moe" in path else _LOGICAL
    if name in table:
        return table[name]
    return tuple(None for _ in leaf.shape)


def _tree_logical(tree, prefix=()):
    if isinstance(tree, dict):
        return {k: _tree_logical(v, prefix + (k,)) for k, v in tree.items()}
    return _leaf_logical(prefix, tree)


def init_params(cfg: ModelConfig, key: jax.Array) -> Dict[str, Any]:
    dt = cfg.dtype
    k_embed, k_blocks, k_head, k_first = jax.random.split(key, 4)
    V, D = cfg.vocab_size, cfg.d_model
    params: Dict[str, Any] = {
        "embed": (jax.random.normal(k_embed, (V, D)) * 0.02).astype(dt),
        "final_norm": jnp.zeros((D,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = (jax.random.normal(k_head, (D, V)) * D ** -0.5).astype(dt)

    n_scan = cfg.num_layers - cfg.first_k_dense
    if cfg.first_k_dense:
        params["first_blocks"] = [
            _block_init(cfg, k, dense_override=True)
            for k in jax.random.split(k_first, cfg.first_k_dense)]
    # stacked block params for scan
    block_keys = jax.random.split(k_blocks, n_scan)
    blocks = [_block_init(cfg, k) for k in block_keys]
    params["blocks"] = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *blocks)
    return params


def params_logical(cfg: ModelConfig, params) -> Dict[str, Any]:
    """Logical-axis tree matching ``params`` (stacked dims get 'layers')."""
    out: Dict[str, Any] = {
        # vocab dim replicated: a gather from a vocab-sharded table forces the
        # SPMD partitioner into replicate-then-repartition (observed in the
        # dry-run HLO); d_model shards over the fsdp axis instead.
        "embed": (None, "embed"),
        "final_norm": (None,),
    }
    if "lm_head" in params:
        out["lm_head"] = ("embed", "vocab")
    if "first_blocks" in params:
        out["first_blocks"] = [_tree_logical(b) for b in params["first_blocks"]]
    blocks_logical = _tree_logical(
        jax.tree_util.tree_map(lambda x: jax.ShapeDtypeStruct(x.shape[1:], x.dtype),
                               params["blocks"]))
    out["blocks"] = jax.tree_util.tree_map(
        lambda lg: ("layers",) + lg, blocks_logical,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))
    return out


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------

def _apply_block(cfg: ModelConfig, p, x, positions, is_local,
                 cache=None, cache_index=None, dense_override=False):
    """One residual block; returns (x, new_cache)."""
    fam = "dense" if dense_override else cfg.family
    new_cache = {}
    if fam in ("dense", "audio", "vlm", "moe", "hybrid"):
        h = L.rms_norm(x, p["ln1"], cfg.rms_eps)
        attn_out, attn_cache = L.attention(
            cfg, p["attn"], h, positions, is_local=is_local,
            cache=cache.get("attn") if cache else None, cache_index=cache_index)
        if fam == "hybrid":
            ssm_out, ssm_state = S.ssm_heads(
                cfg, p["ssm"], h, state=cache.get("ssm") if cache else None)
            attn_out = attn_out + ssm_out
            if cache is not None:
                new_cache["ssm"] = ssm_state
        if cfg.post_block_norm:
            attn_out = L.rms_norm(attn_out, p["ln1_post"], cfg.rms_eps)
        x = x + attn_out
        h2 = L.rms_norm(x, p["ln2"], cfg.rms_eps)
        if fam == "moe":
            # single-token decode is dropless (batching-invariant serving);
            # prefill/training use capacity-factor semantics.
            ff = L.moe(cfg, p["moe"], h2,
                       dropless=cache is not None and x.shape[1] == 1)
        else:
            ff = L.mlp(cfg, p["mlp"], h2)
        if cfg.post_block_norm:
            ff = L.rms_norm(ff, p["ln2_post"], cfg.rms_eps)
        x = x + ff
        if cache is not None:
            new_cache["attn"] = attn_cache
    elif fam == "ssm":
        h = L.rms_norm(x, p["ln1"], cfg.rms_eps)
        t_out, t_state = S.rwkv_time_mix(
            cfg, p["time"], h, state=cache.get("time") if cache else None)
        x = x + t_out
        h2 = L.rms_norm(x, p["ln2"], cfg.rms_eps)
        c_out, c_state = S.rwkv_channel_mix(
            cfg, p["channel"], h2, state=cache.get("channel") if cache else None)
        x = x + c_out
        if cache is not None:
            new_cache["time"], new_cache["channel"] = t_state, c_state
    else:
        raise ValueError(fam)
    return x, (new_cache if cache is not None else None)


def _remat_wrap(cfg: ModelConfig, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.checkpoint_dots
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)


# ---------------------------------------------------------------------------
# forward / loss / features
# ---------------------------------------------------------------------------

def embed_inputs(cfg: ModelConfig, params, batch) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Returns (x (B,S,D), positions (B,S), loss_mask (B,S))."""
    dt = cfg.dtype
    if cfg.family == "audio" or cfg.frontend == "audio_frames":
        x = batch["frame_embeds"].astype(dt)
        B, Sq = x.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(Sq, dtype=jnp.int32), (B, Sq))
        mask = jnp.ones((B, Sq), jnp.float32)
    elif cfg.family == "vlm" or cfg.frontend == "vision_patches":
        patches = batch["patch_embeds"].astype(dt)          # (B,P,D)
        tok = jnp.take(params["embed"], batch["tokens"], axis=0)
        x = jnp.concatenate([patches, tok], axis=1)
        B, Sq = x.shape[:2]
        P = patches.shape[1]
        positions = jnp.broadcast_to(jnp.arange(Sq, dtype=jnp.int32), (B, Sq))
        mask = jnp.concatenate([jnp.zeros((B, P), jnp.float32),
                                jnp.ones_like(batch["tokens"], jnp.float32)], axis=1)
    else:
        x = jnp.take(params["embed"], batch["tokens"], axis=0)
        B, Sq = x.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(Sq, dtype=jnp.int32), (B, Sq))
        mask = jnp.ones((B, Sq), jnp.float32)
    x = constrain(x, ("act_batch", "act_res_seq", "act_embed"))
    return x, positions, mask


def forward_hiddens(cfg: ModelConfig, params, batch) -> Tuple[jax.Array, jax.Array]:
    """Full forward through the stack → (hiddens (B,S,D), loss_mask (B,S))."""
    x, positions, mask = embed_inputs(cfg, params, batch)
    is_local_arr = jnp.asarray(cfg.is_local_pattern(), dtype=jnp.bool_)

    for i in range(cfg.first_k_dense):
        x, _ = _apply_block(cfg, params["first_blocks"][i], x, positions,
                            is_local=False, dense_override=True)

    def block_fn(x, scanned):
        p, is_local = scanned
        x, _ = _apply_block(cfg, p, x, positions, is_local=is_local)
        return x, None

    block_fn = _remat_wrap(cfg, block_fn)
    n_scan = cfg.num_layers - cfg.first_k_dense
    if cfg.scan_layers:
        x, _ = jax.lax.scan(block_fn, x,
                            (params["blocks"], is_local_arr[cfg.first_k_dense:]))
    else:
        for i in range(n_scan):
            p_i = jax.tree_util.tree_map(lambda a: a[i], params["blocks"])
            x, _ = block_fn(x, (p_i, is_local_arr[cfg.first_k_dense + i]))
    x = L.rms_norm(x, params["final_norm"], cfg.rms_eps)
    return x, mask


def logits_from_hiddens(cfg: ModelConfig, params, h: jax.Array) -> jax.Array:
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    # Megatron parallel-CE layout: logits sharded over VOCAB (model axis),
    # seq gathered. The alternative (seq-sharded, vocab-full) makes the
    # lm_head weight grad a full-size f32 [D,V] partial per device — the
    # buffer dump showed 6×4.4 GiB of exactly that (EXPERIMENTS.md §Perf i2).
    logits = jnp.einsum("bsd,dv->bsv", h, head)
    logits = L.softcap(logits, cfg.final_logit_softcap)
    return constrain(logits, ("act_batch", None, "act_vocab"))


def _pad_labels(labels: jax.Array, S: int) -> jax.Array:
    if labels.shape[1] != S:                    # vlm: labels only on text positions
        pad = S - labels.shape[1]
        labels = jnp.concatenate(
            [jnp.zeros((labels.shape[0], pad), labels.dtype), labels], axis=1)
    return labels


def _ce_sums(cfg: ModelConfig, params, h, labels, mask) -> Tuple[jax.Array, jax.Array]:
    """Σ nll over valid positions + Σ mask — optionally seq-chunked so the
    (B,S,V) fp32 softmax intermediates never materialize whole."""
    if cfg.loss_chunk and h.shape[1] > cfg.loss_chunk:
        C = cfg.loss_chunk
        S = h.shape[1]
        n = S // C
        assert S % C == 0, (S, C)
        hc = h.reshape(h.shape[0], n, C, h.shape[-1]).transpose(1, 0, 2, 3)
        lc = labels.reshape(labels.shape[0], n, C).transpose(1, 0, 2)
        mc = mask.reshape(mask.shape[0], n, C).transpose(1, 0, 2)

        def body(carry, xs):
            nll_sum, m_sum = carry
            h_i, l_i, m_i = xs
            logits = logits_from_hiddens(cfg, params, h_i)
            logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
            nll = -jnp.take_along_axis(logp, l_i[..., None], axis=-1)[..., 0]
            return (nll_sum + jnp.sum(nll * m_i), m_sum + jnp.sum(m_i)), None

        (nll_sum, m_sum), _ = jax.lax.scan(
            jax.checkpoint(body), (jnp.float32(0.0), jnp.float32(0.0)),
            (hc, lc, mc))
        return nll_sum, m_sum
    logits = logits_from_hiddens(cfg, params, h)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return jnp.sum(nll * mask), jnp.sum(mask)


def loss_fn(cfg: ModelConfig, params, batch) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Mean next-token CE over valid positions. batch["labels"]: (B, S_text)."""
    h, mask = forward_hiddens(cfg, params, batch)
    labels = _pad_labels(batch["labels"], h.shape[1])
    nll_sum, m_sum = _ce_sums(cfg, params, h, labels, mask)
    loss = nll_sum / jnp.maximum(m_sum, 1.0)
    return loss, {"nll": loss}


def per_example_loss(cfg: ModelConfig, params, batch) -> jax.Array:
    """(B,) per-sequence loss — GRAFT's per-sample signal."""
    h, mask = forward_hiddens(cfg, params, batch)
    labels = _pad_labels(batch["labels"], h.shape[1])
    if cfg.loss_chunk and h.shape[1] > cfg.loss_chunk:
        C = cfg.loss_chunk
        B, S = mask.shape
        n = S // C
        hc = h.reshape(B, n, C, h.shape[-1]).transpose(1, 0, 2, 3)
        lc = labels.reshape(B, n, C).transpose(1, 0, 2)
        mc = mask.reshape(B, n, C).transpose(1, 0, 2)

        def body(carry, xs):
            nll_sum, m_sum = carry                     # (B,), (B,)
            h_i, l_i, m_i = xs
            logits = logits_from_hiddens(cfg, params, h_i)
            logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
            nll = -jnp.take_along_axis(logp, l_i[..., None], axis=-1)[..., 0]
            return (nll_sum + jnp.sum(nll * m_i, axis=1),
                    m_sum + jnp.sum(m_i, axis=1)), None

        (nll_sum, m_sum), _ = jax.lax.scan(
            jax.checkpoint(body),
            (jnp.zeros((B,), jnp.float32), jnp.zeros((B,), jnp.float32)),
            (hc, lc, mc))
        return nll_sum / jnp.maximum(m_sum, 1.0)
    logits = logits_from_hiddens(cfg, params, h)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return jnp.sum(nll * mask, axis=1) / jnp.maximum(jnp.sum(mask, axis=1), 1.0)


def pooled_features(cfg: ModelConfig, params, batch) -> jax.Array:
    """(B, D) mean-pooled final hiddens — GRAFT's feature source at LM scale."""
    h, mask = forward_hiddens(cfg, params, batch)
    w = mask[..., None] / jnp.maximum(jnp.sum(mask, axis=1)[:, None, None], 1.0)
    return jnp.sum(h.astype(jnp.float32) * w, axis=1)
