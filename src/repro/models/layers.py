"""Transformer building blocks: RMSNorm, RoPE, GQA attention (sliding window,
logit softcap, QKV bias, KV cache), gated MLP, and the capacity-factor MoE.

Pure functional JAX: every block is ``f(cfg, params, x, ...)`` with params a
nested dict. Sharding is injected by ``repro.distributed.sharding`` via
``with_sharding_constraint`` on the annotated logical axes.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain


# ---------------------------------------------------------------------------
# norms / rope / misc
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(x.dtype)


def softcap(x: jax.Array, cap: Optional[float]) -> jax.Array:
    if cap is None:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, Dh); positions: (B, S) int32."""
    head_dim = x.shape[-1]
    half = head_dim // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freq  # (B,S,half)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def _activation(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

_FLASH_BLOCKS = (128, 64, 32, 16, 8)


def _flash_blocks(S: int, T: int):
    """Largest kernel tile sizes dividing the q/kv lengths (None = none fit)."""
    bq = next((b for b in _FLASH_BLOCKS if S % b == 0), None)
    bk = next((b for b in _FLASH_BLOCKS if T % b == 0), None)
    return bq, bk


def _flash_feasible(cfg, S: int, T: int) -> bool:
    bq, bk = _flash_blocks(S, T)
    if bq is None or bk is None:
        return False
    # the SAME estimator the kernel wrapper enforces (repro.analysis.vmem):
    # the shape the router plans with is the shape the kernel accepts
    from repro.analysis.vmem import flash_forward_vmem
    return flash_forward_vmem(T, cfg.head_dim, bq).fits


def resolve_attn_backend(cfg, S: int, T: int) -> str:
    """Training/prefill backend for this shape → flash | chunked | dense.

    "auto" keeps the jnp paths off-TPU (interpret-mode Pallas is orders of
    magnitude slower than XLA:CPU); explicit "flash" runs the kernel anywhere
    (interpret on CPU), falling back to the jnp paths only when the
    block-divisibility or VMEM guard refuses the shape.
    """
    b = getattr(cfg, "attn_backend", "auto")
    chunked = "chunked" if cfg.attn_chunk and T > cfg.attn_chunk else "dense"
    if b == "dense":
        return "dense"
    if b == "chunked":
        return chunked
    if b == "flash":
        return "flash" if _flash_feasible(cfg, S, T) else chunked
    if b == "auto":
        if jax.default_backend() == "tpu" and _flash_feasible(cfg, S, T):
            return "flash"
        return chunked
    raise ValueError(f"unknown attn_backend: {b!r}")


def _flash_attention(cfg, q: jax.Array, k: jax.Array, v: jax.Array,
                     is_local) -> jax.Array:
    """Single-dispatch Pallas path: ONE pallas_call per layer. q (B,S,H,Dh)
    pre-scaled (kernel scale=1); k/v (B,S,Hkv,Dh) — streams fold head-major
    so GQA q stream i reads kv stream i // group without repeating K/V.
    Assumes contiguous from-zero positions (forward_hiddens' layout); the
    cache/decode path never routes here.
    """
    from repro.kernels import flash_attention as _fa
    B, S, H, Dh = q.shape
    Hkv = k.shape[2]
    bq, bk = _flash_blocks(S, S)
    qf = q.transpose(0, 2, 1, 3).reshape(B * H, S, Dh)
    kf = k.transpose(0, 2, 1, 3).reshape(B * Hkv, S, Dh)
    vf = v.transpose(0, 2, 1, 3).reshape(B * Hkv, S, Dh)
    if cfg.sliding_window is None:
        window = None
    elif isinstance(is_local, bool):
        window = cfg.sliding_window if is_local else None
    else:
        # traced per-layer local/global pattern (gemma2's scanned
        # alternation): dynamic window operand; w >= S is a no-op mask.
        window = jnp.where(is_local, cfg.sliding_window, S).astype(jnp.int32)
    out = _fa.flash_attention_pallas(
        qf, kf, vf, block_q=bq, block_k=bk, causal=True, window=window,
        softcap=cfg.attn_logit_softcap, group=H // Hkv, scale=1.0,
        interpret=jax.default_backend() != "tpu")
    return out.reshape(B, H, S, Dh).transpose(0, 2, 1, 3)


def attention(cfg, p, x: jax.Array, positions: jax.Array,
              *, is_local: jax.Array | bool = False,
              cache: Optional[dict] = None,
              cache_index: Optional[jax.Array] = None,
              ) -> Tuple[jax.Array, Optional[dict]]:
    """GQA attention. x: (B, S, D). If ``cache`` given, runs one decode step
    (S == new tokens, usually 1) against the cache and returns the updated
    cache; otherwise full self-attention with a causal (+ optional sliding
    window) mask.

    ``is_local`` may be a traced bool (scanned layer pattern, e.g. gemma2's
    local/global alternation) — the window mask is blended with ``where``.
    """
    B, S, D = x.shape
    H, Hkv, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim

    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    # q seq-sharded (scores stay S/tp × T per device); k/v gathered — GQA keeps
    # them small. When the "act_q_seq" rule is None this degrades gracefully to
    # Megatron head-TP (heads entry wins the axis).
    q = constrain(q, ("act_batch", "act_q_seq", "act_heads", None))
    k = constrain(k, ("act_batch", "act_seq", "act_kv_heads", None))
    v = constrain(v, ("act_batch", "act_seq", "act_kv_heads", None))

    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    if cfg.query_scale is not None:
        q = q * cfg.query_scale
    else:
        q = q / jnp.sqrt(jnp.float32(Dh)).astype(q.dtype)

    if cache is not None:
        # decode/prefill-into-cache: append new k/v at cache_index, attend to
        # the cache with per-query causality inside the new chunk.
        k_cache = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, cache_index, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, cache_index, axis=1)
        k_cache = constrain(k_cache, ("act_batch", "act_kv_seq", "act_kv_heads", None))
        v_cache = constrain(v_cache, ("act_batch", "act_kv_seq", "act_kv_heads", None))
        new_cache = {"k": k_cache, "v": v_cache}
        kv_len = k_cache.shape[1]
        k_all, v_all = k_cache, v_cache
        kv_pos = jnp.arange(kv_len, dtype=jnp.int32)[None, :]          # (1,T)
        q_abs = cache_index + jnp.arange(S, dtype=jnp.int32)[:, None]  # (S,1)
        causal = kv_pos <= q_abs                                       # (S,T)
        mask = jnp.broadcast_to(causal[None], (B, S, kv_len))
        if cfg.sliding_window is not None:
            in_window = kv_pos > (q_abs - cfg.sliding_window)
            wmask = jnp.broadcast_to(jnp.logical_and(causal, in_window)[None], mask.shape)
            mask = jnp.where(is_local, wmask, mask) if not isinstance(is_local, bool) \
                else (wmask if is_local else mask)
        # decode/prefill-into-cache keeps the jnp paths (per-query absolute
        # positions; attn_backend targets the training/prefill hot path)
        backend = "chunked" if cfg.attn_chunk and kv_len > cfg.attn_chunk \
            else "dense"
    else:
        new_cache = None
        k_all, v_all = k, v
        kv_len = S
        backend = resolve_attn_backend(cfg, S, kv_len)
        mask = None
        if backend != "flash":      # flash masks inside the kernel
            qpos = positions[:, :, None]
            kpos = positions[:, None, :]
            mask = kpos <= qpos
            if cfg.sliding_window is not None:
                wmask = jnp.logical_and(mask, kpos > qpos - cfg.sliding_window)
                if isinstance(is_local, bool):
                    mask = wmask if is_local else mask
                else:
                    mask = jnp.where(is_local, wmask, mask)

    # grouped query attention: fold the group dim into heads
    group = H // Hkv
    if backend == "flash":
        out = _flash_attention(cfg, q, k_all, v_all, is_local)
    elif backend == "chunked":
        qg = q.reshape(B, S, Hkv, group, Dh)
        out = _chunked_attention(cfg, qg, k_all, v_all, mask)
        out = out.reshape(B, S, H, Dh)
    else:
        qg = q.reshape(B, S, Hkv, group, Dh)
        logits = jnp.einsum("bskgh,btkh->bkgst", qg, k_all)      # (B,Hkv,g,S,T)
        logits = softcap(logits, cfg.attn_logit_softcap)
        logits = jnp.where(mask[:, None, None, :, :], logits.astype(jnp.float32), -1e30)
        probs = jax.nn.softmax(logits, axis=-1).astype(v_all.dtype)
        out = jnp.einsum("bkgst,btkh->bskgh", probs, v_all).reshape(B, S, H, Dh)
    out = constrain(out, ("act_batch", "act_q_seq", "act_heads", None))
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    out = constrain(out, ("act_batch", "act_res_seq", "act_embed"))
    return out, new_cache


def _chunked_attention(cfg, qg: jax.Array, k_all: jax.Array, v_all: jax.Array,
                       mask: jax.Array) -> jax.Array:
    """Flash-style online-softmax attention over KV chunks (pure jnp).

    Scores are materialized only per (S × chunk) tile — this is what makes
    the 32k-prefill cells fit HBM, and it is the jnp analog of a Pallas
    flash kernel (the lowered scan is the schedule a TPU kernel would use).
    qg: (B,S,Hkv,g,Dh); k/v: (B,T,Hkv,Dh); mask: (B,S,T) bool.
    Returns (B,S,Hkv,g,Dh) in v dtype.
    """
    B, S, Hkv, g, Dh = qg.shape
    T = k_all.shape[1]
    C = cfg.attn_chunk
    n_chunks = T // C
    assert T % C == 0, (T, C)
    kc = k_all.reshape(B, n_chunks, C, Hkv, Dh).transpose(1, 0, 2, 3, 4)
    vc = v_all.reshape(B, n_chunks, C, Hkv, Dh).transpose(1, 0, 2, 3, 4)
    maskc = mask.reshape(B, S, n_chunks, C).transpose(2, 0, 1, 3)

    def body(carry, xs):
        m, l, acc = carry                       # (B,Hkv,g,S), (…), (B,Hkv,g,S,Dh)
        k_i, v_i, mask_i = xs                   # (B,C,Hkv,Dh), …, (B,S,C)
        s = jnp.einsum("bskgh,btkh->bkgst", qg, k_i)
        s = softcap(s, cfg.attn_logit_softcap).astype(jnp.float32)
        s = jnp.where(mask_i[:, None, None, :, :], s, -1e30)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        alpha = jnp.exp(m - m_new)
        # A chunk whose mask row is ALL false keeps m_new at -1e30; without
        # the guard p = exp(0) = 1 there, silently averaging V for rows
        # whose whole horizon is masked.
        p = jnp.where(m_new[..., None] > -0.5e30,
                      jnp.exp(s - m_new[..., None]), 0.0)
        l = l * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bkgst,btkh->bkgsh", p.astype(v_i.dtype), v_i).astype(jnp.float32)
        return (m_new, l, acc), None

    m0 = jnp.full((B, Hkv, g, S), -1e30, jnp.float32)
    l0 = jnp.zeros((B, Hkv, g, S), jnp.float32)
    acc0 = jnp.zeros((B, Hkv, g, S, Dh), jnp.float32)
    body = jax.checkpoint(body)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, acc0), (kc, vc, maskc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4).astype(v_all.dtype)  # (B,S,Hkv,g,Dh)


def init_attention_params(key, cfg, dtype) -> dict:
    D, H, Hkv, Dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = D ** -0.5
    p = {
        "wq": (jax.random.normal(k1, (D, H, Dh)) * s).astype(dtype),
        "wk": (jax.random.normal(k2, (D, Hkv, Dh)) * s).astype(dtype),
        "wv": (jax.random.normal(k3, (D, Hkv, Dh)) * s).astype(dtype),
        "wo": (jax.random.normal(k4, (H, Dh, D)) * (H * Dh) ** -0.5).astype(dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H, Dh), dtype)
        p["bk"] = jnp.zeros((Hkv, Dh), dtype)
        p["bv"] = jnp.zeros((Hkv, Dh), dtype)
    return p


# ---------------------------------------------------------------------------
# dense MLP
# ---------------------------------------------------------------------------

def mlp(cfg, p, x: jax.Array) -> jax.Array:
    """Gated MLP (SwiGLU / GeGLU per cfg.mlp_activation)."""
    act = _activation(cfg.mlp_activation)
    gate = act(jnp.einsum("bsd,df->bsf", x, p["w_gate"]))
    up = jnp.einsum("bsd,df->bsf", x, p["w_up"])
    h = constrain(gate * up, ("act_batch", "act_seq", "act_mlp"))
    out = jnp.einsum("bsf,fd->bsd", h, p["w_down"])
    return constrain(out, ("act_batch", "act_res_seq", "act_embed"))


def init_mlp_params(key, cfg, dtype, d_ff: Optional[int] = None) -> dict:
    D, F = cfg.d_model, d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": (jax.random.normal(k1, (D, F)) * D ** -0.5).astype(dtype),
        "w_up": (jax.random.normal(k2, (D, F)) * D ** -0.5).astype(dtype),
        "w_down": (jax.random.normal(k3, (F, D)) * F ** -0.5).astype(dtype),
    }


# ---------------------------------------------------------------------------
# Mixture of Experts (GShard/MaxText-style capacity-factor dispatch)
# ---------------------------------------------------------------------------

def moe(cfg, p, x: jax.Array, dropless: bool = False) -> jax.Array:
    """Top-k routed MoE with static capacity, sort-based dispatch.

    Tokens are split into groups of ``cfg.moe_group_size``; each (group,
    expert) pair has capacity C = group·k/E·cf. Dispatch is a stable
    argsort over expert ids + two gathers (token→buffer, buffer→token) —
    NO (tokens×E×C) one-hot ever materializes (the GShard dispatch-einsum
    formulation costs T·E·C memory/FLOPs, which at kimi-k2's E=384 is
    ~10 TB per layer; gathers are O(T·k)). Tokens stay on their data shard,
    experts on their model shard; the combine's expert-partial sum is the
    only model-axis collective. Overflow tokens beyond capacity are dropped
    (standard; decode uses ``dropless`` so serving is batching-invariant).
    """
    B, S, D = x.shape
    E, k = cfg.num_experts, cfg.num_experts_per_tok
    gs = min(cfg.moe_group_size, B * S)
    T = B * S
    G = T // gs
    if dropless:
        # capacity = group size ⇒ no token is ever dropped
        cap = gs
    else:
        cap = int(gs * k / E * cfg.moe_capacity_factor) + 1

    if cfg.moe_local_groups and S % gs == 0 and S >= gs:
        # chunk-major grouping: groups = contiguous seq chunks, group dim
        # ordered (chunk, batch) so its sharding composes as model-major —
        # byte-identical to the residual stream's (batch:dp, seq:model)
        # layout ⇒ routing/top-k/sort all run on LOCAL tokens, no seq
        # all-gather before the router (§Perf i6).
        n = S // gs
        xt = x.reshape(B, n, gs, D).transpose(1, 0, 2, 3).reshape(G, gs, D)
        xt = constrain(xt, ("act_moe_groups", None, None))
        regroup = "chunk_major"
        g_axis = "act_moe_dispatch"      # expert buffers: model axis is spent
                                         # on experts, G keeps the dp axes
    else:
        xt = x.reshape(G, gs, D)
        xt = constrain(xt, ("act_batch", None, None))
        regroup = "flat"
        g_axis = "act_batch"
    router_logits = jnp.einsum("gsd,de->gse", xt, p["router"].astype(x.dtype))
    probs = jax.nn.softmax(router_logits.astype(jnp.float32), axis=-1)
    gate_vals, ids = jax.lax.top_k(probs, k)                     # (G,gs,k)
    gate_vals = gate_vals / (jnp.sum(gate_vals, -1, keepdims=True) + 1e-9)

    F = gs * k
    ids_flat = ids.reshape(G, F)
    counts = jax.vmap(lambda i: jnp.zeros((E,), jnp.int32).at[i].add(1))(ids_flat)
    starts = jnp.cumsum(counts, axis=1) - counts                  # (G,E) exclusive
    order = jnp.argsort(ids_flat, axis=1)                         # stable (G,F)
    sorted_eid = jnp.take_along_axis(ids_flat, order, axis=1)
    pos_sorted = (jnp.arange(F, dtype=jnp.int32)[None, :] -
                  jnp.take_along_axis(starts, sorted_eid, axis=1))
    # rank of each (token, slot) within its expert queue, original order
    pos_flat = jax.vmap(lambda o, ps: jnp.zeros((F,), jnp.int32).at[o].set(ps)
                        )(order, pos_sorted)
    keep_flat = pos_flat < cap                                    # (G,F)

    # buffer side: which flat assignment fills buffer slot (e, c)?
    b_e = jnp.arange(E * cap, dtype=jnp.int32) // cap             # (E·C,)
    b_c = jnp.arange(E * cap, dtype=jnp.int32) % cap
    src_sorted = starts[:, b_e] + b_c[None, :]                    # (G, E·C)
    slot_valid = b_c[None, :] < jnp.minimum(counts[:, b_e], cap)
    src_flat = jnp.take_along_axis(
        order, jnp.clip(src_sorted, 0, F - 1), axis=1)            # (G, E·C)
    token_of_slot = jnp.where(slot_valid, src_flat // k, 0)
    # shard the slot axis over the expert (model) axis BEFORE gathering so
    # the gather output is born expert-sharded — without this the (G,E·C,D)
    # buffer materializes model-replicated (~10 GB/device at kimi-k2 scale)
    token_of_slot = constrain(token_of_slot, (g_axis, "act_experts"))
    slot_valid = constrain(slot_valid, (g_axis, "act_experts"))

    expert_in = jnp.take_along_axis(xt, token_of_slot[..., None], axis=1)
    expert_in = expert_in * slot_valid[..., None].astype(x.dtype)
    expert_in = expert_in.reshape(G, E, cap, D)
    expert_in = constrain(expert_in, (g_axis, "act_experts", None, None))

    act = _activation(cfg.mlp_activation)
    h = act(jnp.einsum("gecd,edf->gecf", expert_in, p["w_gate"])) * \
        jnp.einsum("gecd,edf->gecf", expert_in, p["w_up"])
    h = constrain(h, (g_axis, "act_experts", None, "act_mlp_inner"))
    expert_out = jnp.einsum("gecf,efd->gecd", h, p["w_down"])
    expert_out = constrain(expert_out, (g_axis, "act_experts", None, None))

    # combine as a scatter-add from the expert side: every shard accumulates
    # its local slots' weighted outputs into (G,gs,D) partials; the psum over
    # the model axis is the layer's only combine collective (embedding-grad
    # pattern — avoids a cross-shard gather that would replicate the buffer).
    flat_out = expert_out.reshape(G, E * cap, D)
    gate_flat = (gate_vals.reshape(G, F) * keep_flat).astype(x.dtype)  # (G,F)
    w_of_slot = jnp.take_along_axis(
        gate_flat, jnp.clip(src_flat, 0, F - 1), axis=1)
    w_of_slot = w_of_slot * slot_valid.astype(x.dtype)            # (G, E·C)
    contrib = flat_out * w_of_slot[..., None]

    def scatter_group(tos, c):
        return jnp.zeros((gs, D), jnp.float32).at[tos].add(c.astype(jnp.float32))

    out = jax.vmap(scatter_group)(token_of_slot, contrib)          # (G,gs,D) f32
    out = out.astype(x.dtype)
    if regroup == "chunk_major":
        n = S // gs
        out = constrain(out, ("act_moe_groups", None, None))
        out = out.reshape(n, B, gs, D).transpose(1, 0, 2, 3).reshape(B, S, D)
    else:
        out = out.reshape(B, S, D)
    return constrain(out, ("act_batch", "act_res_seq", "act_embed"))


def init_moe_params(key, cfg, dtype) -> dict:
    D, F, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    k0, k1, k2, k3 = jax.random.split(key, 4)
    return {
        "router": (jax.random.normal(k0, (D, E)) * D ** -0.5).astype(jnp.float32),
        "w_gate": (jax.random.normal(k1, (E, D, F)) * D ** -0.5).astype(dtype),
        "w_up": (jax.random.normal(k2, (E, D, F)) * D ** -0.5).astype(dtype),
        "w_down": (jax.random.normal(k3, (E, F, D)) * F ** -0.5).astype(dtype),
    }
