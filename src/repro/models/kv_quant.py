"""Int8 KV-cache quantization (the §Roofline decode-cell memory lever).

The cache at rest stores int8 payloads + per-(token, head) f32 absmax
scales (1/(2·Dh) overhead ⇒ ~2× HBM cut for bf16 caches, 4× for f32).
Dequantization happens per KV chunk inside the chunked-attention loop, so
the bf16 working set stays O(chunk), never the whole cache.

Accuracy: per-token-per-head absmax keeps the quantization step within
~0.8 % of the per-head dynamic range; the attention-output error is
sub-bf16-ulp for typical activations (tested in test_kv_quant.py).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp


def quantize_kv(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, H, Dh) → (q int8 same shape, scale f32 (B, S, H))."""
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf), axis=-1) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(xf / scale[..., None]), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_kv(q: jax.Array, scale: jax.Array, dtype) -> jax.Array:
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


def init_quant_cache(batch: int, max_seq: int, kv_heads: int, head_dim: int
                     ) -> Dict[str, jax.Array]:
    return {
        "k_q": jnp.zeros((batch, max_seq, kv_heads, head_dim), jnp.int8),
        "v_q": jnp.zeros((batch, max_seq, kv_heads, head_dim), jnp.int8),
        "k_s": jnp.zeros((batch, max_seq, kv_heads), jnp.float32),
        "v_s": jnp.zeros((batch, max_seq, kv_heads), jnp.float32),
    }


def update_quant_cache(cache: Dict[str, jax.Array], k_new: jax.Array,
                       v_new: jax.Array, index) -> Dict[str, jax.Array]:
    """Append S new KV positions at ``index`` (quantize-on-write)."""
    kq, ks = quantize_kv(k_new)
    vq, vs = quantize_kv(v_new)
    upd = jax.lax.dynamic_update_slice_in_dim
    return {
        "k_q": upd(cache["k_q"], kq, index, axis=1),
        "v_q": upd(cache["v_q"], vq, index, axis=1),
        "k_s": upd(cache["k_s"], ks, index, axis=1),
        "v_s": upd(cache["v_s"], vs, index, axis=1),
    }


def read_quant_cache(cache: Dict[str, jax.Array], dtype
                     ) -> Tuple[jax.Array, jax.Array]:
    """Dequantize the whole cache (small contexts / reference path).
    Production chunked attention dequantizes per KV tile instead."""
    k = dequantize_kv(cache["k_q"], cache["k_s"], dtype)
    v = dequantize_kv(cache["v_q"], cache["v_s"], dtype)
    return k, v


def cache_bytes(batch: int, max_seq: int, kv_heads: int, head_dim: int,
                quantized: bool) -> int:
    """At-rest HBM bytes (per layer) — the roofline accounting helper."""
    n = batch * max_seq * kv_heads
    if quantized:
        return 2 * n * head_dim * 1 + 2 * n * 4        # int8 + f32 scales
    return 2 * n * head_dim * 2                        # bf16
