"""KV-cache / recurrent-state serving path: init_cache, prefill, decode_step.

Cache layout mirrors the scan-stacked block params: every per-layer state
leaf is stacked on a leading (L,) axis so one ``lax.scan`` drives all layers
(xs = (layer params, layer cache), ys = new layer cache). Recurrent families
(rwkv, hymba's SSM heads) carry O(1) state — this is what makes the
``long_500k`` cell feasible for them and is why it is skipped for pure
full-attention archs (DESIGN.md §4).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models import layers as L
from repro.models import ssm as S
from repro.models.model import (ModelConfig, _apply_block, embed_inputs,
                                logits_from_hiddens)


def _layer_cache(cfg: ModelConfig, B: int, max_seq: int,
                 dense_override: bool = False) -> Dict[str, Any]:
    dt = cfg.dtype
    fam = "dense" if dense_override else cfg.family
    D = cfg.d_model
    H, Hkv, Dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    c: Dict[str, Any] = {}
    if fam in ("dense", "audio", "vlm", "moe", "hybrid"):
        c["attn"] = {"k": jnp.zeros((B, max_seq, Hkv, Dh), dt),
                     "v": jnp.zeros((B, max_seq, Hkv, Dh), dt)}
    if fam == "hybrid":
        c["ssm"] = jnp.zeros((B, H, D // H, cfg.ssm_state), jnp.float32)
    if fam == "ssm":
        c["time"] = {"shift": jnp.zeros((B, 1, D), dt),
                     "wkv": jnp.zeros((B, H, D // H, D // H), jnp.float32)}
        c["channel"] = {"shift": jnp.zeros((B, 1, D), dt)}
    return c


def init_cache(cfg: ModelConfig, batch_size: int, max_seq: int) -> Dict[str, Any]:
    n_scan = cfg.num_layers - cfg.first_k_dense
    one = _layer_cache(cfg, batch_size, max_seq)
    cache = {
        "layers": jax.tree_util.tree_map(
            lambda x: jnp.broadcast_to(x, (n_scan,) + x.shape), one),
        "index": jnp.int32(0),
    }
    if cfg.first_k_dense:
        cache["first"] = [_layer_cache(cfg, batch_size, max_seq, dense_override=True)
                          for _ in range(cfg.first_k_dense)]
    return cache


def _run_with_cache(cfg: ModelConfig, params, cache, x: jax.Array
                    ) -> Tuple[jax.Array, Dict[str, Any]]:
    """Push S new token embeddings through the stack, updating the cache."""
    B, Snew, _ = x.shape
    idx = cache["index"]
    positions = idx + jnp.broadcast_to(jnp.arange(Snew, dtype=jnp.int32), (B, Snew))
    is_local_arr = jnp.asarray(cfg.is_local_pattern(), dtype=jnp.bool_)

    new_cache: Dict[str, Any] = {"index": idx + Snew}
    if cfg.first_k_dense:
        firsts = []
        for i in range(cfg.first_k_dense):
            x, nc = _apply_block(cfg, params["first_blocks"][i], x, positions,
                                 is_local=False, cache=cache["first"][i],
                                 cache_index=idx, dense_override=True)
            firsts.append(nc)
        new_cache["first"] = firsts

    def step(xc, scanned):
        p, c, il = scanned
        xc, nc = _apply_block(cfg, p, xc, positions, is_local=il,
                              cache=c, cache_index=idx)
        return xc, nc

    scanned_args = (params["blocks"], cache["layers"],
                    is_local_arr[cfg.first_k_dense:])
    if cfg.scan_layers:
        x, layer_caches = jax.lax.scan(step, x, scanned_args)
    else:
        # unrolled path (roofline cost compiles: scan bodies are counted
        # once by XLA cost analysis — see launch/dryrun.py)
        outs = []
        n_scan = cfg.num_layers - cfg.first_k_dense
        for i in range(n_scan):
            layer_in = jax.tree_util.tree_map(lambda a: a[i], scanned_args[:2])
            x, nc = step(x, (layer_in[0], layer_in[1], scanned_args[2][i]))
            outs.append(nc)
        layer_caches = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *outs)
    new_cache["layers"] = layer_caches
    x = L.rms_norm(x, params["final_norm"], cfg.rms_eps)
    return x, new_cache


def prefill(cfg: ModelConfig, params, batch, max_seq: int
            ) -> Tuple[jax.Array, Dict[str, Any]]:
    """Fill a fresh cache from a full prompt batch → (last-position logits, cache)."""
    x, _, _ = embed_inputs(cfg, params, batch)
    B = x.shape[0]
    cache = init_cache(cfg, B, max_seq)
    h, cache = _run_with_cache(cfg, params, cache, x)
    logits = logits_from_hiddens(cfg, params, h[:, -1:, :])
    return logits, cache


def decode_step(cfg: ModelConfig, params, cache, tokens: jax.Array
                ) -> Tuple[jax.Array, Dict[str, Any]]:
    """One autoregressive step. tokens: (B, 1) int32 → (logits (B,1,V), cache)."""
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.dtype)
    x = constrain(x, ("act_batch", "act_res_seq", "act_embed"))
    h, cache = _run_with_cache(cfg, params, cache, x)
    logits = logits_from_hiddens(cfg, params, h)
    return logits, cache
