"""internvl2-26b [vlm] — 48L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=92553; InternViT (STUB: input_specs() provides patch embeddings)
+ InternLM2-20B backbone. [arXiv:2404.16821; hf]"""
from repro.models.model import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-26b", family="vlm", frontend="vision_patches",
        num_layers=48, d_model=6144, num_heads=48, num_kv_heads=8,
        head_dim=128, d_ff=16384, vocab_size=92553,
        rope_theta=1_000_000.0, mlp_activation="silu",
        num_patches=256,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-smoke", family="vlm", frontend="vision_patches",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=256,
        mlp_activation="silu", num_patches=8, remat="none",
    )
