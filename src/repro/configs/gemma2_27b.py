"""gemma2-27b [dense] — 46L d_model=4608 32H (GQA kv=16) d_ff=36864
vocab=256000; local/global alternating attention (window 4096), attn logit
softcap 50, final logit softcap 30, post-block norms, GeGLU, q-scale
1/sqrt(query_pre_attn_scalar=144... d_model/num_heads=144); head_dim=128.
[arXiv:2408.00118; hf]"""
from repro.models.model import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma2-27b", family="dense",
        num_layers=46, d_model=4608, num_heads=32, num_kv_heads=16,
        head_dim=128, d_ff=36864, vocab_size=256_000,
        rope_theta=10_000.0, mlp_activation="gelu",
        sliding_window=4096, layer_pattern=("local", "global"),
        attn_logit_softcap=50.0, final_logit_softcap=30.0,
        post_block_norm=True, tie_embeddings=True,
        query_scale=(4608 / 32) ** -0.5,   # query_pre_attn_scalar = d_model/heads
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="gemma2-27b-smoke", family="dense",
        num_layers=4, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=256, vocab_size=512,
        mlp_activation="gelu", sliding_window=16,
        layer_pattern=("local", "global"),
        attn_logit_softcap=50.0, final_logit_softcap=30.0,
        post_block_norm=True, tie_embeddings=True,
        query_scale=16.0 ** -0.5, remat="none",
    )
