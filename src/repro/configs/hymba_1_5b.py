"""hymba-1.5b [hybrid] — 32L d_model=1600 25H (GQA kv=5) d_ff=5504
vocab=32001, ssm_state=16; parallel attention+Mamba heads per layer,
sliding-window attention except 3 global layers (first/middle/last).
[arXiv:2411.13676; hf]"""
from repro.models.model import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="hymba-1.5b", family="hybrid",
        num_layers=32, d_model=1600, num_heads=25, num_kv_heads=5,
        head_dim=64, d_ff=5504, vocab_size=32001,
        mlp_activation="silu", ssm_state=16,
        sliding_window=1024, global_layer_indices=(0, 15, 31),
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="hymba-smoke", family="hybrid",
        num_layers=3, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=256,
        mlp_activation="silu", ssm_state=8,
        sliding_window=16, global_layer_indices=(0, 2), remat="none",
    )
