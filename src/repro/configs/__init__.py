"""Architecture registry: one module per assigned architecture.

``get_config(arch_id)`` returns the exact published full config;
``get_smoke_config(arch_id)`` a reduced same-family config for CPU tests.
"""
from __future__ import annotations

import importlib
from typing import Dict

from repro.models.model import ModelConfig

ARCHS = (
    "stablelm_12b", "gemma2_27b", "qwen15_32b", "minicpm_2b",
    "qwen3_moe_235b_a22b", "kimi_k2_1t_a32b", "rwkv6_7b",
    "musicgen_medium", "internvl2_26b", "hymba_1_5b",
)

# canonical CLI ids (dashes) → module names
_ALIASES: Dict[str, str] = {
    "stablelm-12b": "stablelm_12b",
    "gemma2-27b": "gemma2_27b",
    "qwen1.5-32b": "qwen15_32b",
    "qwen15-32b": "qwen15_32b",
    "minicpm-2b": "minicpm_2b",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
    "rwkv6-7b": "rwkv6_7b",
    "musicgen-medium": "musicgen_medium",
    "internvl2-26b": "internvl2_26b",
    "hymba-1.5b": "hymba_1_5b",
    "hymba-1-5b": "hymba_1_5b",
}


def _module(arch: str):
    name = _ALIASES.get(arch, arch.replace("-", "_").replace(".", "_"))
    if name not in ARCHS:
        raise KeyError(f"unknown arch '{arch}'; available: {sorted(_ALIASES)}")
    return importlib.import_module(f"repro.configs.{name}")


def get_config(arch: str, **overrides) -> ModelConfig:
    cfg = _module(arch).config()
    if overrides:
        import dataclasses
        cfg = dataclasses.replace(cfg, **overrides)
    return cfg


def get_smoke_config(arch: str, **overrides) -> ModelConfig:
    cfg = _module(arch).smoke_config()
    if overrides:
        import dataclasses
        cfg = dataclasses.replace(cfg, **overrides)
    return cfg


def all_arch_ids():
    return [a for a in _ALIASES if "_" not in a or a == "hymba-1.5b"] or list(_ALIASES)


CANONICAL_IDS = (
    "stablelm-12b", "gemma2-27b", "qwen1.5-32b", "minicpm-2b",
    "qwen3-moe-235b-a22b", "kimi-k2-1t-a32b", "rwkv6-7b",
    "musicgen-medium", "internvl2-26b", "hymba-1.5b",
)
