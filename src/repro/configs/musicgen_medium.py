"""musicgen-medium [audio] — 48L d_model=1536 24H (MHA kv=24) d_ff=6144
vocab=2048; decoder-only over EnCodec tokens. Frontend (EnCodec) is a STUB:
input_specs() provides precomputed frame embeddings. [arXiv:2306.05284; hf]"""
from repro.models.model import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-medium", family="audio", frontend="audio_frames",
        num_layers=48, d_model=1536, num_heads=24, num_kv_heads=24,
        head_dim=64, d_ff=6144, vocab_size=2048,
        mlp_activation="gelu",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="musicgen-smoke", family="audio", frontend="audio_frames",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        head_dim=16, d_ff=128, vocab_size=128,
        mlp_activation="gelu", remat="none",
    )
