"""minicpm-2b [dense] — 40L d_model=2304 36H (GQA kv=36) d_ff=5760
vocab=122753; WSD schedule (optimizer-side), llama-like arch.
[arXiv:2404.06395; hf]"""
from repro.models.model import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="minicpm-2b", family="dense",
        num_layers=40, d_model=2304, num_heads=36, num_kv_heads=36,
        head_dim=2304 // 36, d_ff=5760, vocab_size=122753,
        rope_theta=10_000.0, mlp_activation="silu", tie_embeddings=True,
    )


# WSD (warmup-stable-decay) is the paired optimizer schedule; the launcher
# selects it via TrainConfig.schedule="wsd" for this arch.
SCHEDULE = "wsd"


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="minicpm-2b-smoke", family="dense",
        num_layers=2, d_model=72, num_heads=6, num_kv_heads=6,
        head_dim=12, d_ff=144, vocab_size=257,   # odd vocab on purpose
        mlp_activation="silu", tie_embeddings=True, remat="none",
    )
