"""qwen3-moe-235b-a22b [moe] — 94L d_model=4096 64H (GQA kv=4) d_ff=1536
(expert ffn) vocab=151936; MoE 128 experts top-8. [hf:Qwen/Qwen3-235B-A22B; hf]"""
from repro.models.model import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-235b-a22b", family="moe",
        num_layers=94, d_model=4096, num_heads=64, num_kv_heads=4,
        head_dim=128, d_ff=1536, vocab_size=151936,
        rope_theta=1_000_000.0, mlp_activation="silu",
        num_experts=128, num_experts_per_tok=8,
        moe_capacity_factor=1.25, moe_group_size=512,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-smoke", family="moe",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=96, vocab_size=256,
        mlp_activation="silu", num_experts=8, num_experts_per_tok=2,
        moe_capacity_factor=1.5, moe_group_size=64, remat="none",
    )
