"""kimi-k2-1t-a32b [moe] — 61L d_model=7168 64H (GQA kv=8) d_ff=2048
(expert ffn) vocab=163840; MoE 384 experts top-8, first layer dense
(d_ff_dense=18432). Kimi K2 — trillion-param MoE (paper-table).
[arXiv:2501.kimi2; unverified]"""
from repro.models.model import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="kimi-k2-1t-a32b", family="moe",
        num_layers=61, d_model=7168, num_heads=64, num_kv_heads=8,
        head_dim=112, d_ff=2048, vocab_size=163840,
        rope_theta=1_000_000.0, mlp_activation="silu",
        num_experts=384, num_experts_per_tok=8,
        moe_capacity_factor=1.25, moe_group_size=512,
        first_k_dense=1, d_ff_dense=18432,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="kimi-k2-smoke", family="moe",
        num_layers=3, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=96, vocab_size=256,
        mlp_activation="silu", num_experts=8, num_experts_per_tok=2,
        moe_capacity_factor=1.5, moe_group_size=64,
        first_k_dense=1, d_ff_dense=128, remat="none",
    )
