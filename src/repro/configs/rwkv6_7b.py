"""rwkv6-7b [ssm] — 32L d_model=4096 (attention-free) d_ff=14336 vocab=65536;
Finch — data-dependent decay. Heads = d_model/64. [arXiv:2404.05892; hf]"""
from repro.models.model import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-7b", family="ssm",
        num_layers=32, d_model=4096, num_heads=64, num_kv_heads=64,
        head_dim=64, d_ff=14336, vocab_size=65536,
        mlp_activation="relu",
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-7b-smoke", family="ssm",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        head_dim=16, d_ff=128, vocab_size=256, remat="none",
    )
