"""Assigned input-shape cells + abstract (no-allocation) input specs.

Every (arch × shape) cell resolves to: which step function to lower, the
ShapeDtypeStruct inputs, their shardings, and shape-specific sharding-rule
overrides (e.g. KV-cache sequence sharding for decode cells).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import configs as config_lib
from repro.core.graft import GraftConfig
from repro.data import sources as data_sources
from repro.distributed import sharding as sh
from repro.launch import steps as steps_lib
from repro.models import decode as decode_lib
from repro.models import model as model_lib
from repro.optim import OptimizerConfig

SHAPES: Dict[str, Dict[str, Any]] = {
    "train_4k": {"kind": "train", "seq": 4096, "batch": 256},
    "prefill_32k": {"kind": "prefill", "seq": 32768, "batch": 32},
    "decode_32k": {"kind": "decode", "seq": 32768, "batch": 128},
    "long_500k": {"kind": "decode", "seq": 524288, "batch": 1},
}

# long_500k requires sub-quadratic sequence handling: only the recurrent /
# bounded-window archs run it (DESIGN.md §4).
LONG_CONTEXT_ARCHS = ("rwkv6-7b", "hymba-1.5b")

# per-shape logical-rule overrides
SHAPE_RULES: Dict[str, Dict[str, Any]] = {
    "train_4k": {},
    "prefill_32k": {},
    "decode_32k": {"act_kv_seq": "model", "act_kv_heads": None},
    "long_500k": {"act_kv_seq": ("data", "model"), "act_kv_heads": None},
}

# named sharding presets (hillclimb levers; see EXPERIMENTS.md §Perf).
# "fsdp": pure ZeRO-3 — batch over every mesh axis, no TP/SP on activations,
# weights stay fully sharded and are all-gathered just-in-time. The right
# regime for dense models when per-chip batch ≥ 1 sequence: collective bytes
# become O(params) instead of O(activations × TP degree).
RULE_PRESETS: Dict[str, Dict[str, Any]] = {
    "default": {},
    "fsdp": {
        "act_batch": ("pod", "data", "model"),
        "act_res_seq": None, "act_q_seq": None, "act_heads": None,
        "act_kv_heads": None, "act_mlp": None, "act_vocab": None,
        "act_experts": "model",      # EP unchanged (MoE weights can't gather)
    },
    # head-TP attention (Megatron classic) instead of seq-sharded attention
    "head_tp": {"act_q_seq": None, "act_kv_heads": "model"},
}

# archs whose optimizer must use factored second moments to fit HBM
_ADAFACTOR_ARCHS = ("kimi-k2-1t-a32b",)


def cell_is_supported(arch: str, shape: str) -> Tuple[bool, str]:
    if shape == "long_500k" and arch not in LONG_CONTEXT_ARCHS:
        return False, ("full-attention KV over 524288 positions — "
                       "sub-quadratic archs only (DESIGN.md §4)")
    return True, ""


def all_cells():
    for arch in config_lib.CANONICAL_IDS:
        for shape in SHAPES:
            yield arch, shape


def default_train_config(arch: str, use_graft: bool = True,
                         batch: int = 256, feature_mode: str = "svd",
                         grad_mode: str = "probe") -> steps_lib.TrainConfig:
    opt_name = "adafactor" if arch in _ADAFACTOR_ARCHS else "adamw"
    schedule = "wsd" if arch == "minicpm-2b" else "cosine"
    rset = tuple(r for r in (16, 32, 64, 128) if r <= batch // 2)
    if not rset:
        rset = (max(1, batch // 4), max(2, batch // 2))
    graft = GraftConfig(rset=rset, eps=0.25, refresh_every=1,
                        feature_mode=feature_mode,
                        grad_mode=grad_mode) if use_graft else None
    return steps_lib.TrainConfig(
        optimizer=OptimizerConfig(name=opt_name, schedule=schedule,
                                  total_steps=10_000, warmup_steps=200,
                                  learning_rate=3e-4),
        graft=graft)


# ---------------------------------------------------------------------------
# abstract inputs
# ---------------------------------------------------------------------------

def source_batch_specs(source: data_sources.DataSourceBase
                       ) -> Dict[str, jax.ShapeDtypeStruct]:
    """Abstract batch tree straight from a data source's ``spec()`` — the
    registry-driven counterpart of :func:`batch_specs` (which infers the
    layout from the model family alone)."""
    return {k: jax.ShapeDtypeStruct(s.shape, s.dtype)
            for k, s in source.spec().items()}


def batch_specs(mcfg: model_lib.ModelConfig, batch: int, seq: int) -> Dict[str, jax.ShapeDtypeStruct]:
    i32 = jnp.int32
    if mcfg.family == "audio":
        return {
            "frame_embeds": jax.ShapeDtypeStruct((batch, seq, mcfg.d_model), mcfg.dtype),
            "labels": jax.ShapeDtypeStruct((batch, seq), i32),
        }
    if mcfg.family == "vlm":
        s_text = seq - mcfg.num_patches
        return {
            "patch_embeds": jax.ShapeDtypeStruct((batch, mcfg.num_patches, mcfg.d_model), mcfg.dtype),
            "tokens": jax.ShapeDtypeStruct((batch, s_text), i32),
            "labels": jax.ShapeDtypeStruct((batch, s_text), i32),
        }
    return {
        "tokens": jax.ShapeDtypeStruct((batch, seq), i32),
        "labels": jax.ShapeDtypeStruct((batch, seq), i32),
    }


def batch_logical(batch_tree):
    return jax.tree_util.tree_map(
        lambda leaf: ("act_batch",) + tuple(None for _ in leaf.shape[1:]),
        batch_tree)


def _cache_leaf_logical(path, leaf):
    names = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
    nd = len(leaf.shape)
    under_layers = "layers" in names
    base = ("layers",) if under_layers else ()
    body_nd = nd - len(base)
    leaf_name = names[-1]
    if leaf_name in ("k", "v"):
        lg = ("act_batch", "act_kv_seq", "act_kv_heads", None)
    elif leaf_name == "wkv":
        lg = ("act_batch", "act_heads", None, None)
    elif leaf_name == "shift":
        lg = ("act_batch", None, None)
    elif leaf_name == "ssm":
        lg = ("act_batch", "act_heads", None, None)
    elif leaf_name == "index":
        lg = ()
    else:
        lg = tuple(None for _ in range(body_nd))
    return base + lg


def cache_logical(abstract_cache):
    flat, treedef = jax.tree_util.tree_flatten_with_path(abstract_cache)
    return jax.tree_util.tree_unflatten(
        treedef, [_cache_leaf_logical(p, l) for p, l in flat])


@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    kind: str                      # train | prefill | decode
    mcfg: model_lib.ModelConfig
    step_fn: Any                   # (state/params, ...) jittable
    abstract_args: Tuple[Any, ...]
    arg_logical: Tuple[Any, ...]
    rules: Dict[str, Any]
    donate: Tuple[int, ...] = ()


def build_cell(arch: str, shape: str, *, variant: str = "graft",
               num_layers_override: Optional[int] = None,
               scan_override: Optional[bool] = None,
               rule_overrides: Optional[Dict[str, Any]] = None,
               smoke: bool = False, exact_cost: bool = False,
               feature_mode: str = "svd", grad_mode: str = "probe",
               data_source: Optional[str] = None) -> Cell:
    """Construct the lowered-artifact description for one cell.

    variant: 'graft' | 'baseline' (train cells only).
    num_layers_override/scan_override: roofline L1/L2 unrolled delta trick.
    exact_cost: disable attn/loss chunking (their internal lax.scans are
    counted once by XLA cost analysis, silently hiding ~T/chunk of the
    FLOPs/bytes) — used ONLY for the roofline cost compiles; math identical.
    feature_mode/grad_mode: selection-input strategies from the
    ``repro.selection.sources`` registries (graft train cells only) — lets
    the dry-run compare roofline costs of e.g. ``pca_sketch`` vs ``svd``.
    data_source: a registered task/data-source name (train cells only) —
    the cell's model config takes the source adapter's task-pinned fields
    (vocab = class count, input frontend) and the abstract batch comes from
    the source's ``spec()`` instead of the family-inferred LM layout, so
    the dry-run compiles/rooflines every registered workload.
    """
    ok, why = cell_is_supported(arch, shape)
    if not ok:
        raise ValueError(f"cell {arch}×{shape} unsupported: {why}")
    info = SHAPES[shape]
    overrides: Dict[str, Any] = {}
    if not smoke:
        # production memory defaults: flash-style KV chunking + seq-chunked CE
        overrides["attn_chunk"] = 0 if exact_cost else 1024
        overrides["loss_chunk"] = 0 if exact_cost else 512
    if num_layers_override is not None:
        overrides["num_layers"] = num_layers_override
        # keep kimi's single dense-first layer inside the override budget
        base = config_lib.get_config(arch)
        if base.first_k_dense >= num_layers_override:
            overrides["first_k_dense"] = 0
    if scan_override is not None:
        overrides["scan_layers"] = scan_override
    mcfg = (config_lib.get_smoke_config(arch, **overrides) if smoke
            else config_lib.get_config(arch, **overrides))
    rules = dict(SHAPE_RULES[shape])
    if rule_overrides:
        rules.update(rule_overrides)

    B, S = info["batch"], info["seq"]
    if smoke:
        B, S = max(4, B // 64), min(S, 64)

    if info["kind"] == "train":
        use_graft = variant in ("graft", "subset", "select")
        tcfg = default_train_config(arch, use_graft=use_graft, batch=B,
                                    feature_mode=feature_mode,
                                    grad_mode=grad_mode)
        if data_source is not None and data_source != "synthetic_lm":
            entry = data_sources.get_source(data_source)
            dcfg = entry.task.derive(mcfg, batch=B, seq=S, seed=0)
            mcfg = dataclasses.replace(
                mcfg, **entry.task.model_overrides(dcfg))
            batch = source_batch_specs(entry.build(dcfg))
        else:
            batch = batch_specs(mcfg, B, S)
        abstract_state = jax.eval_shape(
            lambda key: steps_lib.init_train_state(mcfg, tcfg, key, B),
            jax.ShapeDtypeStruct((2,), jnp.uint32))
        state_logical = steps_lib.train_state_logical(mcfg, tcfg, abstract_state)
        step = steps_lib.make_train_step(
            mcfg, tcfg, kind=variant if variant in
            ("graft", "baseline", "subset", "select") else None)
        return Cell(arch, shape, "train", mcfg, step,
                    (abstract_state, batch),
                    (state_logical, batch_logical(batch)), rules, donate=(0,))

    if info["kind"] == "prefill":
        batch = batch_specs(mcfg, B, S)

        def step(params, b):
            return steps_lib.prefill_step(mcfg, params, b, S)

        abstract_params = jax.eval_shape(
            lambda key: model_lib.init_params(mcfg, key),
            jax.ShapeDtypeStruct((2,), jnp.uint32))
        p_logical = model_lib.params_logical(mcfg, abstract_params)
        return Cell(arch, shape, "prefill", mcfg, step,
                    (abstract_params, batch),
                    (p_logical, batch_logical(batch)), rules)

    # decode
    abstract_params = jax.eval_shape(
        lambda key: model_lib.init_params(mcfg, key),
        jax.ShapeDtypeStruct((2,), jnp.uint32))
    p_logical = model_lib.params_logical(mcfg, abstract_params)
    abstract_cache = jax.eval_shape(
        lambda: decode_lib.init_cache(mcfg, B, S))
    c_logical = cache_logical(abstract_cache)
    tokens = jax.ShapeDtypeStruct((B, 1), jnp.int32)

    def step(params, cache, tok):
        return steps_lib.decode_step(mcfg, params, cache, tok)

    return Cell(arch, shape, "decode", mcfg, step,
                (abstract_params, abstract_cache, tokens),
                (p_logical, c_logical,
                 ("act_batch", None)), rules, donate=(1,))


def input_specs(arch: str, shape: str = "train_4k"):
    """ShapeDtypeStruct stand-ins for every model input of a cell (the
    dry-run contract: weak-type-correct, shardable, no device allocation).

    Training shapes return the batch tree {tokens/labels/embeds...}; decode
    shapes return (params, cache, tokens) stand-ins via build_cell.
    """
    info = SHAPES[shape]
    mcfg = config_lib.get_config(arch)
    if info["kind"] in ("train", "prefill"):
        return batch_specs(mcfg, info["batch"], info["seq"])
    cell = build_cell(arch, shape, variant="serve")
    return cell.abstract_args
