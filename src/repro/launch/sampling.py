"""Token sampling for the serving path: temperature / top-k / top-p.

Pure function of (logits, key) — jit-safe, static knobs, batch-first.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("temperature", "top_k", "top_p"))
def sample_tokens(key: jax.Array, logits: jax.Array,
                  temperature: float = 1.0,
                  top_k: Optional[int] = None,
                  top_p: Optional[float] = None) -> jax.Array:
    """logits: (B, V) → token ids (B,) int32.

    temperature == 0.0 → greedy. top_k and top_p compose (k first, then p).
    """
    if temperature == 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits.astype(jnp.float32) / max(temperature, 1e-6)
    B, V = logits.shape

    if top_k is not None and top_k < V:
        kth = jnp.sort(logits, axis=-1)[:, V - top_k][:, None]
        logits = jnp.where(logits < kth, -jnp.inf, logits)

    if top_p is not None and top_p < 1.0:
        sorted_logits = jnp.sort(logits, axis=-1)[:, ::-1]
        probs = jax.nn.softmax(sorted_logits, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        # keep the smallest prefix with cumulative mass ≥ top_p
        cutoff_idx = jnp.sum((cum < top_p).astype(jnp.int32), axis=-1)
        cutoff_val = jnp.take_along_axis(sorted_logits,
                                         cutoff_idx[:, None], axis=-1)
        logits = jnp.where(logits < cutoff_val, -jnp.inf, logits)

    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)
