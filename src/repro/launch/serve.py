"""Batched serving driver: wave-scheduled static batching.

Requests are grouped into waves of up to ``slots`` requests; each wave is
prefilled together (one jitted ``prefill``) and decoded in lock-step (one
jitted ``decode_step`` per tick for the whole slot batch). Finished slots
idle until the wave drains, then the next wave is admitted. This is the
static-batching compromise: per-slot admission (true continuous batching)
needs per-slot cache indices, which the production serving layer would add
via ragged KV writes — documented as future work in DESIGN.md. Prompts in
a wave are truncated to the wave's minimum length so the shared cache index
stays exact.
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs as config_lib
from repro.distributed import sharding as sh
from repro.launch.mesh import make_host_mesh
from repro.models import decode as decode_lib
from repro.models import model as model_lib


def serve(arch: str = "minicpm-2b", smoke: bool = True, slots: int = 4,
          max_seq: int = 128, max_new_tokens: int = 16, eos_token: int = 1,
          requests: int = 8, seed: int = 0) -> Dict:
    mcfg = config_lib.get_smoke_config(arch) if smoke else config_lib.get_config(arch)
    mesh = make_host_mesh()
    rng = np.random.default_rng(seed)
    prompts = [list(rng.integers(2, mcfg.vocab_size, size=8)) for _ in range(requests)]

    with sh.sharding_rules(mesh):
        params = model_lib.init_params(mcfg, jax.random.PRNGKey(seed))

        def _prefill(p, batch):
            return decode_lib.prefill(mcfg, p, batch, max_seq)

        def _decode(p, cache, tok):
            return decode_lib.decode_step(mcfg, p, cache, tok)

        prefill_fn = jax.jit(_prefill)
        decode_fn = jax.jit(_decode, donate_argnums=(1,))

        results: List[Dict] = []
        t0 = time.time()
        ticks = 0
        wave_start = 0
        while wave_start < len(prompts):
            wave = prompts[wave_start:wave_start + slots]
            ids = list(range(wave_start, wave_start + len(wave)))
            wave_start += len(wave)
            plen = min(len(p) for p in wave)
            toks = np.stack([p[:plen] for p in wave]).astype(np.int32)
            # pad the slot batch to full width (inactive slots decode garbage
            # that is simply discarded — shapes stay static for the jit)
            if len(wave) < slots:
                toks = np.concatenate(
                    [toks, np.zeros((slots - len(wave), plen), np.int32)])
            batch = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(toks)}
            logits, cache = prefill_fn(params, batch)
            last = np.asarray(logits[:, 0, :]).argmax(-1).astype(np.int32)
            outs: List[List[int]] = [[int(last[i])] for i in range(len(wave))]
            done = [last[i] == eos_token for i in range(len(wave))]
            cur = last[:, None]
            for _ in range(max_new_tokens - 1):
                if all(done):
                    break
                logits, cache = decode_fn(params, cache, jnp.asarray(cur))
                ticks += 1
                nxt = np.asarray(logits[:, 0, :]).argmax(-1).astype(np.int32)
                for i in range(len(wave)):
                    if not done[i]:
                        outs[i].append(int(nxt[i]))
                        done[i] = nxt[i] == eos_token
                cur = nxt[:, None]
            for i, rid in enumerate(ids):
                results.append({"request_id": rid, "tokens": outs[i]})
        wall = time.time() - t0

    total = sum(len(r["tokens"]) for r in results)
    return {"requests": len(results), "decode_ticks": ticks,
            "total_new_tokens": total, "wall_s": round(wall, 3),
            "tokens_per_s": round(total / max(wall, 1e-9), 1),
            "results": results}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minicpm-2b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args(argv)
    report = serve(arch=args.arch, slots=args.slots,
                   max_new_tokens=args.max_new, requests=args.requests)
    print(json.dumps({k: v for k, v in report.items() if k != "results"}))


if __name__ == "__main__":
    main()
