"""Held-out evaluation over a fixed synthetic eval stream, task-aware.

The eval stream is the SAME registered data source (same distribution —
same Markov chain / mixture centers / grating signatures) read at a step
offset the training loop can never reach, so eval examples are drawn from
the training distribution but never overlap the train stream (generators
are seeded per (seed, step, example) — disjoint step spaces). The metric
family comes from the source's task adapter: ``lm`` sources report
perplexity, ``classification`` sources report accuracy.

Every factory returns an :class:`EvalFn` with a dispatch/collect split so
eval can run as a NON-BLOCKING side stream: ``dispatch(params)`` enqueues
the jitted per-batch evals plus the on-device reduction and returns a dict
of device scalars without syncing the host; ``collect(handle)`` is the
explicit materialization point. Calling the object (``eval_fn(params)``)
keeps the legacy synchronous semantics — dispatch + collect in one go.
Both paths run the identical device computation, so sync and async eval
produce bit-identical numbers.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

from repro.analysis.sync_guard import sync_allowed
from repro.data import DataConfig, SyntheticLM
from repro.data import sources as data_sources
from repro.models import model as model_lib

# step offset of the held-out slice of the stream: training reaches step
# indices 0..steps, eval reads from 7.7M up — disjoint per-example streams
EVAL_STEP_OFFSET = 7_777_777
EVAL_SEED_OFFSET = EVAL_STEP_OFFSET          # back-compat alias


class EvalFn:
    """Held-out eval with an explicit dispatch/collect split.

    ``dispatch`` enqueues against the LIVE ``params`` buffers — under a
    donating train loop this is safe exactly when the dispatch happens
    before the next donating step is issued (the side-stream discipline of
    ``repro.selection.overlap``): PjRt usage events then order the eval
    reads ahead of the buffer reuse, with no host copy of the params.
    """

    def __init__(self, dispatch_fn: Callable[[Any], Dict[str, jax.Array]]):
        self._dispatch = dispatch_fn

    def dispatch(self, params) -> Dict[str, jax.Array]:
        """Enqueue the full eval (per-batch jits + on-device reduction);
        returns device scalars, never blocks the host."""
        return self._dispatch(params)

    @staticmethod
    def collect(handle: Dict[str, jax.Array]) -> Dict[str, float]:
        """Materialize a dispatched handle to host floats (blocks)."""
        with sync_allowed("eval_collect"):
            return {k: float(v) for k, v in handle.items()}  # lint: allow

    def __call__(self, params) -> Dict[str, float]:
        return self.collect(self.dispatch(params))


def make_eval_fn(mcfg: model_lib.ModelConfig, batch: int, seq: int,
                 seed: int = 0, num_batches: int = 4) -> EvalFn:
    """LM-source eval (the legacy entry point; kept for ad-hoc scripts)."""
    data = SyntheticLM(DataConfig(vocab_size=mcfg.vocab_size, seq_len=seq,
                                  global_batch=batch, seed=seed))
    return _lm_eval(mcfg, [data.batch_at(EVAL_STEP_OFFSET + i)
                           for i in range(num_batches)])


def _lm_eval(mcfg: model_lib.ModelConfig, eval_batches) -> EvalFn:
    @jax.jit
    def one(params, batch):
        loss, _ = model_lib.loss_fn(mcfg, params, batch)
        return loss

    staged = [_device_batch(b) for b in eval_batches]   # staged once

    def dispatch(params) -> Dict[str, jax.Array]:
        mean = jnp.mean(jnp.stack([one(params, b) for b in staged]))
        return {"eval_loss": mean, "eval_ppl": jnp.exp(mean)}

    return EvalFn(dispatch)


def _classification_eval(mcfg: model_lib.ModelConfig, eval_batches) -> EvalFn:
    @jax.jit
    def one(params, batch):
        h, mask = model_lib.forward_hiddens(mcfg, params, batch)
        labels = model_lib._pad_labels(batch["labels"], h.shape[1])
        logits = model_lib.logits_from_hiddens(mcfg, params, h)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
        denom = jnp.maximum(jnp.sum(mask), 1.0)
        loss = jnp.sum(nll * mask) / denom
        hit = (jnp.argmax(logits, axis=-1) == labels).astype(jnp.float32)
        return loss, jnp.sum(hit * mask) / denom

    staged = [_device_batch(b) for b in eval_batches]

    def dispatch(params) -> Dict[str, jax.Array]:
        pairs = [one(params, b) for b in staged]
        return {"eval_loss": jnp.mean(jnp.stack([l for l, _ in pairs])),
                "eval_acc": jnp.mean(jnp.stack([a for _, a in pairs]))}

    return EvalFn(dispatch)


def _device_batch(b):
    return {k: jnp.asarray(v) for k, v in b.items()}


def make_eval_fn_for(experiment, mcfg: model_lib.ModelConfig,
                     num_batches: int = 4) -> EvalFn:
    """Eval fn for a ``repro.api.ExperimentConfig`` — one place owns the
    eval-batch policy (≤8 examples per batch, seed shifted out of the train
    stream) so the EvalCallback and ad-hoc scripts agree, for EVERY
    registered data source."""
    dcfg = experiment.finalized().data
    entry = data_sources.entry_for_config(dcfg)
    eval_cfg = dataclasses.replace(
        dcfg, global_batch=min(dcfg.global_batch, 8),
        num_hosts=1, host_index=0)
    data = entry.build(eval_cfg)
    eval_batches = [data.batch_at(EVAL_STEP_OFFSET + i)
                    for i in range(num_batches)]
    if entry.task.kind == "classification":
        return _classification_eval(mcfg, eval_batches)
    return _lm_eval(mcfg, eval_batches)
