"""Held-out evaluation: perplexity over a fixed synthetic eval stream.

The eval stream uses a shifted seed so it never overlaps the train stream
(the generator is seeded per (seed, step, example) — disjoint seed spaces).
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.data import DataConfig, SyntheticLM
from repro.models import model as model_lib

EVAL_SEED_OFFSET = 7_777_777


def make_eval_fn(mcfg: model_lib.ModelConfig, batch: int, seq: int,
                 seed: int = 0, num_batches: int = 4):
    data = SyntheticLM(DataConfig(vocab_size=mcfg.vocab_size, seq_len=seq,
                                  global_batch=batch,
                                  seed=seed + EVAL_SEED_OFFSET))
    eval_batches = [data.batch_at(i) for i in range(num_batches)]

    @jax.jit
    def one(params, tokens, labels):
        loss, _ = model_lib.loss_fn(mcfg, params,
                                    {"tokens": tokens, "labels": labels})
        return loss

    def evaluate(params) -> Dict[str, float]:
        losses = []
        for b in eval_batches:
            losses.append(float(one(params, jnp.asarray(b["tokens"]),
                                    jnp.asarray(b["labels"]))))
        mean = sum(losses) / len(losses)
        return {"eval_loss": mean, "eval_ppl": float(jnp.exp(mean))}

    return evaluate


def make_eval_fn_for(experiment, mcfg: model_lib.ModelConfig,
                     num_batches: int = 4):
    """Eval fn for a ``repro.api.ExperimentConfig`` — one place owns the
    eval-batch policy (≤8 sequences, train seq/seed) so the EvalCallback and
    ad-hoc scripts agree."""
    tr = experiment.train
    return make_eval_fn(mcfg, batch=min(tr.batch, 8), seq=tr.seq,
                        seed=tr.seed, num_batches=num_batches)
