"""Production mesh construction.

Defined as FUNCTIONS so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before any device query).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips/pod ("data","model"); 2 pods = 512 ("pod","data","model")."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh (tests / single-host runs)."""
    return jax.make_mesh(tuple(shape), tuple(axes))


def make_host_mesh():
    """Whatever devices exist, as a 1-D 'data' mesh (CPU examples/tests)."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",))
