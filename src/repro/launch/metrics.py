"""Training telemetry: JSONL metrics stream + throughput/MFU tracking.

Production habits kept: append-only JSONL (greppable), host-side only, and
— since the async host loop — NO device sync on the step path at all. The
trainer hands each step's metrics over as a :class:`MetricsFuture` (a
mapping over still-in-flight device scalars); the logger stamps the
host-side fields (wall time, tokens_seen, step timing) at ``log`` time but
defers the device→host materialization to the flush boundary, so the host
keeps dispatching ahead of the device between flushes.

Rows are BUFFERED: one logical row per step, but materialization + the
write syscall happen only every ``flush_every`` rows (and on ``flush``/
``close``). The trade: crash-safety is BOUNDED, not per-row — a hard kill
between flushes drops at most the last ``flush_every − 1`` rows (a clean
stop, including preemption via ``EmergencySaver``, drains the buffer
through ``close``). Set ``flush_every=1`` to restore per-row durability.

Step timing is HONEST: ``step_time_s`` is the duration the caller measured
around the step dispatch itself (``step_time=``), not the wall time between
``log`` calls — so an eval or checkpoint pause between steps no longer
contaminates the next step's ``mfu``/``tokens_per_s``. The host-side gap on
top of the dispatch is reported separately as ``host_overhead_s``. Callers
that don't pass ``step_time`` keep the legacy between-calls clock.
"""
from __future__ import annotations

import json
import os
import time
from typing import Any, Dict, Iterator, Mapping, MutableMapping, Optional

import jax

PEAK_FLOPS_PER_CHIP = 197e12


def train_step_flops(num_params: int, tokens_per_step: int,
                     remat: bool = True) -> float:
    """6·N·D (+2·N·D recompute under full remat)."""
    base = 6.0 * num_params * tokens_per_step
    return base * (8.0 / 6.0) if remat else base


class MetricsFuture(MutableMapping):
    """One step's metrics as unmaterialized device scalars.

    Behaves like a dict (callbacks may mutate it in place, per the
    ``on_step_end`` contract), but ``float()``-ing the values — the
    host↔device sync — is deferred until someone actually reads one
    (``[]``/``items``) or calls :meth:`materialize`. Key-level operations
    (``in``, ``keys``, ``len``, assignment) never sync, so callbacks can
    route on the row shape without stalling the dispatch queue. ``update``
    merges more values in (the eval side stream injects its device scalars
    here, tagged to the step they were dispatched at).
    """

    __slots__ = ("_data", "_ready")

    def __init__(self, data: Optional[Mapping[str, Any]] = None):
        self._data: Dict[str, Any] = dict(data) if data else {}
        self._ready = False

    # -- key-level ops: never sync --------------------------------------
    def __iter__(self) -> Iterator[str]:
        return iter(self._data)

    def __len__(self) -> int:
        return len(self._data)

    def __contains__(self, key) -> bool:
        return key in self._data

    def keys(self):
        return self._data.keys()

    @property
    def materialized(self) -> bool:
        return self._ready

    def __setitem__(self, key: str, value: Any) -> None:
        self._data[key] = value
        if self._ready:              # keep the materialized invariant
            self._ready = False
            self.materialize()

    def __delitem__(self, key: str) -> None:
        del self._data[key]

    # -- value-level ops: sync ------------------------------------------
    def __getitem__(self, key: str) -> float:
        return self.materialize()[key]

    def materialize(self) -> Dict[str, float]:
        """Pull every value to the host as a plain float (cached)."""
        if not self._ready:
            self._data = {k: float(v)
                          for k, v in jax.device_get(self._data).items()}
            self._ready = True
        return self._data

    def update(self, other: Mapping[str, Any]) -> None:
        if isinstance(other, MetricsFuture):
            other = other._data
        self._data.update(other)
        if self._ready:                  # keep the materialized invariant
            self._ready = False
            self.materialize()


def materialize_metrics(metrics: Mapping[str, Any]) -> Dict[str, float]:
    """Plain ``{k: float}`` from a MetricsFuture or an eager dict — the one
    sync point for consumers that need host values NOW (checkpoint
    manifests, console lines, reports)."""
    if isinstance(metrics, MetricsFuture):
        return metrics.materialize()
    return {k: float(v) for k, v in metrics.items()}


class MetricsLogger:
    def __init__(self, path: Optional[str] = None, num_chips: int = 1,
                 flops_per_step: Optional[float] = None,
                 flush_every: int = 20):
        self.path = path
        self.num_chips = num_chips
        self.flops_per_step = flops_per_step
        self.flush_every = max(1, flush_every)
        self._f = open(path, "a") if path else None
        # pending rows: (host-side fields, metrics mapping) pairs; device
        # values are materialized only when the pair is drained
        self._pending: list = []
        self._last_t: Optional[float] = None
        self.tokens_seen = 0
        self.drain_s = 0.0               # cumulative time spent materializing

    def log(self, step: int, metrics: Mapping[str, Any], tokens: int = 0,
            step_time: Optional[float] = None) -> Dict[str, Any]:
        """Queue one row. Host-side fields (time, tokens_seen, timing) are
        stamped NOW; device values drain at the next flush boundary.

        ``step_time`` is the caller's measurement around the step dispatch
        (``Trainer.last_step_time``); when given, throughput/MFU are
        computed from it and the extra host-side gap between ``log`` calls
        lands in ``host_overhead_s``. Without it, the legacy between-calls
        clock is used (which smears eval/checkpoint pauses into the next
        step — pass ``step_time`` for honest numbers).
        """
        now = time.time()
        base: Dict[str, Any] = {"step": step, "time": now}
        if tokens:
            self.tokens_seen += tokens
            base["tokens_seen"] = self.tokens_seen
        gap = (now - self._last_t) if self._last_t is not None else None
        dt = step_time if step_time is not None else gap
        if dt is not None and dt > 0:
            base["step_time_s"] = dt
            if tokens:
                base["tokens_per_s"] = tokens / dt
            if self.flops_per_step:
                base["mfu"] = (self.flops_per_step /
                               (dt * self.num_chips * PEAK_FLOPS_PER_CHIP))
            if step_time is not None and gap is not None:
                base["host_overhead_s"] = max(0.0, gap - step_time)
        self._last_t = now
        if self._f:
            # no stream, no queue: without a file the row would only be
            # materialized to be thrown away — leave the futures untouched
            self._pending.append((base, metrics))
            if len(self._pending) >= self.flush_every:
                self.flush()
        return base

    def flush(self):
        """Drain the pending rows: materialize device values (the only
        host↔device sync in the logger) and write the JSONL block."""
        if not self._pending:
            return
        t0 = time.time()
        lines = []
        for base, metrics in self._pending:
            row = dict(base)
            row.update(materialize_metrics(metrics))
            lines.append(json.dumps(row))
        self._pending.clear()
        self.drain_s += time.time() - t0
        if self._f:
            self._f.write("\n".join(lines) + "\n")
            self._f.flush()

    def close(self):
        self.flush()
        if self._f:
            self._f.close()


def format_step_line(step: int, metrics: Mapping[str, Any], dt: float,
                     use_graft: bool = False) -> str:
    """One console progress line (the ConsoleCallback / legacy-loop format).
    Materializes ``metrics`` — only call for rows actually printed."""
    metrics = materialize_metrics(metrics)
    extra = (f" rank={metrics.get('rank', 0):.0f}"
             f" align={metrics.get('alignment', 0):.3f}" if use_graft else "")
    return (f"[train] step {step:5d} loss {metrics['loss']:.4f} "
            f"gnorm {metrics['grad_norm']:.3f} {dt*1e3:.0f}ms{extra}")


def read_metrics(path: str):
    out = []
    if not os.path.exists(path):
        return out
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out
