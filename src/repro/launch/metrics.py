"""Training telemetry: JSONL metrics stream + throughput/MFU tracking.

Production habits kept: append-only JSONL (greppable), host-side only, and
— since the async host loop — NO device sync on the step path at all. The
trainer hands each step's metrics over as a :class:`MetricsFuture` (a
mapping over still-in-flight device scalars); the logger stamps the
host-side fields (wall time, tokens_seen, step timing) at ``log`` time but
defers the device→host materialization to the flush boundary, so the host
keeps dispatching ahead of the device between flushes.

Rows are BUFFERED: one logical row per step, but materialization + the
write syscall happen only every ``flush_every`` rows (and on ``flush``/
``close``). The trade: crash-safety is BOUNDED, not per-row — a hard kill
between flushes drops at most the last ``flush_every − 1`` rows (a clean
stop, including preemption via ``EmergencySaver``, drains the buffer
through ``close``). Set ``flush_every=1`` to restore per-row durability.

Step timing is HONEST: ``step_time_s`` is the duration the caller measured
around the step dispatch itself (``step_time=``), not the wall time between
``log`` calls — so an eval or checkpoint pause between steps no longer
contaminates the next step's ``mfu``/``tokens_per_s``. The host-side gap on
top of the dispatch is reported separately as ``host_overhead_s``. Callers
that don't pass ``step_time`` keep the legacy between-calls clock.
"""
from __future__ import annotations

import json
import math
import os
import queue
import threading
import time
from typing import Any, Dict, Iterator, List, Mapping, MutableMapping, \
    Optional, Tuple

import jax

from repro.analysis.sync_guard import sync_allowed

PEAK_FLOPS_PER_CHIP = 197e12


def _attn_kv_horizon(S: int, window: Optional[int]) -> float:
    """Mean per-query causal KV horizon length over a length-S sequence."""
    if window is not None and window < S:
        w = window
        # the first w queries see q+1 keys, the rest see exactly w
        return (w * (w + 1) / 2.0 + (S - w) * w) / S
    return (S + 1) / 2.0


def attention_train_flops(mcfg, seq: int, tokens_per_step: int,
                          remat: bool = True) -> float:
    """Per-step matmul FLOPs of the attention score/value products — the
    O(S²·Dh·H) term that 6·N·tokens misses. Causal- and window-aware,
    honoring the per-layer local/global pattern (gemma2, hymba)."""
    if mcfg.family == "ssm" or not mcfg.num_heads:
        return 0.0
    local = mcfg.is_local_pattern()
    per_token = 0.0
    for i in range(mcfg.num_layers):
        window = mcfg.sliding_window if (mcfg.sliding_window and local[i]) \
            else None
        kv = _attn_kv_horizon(seq, window)
        per_token += 4.0 * kv * mcfg.num_heads * mcfg.head_dim  # QKᵀ + PV
    total = per_token * 3.0                  # forward + 2× backward
    if remat:
        total *= 4.0 / 3.0                   # forward recompute under remat
    return total * tokens_per_step


def train_step_flops(num_params: int, tokens_per_step: int,
                     remat: bool = True, mcfg=None,
                     seq: Optional[int] = None) -> float:
    """6·N·D (+2·N·D recompute under full remat), plus — when the model
    config and sequence length are given — the attention O(S²) term.
    Without them the legacy parameter-only estimate is returned (inflating
    ``mfu`` as sequence length grows)."""
    base = 6.0 * num_params * tokens_per_step
    total = base * (8.0 / 6.0) if remat else base
    if mcfg is not None and seq:
        total += attention_train_flops(mcfg, seq, tokens_per_step, remat=remat)
    return total


class DeviceClock:
    """Device-time source: completion stamps without syncing the step path.

    The dispatch clock (``Trainer.last_step_time``) measures how long the
    host took to ENQUEUE a step — under the async host loop that is dispatch
    jitter, not device time. Instead, each step hands one of its detached
    device scalars to :meth:`observe`; a daemon thread ``block_until_ready``s
    the markers in order and stamps the completion wall time. With the
    dispatch queue saturated (the steady state the async loop maintains),
    the delta between consecutive completion stamps IS the device execution
    time of the step. The first observed step has no predecessor stamp and
    is never timed, so N observed steps yield N−1 device timings.

    ``stall_timeout_s`` arms a watchdog: when the stamper thread has been
    blocked on one marker longer than the timeout, the blocking consumers
    (:meth:`device_time`, :meth:`drain`) log the stuck step once, stop
    waiting, and return what they have — so a wedged device degrades the
    report to dispatch-sourced timing (``mfu_source: dispatch``) instead of
    hanging it. The stall clears itself if the marker eventually completes.
    """

    def __init__(self, stall_timeout_s: Optional[float] = None):
        self.stall_timeout_s = stall_timeout_s
        self.stalled = False
        self._stall_warned = False
        self._q: "queue.SimpleQueue" = queue.SimpleQueue()
        self._cond = threading.Condition()
        self._times: Dict[int, float] = {}          # step → device seconds
        self._fresh: List[Tuple[int, float]] = []   # not yet poll()ed
        self._prev_t: Optional[float] = None
        self._pending = 0
        self._waiting: Optional[Tuple[int, float]] = None  # (step, t_block)
        self._closed = False
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="device-clock")
        self._thread.start()

    def _run(self):
        while True:
            item = self._q.get()
            if item is None:
                return
            step, marker = item
            with self._cond:
                self._waiting = (step, time.time())
            try:
                # clock-thread blocking IS the design (off the step path);
                # duck-typed so chaos StallMarkers time-shift the stamp
                if hasattr(marker, "block_until_ready"):
                    marker.block_until_ready()              # lint: allow
                else:
                    jax.block_until_ready(marker)           # lint: allow
            except Exception:
                pass                      # a failed step still advances time
            t = time.time()
            with self._cond:
                self._waiting = None
                self.stalled = False      # marker landed — stall cleared
                if self._prev_t is not None:
                    dt = t - self._prev_t
                    self._times[step] = dt
                    self._fresh.append((step, dt))
                self._prev_t = t
                self._pending -= 1
                self._cond.notify_all()

    def _stalled_now(self) -> bool:
        """Watchdog check (condition must be held): has the stamper been
        blocked on a single marker past ``stall_timeout_s``? Warns once,
        naming the stuck step."""
        if self.stall_timeout_s is not None and self._waiting is not None:
            step, t0 = self._waiting
            if time.time() - t0 >= self.stall_timeout_s:
                self.stalled = True
                if not self._stall_warned:
                    self._stall_warned = True
                    print(f"[device-clock] WARNING: step {step} marker "
                          f"incomplete after {self.stall_timeout_s:.1f}s — "
                          "device stall suspected; timing falls back to the "
                          "dispatch clock (mfu_source: dispatch)", flush=True)
        return self.stalled

    def observe(self, step: int, marker) -> None:
        """Register one step's device marker (must be a DETACHED array —
        the clock thread holds it until it completes)."""
        if self._closed:
            return
        with self._cond:
            self._pending += 1
        self._q.put((step, marker))

    def device_time(self, step: int,
                    timeout: Optional[float] = None) -> Optional[float]:
        """Device seconds for ``step``; optionally wait for the stamp.
        Returns immediately (with what exists) once the watchdog trips."""
        with self._cond:
            if timeout and step not in self._times and self._pending:
                deadline = time.time() + timeout
                while (step not in self._times and self._pending
                       and not self._stalled_now()):
                    remaining = deadline - time.time()
                    if remaining <= 0:
                        break
                    # sliced wait: a hung stamper never notifies, so the
                    # watchdog must get re-checked on a bounded cadence
                    self._cond.wait(min(remaining, 0.25))
            return self._times.get(step)

    def poll(self) -> List[Tuple[int, float]]:
        """Drain newly completed (step, device_dt) pairs (straggler feed)."""
        with self._cond:
            out, self._fresh = self._fresh, []
            return out

    def drain(self, timeout: float = 30.0) -> None:
        """Block until every observed marker has been stamped — or the
        watchdog declares the device stalled."""
        with self._cond:
            deadline = time.time() + timeout
            while self._pending and not self._stalled_now():
                remaining = deadline - time.time()
                if remaining <= 0:
                    break
                self._cond.wait(min(remaining, 0.25))

    @property
    def timed_steps(self) -> int:
        with self._cond:
            return len(self._times)

    @property
    def total_device_s(self) -> float:
        with self._cond:
            return sum(self._times.values())

    def close(self) -> None:
        if not self._closed:
            self._closed = True
            self._q.put(None)
            self._thread.join(timeout=5.0)


class MetricsFuture(MutableMapping):
    """One step's metrics as unmaterialized device scalars.

    Behaves like a dict (callbacks may mutate it in place, per the
    ``on_step_end`` contract), but ``float()``-ing the values — the
    host↔device sync — is deferred until someone actually reads one
    (``[]``/``items``) or calls :meth:`materialize`. Key-level operations
    (``in``, ``keys``, ``len``, assignment) never sync, so callbacks can
    route on the row shape without stalling the dispatch queue. ``update``
    merges more values in (the eval side stream injects its device scalars
    here, tagged to the step they were dispatched at).
    """

    __slots__ = ("_data", "_ready")

    def __init__(self, data: Optional[Mapping[str, Any]] = None):
        self._data: Dict[str, Any] = dict(data) if data else {}
        self._ready = False

    # -- key-level ops: never sync --------------------------------------
    def __iter__(self) -> Iterator[str]:
        return iter(self._data)

    def __len__(self) -> int:
        return len(self._data)

    def __contains__(self, key) -> bool:
        return key in self._data

    def keys(self):
        return self._data.keys()

    @property
    def materialized(self) -> bool:
        return self._ready

    def __setitem__(self, key: str, value: Any) -> None:
        self._data[key] = value
        if self._ready:              # keep the materialized invariant
            self._ready = False
            self.materialize()

    def __delitem__(self, key: str) -> None:
        del self._data[key]

    # -- value-level ops: sync ------------------------------------------
    def __getitem__(self, key: str) -> float:
        return self.materialize()[key]

    def materialize(self) -> Dict[str, float]:
        """Pull every value to the host as a plain float (cached)."""
        if not self._ready:
            # deliberately NOT a sanctioned site itself: under train.audit
            # a materialize outside a wrapped drain point must fire SY001
            self._data = {k: float(v)                       # lint: allow
                          for k, v in jax.device_get(self._data).items()}
            self._ready = True
        return self._data

    def update(self, other: Mapping[str, Any]) -> None:
        if isinstance(other, MetricsFuture):
            other = other._data
        self._data.update(other)
        if self._ready:                  # keep the materialized invariant
            self._ready = False
            self.materialize()


def sanitize_row(row: Mapping[str, Any]) -> Dict[str, Any]:
    """JSON-safe copy of a metrics row: non-finite floats become ``null``
    and their keys are listed under ``nonfinite_keys``. Python's default
    ``json.dumps`` emits bare ``NaN``/``Infinity`` literals — NOT valid
    JSON — which breaks every strict downstream parser; a sentinel-skipped
    step (NaN loss is recorded honestly) must not poison the stream."""
    out: Dict[str, Any] = {}
    bad = []
    for k, v in row.items():
        if isinstance(v, float) and not math.isfinite(v):
            out[k] = None
            bad.append(k)
        else:
            out[k] = v
    if bad:
        out["nonfinite_keys"] = sorted(bad)
    return out


def materialize_metrics(metrics: Mapping[str, Any]) -> Dict[str, float]:
    """Plain ``{k: float}`` from a MetricsFuture or an eager dict — the one
    sync point for consumers that need host values NOW (checkpoint
    manifests, console lines, reports)."""
    if isinstance(metrics, MetricsFuture):
        return metrics.materialize()
    return {k: float(v) for k, v in metrics.items()}       # lint: allow


class MetricsLogger:
    def __init__(self, path: Optional[str] = None, num_chips: int = 1,
                 flops_per_step: Optional[float] = None,
                 flush_every: int = 20,
                 device_clock: Optional[DeviceClock] = None):
        self.path = path
        self.num_chips = num_chips
        self.flops_per_step = flops_per_step
        self.flush_every = max(1, flush_every)
        self.device_clock = device_clock
        self._f = open(path, "a") if path else None
        # pending rows: (host-side fields, metrics mapping, tokens) triples;
        # device values are materialized only when the row is drained
        self._pending: list = []
        self._last_t: Optional[float] = None
        self.tokens_seen = 0
        self.drain_s = 0.0               # cumulative time spent materializing

    def log(self, step: int, metrics: Mapping[str, Any], tokens: int = 0,
            step_time: Optional[float] = None) -> Dict[str, Any]:
        """Queue one row. Host-side fields (time, tokens_seen, timing) are
        stamped NOW; device values drain at the next flush boundary.

        ``step_time`` is the caller's measurement around the step dispatch
        (``Trainer.last_step_time``); when given, throughput/MFU are
        computed from it and the extra host-side gap between ``log`` calls
        lands in ``host_overhead_s``. Without it, the legacy between-calls
        clock is used (which smears eval/checkpoint pauses into the next
        step — pass ``step_time`` for honest numbers).
        """
        now = time.time()
        base: Dict[str, Any] = {"step": step, "time": now}
        if tokens:
            self.tokens_seen += tokens
            base["tokens_seen"] = self.tokens_seen
        gap = (now - self._last_t) if self._last_t is not None else None
        dt = step_time if step_time is not None else gap
        if dt is not None and dt > 0:
            base["step_time_s"] = dt
            if tokens:
                base["tokens_per_s"] = tokens / dt
            if self.flops_per_step:
                base["mfu"] = (self.flops_per_step /
                               (dt * self.num_chips * PEAK_FLOPS_PER_CHIP))
                base["mfu_source"] = "dispatch"
            if step_time is not None and gap is not None:
                base["host_overhead_s"] = max(0.0, gap - step_time)
        self._last_t = now
        if self._f:
            # no stream, no queue: without a file the row would only be
            # materialized to be thrown away — leave the futures untouched
            self._pending.append((base, metrics, tokens))
            if len(self._pending) >= self.flush_every:
                self.flush()
        return base

    def flush(self):
        """Drain the pending rows: materialize device values (the only
        host↔device sync in the logger) and write the JSONL block. With a
        :class:`DeviceClock` attached, ``mfu``/throughput are re-sourced
        from device time here — materializing the row's metrics guarantees
        the device has finished the step, so the stamp is (near-)ready."""
        if not self._pending:
            return
        t0 = time.time()
        lines = []
        with sync_allowed("metrics_flush"):
            for base, metrics, tokens in self._pending:
                row = dict(base)
                row.update(materialize_metrics(metrics))
                if self.device_clock is not None:
                    dev_dt = self.device_clock.device_time(row["step"],
                                                           timeout=1.0)
                    if dev_dt is not None and dev_dt > 0:
                        row["device_step_time_s"] = dev_dt
                        if tokens:
                            row["tokens_per_s"] = tokens / dev_dt
                        if self.flops_per_step:
                            row["mfu"] = (self.flops_per_step /
                                          (dev_dt * self.num_chips *
                                           PEAK_FLOPS_PER_CHIP))
                            row["mfu_source"] = "device"
                lines.append(json.dumps(sanitize_row(row), allow_nan=False))
        self._pending.clear()
        self.drain_s += time.time() - t0
        if self._f:
            self._f.write("\n".join(lines) + "\n")
            self._f.flush()

    def close(self):
        self.flush()
        if self._f:
            self._f.close()


def format_step_line(step: int, metrics: Mapping[str, Any], dt: float,
                     use_graft: bool = False) -> str:
    """One console progress line (the ConsoleCallback / legacy-loop format).
    Materializes ``metrics`` — only call for rows actually printed."""
    metrics = materialize_metrics(metrics)
    extra = (f" rank={metrics.get('rank', 0):.0f}"
             f" align={metrics.get('alignment', 0):.3f}" if use_graft else "")
    return (f"[train] step {step:5d} loss {metrics['loss']:.4f} "
            f"gnorm {metrics['grad_norm']:.3f} {dt*1e3:.0f}ms{extra}")


def read_metrics(path: str):
    out = []
    if not os.path.exists(path):
        return out
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out
