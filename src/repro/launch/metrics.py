"""Training telemetry: JSONL metrics stream + throughput/MFU tracking.

Production habits kept: append-only JSONL (greppable), host-side only (no
device sync beyond the metrics already materialized by the step), analytic
FLOPs/step so MFU is reported against the 197 TFLOP/s bf16 peak.

Rows are BUFFERED: one logical row per step, but the host write syscall
happens only every ``flush_every`` rows (and on ``flush``/``close``), so at
production step times the telemetry stream never stalls the step loop on
file I/O. The trade: crash-safety is BOUNDED, not per-row — a hard kill
between flushes drops at most the last ``flush_every − 1`` rows (a clean
stop, including preemption via ``EmergencySaver``, drains the buffer through
``close``). Set ``flush_every=1`` to restore per-row durability.
"""
from __future__ import annotations

import json
import os
import time
from typing import Any, Dict, Optional

PEAK_FLOPS_PER_CHIP = 197e12


def train_step_flops(num_params: int, tokens_per_step: int,
                     remat: bool = True) -> float:
    """6·N·D (+2·N·D recompute under full remat)."""
    base = 6.0 * num_params * tokens_per_step
    return base * (8.0 / 6.0) if remat else base


class MetricsLogger:
    def __init__(self, path: Optional[str] = None, num_chips: int = 1,
                 flops_per_step: Optional[float] = None,
                 flush_every: int = 20):
        self.path = path
        self.num_chips = num_chips
        self.flops_per_step = flops_per_step
        self.flush_every = max(1, flush_every)
        self._f = open(path, "a") if path else None
        self._buf: list = []
        self._last_t: Optional[float] = None
        self.tokens_seen = 0

    def log(self, step: int, metrics: Dict[str, Any],
            tokens: int = 0) -> Dict[str, Any]:
        now = time.time()
        row = {"step": step, "time": now, **{k: float(v)
                                             for k, v in metrics.items()}}
        if tokens:
            self.tokens_seen += tokens
            row["tokens_seen"] = self.tokens_seen
        if self._last_t is not None:
            dt = now - self._last_t
            row["step_time_s"] = dt
            if tokens and dt > 0:
                row["tokens_per_s"] = tokens / dt
            if self.flops_per_step and dt > 0:
                row["mfu"] = (self.flops_per_step /
                              (dt * self.num_chips * PEAK_FLOPS_PER_CHIP))
        self._last_t = now
        if self._f:
            self._buf.append(json.dumps(row))
            if len(self._buf) >= self.flush_every:
                self.flush()
        return row

    def flush(self):
        """Drain the row buffer to disk (called automatically every
        ``flush_every`` rows and on ``close``)."""
        if self._f and self._buf:
            self._f.write("\n".join(self._buf) + "\n")
            self._f.flush()
            self._buf.clear()

    def close(self):
        if self._f:
            self.flush()
            self._f.close()


def format_step_line(step: int, metrics: Dict[str, Any], dt: float,
                     use_graft: bool = False) -> str:
    """One console progress line (the ConsoleCallback / legacy-loop format)."""
    extra = (f" rank={metrics.get('rank', 0):.0f}"
             f" align={metrics.get('alignment', 0):.3f}" if use_graft else "")
    return (f"[train] step {step:5d} loss {metrics['loss']:.4f} "
            f"gnorm {metrics['grad_norm']:.3f} {dt*1e3:.0f}ms{extra}")


def read_metrics(path: str):
    out = []
    if not os.path.exists(path):
        return out
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                out.append(json.loads(line))
    return out
