"""Jittable train / serve steps with GRAFT integrated as a first-class
feature, plus abstract state construction for the no-allocation dry-run.

Three step families:
  * ``baseline_train_step``  — full-batch fwd+bwd+update (the paper's "Full")
  * ``graft_train_step``     — selection forward (features + grad embeddings
    + Fast MaxVol + rank choice) followed by subset fwd+bwd+update. With
    ``refresh_every == 1`` the selection is unconditional (dry-run worst
    case); otherwise a ``lax.cond`` reuses the previous subset (paper Alg. 1).
  * ``prefill_step`` / ``decode_step`` — serving paths.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models import decode as decode_lib
from repro.models import model as model_lib
from repro.optim import OptimizerConfig, make_optimizer
from repro.selection import base as selection_base
from repro.selection import graft as graft_lib
from repro.selection import registry as sampler_registry
from repro.selection import sources as sources_lib

PyTree = Any


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    optimizer: OptimizerConfig = OptimizerConfig()
    graft: Optional[graft_lib.GraftConfig] = None
    sampler: str = "graft"          # registry name; any repro.selection sampler
    probe_positions: int = 256      # positions per sequence for grad embeddings
                                    # (0 = all; the paper's K×M regime is tiny)
    microbatches: int = 1           # >1: sequential accumulation (§Perf memory lever)
    sentinel: bool = True           # on-device divergence sentinel: fused
                                    # health word + skip-update (a poisoned
                                    # gradient never touches params)
    spike_z: float = 6.0            # loss-spike z-score vs the EMA carried in
                                    # train state (0 = finite-checks only)

    @property
    def use_graft(self) -> bool:
        return self.graft is not None


# ---------------------------------------------------------------------------
# state
# ---------------------------------------------------------------------------

# steps of healthy-loss EMA history required before the spike z-score may
# veto a step — a cold EMA (mean 0, var 0) would flag the very first loss
SENTINEL_WARMUP = 16


def init_health() -> Dict[str, jax.Array]:
    """Divergence-sentinel carry: loss EMA (mean/var), its sample count,
    and the consecutive-bad-step streak — all device scalars, updated
    inside the train step so the sentinel costs zero host syncs."""
    return {"ema_mean": jnp.float32(0.0), "ema_var": jnp.float32(0.0),
            "count": jnp.int32(0), "bad_streak": jnp.int32(0)}


def init_sampler_carry(mcfg, tcfg: TrainConfig, params, batch_size: int):
    """The registry sampler's initial cross-step state (Sampler-v2 carry).

    ``{}`` for the stateless strategies; the (L, d) sketch reservoir for
    ``streaming_graft``. The gradient-embedding width d comes from the
    registered grad source (``embed_dim``), so the carry is sized before
    any batch exists — shape-only, safe under ``eval_shape``.
    """
    smp = sampler_registry.get_sampler(tcfg.sampler)
    grad_source = sources_lib.resolve_grad_source(tcfg.graft.grad_mode)
    spec = selection_base.CarrySpec(
        batch_size=batch_size, grad_dim=grad_source.embed_dim(mcfg, params))
    return smp.init_carry(tcfg.graft, spec)


def init_train_state(mcfg: model_lib.ModelConfig, tcfg: TrainConfig,
                     key: jax.Array, batch_size: int) -> Dict[str, PyTree]:
    params = model_lib.init_params(mcfg, key)
    opt = make_optimizer(tcfg.optimizer)
    state: Dict[str, PyTree] = {
        "params": params,
        "opt": opt.init(params),
        "step": jnp.int32(0),
    }
    if tcfg.use_graft:
        state["graft"] = graft_lib.init_state(tcfg.graft, batch_size)
        state["sampler_carry"] = init_sampler_carry(mcfg, tcfg, params,
                                                    batch_size)
    if tcfg.sentinel:
        state["health"] = init_health()
    return state


def _replicated_logical(tree):
    return jax.tree_util.tree_map(
        lambda leaf: tuple(None for _ in getattr(leaf, "shape", ())), tree)


def opt_state_logical(opt_name: str, p_logical, abstract_params):
    """Logical-axis tree for the optimizer state (mirrors param sharding;
    Adafactor's factored moments drop the reduced axis)."""
    if opt_name in ("sgd", "lion"):
        return {"m": p_logical}
    if opt_name == "adamw":
        return {"m": p_logical, "v": p_logical}
    if opt_name == "adafactor":
        def factored(lg, leaf):
            if len(leaf.shape) >= 2:
                return {"vr": tuple(lg[:-1]), "vc": tuple(lg[:-2]) + (lg[-1],)}
            return {"v": tuple(lg)}
        return {"v": jax.tree_util.tree_map(
            factored, p_logical, abstract_params,
            is_leaf=lambda x: isinstance(x, tuple))}
    raise ValueError(opt_name)


def train_state_logical(mcfg, tcfg: TrainConfig, abstract_state):
    p_logical = model_lib.params_logical(mcfg, abstract_state["params"])
    out = {
        "params": p_logical,
        "opt": opt_state_logical(tcfg.optimizer.name, p_logical,
                                 abstract_state["params"]),
        "step": (),
    }
    if "graft" in abstract_state:
        out["graft"] = _replicated_logical(abstract_state["graft"])
    if "sampler_carry" in abstract_state:
        out["sampler_carry"] = _replicated_logical(
            abstract_state["sampler_carry"])
    if "health" in abstract_state:
        out["health"] = _replicated_logical(abstract_state["health"])
    return out


# ---------------------------------------------------------------------------
# GRAFT selection inputs at LM scale (DESIGN.md §3 hardware adaptation)
# ---------------------------------------------------------------------------

def selection_inputs(mcfg, tcfg: TrainConfig, params, batch
                     ) -> Tuple[jax.Array, jax.Array, jax.Array, jax.Array]:
    """One full-batch forward → (V (K,R_max), G (d,K), ḡ (d,), scores (K,)).

    The feature path (V) and gradient-embedding path (G) are resolved from
    the ``repro.selection.sources`` registries by ``GraftConfig.feature_mode``
    (``svd`` | ``sketch_svd`` | ``pca_sketch`` | ``pooled_raw`` | ``ica``)
    and ``GraftConfig.grad_mode`` (``probe`` | ``logit_embed`` | ``full``).
    Batch-layout agnostic: any registered data source's batch works —
    ``forward_hiddens`` dispatches on the model frontend, and the label
    padding below covers frontends whose labels don't span every position.
    Defaults reproduce the paper's setup:
    relevance-ordered SVD of mean-pooled final hiddens × per-example probe
    gradients from the softmax error signal (no extra backward). Scores =
    per-example probe cross-entropy (drives ``loss_topk``-style samplers for
    free — same logits).
    """
    gcfg = tcfg.graft
    extractor = sources_lib.resolve_features(gcfg.feature_mode)
    grad_source = sources_lib.resolve_grad_source(gcfg.grad_mode)
    h, mask = model_lib.forward_hiddens(mcfg, params, batch)
    h = jax.lax.stop_gradient(h)
    S = h.shape[1]
    stride = max(1, S // tcfg.probe_positions) if tcfg.probe_positions else 1
    hp = h[:, ::stride, :]
    labels = batch["labels"]
    if labels.shape[1] != S:                       # vlm: pad vision positions
        pad = S - labels.shape[1]
        labels = jnp.concatenate(
            [jnp.zeros((labels.shape[0], pad), labels.dtype), labels], axis=1)
    lp = labels[:, ::stride]
    mp = mask[:, ::stride].astype(jnp.float32)     # labeled probe positions
    logits = model_lib.logits_from_hiddens(mcfg, params, hp)
    emb = grad_source(sources_lib.GradSourceInputs(
        logits=logits, labels=lp, hiddens=hp, mcfg=mcfg, params=params,
        batch=batch, mask=mp))
    emb = constrain(emb, ("act_batch", None))      # (K, E) f32
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, lp[..., None], axis=-1)[..., 0]
    # masked mean: frontends that prepend unlabeled patch/frame positions
    # (vlm) must not let fake label-0 CE at those positions swamp the score
    scores = jnp.sum(nll * mp, axis=-1) / \
        jnp.maximum(jnp.sum(mp, axis=-1), 1.0)     # (K,) probe CE per example
    # the K×R feature/gradient matrices are tiny — replicate for MaxVol
    pooled = jnp.sum(h.astype(jnp.float32) * mask[..., None], axis=1) / \
        jnp.maximum(jnp.sum(mask, axis=1), 1.0)[:, None]
    V = extractor(pooled, gcfg.r_max)
    G = emb.T                                      # (d=E, K)
    g_bar = jnp.mean(emb, axis=0)
    return V, G, g_bar, scores


def make_selection_refresh(mcfg, tcfg: TrainConfig):
    """``(params, batch, carry, step) → (SelectionState, carry')``: the
    selection forward alone — features + grad embeddings + the registry
    sampler's decision, with the sampler's cross-step carry threaded
    through (Sampler-v2; ``{}`` in/out for stateless strategies).

    ``graft_train_step`` inlines this under its refresh ``lax.cond``; the
    ``OverlappedSelector`` (``repro.selection.overlap``) jits it as its OWN
    dispatch so the refresh pipelines against the train-step stream instead
    of serializing inside it.
    """
    smp = sampler_registry.get_sampler(tcfg.sampler)
    gcfg = tcfg.graft

    def refresh(params, batch, carry, step):
        V, G, g_bar, scores = selection_inputs(mcfg, tcfg, params, batch)
        key = selection_base.default_select_key(step)
        return smp.select(gcfg, selection_base.SelectionInputs(
            V, G, g_bar, scores, key), carry, step)

    return refresh


def _take_batch(batch, pivots: jax.Array, k_global: int):
    def take(x):
        if hasattr(x, "shape") and x.ndim >= 1 and x.shape[0] == k_global:
            sub = jnp.take(x, pivots, axis=0)
            return constrain(sub, ("act_batch",) + (None,) * (sub.ndim - 1))
        return x
    return jax.tree_util.tree_map(take, batch)


def _state_carry(tcfg: TrainConfig, state):
    """The sampler carry held in the train state; ``{}`` for legacy state
    dicts built before the v2 protocol (their structure is preserved — the
    step functions only store a carry back when the key exists)."""
    if "sampler_carry" in state:
        return state["sampler_carry"]
    smp = sampler_registry.get_sampler(tcfg.sampler)
    if smp.stateful:
        raise ValueError(
            f"sampler '{smp.name}' is stateful but the train state has no "
            f"'sampler_carry' — build the state with init_train_state")
    return selection_base.EMPTY_CARRY


# ---------------------------------------------------------------------------
# train steps
# ---------------------------------------------------------------------------

def baseline_train_step(mcfg, tcfg: TrainConfig, state, batch):
    opt = make_optimizer(tcfg.optimizer)

    if tcfg.microbatches > 1:
        from repro.distributed.accumulate import accumulated_grads
        loss_val, grads = accumulated_grads(
            lambda p, mb: model_lib.loss_fn(mcfg, p, mb)[0],
            state["params"], batch, tcfg.microbatches)
        params, opt_state, metrics = opt.apply(
            state["params"], grads, state["opt"], state["step"])
        new_state = dict(state, params=params, opt=opt_state,
                         step=state["step"] + 1)
        return new_state, dict(metrics, loss=loss_val)

    def loss(params):
        return model_lib.loss_fn(mcfg, params, batch)

    (loss_val, aux), grads = jax.value_and_grad(loss, has_aux=True)(state["params"])
    params, opt_state, metrics = opt.apply(
        state["params"], grads, state["opt"], state["step"])
    new_state = dict(state, params=params, opt=opt_state, step=state["step"] + 1)
    metrics = dict(metrics, loss=loss_val)
    return new_state, metrics


def graft_train_step(mcfg, tcfg: TrainConfig, state, batch):
    """Alg. 1 as one jitted step, sampler-generic: the subset strategy is
    resolved from the registry by ``tcfg.sampler`` (default: GRAFT)."""
    gcfg = tcfg.graft
    refresh = make_selection_refresh(mcfg, tcfg)
    opt = make_optimizer(tcfg.optimizer)
    k_global = jax.tree_util.tree_leaves(batch)[0].shape[0]
    carry0 = _state_carry(tcfg, state)

    def do_select(_):
        return refresh(state["params"], batch, carry0, state["step"])

    if gcfg.refresh_every == 1:
        graft_state, carry = do_select(None)
    else:
        # both branches return (SelectionState, carry): the non-refresh
        # branch keeps the carry untouched, so the reservoir only advances
        # on refresh steps (what makes rollback/resume bit-exact)
        graft_state, carry = jax.lax.cond(
            state["step"] % gcfg.refresh_every == 0,
            do_select,
            lambda _: (state["graft"]._replace(step=state["step"]), carry0),
            None)

    sub_batch = _take_batch(batch, graft_state.pivots, k_global)
    weights = graft_state.weights                   # (R_max,) sum=1, 0 inactive

    def loss(params):
        pel = model_lib.per_example_loss(mcfg, params, sub_batch)
        return jnp.sum(pel * weights)

    loss_val, grads = jax.value_and_grad(loss)(state["params"])
    params, opt_state, metrics = opt.apply(
        state["params"], grads, state["opt"], state["step"])
    new_state = dict(state, params=params, opt=opt_state,
                     step=state["step"] + 1, graft=graft_state)
    if "sampler_carry" in state:
        new_state["sampler_carry"] = carry
    metrics = dict(metrics, loss=loss_val, rank=graft_state.rank,
                   proj_error=graft_state.last_error,
                   alignment=graft_state.alignment)
    return new_state, metrics


def subset_train_step(mcfg, tcfg: TrainConfig, state, batch):
    """Alg. 1 'else' branch: steady-state GRAFT step between refreshes —
    train on the STORED subset, no selection forward. This is the per-step
    cost once the selection is amortized over S (the paper's S = 20–50)."""
    opt = make_optimizer(tcfg.optimizer)
    k_global = jax.tree_util.tree_leaves(batch)[0].shape[0]
    graft_state = state["graft"]
    sub_batch = _take_batch(batch, graft_state.pivots, k_global)
    weights = graft_state.weights

    def loss(params):
        pel = model_lib.per_example_loss(mcfg, params, sub_batch)
        return jnp.sum(pel * weights)

    loss_val, grads = jax.value_and_grad(loss)(state["params"])
    params, opt_state, metrics = opt.apply(
        state["params"], grads, state["opt"], state["step"])
    new_state = dict(state, params=params, opt=opt_state,
                     step=state["step"] + 1,
                     graft=graft_state._replace(step=state["step"] + 1))
    return new_state, dict(metrics, loss=loss_val)


def selection_step(mcfg, tcfg: TrainConfig, state, batch):
    """Selection only (features + grad embeddings + MaxVol + rank sweep) —
    isolates the refresh cost for the amortization analysis (§Perf)."""
    refresh = make_selection_refresh(mcfg, tcfg)
    graft_state, carry = refresh(state["params"], batch,
                                 _state_carry(tcfg, state), state["step"])
    new_state = dict(state, graft=graft_state)
    if "sampler_carry" in state:
        new_state["sampler_carry"] = carry
    return new_state, {"rank": graft_state.rank,
                       "proj_error": graft_state.last_error}


def apply_sentinel(tcfg: TrainConfig, state, new_state, metrics):
    """Fused divergence sentinel + skip-update, entirely on device.

    The health word: loss and global grad norm must be finite (the norm is
    a sum of squares over EVERY grad leaf, so one non-finite grad entry
    anywhere poisons it — an all-leaves check for the price of a scalar),
    and — once the loss EMA has ``SENTINEL_WARMUP`` healthy samples — the
    loss must sit within ``spike_z`` EMA standard deviations of the mean.

    Skip-update: on an unhealthy step every updated leaf (params, opt,
    graft) is ``where``-selected back to its input value with only ``step``
    advanced, so a poisoned gradient never touches params. On a healthy
    step the select returns the new values bit-exactly — the sentinel is
    trajectory-neutral (why ``train.sentinel`` is excluded from
    ``config_hash``, like ``graft.overlap``).

    The verdict rides the step's metrics (``healthy``, ``bad_streak``) and
    the ``bad_streak`` counter in the carried health state, so the host
    learns about divergence lazily at its existing drain boundaries — zero
    new syncs on the step path.
    """
    health = state["health"]
    loss = metrics["loss"].astype(jnp.float32)
    finite = jnp.isfinite(loss)
    if "grad_norm" in metrics:
        finite = finite & jnp.isfinite(
            metrics["grad_norm"].astype(jnp.float32))
    mean, var = health["ema_mean"], health["ema_var"]
    if tcfg.spike_z:
        std = jnp.sqrt(jnp.maximum(var, 1e-6))
        warm = health["count"] >= SENTINEL_WARMUP
        spike = warm & (jnp.abs(loss - mean) > tcfg.spike_z * std)
        healthy = finite & ~spike
    else:
        healthy = finite
    # EMA advances on healthy steps only: a poisoned loss must never drag
    # the reference it is judged against (the where's untaken branch may
    # hold NaN — select drops it, nothing differentiates through this)
    decay = jnp.float32(0.9)
    dev = loss - mean
    new_health = {
        "ema_mean": jnp.where(healthy, decay * mean + (1 - decay) * loss,
                              mean),
        "ema_var": jnp.where(healthy, decay * var + (1 - decay) * dev * dev,
                             var),
        "count": jnp.where(healthy, health["count"] + 1, health["count"]),
        "bad_streak": jnp.where(healthy, jnp.int32(0),
                                health["bad_streak"] + 1),
    }
    fallback = dict(state, step=state["step"] + 1)
    if "graft" in state:
        fallback["graft"] = state["graft"]._replace(step=state["step"] + 1)
    fallback.pop("health")
    candidate = {k: v for k, v in new_state.items() if k != "health"}
    selected = jax.tree_util.tree_map(
        lambda n, f: jnp.where(healthy, n, f), candidate, fallback)
    selected["health"] = new_health
    # the step's own metrics keep their true (possibly non-finite) values —
    # telemetry should show WHAT was skipped, not hide it
    return selected, dict(metrics, healthy=healthy.astype(jnp.float32),
                          bad_streak=new_health["bad_streak"])


def make_train_step(mcfg, tcfg: TrainConfig, kind: Optional[str] = None):
    step = {None: graft_train_step if tcfg.use_graft else baseline_train_step,
            "graft": graft_train_step, "baseline": baseline_train_step,
            "subset": subset_train_step, "select": selection_step}[kind]
    use_sentinel = tcfg.sentinel and kind != "select"

    def fn(state, batch):
        new_state, metrics = step(mcfg, tcfg, state, batch)
        if use_sentinel and "health" in state:
            new_state, metrics = apply_sentinel(tcfg, state, new_state,
                                                metrics)
        return new_state, metrics
    return fn


def detach_metrics(metrics):
    """Fresh buffers for metric scalars (tiny async copies, no sync).

    A metric that PASSES THROUGH a donating jit untouched (e.g. the graft
    ``rank`` between refreshes) comes back aliased to the donated input
    buffer, which the NEXT step's donation recycles — a deferred read
    (``MetricsFuture`` drained at a flush boundary) would then hit a
    deleted array. The copies are enqueued before that next dispatch, so
    PjRt orders them ahead of the buffer reuse."""
    return {k: jnp.copy(v) if isinstance(v, jax.Array) else v
            for k, v in metrics.items()}


def make_run_step(mcfg, tcfg: TrainConfig, donate: bool = True):
    """Uniform host-callable ``(state, batch, step) → (state, metrics)`` —
    the one place that owns the jit/donation wiring for the training loop.

    Resolves to the :class:`~repro.selection.overlap.OverlappedSelector`
    when ``graft.overlap`` is set (refresh and subset-train as separate,
    pipelined dispatches), else a single donated jit of the sequential
    step. Either way the returned metrics are DEVICE scalars of a
    still-in-flight dispatch: callers that want the host to run ahead must
    not ``float()`` them per step (the ``Trainer`` wraps them in a
    ``MetricsFuture`` and drains at flush boundaries). Side computations
    on ``state['params']`` (eval, refresh) must follow the
    ``SideStream`` discipline: enqueue before the next call donates.
    """
    if tcfg.use_graft and tcfg.graft.overlap:
        from repro.selection.overlap import OverlappedSelector
        inner = OverlappedSelector(mcfg, tcfg, donate=donate).step
    else:
        jitted = jax.jit(make_train_step(mcfg, tcfg),
                         donate_argnums=(0,) if donate else ())

        def inner(state, batch, step):
            return jitted(state, batch)

    def run_step(state, batch, step):
        state, metrics = inner(state, batch, step)
        return state, detach_metrics(metrics)

    return run_step


# ---------------------------------------------------------------------------
# serve steps
# ---------------------------------------------------------------------------

def prefill_step(mcfg, params, batch, max_seq: int):
    return decode_lib.prefill(mcfg, params, batch, max_seq)


def decode_step(mcfg, params, cache, tokens):
    return decode_lib.decode_step(mcfg, params, cache, tokens)
