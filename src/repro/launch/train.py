"""Legacy flat-config training driver — now a thin deprecation shim over
``repro.api``.

``RunConfig`` and ``train(run)`` keep their exact signatures and report
shape, but the loop itself lives in ``repro.api.Trainer``: the flat
``RunConfig`` is translated to a declarative ``ExperimentConfig`` and every
behavior the monolithic loop hardwired (checkpointing, eval, JSONL
telemetry, straggler monitoring, preemption) is a ``Callback`` plugin.
New code should use ``repro.api`` directly::

    from repro.api import ExperimentConfig, Trainer
    report = Trainer(ExperimentConfig()).fit()
"""
from __future__ import annotations

import argparse
import dataclasses
import json
from typing import Any, Dict, Optional

from repro.api.config import (ExperimentConfig, GraftConfig,
                              ModelConfig as ApiModelConfig,
                              OptimizerConfig,
                              TrainConfig as ApiTrainConfig)


@dataclasses.dataclass
class RunConfig:
    arch: str = "minicpm-2b"
    smoke: bool = True
    steps: int = 100
    batch: int = 16
    seq: int = 64
    use_graft: bool = True
    sampler: str = "graft"              # any repro.selection registry name
    graft_rset: tuple = (2, 4, 8)
    graft_eps: float = 0.25
    graft_refresh: int = 5
    lr: float = 3e-4
    optimizer: str = "adamw"
    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 50
    log_every: int = 10
    seed: int = 0
    stop_after: Optional[int] = None    # simulate preemption after N steps
                                        # (schedule still spans ``steps``)
    metrics_path: Optional[str] = None  # JSONL telemetry stream
    eval_every: int = 0                 # 0 = no held-out evaluation


def to_experiment(run: RunConfig) -> ExperimentConfig:
    """Translate the flat legacy RunConfig into the declarative API config
    (exact semantics: the two drivers produce identical trajectories)."""
    graft = GraftConfig(rset=tuple(run.graft_rset), eps=run.graft_eps,
                        refresh_every=run.graft_refresh,
                        grad_mode="probe") if run.use_graft else None
    return ExperimentConfig(
        model=ApiModelConfig(arch=run.arch, smoke=run.smoke),
        train=ApiTrainConfig(
            steps=run.steps, batch=run.batch, seq=run.seq, seed=run.seed,
            sampler=run.sampler, probe_positions=min(64, run.seq),
            log_every=run.log_every, eval_every=run.eval_every,
            checkpoint_dir=run.checkpoint_dir,
            checkpoint_every=run.checkpoint_every,
            metrics_path=run.metrics_path, stop_after=run.stop_after),
        graft=graft,
        optimizer=OptimizerConfig(
            name=run.optimizer, learning_rate=run.lr, schedule="cosine",
            total_steps=run.steps, warmup_steps=max(run.steps // 20, 1)))


def build(run: RunConfig):
    """(deprecated) → (model config, step TrainConfig, data pipeline)."""
    return to_experiment(run).build()


def train(run: RunConfig, callbacks=None) -> Dict[str, Any]:
    """(deprecated) Train ``run`` via ``repro.api.Trainer``. ``callbacks``
    are legacy per-step functions ``fn(step, state, metrics)``."""
    from repro.api.callbacks import LegacyFunctionCallback
    from repro.api.trainer import Trainer
    extra = ([LegacyFunctionCallback(cb) for cb in callbacks]
             if callbacks else None)
    return Trainer(to_experiment(run), callbacks=extra).fit()


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minicpm-2b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--no-graft", action="store_true")
    ap.add_argument("--sampler", default="graft",
                    help="selection strategy (see repro.selection.available())")
    ap.add_argument("--full-config", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    run = RunConfig(arch=args.arch, smoke=not args.full_config,
                    steps=args.steps, batch=args.batch, seq=args.seq,
                    use_graft=not args.no_graft, sampler=args.sampler,
                    checkpoint_dir=args.ckpt_dir, seed=args.seed)
    report = train(run)
    print(json.dumps({k: v for k, v in report.items() if k != "history"},
                     indent=1))


if __name__ == "__main__":
    main()
