"""Fault-tolerant training driver with GRAFT integrated.

Runs for real on whatever devices exist (CPU tests / examples use the tiny
configs; on TPU the same loop drives the production mesh). Features:
auto-resume from the latest checkpoint, async + emergency checkpointing,
data-pipeline state in the manifest, straggler monitoring, GRAFT on/off.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs as config_lib
from repro.checkpoint import CheckpointManager, EmergencySaver
from repro.core.graft import GraftConfig
from repro.data import DataConfig, SyntheticLM
from repro.distributed import sharding as sh
from repro.distributed.straggler import StragglerMonitor
from repro.launch import steps as steps_lib
from repro.launch.evaluate import make_eval_fn
from repro.launch.mesh import make_host_mesh
from repro.launch.metrics import MetricsLogger, train_step_flops
from repro.optim import OptimizerConfig


@dataclasses.dataclass
class RunConfig:
    arch: str = "minicpm-2b"
    smoke: bool = True
    steps: int = 100
    batch: int = 16
    seq: int = 64
    use_graft: bool = True
    sampler: str = "graft"              # any repro.selection registry name
    graft_rset: tuple = (2, 4, 8)
    graft_eps: float = 0.25
    graft_refresh: int = 5
    lr: float = 3e-4
    optimizer: str = "adamw"
    checkpoint_dir: Optional[str] = None
    checkpoint_every: int = 50
    log_every: int = 10
    seed: int = 0
    stop_after: Optional[int] = None    # simulate preemption after N steps
                                        # (schedule still spans ``steps``)
    metrics_path: Optional[str] = None  # JSONL telemetry stream
    eval_every: int = 0                 # 0 = no held-out evaluation


def build(run: RunConfig):
    mcfg = (config_lib.get_smoke_config(run.arch) if run.smoke
            else config_lib.get_config(run.arch))
    graft = GraftConfig(rset=run.graft_rset, eps=run.graft_eps,
                        refresh_every=run.graft_refresh,
                        grad_mode="probe") if run.use_graft else None
    tcfg = steps_lib.TrainConfig(
        optimizer=OptimizerConfig(name=run.optimizer, learning_rate=run.lr,
                                  schedule="cosine", total_steps=run.steps,
                                  warmup_steps=max(run.steps // 20, 1)),
        graft=graft, sampler=run.sampler, probe_positions=min(64, run.seq))
    data = SyntheticLM(DataConfig(vocab_size=mcfg.vocab_size, seq_len=run.seq,
                                  global_batch=run.batch, seed=run.seed))
    return mcfg, tcfg, data


def train(run: RunConfig, callbacks=None) -> Dict[str, Any]:
    mcfg, tcfg, data = build(run)
    mesh = make_host_mesh()
    step_fn = steps_lib.make_train_step(mcfg, tcfg)
    jitted = jax.jit(step_fn, donate_argnums=(0,))

    ckpt = (CheckpointManager(run.checkpoint_dir, keep_last_n=2, async_save=True)
            if run.checkpoint_dir else None)
    saver = EmergencySaver()
    monitor = StragglerMonitor()
    eval_fn = (make_eval_fn(mcfg, batch=min(run.batch, 8), seq=run.seq,
                            seed=run.seed) if run.eval_every else None)

    with sh.sharding_rules(mesh):
        state = steps_lib.init_train_state(
            mcfg, tcfg, jax.random.PRNGKey(run.seed), run.batch)
        start_step = 0
        if ckpt is not None and ckpt.latest_step() is not None:
            s = ckpt.latest_step()
            manifest = ckpt.manifest(s)
            state = ckpt.restore(s, state)
            data.load_state_dict(manifest["extra"]["data"])
            start_step = int(manifest["extra"]["train_step"])
            print(f"[train] resumed from step {start_step}")

        n_params = sum(int(np.prod(l.shape)) for l in
                       jax.tree_util.tree_leaves(state["params"]))
        logger = MetricsLogger(
            run.metrics_path, num_chips=len(jax.devices()),
            flops_per_step=train_step_flops(
                n_params, run.batch * run.seq, remat=mcfg.remat != "none"))
        history = []
        it = iter(data)
        # fast-forward the iterator to the checkpointed step
        data.load_state_dict({"step": start_step})
        t_start = time.time()
        for step in range(start_step, run.steps):
            batch_np = next(it)
            batch = {k: jnp.asarray(v) for k, v in batch_np.items()}
            t0 = time.time()
            state, metrics = jitted(state, batch)
            metrics = {k: float(v) for k, v in metrics.items()}
            dt = time.time() - t0
            monitor.record(dt)
            logger.log(step, metrics, tokens=run.batch * run.seq)
            if eval_fn is not None and (step + 1) % run.eval_every == 0:
                metrics.update(eval_fn(state["params"]))
            history.append(metrics)
            if callbacks:
                for cb in callbacks:
                    cb(step, state, metrics)
            if step % run.log_every == 0:
                extra = (f" rank={metrics.get('rank', 0):.0f}"
                         f" align={metrics.get('alignment', 0):.3f}"
                         if tcfg.use_graft else "")
                print(f"[train] step {step:5d} loss {metrics['loss']:.4f} "
                      f"gnorm {metrics['grad_norm']:.3f} {dt*1e3:.0f}ms{extra}",
                      flush=True)
            stop = saver.should_stop or (
                run.stop_after is not None and step + 1 >= run.stop_after)
            if ckpt is not None and (
                    (step + 1) % run.checkpoint_every == 0 or stop or
                    step + 1 == run.steps):
                ckpt.save(step + 1, state,
                          extra={"train_step": step + 1,
                                 "data": data.state_dict(),
                                 "metrics": metrics})
            if stop:
                print("[train] emergency checkpoint written — exiting")
                break
        if ckpt is not None:
            ckpt.wait()
        logger.close()
    wall = time.time() - t_start
    report = {"final_loss": history[-1]["loss"] if history else None,
              "history": history, "wall_s": wall,
              "straggler": monitor.summary()}
    return report


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minicpm-2b")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--no-graft", action="store_true")
    ap.add_argument("--sampler", default="graft",
                    help="selection strategy (see repro.selection.available())")
    ap.add_argument("--full-config", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    run = RunConfig(arch=args.arch, smoke=not args.full_config,
                    steps=args.steps, batch=args.batch, seq=args.seq,
                    use_graft=not args.no_graft, sampler=args.sampler,
                    checkpoint_dir=args.ckpt_dir, seed=args.seed)
    report = train(run)
    print(json.dumps({k: v for k, v in report.items() if k != "history"},
                     indent=1))


if __name__ == "__main__":
    main()
