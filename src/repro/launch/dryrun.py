import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=" +
                           os.environ.get("REPRO_DRYRUN_DEVICES", "512")).strip()
# ^ MUST precede any jax import: jax locks the device count on first init.
#
# Multi-pod dry-run: lower + compile every (arch × input-shape) cell on the
# production meshes with ShapeDtypeStruct stand-ins (zero allocation), print
# memory_analysis (fits 16 GB/chip?) and cost_analysis (roofline terms), and
# parse collective bytes from the compiled HLO. Results are cached per cell
# as JSON under experiments/dryrun/ so the sweep is resumable.
#
# Scan-over-layers keeps compiles O(1) in depth but XLA cost_analysis counts
# the loop body ONCE — so FLOPs/bytes/collective-bytes are measured via an
# L=p vs L=2p UNROLLED delta (p = layer-pattern period) and scaled to the
# full depth: total = c(p) + (L-p)/p · [c(2p) - c(p)]. memory_analysis comes
# from the real full-depth scan compile. See EXPERIMENTS.md §Dry-run.
import argparse
import json
import re
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax

from repro import configs as config_lib
from repro.compat import cost_analysis_dict
from repro.distributed import sharding as sh
from repro.launch import specs as specs_lib
from repro.launch.mesh import make_mesh, make_production_mesh

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
                "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "c128": 16}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def parse_collective_bytes(hlo_text: str) -> Dict[str, Any]:
    """Sum operand bytes of every collective op in the (optimized) HLO."""
    totals: Dict[str, int] = {c: 0 for c in _COLLECTIVES}
    counts: Dict[str, int] = {c: 0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        if "=" not in s:
            continue
        rhs = s.split("=", 1)[1]
        op = None
        for c in _COLLECTIVES:
            # match ` all-reduce(` / `all-reduce-start(` but not fused names
            if re.search(rf"\b{c}(-start)?\(", rhs):
                op = c
                break
        if op is None:
            continue
        # operand shapes = shape literals inside the call parens
        inner = rhs.split("(", 1)[1]
        nbytes = sum(_shape_bytes(d, dims) for d, dims in _SHAPE_RE.findall(inner))
        if nbytes == 0:
            # older syntax: operands without inline shapes — fall back to
            # the result shape (lhs)
            lhs = s.split("=", 1)[0]
            nbytes = sum(_shape_bytes(d, dims) for d, dims in _SHAPE_RE.findall(rhs.split("(")[0]))
        totals[op] += nbytes
        counts[op] += 1
    return {"bytes_by_op": totals, "count_by_op": counts,
            "total_bytes": sum(totals.values()),
            "total_count": sum(counts.values())}


def _memory_dict(compiled) -> Dict[str, Any]:
    out: Dict[str, Any] = {}
    try:
        ma = compiled.memory_analysis()
        for attr in ("generated_code_size_in_bytes", "argument_size_in_bytes",
                     "output_size_in_bytes", "temp_size_in_bytes",
                     "alias_size_in_bytes", "host_generated_code_size_in_bytes",
                     "host_argument_size_in_bytes", "host_output_size_in_bytes",
                     "host_temp_size_in_bytes", "host_alias_size_in_bytes"):
            if hasattr(ma, attr):
                out[attr] = int(getattr(ma, attr))
        out["repr"] = str(ma)
    except Exception as e:                                    # pragma: no cover
        out["error"] = repr(e)
    return out


def _shardings_for(cell, mesh, rules):
    def one(abstract, logical):
        if isinstance(logical, tuple) and all(
                isinstance(e, (str, type(None))) for e in logical):
            spec = sh.logical_to_spec(logical, mesh, dict(sh.DEFAULT_RULES, **rules))
            spec = sh.drop_indivisible(spec, abstract.shape, mesh)
            return jax.sharding.NamedSharding(mesh, spec)
        return jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())

    return tuple(
        jax.tree_util.tree_map(one, aa, lg,
                               is_leaf=lambda x: isinstance(x, tuple) and all(
                                   isinstance(e, (str, type(None))) for e in x))
        for aa, lg in zip(cell.abstract_args, cell.arg_logical))


def compile_cell(cell: specs_lib.Cell, mesh) -> Dict[str, Any]:
    rules = cell.rules
    in_shardings = _shardings_for(cell, mesh, rules)
    with sh.sharding_rules(mesh, rules), mesh:
        jitted = jax.jit(cell.step_fn, in_shardings=in_shardings,
                         donate_argnums=cell.donate)
        t0 = time.time()
        lowered = jitted.lower(*cell.abstract_args)
        t1 = time.time()
        compiled = lowered.compile()
        t2 = time.time()
    cost = cost_analysis_dict(compiled)
    hlo = compiled.as_text()
    coll = parse_collective_bytes(hlo)
    result = {
        "flops": float(cost.get("flops", 0.0)),
        "bytes_accessed": float(cost.get("bytes accessed", 0.0)),
        "cost_analysis_keys": sorted(cost)[:40],
        "collectives": coll,
        "memory": _memory_dict(compiled),
        "lower_s": round(t1 - t0, 2),
        "compile_s": round(t2 - t1, 2),
        "hlo_ops": hlo.count("\n"),
    }
    del compiled, lowered, hlo
    return result


def run_cell(arch: str, shape: str, mesh_kind: str, variant: str,
             with_deltas: bool = True, smoke: bool = False,
             mesh_override=None, rules_preset: str = "default",
             feature_mode: str = "svd", grad_mode: str = "probe",
             data_source: str = "synthetic_lm") -> Dict[str, Any]:
    cfg = config_lib.get_config(arch)
    period = max(len(cfg.layer_pattern), 1) if cfg.layer_pattern else 1
    if cfg.global_layer_indices:
        period = 1            # pattern handled via indices; uniform enough
    period = max(period, 1)
    p1 = period + cfg.first_k_dense
    p2 = 2 * period + cfg.first_k_dense

    if mesh_override is not None:
        mesh = mesh_override
    else:
        mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))

    rule_overrides = dict(specs_lib.RULE_PRESETS[rules_preset])
    sel_modes = {"feature_mode": feature_mode, "grad_mode": grad_mode}
    if shape.startswith("train"):
        # task workloads only exist for train cells (serve stays LM-shaped)
        sel_modes["data_source"] = data_source
    out: Dict[str, Any] = {
        "arch": arch, "shape": shape, "mesh": mesh_kind,
        "mesh_shape": dict(zip(mesh.axis_names, mesh.devices.shape)),
        "variant": variant, "smoke": smoke, "rules_preset": rules_preset,
        "num_layers": cfg.num_layers, "period": period, **sel_modes,
    }

    # 1) full-depth scan compile — THE dry-run artifact (memory + success)
    cell = specs_lib.build_cell(arch, shape, variant=variant, smoke=smoke,
                                rule_overrides=rule_overrides, **sel_modes)
    out["full"] = compile_cell(cell, mesh)

    # 2) unrolled L=p / L=2p compiles — roofline cost deltas (exact_cost:
    #    chunked attention/CE disabled so no lax.scan hides FLOPs)
    if with_deltas:
        cell1 = specs_lib.build_cell(arch, shape, variant=variant,
                                     num_layers_override=p1,
                                     scan_override=False, smoke=smoke,
                                     exact_cost=True,
                                     rule_overrides=rule_overrides,
                                     **sel_modes)
        cell2 = specs_lib.build_cell(arch, shape, variant=variant,
                                     num_layers_override=p2,
                                     scan_override=False, smoke=smoke,
                                     exact_cost=True,
                                     rule_overrides=rule_overrides,
                                     **sel_modes)
        c1 = compile_cell(cell1, mesh)
        c2 = compile_cell(cell2, mesh)
        out["unrolled_p1"] = c1
        out["unrolled_p2"] = c2
        L_scan = cfg.num_layers - cfg.first_k_dense
        reps = (L_scan - period) / period
        def scaled(key):
            return c1[key] + reps * (c2[key] - c1[key])
        out["scaled"] = {
            "flops": scaled("flops"),
            "bytes_accessed": scaled("bytes_accessed"),
            "collective_bytes": (c1["collectives"]["total_bytes"] + reps *
                                 (c2["collectives"]["total_bytes"] -
                                  c1["collectives"]["total_bytes"])),
        }
    return out


def result_path(arch: str, shape: str, mesh_kind: str, variant: str) -> str:
    os.makedirs(OUT_DIR, exist_ok=True)
    return os.path.join(
        OUT_DIR, f"{arch}__{shape}__{mesh_kind}__{variant}.json")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description="multi-pod dry-run")
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(specs_lib.SHAPES))
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--variant", default=None,
                    help="train cells: graft|baseline (default: both)")
    ap.add_argument("--all", action="store_true", help="sweep every cell")
    ap.add_argument("--no-deltas", action="store_true",
                    help="skip the unrolled L1/L2 roofline compiles")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced configs (CI)")
    ap.add_argument("--feature-mode", default="svd",
                    help="selection feature extractor for graft cells "
                         "(repro.selection.sources registry)")
    ap.add_argument("--grad-mode", default="probe",
                    help="selection gradient source for graft cells")
    ap.add_argument("--data-source", default="synthetic_lm",
                    help="task/data-source registry name for train cells "
                         "(repro.data.sources registry)")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args(argv)

    cells = []
    for arch, shape in specs_lib.all_cells():
        if args.arch and arch != args.arch:
            continue
        if args.shape and shape != args.shape:
            continue
        ok, why = specs_lib.cell_is_supported(arch, shape)
        if not ok:
            if args.list:
                print(f"SKIP {arch:24s} {shape:12s} — {why}")
            continue
        if shape == "train_4k":
            variants = [args.variant] if args.variant else ["graft", "baseline"]
        else:
            variants = ["serve"]
        for v in variants:
            cells.append((arch, shape, v))
    if args.list:
        for arch, shape, v in cells:
            print(f"CELL {arch:24s} {shape:12s} {v}")
        return 0
    if not cells:
        print("nothing to do")
        return 1

    failures = 0
    for arch, shape, v in cells:
        path = result_path(arch, shape, args.mesh, v)
        if args.skip_existing and os.path.exists(path):
            print(f"[cached] {arch} {shape} {args.mesh} {v}")
            continue
        print(f"[dryrun] {arch} {shape} {args.mesh} {v} ...", flush=True)
        t0 = time.time()
        try:
            res = run_cell(arch, shape, args.mesh,
                           "graft" if v == "graft" else
                           ("baseline" if v == "baseline" else "serve"),
                           with_deltas=not args.no_deltas, smoke=args.smoke,
                           feature_mode=args.feature_mode,
                           grad_mode=args.grad_mode,
                           data_source=args.data_source)
            res["ok"] = True
        except Exception:
            res = {"arch": arch, "shape": shape, "mesh": args.mesh,
                   "variant": v, "ok": False,
                   "error": traceback.format_exc()}
            failures += 1
            print(res["error"], file=sys.stderr)
        res["wall_s"] = round(time.time() - t0, 1)
        with open(path, "w") as f:
            json.dump(res, f, indent=1)
        status = "OK" if res.get("ok") else "FAIL"
        mem = res.get("full", {}).get("memory", {})
        print(f"  -> {status} in {res['wall_s']}s  "
              f"args={mem.get('argument_size_in_bytes', 0)/2**30:.2f}GiB "
              f"temp={mem.get('temp_size_in_bytes', 0)/2**30:.2f}GiB",
              flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
