"""Pluggable execution backends (see ``repro.backend.base``).

Importing this package registers both shipped backends; ``resolve`` turns
the tagged ``backend`` config section into a live :class:`Backend`.
"""
from repro.backend.base import (
    AllReduceSpec,
    Backend,
    BackendEntry,
    LocalBackendConfig,
    MultiProcessBackendConfig,
    available_backends,
    backend_name_of,
    entry_for_config,
    get_backend,
    register_backend,
    resolve,
)
from repro.backend.local import LocalBackend
from repro.backend.multiprocess import MultiProcessBackend

__all__ = [
    "AllReduceSpec",
    "Backend",
    "BackendEntry",
    "LocalBackend",
    "LocalBackendConfig",
    "MultiProcessBackend",
    "MultiProcessBackendConfig",
    "available_backends",
    "backend_name_of",
    "entry_for_config",
    "get_backend",
    "register_backend",
    "resolve",
]
