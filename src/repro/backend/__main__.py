"""``python -m repro.backend`` — real 2-process multihost smoke harness.

Spawns an actual ``jax.distributed`` CPU fleet (coordinator + worker, gloo
collectives) through the public ``python -m repro.api`` launcher and checks
the two invariants the backend subsystem promises:

  1. **Loss parity** — a 2-process run of a config tracks the
     single-process run of the SAME config step for step. (Not bit-exact:
     a different device count partitions the batch-axis reductions
     differently, so float sums reassociate — observed drift is ~1e-4 by
     step 5; the harness allows ``rtol=3e-3`` and additionally requires
     the FIRST step, whose reduction order coincides, to match tightly.)
  2. **Elastic resume** — the 2-process run's mid-run checkpoint resumes
     SINGLE-process via ``--resume`` alone (topology recorded in the
     manifest; restore reshards), and the post-resume losses track the
     uninterrupted single-process reference.

Exit code 0 = both hold. ``--json`` emits the measured losses for CI logs.
This is the CI ``multihost`` job's entry point; the same scenario runs as
a ``slow``-marked pytest in ``tests/test_backend.py``.
"""
from __future__ import annotations

import argparse
import json
import os
import socket
import subprocess
import sys
import tempfile
from typing import Dict, List


STEPS = 8
CKPT_EVERY = 4

BASE_OVERRIDES = [
    "--train.steps=8",
    "--train.batch=8",
    "--train.seq=16",
    "--train.log_every=0",
    "--train.checkpoint_every=4",
    "--train.metrics_flush_every=1",
    "--graft.refresh_every=2",
]


def _free_port() -> int:
    with socket.socket() as s:
        s.bind(("127.0.0.1", 0))
        return s.getsockname()[1]


def _run_api(args: List[str], env_extra: Dict[str, str] = None,
             timeout: int = 900) -> subprocess.CompletedProcess:
    env = dict(os.environ)
    env.setdefault("JAX_PLATFORMS", "cpu")
    if env_extra:
        env.update(env_extra)
    return subprocess.run([sys.executable, "-m", "repro.api"] + args,
                          capture_output=True, text=True, env=env,
                          timeout=timeout)


def _losses(metrics_path: str) -> Dict[int, float]:
    out: Dict[int, float] = {}
    with open(metrics_path) as f:
        for line in f:
            row = json.loads(line)
            if "loss" in row:
                out[int(row["step"])] = float(row["loss"])
    return out


def _fail(proc: subprocess.CompletedProcess, label: str) -> None:
    sys.stderr.write(f"--- {label} stdout ---\n{proc.stdout[-4000:]}\n"
                     f"--- {label} stderr ---\n{proc.stderr[-4000:]}\n")
    raise SystemExit(f"{label} exited {proc.returncode}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.backend",
                                 description=__doc__)
    ap.add_argument("--json", action="store_true",
                    help="emit measured losses as JSON")
    ap.add_argument("--workdir", default=None,
                    help="keep artifacts here instead of a temp dir")
    args = ap.parse_args(argv)

    work = args.workdir or tempfile.mkdtemp(prefix="multihost.")
    os.makedirs(work, exist_ok=True)
    import numpy as np

    # ---- phase 1: single-process reference -----------------------------
    ref_metrics = os.path.join(work, "ref.jsonl")
    ref_ckpt = os.path.join(work, "ref_ckpt")
    proc = _run_api(BASE_OVERRIDES + [
        f"--train.metrics_path={ref_metrics}",
        f"--train.checkpoint_dir={ref_ckpt}"])
    if proc.returncode != 0:
        _fail(proc, "reference")
    ref = _losses(ref_metrics)
    print(f"[multihost] reference losses: "
          f"{[round(ref[s], 5) for s in sorted(ref)]}")

    # ---- phase 2: 2-process jax.distributed run ------------------------
    port = _free_port()
    two_ckpt = os.path.join(work, "two_ckpt")
    metrics = {i: os.path.join(work, f"two.p{i}.jsonl") for i in (0, 1)}
    procs = {}
    for pid in (0, 1):
        cmd = BASE_OVERRIDES + [
            f"--train.metrics_path={metrics[pid]}",
            f"--train.checkpoint_dir={two_ckpt}",
            "--train.stop_after=4",            # leave room to resume
            "--backend.kind=multiprocess",
            f"--backend.coordinator=127.0.0.1:{port}",
            "--backend.num_processes=2",
            f"--backend.process_id={pid}",
        ]
        env = dict(os.environ)
        env.setdefault("JAX_PLATFORMS", "cpu")
        procs[pid] = subprocess.Popen(
            [sys.executable, "-m", "repro.api"] + cmd,
            stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True,
            env=env)
    for pid, p in procs.items():
        out, err = p.communicate(timeout=900)
        if p.returncode != 0:
            sys.stderr.write(f"--- 2proc rank {pid} stdout ---\n"
                             f"{out[-4000:]}\n--- stderr ---\n{err[-4000:]}\n")
            raise SystemExit(f"2-process rank {pid} exited {p.returncode}")
    two = _losses(metrics[0])
    print(f"[multihost] 2-process losses:  "
          f"{[round(two[s], 5) for s in sorted(two)]}")
    steps = sorted(two)
    assert steps, "2-process run produced no metrics"
    # first step's reduction order coincides → tight; later steps reassociate
    assert abs(two[steps[0]] - ref[steps[0]]) < 1e-5, \
        f"step {steps[0]}: {two[steps[0]]} vs {ref[steps[0]]}"
    for s in steps:
        assert np.isclose(two[s], ref[s], rtol=3e-3, atol=0), \
            f"loss parity broke at step {s}: 2proc {two[s]} vs ref {ref[s]}"
    print("[multihost] loss parity OK (2 processes == 1 process)")

    # ---- phase 3: elastic resume (2-process ckpt → 1 process) ----------
    proc = _run_api([f"--resume={two_ckpt}"])
    if proc.returncode != 0:
        _fail(proc, "elastic-resume")
    assert "resumed from step 4" in proc.stdout + proc.stderr, \
        "resume did not restore the 2-process checkpoint"
    # the report JSON is the last brace-opened block on stdout (restore
    # logs a topology dict earlier, so rindex, not index)
    report = json.loads(proc.stdout[proc.stdout.rindex("\n{") + 1:])
    final = float(report["final_loss"])
    ref_final = ref[max(ref)]
    assert np.isclose(final, ref_final, rtol=3e-3, atol=0), \
        f"post-resume final loss {final} vs reference {ref_final}"
    print(f"[multihost] elastic resume OK (2proc ckpt → 1 proc, "
          f"final {final:.5f} vs ref {ref_final:.5f})")

    if args.json:
        print(json.dumps({"reference": ref, "two_process": two,
                          "resume_final": final}, sort_keys=True))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
