"""The single-process backend — today's semantics, bit-identical.

Every primitive here is exactly what the pre-backend ``Trainer`` hardwired:
the mesh is ``launch.mesh.make_host_mesh()`` (all local devices, 1-D
``data`` axis), ``shard_batch`` is ``jnp.asarray`` per array, ``replicate``
is the identity, and there is no distributed runtime to bring up. The
bit-identity regression test (``tests/test_backend.py``) pins this against
a hand-rolled pre-backend loop — trajectory and graft pivots both.
"""
from __future__ import annotations

from typing import Any, Dict

import jax.numpy as jnp

from repro.backend import base
from repro.launch.mesh import make_host_mesh


class LocalBackend(base.Backend):
    name = "local"

    def _build_mesh(self):
        return make_host_mesh()

    def shard_batch(self, batch: Dict[str, Any]) -> Dict[str, Any]:
        return {k: jnp.asarray(v) for k, v in batch.items()}


def _build(cfg: base.LocalBackendConfig) -> LocalBackend:
    return LocalBackend(cfg)


LOCAL = base.register_backend(base.BackendEntry(
    "local", base.LocalBackendConfig, _build))
