"""Pluggable execution backends — how a ``Trainer`` touches devices.

A :class:`Backend` owns every process/device decision the training loop
used to hardwire: distributed runtime bring-up, mesh construction, host →
device batch staging, cross-process reduction, data-pipeline host sharding,
and the topology stamp that makes checkpoints elastically restorable.
The ``Trainer`` itself stays a pure step-dispatch loop — it asks the
backend, never ``jax`` directly (machine-enforced by lint rule LN004:
``jax.distributed.*`` / mesh construction / ``jax.process_index`` are
forbidden outside ``repro/backend/`` + ``launch/mesh.py``).

Two implementations ship:

  * :class:`~repro.backend.local.LocalBackend` — single process, all local
    devices as a 1-D ``data`` mesh. Bit-identical to the pre-backend
    trainer (its ``shard_batch`` is exactly ``jnp.asarray``).
  * :class:`~repro.backend.multiprocess.MultiProcessBackend` —
    ``jax.distributed.initialize`` over every participating process (gloo
    collectives on CPU), a global data mesh, per-process ``DataSource``
    shards keyed on ``process_index``, and global-array batch assembly.

The registry mirrors ``repro.data.sources``: each backend pairs a frozen
config dataclass (the tagged, hash-neutral ``backend`` section of an
``ExperimentConfig``) with a builder. ``--backend.kind=multiprocess`` swaps
the section; per-backend fields override on top.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

from repro.registry import Registry


class AllReduceSpec(NamedTuple):
    """How cross-process reductions run on this backend: the mesh axis they
    travel over and whether the int8 error-feedback compression
    (``repro.distributed.compression``) wraps them."""
    axis: str
    num_shards: int
    compressed: bool


# ---------------------------------------------------------------------------
# configs (the tagged ``backend`` section)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LocalBackendConfig:
    """Single-process execution on whatever devices exist (the default)."""


@dataclasses.dataclass(frozen=True)
class MultiProcessBackendConfig:
    """One process per host, joined via ``jax.distributed.initialize``.

    ``num_processes``/``process_id`` of 0/-1 mean "read the
    ``JAX_NUM_PROCESSES`` / ``JAX_PROCESS_ID`` environment" — the launch
    recipe sets them per worker so one config file serves every rank.
    """
    coordinator: str = "127.0.0.1:12321"
    num_processes: int = 0              # 0 = $JAX_NUM_PROCESSES
    process_id: int = -1                # -1 = $JAX_PROCESS_ID
    compress_reduce: bool = False       # int8 error-feedback on all_reduce
    prefetch: int = 0                   # BatchStager lookahead depth


# ---------------------------------------------------------------------------
# protocol
# ---------------------------------------------------------------------------

class Backend:
    """Execution-strategy protocol. Subclasses override the device-touching
    primitives; everything here is the single-process default so a new
    backend only implements what it changes."""

    name: str = "abstract"

    def __init__(self, config: Any):
        self.config = config
        self._mesh = None

    # -------------------------------- lifecycle -----------------------------
    def setup(self) -> None:
        """Bring up the distributed runtime (before any device query)."""

    def teardown(self) -> None:
        """Release the distributed runtime (idempotent)."""

    # -------------------------------- topology ------------------------------
    @property
    def process_index(self) -> int:
        return 0

    @property
    def process_count(self) -> int:
        return 1

    def device_count(self) -> int:
        import jax
        return len(jax.devices())       # lint: allow — backend owns devices

    def local_device_count(self) -> int:
        return self.device_count()

    @property
    def is_primary(self) -> bool:
        """The one process that writes checkpoints/telemetry files."""
        return self.process_index == 0

    def data_shard(self) -> Tuple[int, int]:
        """``(num_hosts, host_index)`` for the data pipeline — which slice
        of every global batch this process generates."""
        return self.process_count, self.process_index

    def topology(self) -> Dict[str, Any]:
        """The manifest stamp that makes checkpoints elastic: enough to
        detect a mismatched restore and to decide a reshard is safe."""
        return {"process_count": self.process_count,
                "device_count": self.device_count(),
                "shard_layout": "replicated"}

    # -------------------------------- devices -------------------------------
    def mesh(self):
        """The backend's mesh (cached — construction queries devices)."""
        if self._mesh is None:
            self._mesh = self._build_mesh()
        return self._mesh

    def _build_mesh(self):
        raise NotImplementedError

    def shard_batch(self, batch: Dict[str, Any]) -> Dict[str, Any]:
        """Host-local numpy batch → device arrays the step function can
        consume (global arrays on multi-process backends)."""
        raise NotImplementedError

    def device_put(self, arr):
        """One host array → a device array replicated the way this backend
        replicates train state (the elastic-restore leaf primitive)."""
        import jax
        return jax.device_put(arr)

    def replicate(self, tree):
        """Train-state tree → this backend's resident form. The local
        backend is the identity (bit-identical to the pre-backend loop)."""
        return tree

    def to_host(self, tree):
        """Device tree → host numpy (the checkpoint gather). Must work for
        every array the backend produces, addressable or not."""
        import jax
        import numpy as np
        return jax.tree_util.tree_map(np.asarray, tree)

    # ------------------------------ collectives -----------------------------
    def all_reduce_spec(self) -> AllReduceSpec:
        return AllReduceSpec(axis="data", num_shards=self.process_count,
                             compressed=False)

    def all_reduce(self, tree):
        """Cross-process mean of host-side values (identity when single
        process). Multi-process backends route this over the global mesh —
        optionally through the int8 error-feedback compressed reduce."""
        return tree

    def check_consistent(self, tag: str) -> None:
        """Fail loudly when the participating processes disagree on
        ``tag`` (config-hash divergence = silent corruption later)."""

    # -------------------------------- staging -------------------------------
    @property
    def staging_depth(self) -> int:
        """BatchStager lookahead (0 = stage inline, bit-identical order)."""
        return 0


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BackendEntry:
    name: str
    config_cls: type
    build: Callable[[Any], Backend]


_BACKENDS: Registry = Registry("execution backend")


def register_backend(entry: BackendEntry, *,
                     overwrite: bool = False) -> BackendEntry:
    for other in _BACKENDS.values():
        if other.name != entry.name and other.config_cls is entry.config_cls:
            raise ValueError(
                f"config class {entry.config_cls.__name__} already tags "
                f"backend '{other.name}' — one config class per backend")
    return _BACKENDS.register(entry.name, entry, overwrite=overwrite)


def get_backend(name: str) -> BackendEntry:
    return _BACKENDS.get(name)


def available_backends() -> Tuple[str, ...]:
    return _BACKENDS.available()


def entry_for_config(bcfg: Any) -> BackendEntry:
    for entry in _BACKENDS.values():
        if type(bcfg) is entry.config_cls:
            return entry
    raise KeyError(f"no registered backend owns config type "
                   f"{type(bcfg).__name__} (available: "
                   f"{available_backends()})")


def backend_name_of(bcfg: Any) -> str:
    return entry_for_config(bcfg).name


def resolve(bcfg: Optional[Any]) -> Backend:
    """Backend-config section → live ``Backend`` (``None`` = local)."""
    if bcfg is None:
        bcfg = LocalBackendConfig()
    if isinstance(bcfg, Backend):       # tests hand a pre-built backend in
        return bcfg
    return entry_for_config(bcfg).build(bcfg)
