"""Multi-process execution: one JAX process per host, one global mesh.

Bring-up recipe (the part that is easy to get wrong on CPU): the gloo
collectives implementation must be selected BEFORE
``jax.distributed.initialize`` — the default CPU backend cannot run
multi-process computations at all. After initialize, ``jax.devices()``
returns the GLOBAL device list and the mesh spans every process.

Data flows per-process: each rank builds a ``DataSource`` shard keyed on
``process_index`` (the per-global-example seeding in ``repro.data`` makes
the union byte-identical to a single-host run), and ``shard_batch``
stitches the host-local shards into global arrays laid out along the
``data`` axis via ``assemble_global_batch``. Train state is replicated
everywhere; carry/rank stats reduce over the same mesh axis the step
function already psums over, optionally through the int8 error-feedback
compressed reduce.
"""
from __future__ import annotations

import os
from typing import Any, Dict

import numpy as np

from repro.backend import base


def _env_int(name: str, default: int) -> int:
    raw = os.environ.get(name, "")
    return int(raw) if raw else default


class MultiProcessBackend(base.Backend):
    name = "multiprocess"

    def __init__(self, config: base.MultiProcessBackendConfig):
        super().__init__(config)
        self._initialized = False
        # error-feedback accumulators for the compressed reduce, keyed by
        # leaf position (reset when the reduced tree changes shape)
        self._ef_errors = None

    # -------------------------------- lifecycle -----------------------------
    def setup(self) -> None:
        import jax

        num = self.config.num_processes or _env_int("JAX_NUM_PROCESSES", 0)
        pid = self.config.process_id
        if pid < 0:
            pid = _env_int("JAX_PROCESS_ID", -1)
        if num <= 0 or pid < 0:
            raise ValueError(
                "multiprocess backend needs num_processes>=1 and process_id "
                ">=0 — set backend.num_processes/backend.process_id or the "
                "JAX_NUM_PROCESSES/JAX_PROCESS_ID environment variables")
        # the default CPU collectives cannot run multi-process programs;
        # must be set BEFORE initialize — and nothing here may query devices
        # first (jax.devices()/default_backend() would freeze a
        # single-process runtime before the fleet forms)
        jax.config.update("jax_cpu_collectives_implementation", "gloo")
        jax.distributed.initialize(
            coordinator_address=self.config.coordinator,
            num_processes=num,
            process_id=pid)
        self._initialized = True

    def teardown(self) -> None:
        if not self._initialized:
            return
        import jax
        try:
            jax.distributed.shutdown()
        finally:
            self._initialized = False

    # -------------------------------- topology ------------------------------
    @property
    def process_index(self) -> int:
        import jax
        return jax.process_index()

    @property
    def process_count(self) -> int:
        import jax
        return jax.process_count()

    def local_device_count(self) -> int:
        import jax
        return jax.local_device_count()

    # -------------------------------- devices -------------------------------
    def _build_mesh(self):
        import jax
        return jax.make_mesh((self.device_count(),), ("data",))

    def shard_batch(self, batch: Dict[str, Any]) -> Dict[str, Any]:
        from repro.distributed.pipeline import assemble_global_batch
        return assemble_global_batch(self.mesh(), batch, axis="data")

    def device_put(self, arr):
        import jax
        from jax.sharding import NamedSharding, PartitionSpec as P
        return jax.make_array_from_process_local_data(
            NamedSharding(self.mesh(), P()), np.asarray(arr))

    def replicate(self, tree):
        import jax
        return jax.tree_util.tree_map(self.device_put, tree)

    def to_host(self, tree):
        import jax
        from jax.experimental import multihost_utils

        def gather(leaf):
            if hasattr(leaf, "is_fully_addressable") and \
                    not leaf.is_fully_addressable:
                shards = getattr(leaf, "addressable_shards", None)
                if shards and tuple(shards[0].data.shape) == \
                        tuple(leaf.shape):
                    # replicated: this process's shard IS the global array
                    return np.asarray(shards[0].data)
                return np.asarray(
                    multihost_utils.process_allgather(leaf, tiled=True))
            return np.asarray(leaf)

        return jax.tree_util.tree_map(gather, tree)

    # ------------------------------ collectives -----------------------------
    def all_reduce_spec(self) -> base.AllReduceSpec:
        return base.AllReduceSpec(axis="data",
                                  num_shards=self.process_count,
                                  compressed=self.config.compress_reduce)

    def all_reduce(self, tree):
        """Cross-process mean of host-side scalars/arrays (metrics, rank
        stats). Routed through the global mesh so every rank agrees;
        ``compress_reduce`` swaps the f32 psum for the int8 error-feedback
        reduce from ``repro.distributed.compression`` (per-call rounding
        carried in host-side accumulators, cancels over calls)."""
        import jax
        import jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.compat import shard_map

        mesh = self.mesh()
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        arrs = [self.device_put(np.asarray(l, dtype=np.float32))
                for l in leaves]
        spec = tuple(P() for _ in arrs)

        if self.config.compress_reduce:
            from repro.distributed.compression import ef_compressed_psum
            nshards = mesh.shape["data"]
            if (self._ef_errors is None
                    or len(self._ef_errors) != len(arrs)
                    or any(e.shape != np.shape(l)
                           for e, l in zip(self._ef_errors, leaves))):
                self._ef_errors = [
                    np.zeros(np.shape(l), np.float32) for l in leaves]
            errs = [self.device_put(e) for e in self._ef_errors]

            def reduce_all(*args):
                xs, es = args[:len(arrs)], args[len(arrs):]
                outs = [ef_compressed_psum(x, e, "data", nshards)
                        for x, e in zip(xs, es)]
                return tuple(o[0] for o in outs) + tuple(o[1] for o in outs)

            fn = shard_map(reduce_all, mesh=mesh,
                           in_specs=spec + spec, out_specs=spec + spec)
            out = fn(*(tuple(arrs) + tuple(errs)))
            reduced, new_errs = out[:len(arrs)], out[len(arrs):]
            self._ef_errors = [np.asarray(self.to_host(e)).reshape(
                np.shape(l)) for e, l in zip(new_errs, leaves)]
        else:
            def mean_all(*xs):
                # values are replicated — psum over the axis then
                # renormalize by shard count gives the cross-process mean
                # of per-process values
                n = jax.lax.psum(jnp.ones(()), "data")
                return tuple(jax.lax.psum(x, "data") / n for x in xs)

            fn = shard_map(mean_all, mesh=mesh, in_specs=spec,
                           out_specs=spec)
            reduced = fn(*arrs)

        host = [np.asarray(self.to_host(o)) for o in reduced]
        return jax.tree_util.tree_unflatten(
            treedef, [h.reshape(np.shape(l)) for h, l in zip(host, leaves)])

    def check_consistent(self, tag: str) -> None:
        """All processes must agree on ``tag`` (e.g. the config hash) —
        divergence now is silent state corruption later."""
        import hashlib
        from jax.experimental import multihost_utils
        # NOT Python hash() — that's salted per process (PYTHONHASHSEED)
        word = int.from_bytes(hashlib.sha256(tag.encode()).digest()[:8],
                              "big", signed=True)
        digest = np.asarray([word], dtype=np.int64)
        gathered = np.asarray(multihost_utils.process_allgather(digest))
        if not (gathered == gathered.reshape(-1)[0]).all():
            raise RuntimeError(
                f"processes disagree on '{tag[:32]}…' — every rank must "
                "launch with an identical experiment config")

    # -------------------------------- staging -------------------------------
    @property
    def staging_depth(self) -> int:
        return self.config.prefetch


def _build(cfg: base.MultiProcessBackendConfig) -> MultiProcessBackend:
    return MultiProcessBackend(cfg)


MULTIPROCESS = base.register_backend(base.BackendEntry(
    "multiprocess", base.MultiProcessBackendConfig, _build))
