"""Small shims over jax APIs that moved between releases.

Everything here must work on the pinned CI version (jax 0.4.x) AND on
newer releases, so call sites never branch on ``jax.__version__``.
"""
from __future__ import annotations

from typing import Any, Dict

try:                                        # jax >= 0.6: top-level export
    from jax import shard_map               # type: ignore[attr-defined]
except ImportError:                         # jax 0.4.x
    from jax.experimental.shard_map import shard_map  # noqa: F401

try:                                        # jax >= 0.5: varying-axis marker
    from jax.lax import pvary               # type: ignore[attr-defined]
except ImportError:
    def pvary(x, axis_names):               # 0.4.x has no vma tracking:
        del axis_names                      # every value is already treated
        return x                            # as device-varying inside shard_map


def cost_analysis_dict(compiled) -> Dict[str, Any]:
    """``Compiled.cost_analysis()`` as a flat dict.

    jax 0.4.x returns a single-element list of dicts (one per partition);
    newer releases return the dict directly. ``None`` (backend without cost
    analysis) becomes ``{}``.
    """
    cost = compiled.cost_analysis()
    if cost is None:
        return {}
    if isinstance(cost, (list, tuple)):
        return dict(cost[0]) if cost else {}
    return dict(cost)
