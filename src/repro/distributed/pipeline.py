"""Experimental GPipe-style pipeline parallelism over the "pod" axis.

DESIGN.md §5 maps the 2-pod production mesh's pod axis to data parallelism
(batch 256 ≥ 512 chips makes DP strictly better than a 2-stage pipeline's
bubble). This module exists for >2-pod deployments where DP batch runs out:
a shard_map+ppermute GPipe executor with the standard (S + M − 1)/M bubble.

Mechanics: layers are partitioned into S contiguous stages (one per pod);
each pipeline tick every stage applies its layers to its resident
microbatch, then activations rotate one stage forward via
``jax.lax.ppermute``. After S + M − 1 ticks all M microbatches have passed
through all S stages. Stage-local layer weights never move.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.compat import pvary, shard_map


def pipeline_forward(layer_fn: Callable, stage_params, x_micro: jax.Array,
                     mesh: Mesh, axis: str = "pod") -> jax.Array:
    """Run M microbatches through S pipeline stages.

    layer_fn(params, x) -> x          one stage's computation
    stage_params: pytree with leading (S,) stage axis, sharded over ``axis``
    x_micro: (M, mb, ...) microbatches (replicated; stage 0 consumes them)
    Returns (M, mb, ...) outputs as produced by the last stage.
    """
    S = mesh.shape[axis]
    M = x_micro.shape[0]
    ticks = S + M - 1

    def per_stage(params_s, x_all):
        # params_s: this stage's params (leading axis stripped by shard_map)
        stage = jax.lax.axis_index(axis)
        p_local = jax.tree_util.tree_map(lambda a: a[0], params_s)
        # carries are device-varying (they hold per-stage state) — mark them
        buf = pvary(jnp.zeros_like(x_all[0]), (axis,))    # (mb, …)
        outs = pvary(jnp.zeros_like(x_all), (axis,))      # (M, mb, …)

        def tick(t, carry):
            buf, outs = carry
            # stage 0 ingests microbatch t (if any remain)
            feed = x_all[jnp.clip(t, 0, M - 1)]
            buf = jnp.where(stage == 0,
                            jnp.where(t < M, feed, jnp.zeros_like(feed)), buf)
            buf = layer_fn(p_local, buf)
            # last stage emits microbatch index t - (S - 1); masked update
            # (a lax.cond would mix varying/invariant manual axes)
            out_idx = t - (S - 1)
            emit = jnp.logical_and(stage == S - 1, out_idx >= 0)
            idx = jnp.clip(out_idx, 0, M - 1)
            outs = outs.at[idx].set(jnp.where(emit, buf, outs[idx]))
            # rotate activations forward one stage
            buf = jax.lax.ppermute(
                buf, axis, [(i, (i + 1) % S) for i in range(S)])
            return buf, outs

        _, outs = jax.lax.fori_loop(0, ticks, tick, (buf, outs))
        # only the last stage holds real outputs; psum broadcasts them so
        # every shard returns the identical (replicated) result
        outs = jnp.where(stage == S - 1, outs, jnp.zeros_like(outs))
        return jax.lax.psum(outs, axis)

    specs_params = jax.tree_util.tree_map(lambda _: P(axis), stage_params)
    fn = shard_map(per_stage, mesh=mesh,
                   in_specs=(specs_params, P()),
                   out_specs=P())
    return fn(stage_params, x_micro)


def pipeline_bubble_fraction(num_stages: int, num_micro: int) -> float:
    """Idle fraction of the GPipe schedule: (S−1)/(S+M−1)."""
    return (num_stages - 1) / (num_stages + num_micro - 1)
