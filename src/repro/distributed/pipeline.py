"""Cross-process batch staging + experimental GPipe pipeline parallelism.

Two collaborators of the execution-backend subsystem (``repro.backend``):

  * **Batch staging** — ``assemble_global_batch`` turns each process's
    host-local batch shard into global arrays laid out along the mesh's
    data axis (the ``MultiProcessBackend.shard_batch`` primitive), and
    :class:`BatchStager` decouples the host→device transfer from the
    dispatch loop with optional lookahead, while keeping the data source's
    one-integer resumable state accounted to the batch actually CONSUMED
    (what checkpoints must record — a prefetched-but-unconsumed batch must
    replay after resume).
  * **GPipe executor** — DESIGN.md §5 maps the 2-pod production mesh's pod
    axis to data parallelism (batch 256 ≥ 512 chips makes DP strictly
    better than a 2-stage pipeline's bubble). ``pipeline_forward`` exists
    for >2-pod deployments where DP batch runs out: a shard_map+ppermute
    GPipe executor with the standard (S + M − 1)/M bubble.
"""
from __future__ import annotations

import collections
import concurrent.futures
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import pvary, shard_map


# ---------------------------------------------------------------------------
# cross-process batch staging (backend collaborator)
# ---------------------------------------------------------------------------

def assemble_global_batch(mesh: Mesh, batch: Dict[str, np.ndarray],
                          axis: str = "data") -> Dict[str, Any]:
    """Each process's host-local batch shard → global device arrays.

    The leading (batch) dimension of every array is laid out along the
    mesh's ``axis``; each process contributes only its own shard
    (``jax.make_array_from_process_local_data`` stitches the global view).
    Single-process meshes degrade to a plain sharded device_put."""
    out = {}
    for k, v in batch.items():
        v = np.asarray(v)
        spec = P(axis, *([None] * (v.ndim - 1)))
        out[k] = jax.make_array_from_process_local_data(
            NamedSharding(mesh, spec), v)
    return out


class BatchStager:
    """Stages host batches onto devices ahead of the dispatch loop.

    ``depth=0`` pulls + stages inline on ``next()`` — the order of
    operations is exactly the pre-stager loop (bit-identical). ``depth>=1``
    keeps that many batches pulled + staged ahead on a worker thread, so
    the host transfer of batch N+1 overlaps step N's dispatch.

    State accounting: ``consumed_state()`` is the data source's
    ``state_dict`` as of the last batch handed to the caller — lookahead
    pulls advance the live source, but a checkpoint written mid-stream must
    replay the staged-yet-unconsumed batches after resume. ``reset()``
    drops the lookahead after an external rewind (restore/rollback
    ``load_state_dict``) so stale staged batches never reach the loop.
    """

    def __init__(self, source, stage: Callable[[Dict[str, np.ndarray]], Any],
                 depth: int = 0):
        self.source = source
        self.stage = stage
        self.depth = depth
        self._it = iter(source)
        self._queue: collections.deque = collections.deque()
        self._consumed = source.state_dict()
        self._pool = (concurrent.futures.ThreadPoolExecutor(
            max_workers=1, thread_name_prefix="batch-stager")
            if depth > 0 else None)

    def _submit(self) -> None:
        host = next(self._it)
        state_after = self.source.state_dict()
        self._queue.append((self._pool.submit(self.stage, host), state_after))

    def __iter__(self):
        return self

    def __next__(self):
        if self.depth == 0:
            host = next(self._it)
            self._consumed = self.source.state_dict()
            return self.stage(host)
        while len(self._queue) < self.depth + 1:
            self._submit()
        fut, state_after = self._queue.popleft()
        self._consumed = state_after
        return fut.result()

    def consumed_state(self) -> Dict[str, int]:
        return dict(self._consumed)

    def reset(self) -> None:
        """Drop the lookahead after the source was rewound externally."""
        for fut, _ in self._queue:
            fut.cancel()
        self._queue.clear()
        self._consumed = self.source.state_dict()

    def close(self) -> None:
        self.reset()
        if self._pool is not None:
            self._pool.shutdown(wait=False)


def pipeline_forward(layer_fn: Callable, stage_params, x_micro: jax.Array,
                     mesh: Mesh, axis: str = "pod") -> jax.Array:
    """Run M microbatches through S pipeline stages.

    layer_fn(params, x) -> x          one stage's computation
    stage_params: pytree with leading (S,) stage axis, sharded over ``axis``
    x_micro: (M, mb, ...) microbatches (replicated; stage 0 consumes them)
    Returns (M, mb, ...) outputs as produced by the last stage.
    """
    S = mesh.shape[axis]
    M = x_micro.shape[0]
    ticks = S + M - 1

    def per_stage(params_s, x_all):
        # params_s: this stage's params (leading axis stripped by shard_map)
        stage = jax.lax.axis_index(axis)
        p_local = jax.tree_util.tree_map(lambda a: a[0], params_s)
        # carries are device-varying (they hold per-stage state) — mark them
        buf = pvary(jnp.zeros_like(x_all[0]), (axis,))    # (mb, …)
        outs = pvary(jnp.zeros_like(x_all), (axis,))      # (M, mb, …)

        def tick(t, carry):
            buf, outs = carry
            # stage 0 ingests microbatch t (if any remain)
            feed = x_all[jnp.clip(t, 0, M - 1)]
            buf = jnp.where(stage == 0,
                            jnp.where(t < M, feed, jnp.zeros_like(feed)), buf)
            buf = layer_fn(p_local, buf)
            # last stage emits microbatch index t - (S - 1); masked update
            # (a lax.cond would mix varying/invariant manual axes)
            out_idx = t - (S - 1)
            emit = jnp.logical_and(stage == S - 1, out_idx >= 0)
            idx = jnp.clip(out_idx, 0, M - 1)
            outs = outs.at[idx].set(jnp.where(emit, buf, outs[idx]))
            # rotate activations forward one stage
            buf = jax.lax.ppermute(
                buf, axis, [(i, (i + 1) % S) for i in range(S)])
            return buf, outs

        _, outs = jax.lax.fori_loop(0, ticks, tick, (buf, outs))
        # only the last stage holds real outputs; psum broadcasts them so
        # every shard returns the identical (replicated) result
        outs = jnp.where(stage == S - 1, outs, jnp.zeros_like(outs))
        return jax.lax.psum(outs, axis)

    specs_params = jax.tree_util.tree_map(lambda _: P(axis), stage_params)
    fn = shard_map(per_stage, mesh=mesh,
                   in_specs=(specs_params, P()),
                   out_specs=P())
    return fn(stage_params, x_micro)


def pipeline_bubble_fraction(num_stages: int, num_micro: int) -> float:
    """Idle fraction of the GPipe schedule: (S−1)/(S+M−1)."""
    return (num_stages - 1) / (num_stages + num_micro - 1)
