"""Distributed runtime: logical-axis sharding, gradient compression,
microbatching, pipeline-parallel experiments, straggler monitoring."""
