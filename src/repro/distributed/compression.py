"""Int8 error-feedback gradient compression for the slow inter-pod links.

Cross-pod ICI/DCN bandwidth is the scarcest resource in a multi-pod DP
setup. The pod-axis gradient all-reduce is compressed: per-block int8
quantization (absmax scaling) with an error-feedback accumulator so the
quantization bias cancels over steps (Seide et al. / EF-SGD) — convergence
is preserved while cross-pod bytes drop ~2× vs bf16 / ~4× vs f32.

Two entry points:
  * ``quantize_int8`` / ``dequantize_int8`` — pure ops (unit-tested bounds);
  * ``ef_compressed_psum`` — shard_map-ready: quantize(g + e) → int8 psum
    over ``axis`` → dequantize; updates the error state.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

PyTree = Any
_BLOCK = 256


def _pad_to_block(x: jax.Array) -> Tuple[jax.Array, int]:
    flat = x.reshape(-1).astype(jnp.float32)
    pad = (-flat.shape[0]) % _BLOCK
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), jnp.float32)])
    return flat, pad


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Per-block absmax int8 quantization. Returns (q int8 (N/B, B), scales)."""
    flat, _ = _pad_to_block(x)
    blocks = flat.reshape(-1, _BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale[:, 0]


def dequantize_int8(q: jax.Array, scale: jax.Array, shape, dtype) -> jax.Array:
    flat = (q.astype(jnp.float32) * scale[:, None]).reshape(-1)
    n = 1
    for d in shape:
        n *= d
    return flat[:n].reshape(shape).astype(dtype)


def quantization_error(x: jax.Array) -> jax.Array:
    q, s = quantize_int8(x)
    return x.astype(jnp.float32) - dequantize_int8(q, s, x.shape, jnp.float32)


def ef_compressed_psum(grad: jax.Array, error: jax.Array, axis: str,
                       num_shards: int) -> Tuple[jax.Array, jax.Array]:
    """Error-feedback compressed all-reduce over ``axis`` (inside shard_map).

    Protocol: (1) pmax agrees on a GLOBAL per-block scale (tiny f32
    collective), (2) every shard quantizes its EF-compensated gradient with
    that shared scale, (3) int32 psum of the int8 payload — the integer sum
    is exact under a shared scale, so the only residual is each shard's own
    rounding, which the error accumulator replays next step. Wire cost:
    int8 payload + 1/256 scale overhead (roofline charges ~¼ of f32 bytes).
    Returns (mean-reduced gradient f32, new error state).
    """
    compensated = grad.astype(jnp.float32) + error
    flat, _ = _pad_to_block(compensated)
    blocks = flat.reshape(-1, _BLOCK)
    scale_local = jnp.max(jnp.abs(blocks), axis=1) / 127.0 + 1e-12
    scale = jax.lax.pmax(scale_local, axis)                       # shared scale
    q = jnp.clip(jnp.round(blocks / scale[:, None]), -127, 127)
    local = dequantize_int8(q.astype(jnp.int8), scale,
                            grad.shape, jnp.float32)
    new_error = compensated - local
    summed = jax.lax.psum(q.astype(jnp.int32), axis)              # exact int sum
    flat_mean = (summed.astype(jnp.float32) *
                 scale[:, None] / num_shards).reshape(-1)
    n = 1
    for d in grad.shape:
        n *= d
    return flat_mean[:n].reshape(grad.shape), new_error


def ef_compressed_psum_tree(grads: PyTree, errors: PyTree, axis: str,
                            num_shards: int) -> Tuple[PyTree, PyTree]:
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree_util.tree_leaves(errors)
    outs = [ef_compressed_psum(g, e, axis, num_shards)
            for g, e in zip(flat_g, flat_e)]
    return (jax.tree_util.tree_unflatten(treedef, [o[0] for o in outs]),
            jax.tree_util.tree_unflatten(treedef, [o[1] for o in outs]))
