"""Microbatch gradient accumulation inside one jit step.

Scanning over microbatches bounds live activation memory to one microbatch
(the backward of the accumulation scan recomputes per-microbatch under the
remat policy) and defers the gradient psum to the final accumulate — under
pjit the cross-device reduce happens once per step, not per microbatch,
which is the compute/comm-overlap-friendly schedule.
"""
from __future__ import annotations

from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


def split_microbatches(batch: PyTree, num_micro: int) -> PyTree:
    """(B, ...) leaves → (num_micro, B/num_micro, ...)."""
    def re(x):
        b = x.shape[0]
        if b % num_micro:
            raise ValueError(f"batch {b} not divisible by {num_micro} microbatches")
        return x.reshape(num_micro, b // num_micro, *x.shape[1:])
    return jax.tree_util.tree_map(re, batch)


def accumulated_grads(loss_fn: Callable[[PyTree, PyTree], jax.Array],
                      params: PyTree, batch: PyTree, num_micro: int
                      ) -> Tuple[jax.Array, PyTree]:
    """Mean loss + mean grads over ``num_micro`` sequential microbatches."""
    micro = split_microbatches(batch, num_micro)

    def body(carry, mb):
        loss_sum, grad_sum = carry
        loss, grads = jax.value_and_grad(loss_fn)(params, mb)
        grad_sum = jax.tree_util.tree_map(
            lambda a, g: a + g.astype(a.dtype), grad_sum, grads)
        return (loss_sum + loss, grad_sum), None

    # accumulate in param dtype: an f32 accumulator for a 1T-param model is
    # 15.6 GB/chip — at ≤8 microbatches bf16 accumulation loses <0.5 ulp/step
    zero_grads = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32 if p.dtype == jnp.float32
                            else p.dtype), params)
    (loss_sum, grad_sum), _ = jax.lax.scan(
        body, (jnp.float32(0.0), zero_grads), micro)
    inv = 1.0 / num_micro
    grads = jax.tree_util.tree_map(
        lambda g, p: (g.astype(jnp.float32) * inv).astype(p.dtype),
        grad_sum, params)
    return loss_sum * inv, grads
