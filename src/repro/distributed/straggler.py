"""Straggler detection + mitigation hooks.

On a real multi-host pod, per-step wall time is the max over hosts — one
slow host (thermal throttle, faulty HBM, noisy neighbor) drags the fleet.
The monitor keeps a robust EMA of step times and flags outliers; the
GRAFT-specific mitigation (DESIGN.md §5) is to shrink the subset rank R on
flagged steps — selection gives the framework a *compute-elastic* knob that
plain training lacks: the coordinator broadcasts a reduced rank index and
every host deterministically trains on the first R' MaxVol pivots.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional


@dataclasses.dataclass
class StragglerConfig:
    ema_decay: float = 0.9
    threshold: float = 1.5          # step flagged if > threshold × EMA
    min_history: int = 5
    rank_backoff: float = 0.5       # shrink GRAFT rank to this fraction


class StragglerMonitor:
    def __init__(self, cfg: Optional[StragglerConfig] = None,
                 process_index: int = 0):
        self.cfg = cfg if cfg is not None else StragglerConfig()
        self.process_index = process_index
        self.ema: Optional[float] = None
        self.count = 0
        self.flagged: List[int] = []
        self._history: List[float] = []

    def record(self, step_time_s: float) -> bool:
        """Returns True if this step is a straggler."""
        self._history.append(step_time_s)
        is_straggler = False
        if self.ema is not None and self.count >= self.cfg.min_history:
            is_straggler = step_time_s > self.cfg.threshold * self.ema
        if is_straggler:
            self.flagged.append(self.count)
        else:
            # stragglers don't poison the EMA
            self.ema = (step_time_s if self.ema is None else
                        self.cfg.ema_decay * self.ema +
                        (1 - self.cfg.ema_decay) * step_time_s)
        self.count += 1
        return is_straggler

    def suggested_rank(self, current_rank: int, is_straggler: bool) -> int:
        """GRAFT mitigation: cut the subset size while degraded."""
        if not is_straggler:
            return current_rank
        return max(1, int(current_rank * self.cfg.rank_backoff))

    def summary(self) -> Dict[str, float]:
        return {
            "process_index": self.process_index,
            "steps": self.count,
            "flagged": len(self.flagged),
            "ema_s": self.ema or 0.0,
            "p50_s": (sorted(self._history)[len(self._history) // 2]
                      if self._history else 0.0),
            "max_s": max(self._history) if self._history else 0.0,
        }


def merge_summaries(summaries: List[Dict[str, float]]) -> Dict[str, float]:
    """Fleet view from per-process monitor summaries: the coordinator's
    mitigation decision keys on the WORST host, so attribute it."""
    if not summaries:
        return {"processes": 0, "flagged_total": 0, "worst_process": -1,
                "worst_ema_s": 0.0, "max_s": 0.0}
    worst = max(summaries, key=lambda s: s.get("ema_s", 0.0))
    return {
        "processes": len(summaries),
        "flagged_total": int(sum(s.get("flagged", 0) for s in summaries)),
        "worst_process": int(worst.get("process_index", 0)),
        "worst_ema_s": float(worst.get("ema_s", 0.0)),
        "max_s": float(max(s.get("max_s", 0.0) for s in summaries)),
    }
