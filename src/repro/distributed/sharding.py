"""Logical-axis sharding rules (MaxText-style) — the hillclimbing lever.

Model code annotates activations/params with LOGICAL axis names; a rule
table maps them to mesh axes. Swapping a rule re-shards the whole model
without touching model code. Rules are thread-local + context-managed so
the dry-run can sweep sharding variants.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Union[str, Tuple[str, ...], None]

# Default rules for the production meshes.
#   single-pod mesh axes: ("data", "model")
#   multi-pod mesh axes:  ("pod", "data", "model")
# "pod" appears in batch/dp rules only when present in the mesh (filtered).
DEFAULT_RULES: Dict[str, MeshAxes] = {
    # --- activation axes ---
    "act_batch": ("pod", "data"),      # DP over pod+data
    "act_seq": None,                    # seq inside attention/MLP (full per TP rank)
    "act_res_seq": "model",            # Megatron-style sequence parallelism on the
                                        # residual stream (scan carries shard 256-way)
    "act_q_seq": "model",              # query seq inside attention: seq-sharded
                                        # attention (scores S/16×T per device);
                                        # flip to None to restore head-TP attention
    "act_kv_seq": None,                 # KV-cache length (long-context override)
    "act_heads": "model",              # TP attention heads
    "act_kv_heads": None,               # GQA K/V replicated (small); set "model"
                                        # together with act_q_seq=None for head-TP
    "act_embed": None,
    "act_mlp": "model",
    "act_experts": "model",            # EP
    "act_mlp_inner": None,              # expert-FFN hidden dim (E already on model)
    "act_moe_groups": ("model", "pod", "data"),  # chunk-major MoE groups: the
                                        # (chunk, batch)-ordered group dim is
                                        # byte-identical to (batch:dp, seq:model)
    "act_moe_dispatch": ("pod", "data"),  # expert-buffer token dim (G) when the
                                        # model axis is spent on experts
    "act_vocab": "model",
    # --- parameter axes ---
    "embed": "data",                   # FSDP: shard the d_model dim of weights
    "heads": "model",
    "kv_heads": "model",
    "mlp": "model",
    "experts": "model",
    "vocab": "model",
    "layers": None,                     # scan-stacked dim
    "ssm_inner": "model",
    "unsharded": None,
}


class _State(threading.local):
    def __init__(self):
        self.rules: Dict[str, MeshAxes] = dict(DEFAULT_RULES)
        self.mesh: Optional[Mesh] = None
        self.enabled: bool = False


_STATE = _State()


@contextlib.contextmanager
def sharding_rules(mesh: Mesh, overrides: Optional[Dict[str, MeshAxes]] = None):
    """Activate logical-axis constraint application under ``mesh``."""
    prev = (_STATE.rules, _STATE.mesh, _STATE.enabled)
    rules = dict(DEFAULT_RULES)
    if overrides:
        rules.update(overrides)
    _STATE.rules, _STATE.mesh, _STATE.enabled = rules, mesh, True
    try:
        yield
    finally:
        _STATE.rules, _STATE.mesh, _STATE.enabled = prev


def logical_to_spec(logical: Sequence[Optional[str]],
                    mesh: Optional[Mesh] = None,
                    rules: Optional[Dict[str, MeshAxes]] = None) -> P:
    """Map logical axis names to a PartitionSpec valid for ``mesh``."""
    mesh = mesh or _STATE.mesh
    rules = rules or _STATE.rules
    mesh_axes = set(mesh.axis_names) if mesh is not None else set()
    spec = []
    for name in logical:
        if name is None:
            spec.append(None)
            continue
        target = rules.get(name)
        if target is None:
            spec.append(None)
        elif isinstance(target, str):
            spec.append(target if target in mesh_axes else None)
        else:
            filtered = tuple(a for a in target if a in mesh_axes)
            spec.append(filtered if filtered else None)
    return P(*spec)


def _axis_size(mesh: Mesh, target: MeshAxes) -> int:
    if target is None:
        return 1
    if isinstance(target, str):
        return mesh.shape[target]
    size = 1
    for a in target:
        size *= mesh.shape[a]
    return size


def drop_indivisible(spec: P, shape: Tuple[int, ...], mesh: Mesh) -> P:
    """Sanitize a spec against concrete dims: drop entries that don't divide
    the dim size (e.g. kv_heads=4 over a 16-way axis stays replicated) and
    drop repeated mesh axes (first occurrence wins) so rule overrides like
    kv_seq→("data","model") can coexist with batch→"data" on small batches.
    """
    used: set = set()
    out = []
    for dim, entry in zip(shape, tuple(spec) + (None,) * (len(shape) - len(spec))):
        if entry is None:
            out.append(None)
            continue
        axes = (entry,) if isinstance(entry, str) else tuple(entry)
        axes = tuple(a for a in axes if a not in used)
        # longest prefix of the axis tuple whose product divides the dim
        # (e.g. a 128-row GRAFT subset over ("pod","data","model")=512 chips
        # falls back to ("pod","data")=32-way instead of replicating)
        while axes and (dim % _axis_size(mesh, axes) != 0):
            axes = axes[:-1]
        if not axes:
            out.append(None)
            continue
        entry2: MeshAxes = axes[0] if len(axes) == 1 else axes
        used.update(axes)
        out.append(entry2)
    return P(*out)


def constrain(x: jax.Array, logical: Sequence[Optional[str]]) -> jax.Array:
    """``with_sharding_constraint`` by logical names; no-op outside a mesh."""
    if not _STATE.enabled or _STATE.mesh is None:
        return x
    spec = drop_indivisible(logical_to_spec(logical), x.shape, _STATE.mesh)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(_STATE.mesh, spec))


def named_sharding(mesh: Mesh, logical: Sequence[Optional[str]],
                   rules: Optional[Dict[str, MeshAxes]] = None) -> NamedSharding:
    return NamedSharding(mesh, logical_to_spec(logical, mesh, rules))


def param_sharding_tree(params_logical, mesh: Mesh,
                        rules: Optional[Dict[str, MeshAxes]] = None):
    """Map a pytree of logical-axis tuples to NamedShardings."""
    return jax.tree_util.tree_map(
        lambda lg: named_sharding(mesh, lg, rules), params_logical,
        is_leaf=lambda x: isinstance(x, tuple))


def param_shardings(abstract_params, params_logical, mesh: Mesh,
                    rules: Optional[Dict[str, MeshAxes]] = None):
    """NamedShardings for a param pytree, with indivisible axes dropped.

    ``abstract_params``: pytree of ShapeDtypeStruct (from ``jax.eval_shape``);
    ``params_logical``: matching pytree of logical-axis name tuples.
    """
    def one(abstract, logical):
        spec = logical_to_spec(logical, mesh, rules)
        spec = drop_indivisible(spec, abstract.shape, mesh)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map(
        one, abstract_params, params_logical,
        is_leaf=lambda x: isinstance(x, tuple))
