"""One generic name → object registry behind every pluggable subsystem.

The repo grew three near-identical registries (samplers in
``selection/registry.py``, feature extractors / grad sources in
``selection/sources.py``, task/data sources in ``data/sources.py``) with
drifting method names and error texts. They are now all instances of
:class:`Registry`, which pins the shared contract:

  * ``register(name, obj, *, overwrite=False)`` — duplicate names raise
    ``ValueError("<kind> '<name>' already registered")`` unless
    ``overwrite=True`` is passed explicitly;
  * ``get(name)`` — unknown names raise
    ``KeyError("unknown <kind> '<name>'; available: (...)")`` so the caller
    sees every valid choice in the error itself;
  * ``available()`` — sorted name tuple, the one enumeration CI matrices
    and conformance tests iterate.

``Registry`` subclasses ``dict`` on purpose: the existing registries were
bare module-level dicts that tests (and some internal call sites) poke
directly — ``_REGISTRY.pop(name, None)`` cleanup, ``.values()`` scans —
and all of that keeps working on the same object.

Registries whose defaults live in a sibling module (samplers) pass
``ensure_defaults``: a zero-arg import hook run before ``get``/
``available`` whenever the registry is empty, so bare imports of the
registry module still resolve the built-ins lazily.
"""
from __future__ import annotations

from typing import Callable, Optional, Tuple, TypeVar

T = TypeVar("T")


class Registry(dict):
    """Name → object mapping with uniform register/get/available semantics."""

    def __init__(self, kind: str,
                 ensure_defaults: Optional[Callable[[], None]] = None):
        super().__init__()
        self.kind = kind
        self._ensure = ensure_defaults

    def _ensure_defaults(self) -> None:
        if self._ensure is not None and not self:
            self._ensure()

    def register(self, name: str, obj: T, *, overwrite: bool = False) -> T:
        if not overwrite and name in self:
            raise ValueError(f"{self.kind} '{name}' already registered")
        self[name] = obj
        return obj

    def get(self, name: str) -> T:  # type: ignore[override]
        # NOT dict.get: unknown names raise with the available choices
        # (the registry contract) instead of silently returning None.
        self._ensure_defaults()
        if name not in self:
            raise KeyError(f"unknown {self.kind} '{name}'; "
                           f"available: {self.available()}")
        return self[name]

    def available(self) -> Tuple[str, ...]:
        self._ensure_defaults()
        return tuple(sorted(self))
