"""Deterministic synthetic data pipelines (no external datasets offline).

Design goals that mirror a production loader:
  * host-sharded: each data-parallel host generates only its slice of the
    global batch (hash of (seed, step, global_example_index) — no host ever
    materializes the global batch);
  * resumable: iterator state is one integer (step) and rides in the
    checkpoint manifest;
  * learnable: sequences follow a hidden Markov chain over token clusters +
    Zipfian unigrams, so models actually reduce loss and subset-selection
    quality differences show up (a pure-uniform stream would make every
    selection method look identical).

Every training source implements the ``DataSource`` protocol (see
``DataSourceBase``): ``spec()`` declares the local batch layout,
``batch_at(step)``/``__call__(step)`` produce the host-local shard, and the
resumable iterator state is ONE integer. New task workloads (classification,
vision, …) register in ``repro.data.sources`` — this module keeps only the
protocol plumbing and the LM source.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, NamedTuple, Optional, Tuple

import numpy as np


class ArraySpec(NamedTuple):
    """Shape/dtype of one batch entry (numpy-level on purpose: the data
    layer never imports jax; ``launch/specs.py`` converts to
    ``jax.ShapeDtypeStruct`` for the dry-run compiler)."""
    shape: Tuple[int, ...]
    dtype: np.dtype


class DataSourceBase:
    """Shared plumbing for every registered data source.

    Subclasses set ``self.cfg`` (with ``global_batch``/``num_hosts``/
    ``host_index``) and implement ``batch_at(step)`` + ``spec()``; the base
    provides the one-integer resumable iterator, ``__call__``, and the
    microbatch-stack layout the vmapped selection engine consumes.
    """

    cfg: "object"

    def __init__(self):
        self._step = 0

    # ---- protocol: batch layout + production ----
    def spec(self) -> Dict[str, ArraySpec]:
        """Local (host-shard) batch layout: name → (shape, dtype)."""
        raise NotImplementedError

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """Deterministic local batch for ``step`` (host shard only)."""
        raise NotImplementedError

    def __call__(self, step: int) -> Dict[str, np.ndarray]:
        return self.batch_at(step)

    # ---- resumable iterator state (one integer) ----
    def state_dict(self) -> Dict[str, int]:
        return {"step": self._step}

    def load_state_dict(self, state: Dict[str, int]) -> None:
        self._step = int(state["step"])

    def microbatch_stack(self, step: int, num_micro: int) -> Dict[str, np.ndarray]:
        """``num_micro`` consecutive batches stacked on a new leading axis —
        the input layout of the vmapped multi-batch selection path
        (``repro.selection.engine.select_multi_batch``): one jit selects for
        every microbatch at once. Does not advance the iterator."""
        stack = [self.batch_at(step + i) for i in range(num_micro)]
        return {k: np.stack([b[k] for b in stack]) for k in stack[0]}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            b = self.batch_at(self._step)
            self._step += 1
            yield b


def zipf_class_probs(num_classes: int, imbalance: float) -> np.ndarray:
    """Zipf-like class skew (``imbalance=0`` → uniform): random subsets miss
    rare classes, which is exactly the regime where diversity-seeking
    selection pays off."""
    if imbalance <= 0:
        return np.full(num_classes, 1.0 / num_classes)
    p = 1.0 / np.arange(1, num_classes + 1, dtype=np.float64) ** imbalance
    return p / p.sum()


@dataclasses.dataclass
class DataConfig:
    vocab_size: int = 1024
    seq_len: int = 128
    global_batch: int = 32
    seed: int = 0
    num_clusters: int = 16         # hidden-state count of the Markov source
    cluster_stickiness: float = 0.8
    # host sharding
    num_hosts: int = 1
    host_index: int = 0

    @property
    def local_batch(self) -> int:
        assert self.global_batch % self.num_hosts == 0
        return self.global_batch // self.num_hosts


class SyntheticLM(DataSourceBase):
    """Markov-over-clusters token source; __call__(step) -> local batch."""

    def __init__(self, cfg: DataConfig):
        super().__init__()
        self.cfg = cfg
        root = np.random.default_rng(cfg.seed)
        C, V = cfg.num_clusters, cfg.vocab_size
        # sticky transition matrix between clusters
        trans = root.random((C, C)) + np.eye(C) * (
            cfg.cluster_stickiness * C / (1 - cfg.cluster_stickiness + 1e-9))
        self.trans = trans / trans.sum(1, keepdims=True)
        # per-cluster Zipfian token distributions over disjoint-ish supports
        ranks = np.arange(1, V + 1, dtype=np.float64)
        zipf = 1.0 / ranks
        self.cluster_tokens = []
        for c in range(C):
            perm = np.random.default_rng(cfg.seed * 1000 + c).permutation(V)
            p = zipf[np.argsort(perm)]
            self.cluster_tokens.append(p / p.sum())
        self.cluster_tokens = np.stack(self.cluster_tokens)   # (C, V)
        # precomputed CDFs: token sampling is a binary search, not a choice()
        self._tok_cdf = np.cumsum(self.cluster_tokens, axis=1)
        self._trans_cdf = np.cumsum(self.trans, axis=1)

    def spec(self) -> Dict[str, ArraySpec]:
        B, S = self.cfg.local_batch, self.cfg.seq_len
        return {"tokens": ArraySpec((B, S), np.dtype(np.int32)),
                "labels": ArraySpec((B, S), np.dtype(np.int32))}

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """Deterministic batch for ``step`` (local shard only)."""
        cfg = self.cfg
        B, S = cfg.local_batch, cfg.seq_len
        start = step * cfg.global_batch + cfg.host_index * B
        tokens = np.empty((B, S + 1), dtype=np.int32)
        V = cfg.vocab_size
        for i in range(B):
            # per-GLOBAL-example stream ⇒ identical data for any host count
            # (elastic re-sharding keeps the byte-exact token stream)
            g = np.random.default_rng((cfg.seed, 0x5EED, step, start + i))
            u_tok = g.random(S + 1)
            u_cl = g.random(S + 1)
            c = int(g.integers(cfg.num_clusters))
            for t in range(S + 1):
                tokens[i, t] = min(np.searchsorted(self._tok_cdf[c], u_tok[t]), V - 1)
                c = min(int(np.searchsorted(self._trans_cdf[c], u_cl[t])),
                        cfg.num_clusters - 1)
        return {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}


class SyntheticClassification:
    """Gaussian-cluster classification set (paper's CIFAR/IMDB analog).

    Fixed finite dataset (n examples) so fraction sweeps Ψ(f) make sense;
    includes label noise + per-class difficulty so selection methods
    differentiate.
    """

    def __init__(self, n: int = 4096, dim: int = 64, num_classes: int = 10,
                 noise: float = 0.8, label_noise: float = 0.02, seed: int = 0,
                 imbalance: float = 0.0):
        g = np.random.default_rng(seed)
        self.num_classes = num_classes
        centers = g.normal(size=(num_classes, dim)) * 2.0
        if imbalance > 0:
            self.y = g.choice(num_classes, size=n,
                              p=zipf_class_probs(num_classes, imbalance)
                              ).astype(np.int32)
        else:
            self.y = g.integers(num_classes, size=n).astype(np.int32)
        scales = 0.5 + 1.5 * g.random(num_classes)           # per-class difficulty
        self.x = (centers[self.y] +
                  g.normal(size=(n, dim)) * noise * scales[self.y][:, None]
                  ).astype(np.float32)
        flip = g.random(n) < label_noise
        self.y[flip] = g.integers(num_classes, size=flip.sum())

    def split(self, test_fraction: float = 0.2, seed: int = 1):
        g = np.random.default_rng(seed)
        n = len(self.y)
        perm = g.permutation(n)
        k = int(n * (1 - test_fraction))
        tr, te = perm[:k], perm[k:]
        return (self.x[tr], self.y[tr]), (self.x[te], self.y[te])


def batches(x: np.ndarray, y: np.ndarray, batch_size: int, seed: int = 0
            ) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
    g = np.random.default_rng(seed)
    n = len(y)
    while True:
        idx = g.choice(n, batch_size, replace=False)
        yield x[idx], y[idx]
