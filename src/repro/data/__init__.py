"""Deterministic, host-sharded, resumable synthetic data pipelines + the
task/data-source registry (``repro.data.sources``)."""
from repro.data.pipeline import (ArraySpec, DataConfig, DataSourceBase,
                                 SyntheticClassification, SyntheticLM,
                                 batches)
from repro.data.sources import (ClassificationConfig, SourceEntry,
                                SyntheticClassificationSource,
                                SyntheticVisionSource, TaskAdapter,
                                VisionConfig, available_sources,
                                build_source, derive_config,
                                entry_for_config, get_source,
                                register_source, source_name_of)

__all__ = [
    "ArraySpec", "DataConfig", "DataSourceBase", "SyntheticLM",
    "SyntheticClassification", "batches",
    # data-source registry
    "SourceEntry", "TaskAdapter", "register_source", "get_source",
    "available_sources", "entry_for_config", "source_name_of",
    "derive_config", "build_source",
    "ClassificationConfig", "SyntheticClassificationSource",
    "VisionConfig", "SyntheticVisionSource",
]
