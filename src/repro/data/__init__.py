"""Deterministic, host-sharded, resumable synthetic data pipelines."""
from repro.data.pipeline import (DataConfig, SyntheticClassification,
                                 SyntheticLM, batches)

__all__ = ["DataConfig", "SyntheticLM", "SyntheticClassification", "batches"]
