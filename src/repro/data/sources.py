"""Task/data-source registry: named training workloads behind one protocol.

Mirrors the sampler registry (``repro.selection.registry``) and the
feature/grad-source registries (``repro.selection.sources``): every workload
a ``Trainer`` can consume is a registered :class:`SourceEntry` pairing

  * a **config dataclass** — the ``data`` section of an
    ``ExperimentConfig`` (tagged by the registry name, JSON round-trip,
    ``--data.field=value`` CLI overrides);
  * a **source builder** — config → :class:`~repro.data.pipeline.DataSourceBase`
    (``spec()`` shapes/dtypes, ``__call__(step)`` host-sharded local batch,
    one-integer resumable state);
  * a **task adapter** — how the workload hooks into the model: which
    ``ModelConfig`` fields it pins (vocab = class count, input frontend),
    how a default config derives from model/train, what a mismatched
    section must complain about, and which eval metric applies.

Every source emits batches in a layout the unified model already consumes
(``tokens`` | ``frame_embeds`` | ``patch_embeds`` + ``labels``), so the
GRAFT selection forward (``launch/steps.py:selection_inputs``), the probe /
logit-embed / full gradient sources, and every registered sampler work
unchanged on non-LM batches.

Built-in workloads:

  * ``synthetic_lm``             — Markov-over-clusters token stream
                                   (``repro.data.pipeline.SyntheticLM``)
  * ``synthetic_classification`` — Gaussian-mixture feature clusters with
                                   controllable class imbalance + label
                                   noise, spread over ``frames`` sequence
                                   positions (per-class selection quality is
                                   measurable via ``classes_at``)
  * ``synthetic_vision``         — procedural class-conditioned gratings in
                                   CNN-compatible NHWC layout, patchified
                                   into the model's vision frontend

Adding a workload is one registration::

    register_source(SourceEntry("mine", MyConfig, build_fn, my_adapter))
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.data.pipeline import (ArraySpec, DataConfig, DataSourceBase,
                                 SyntheticLM, zipf_class_probs)
from repro.registry import Registry


# ---------------------------------------------------------------------------
# task adapters
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TaskAdapter:
    """How a data source plugs into the model and the eval loop.

    ``kind`` selects the eval metric family (``lm`` → perplexity,
    ``classification`` → accuracy). ``model_overrides(dcfg)`` returns the
    ``ModelConfig`` fields the task pins (applied on top of the arch config
    at build time). ``derive(mcfg, batch, seq, seed)`` materializes the
    default config for a model/train pair; ``finalize`` fills derivable
    sentinel fields of an explicit config; ``validate`` returns loud
    mismatch strings (a silent mismatch NaNs or shape-errors deep in jit).
    """
    kind: str
    model_overrides: Callable[[Any], Dict[str, Any]]
    derive: Callable[..., Any]
    validate: Callable[[Any, Any, int, int], List[str]]
    finalize: Optional[Callable[..., Any]] = None


@dataclasses.dataclass(frozen=True)
class SourceEntry:
    """One registered workload: config type + source builder + task hookup."""
    name: str
    config_cls: type
    build: Callable[[Any], DataSourceBase]
    task: TaskAdapter


# generic registry (repro.registry) — shared register/get/available
# semantics with the sampler and feature/grad-source registries
_SOURCES: Registry = Registry("data source")


def register_source(entry: SourceEntry, *, overwrite: bool = False) -> SourceEntry:
    # source-specific invariant on top of the generic registry: the tagged
    # config section resolves by config CLASS, so two sources must never
    # share one
    for other in _SOURCES.values():
        if other.name != entry.name and other.config_cls is entry.config_cls:
            raise ValueError(
                f"config class {entry.config_cls.__name__} already tags "
                f"source '{other.name}' — one config class per source")
    return _SOURCES.register(entry.name, entry, overwrite=overwrite)


def get_source(name: str) -> SourceEntry:
    return _SOURCES.get(name)


def available_sources() -> Tuple[str, ...]:
    return _SOURCES.available()


def entry_for_config(dcfg: Any) -> SourceEntry:
    """Resolve the registry entry that owns ``dcfg``'s config class."""
    for entry in _SOURCES.values():
        if type(dcfg) is entry.config_cls:
            return entry
    raise KeyError(f"no registered data source owns config type "
                   f"{type(dcfg).__name__} (available: {available_sources()})")


def source_name_of(dcfg: Any) -> str:
    return entry_for_config(dcfg).name


def derive_config(name: str, mcfg: Any, *, batch: int, seq: int,
                  seed: int) -> Any:
    """Materialized default config for source ``name`` against a model
    config + loop shape — the ``data.source=<name>`` override path."""
    return get_source(name).task.derive(mcfg, batch=batch, seq=seq, seed=seed)


def finalize_config(dcfg: Any, mcfg: Any, *, batch: int, seq: int,
                    seed: int) -> Any:
    """Fill the derivable sentinel fields (0 = derive) of an explicit
    config; identity for fully-specified sections."""
    entry = entry_for_config(dcfg)
    if entry.task.finalize is None:
        return dcfg
    return entry.task.finalize(dcfg, mcfg, batch=batch, seq=seq, seed=seed)


def validate_config(dcfg: Any, mcfg: Any, *, batch: int, seq: int) -> List[str]:
    return entry_for_config(dcfg).task.validate(dcfg, mcfg, batch, seq)


def build_source(dcfg: Any) -> DataSourceBase:
    return entry_for_config(dcfg).build(dcfg)


def shard_for_backend(dcfg: Any, backend: Any) -> Any:
    """This process's host-shard view of a rank-agnostic ``data`` section.

    Every source config carries ``num_hosts``/``host_index``; the backend's
    ``data_shard()`` (process_count, process_index) fills them at BUILD time
    only — the stored/serialized section stays rank-agnostic so all
    processes hash identically and checkpoints restore on any topology.
    Per-global-example seeding makes the union of the shards byte-identical
    to a single-host run."""
    num_hosts, host_index = backend.data_shard()
    if (num_hosts, host_index) == (dcfg.num_hosts, dcfg.host_index):
        return dcfg
    if dcfg.global_batch % num_hosts != 0:
        raise ValueError(
            f"global batch {dcfg.global_batch} does not divide over "
            f"{num_hosts} processes — pick train.batch divisible by the "
            "process count")
    return dataclasses.replace(dcfg, num_hosts=num_hosts,
                               host_index=host_index)


# ---------------------------------------------------------------------------
# synthetic_lm (the original pipeline, unchanged semantics)
# ---------------------------------------------------------------------------

def _lm_derive(mcfg, *, batch: int, seq: int, seed: int) -> DataConfig:
    return DataConfig(vocab_size=mcfg.vocab_size, seq_len=seq,
                      global_batch=batch, seed=seed)


def _lm_validate(dcfg: DataConfig, mcfg, batch: int, seq: int) -> List[str]:
    return [
        f"data.{k}={got} != {want} ({src})"
        for k, got, want, src in [
            ("global_batch", dcfg.global_batch, batch, "train.batch"),
            ("seq_len", dcfg.seq_len, seq, "train.seq"),
            ("vocab_size", dcfg.vocab_size, mcfg.vocab_size, "model vocab"),
        ] if got != want]


SYNTHETIC_LM = register_source(SourceEntry(
    "synthetic_lm", DataConfig, SyntheticLM,
    TaskAdapter(kind="lm", model_overrides=lambda dcfg: {},
                derive=_lm_derive, validate=_lm_validate)))


# ---------------------------------------------------------------------------
# shared plumbing for classification-style sources (configs with
# embed_dim / global_batch sentinels and a class-count-pinned head)
# ---------------------------------------------------------------------------

def _finalize_embed_batch(dcfg, mcfg, *, batch: int, seq: int, seed: int):
    """Fill the ``embed_dim``/``global_batch`` = 0 sentinels from
    model/train; identity when both are explicit."""
    repl: Dict[str, Any] = {}
    if dcfg.embed_dim <= 0:
        repl["embed_dim"] = mcfg.d_model
    if dcfg.global_batch <= 0:
        repl["global_batch"] = batch
    return dataclasses.replace(dcfg, **repl) if repl else dcfg


def _validate_embed_batch(dcfg, mcfg, batch: int) -> List[str]:
    return [
        f"data.{k}={got} != {want} ({src})"
        for k, got, want, src in [
            ("global_batch", dcfg.global_batch, batch, "train.batch"),
            ("embed_dim", dcfg.embed_dim, mcfg.d_model, "model d_model"),
            ("num_classes", dcfg.num_classes, mcfg.vocab_size,
             "model vocab (task-pinned)"),
        ] if got != want]


# ---------------------------------------------------------------------------
# synthetic_classification
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ClassificationConfig:
    """Gaussian-mixture classification stream (the paper's CIFAR/IMDB
    analog as an infinite per-example-seeded stream).

    ``imbalance`` applies a Zipf skew over classes and ``label_noise`` flips
    a fraction of labels — the two knobs that make per-class selection
    quality measurable (random subsets miss rare classes; loss-topk chases
    flipped labels). Features are spread over ``frames`` sequence positions
    (each a zero-padded chunk of the feature vector) so the sequence model,
    probe-position striding, and pooled selection features all engage.
    ``embed_dim``/``global_batch`` of 0 mean "derive from model/train".
    """
    num_classes: int = 10
    feature_dim: int = 64
    frames: int = 4                 # sequence positions the features span
    embed_dim: int = 0              # model d_model; 0 = derive
    class_sep: float = 2.0          # center scale (separability)
    noise: float = 0.8              # within-cluster std, × per-class scale
    label_noise: float = 0.02       # fraction of labels flipped
    imbalance: float = 0.0          # Zipf exponent over classes (0 = uniform)
    global_batch: int = 0           # 0 = derive from train.batch
    seed: int = 0
    num_hosts: int = 1
    host_index: int = 0

    @property
    def local_batch(self) -> int:
        assert self.global_batch % self.num_hosts == 0
        return self.global_batch // self.num_hosts

    @property
    def chunk(self) -> int:
        return math.ceil(self.feature_dim / self.frames)


class SyntheticClassificationSource(DataSourceBase):
    """Per-example-seeded Gaussian-mixture stream → model-ready batches
    (``frame_embeds`` (B, frames, embed_dim) + ``labels`` (B, frames))."""

    _STREAM = 0xC1A55

    def __init__(self, cfg: ClassificationConfig):
        super().__init__()
        if cfg.chunk > cfg.embed_dim:
            raise ValueError(
                f"feature chunk {cfg.chunk} (feature_dim {cfg.feature_dim} "
                f"over {cfg.frames} frames) exceeds embed_dim {cfg.embed_dim}")
        self.cfg = cfg
        root = np.random.default_rng(cfg.seed)
        C, D = cfg.num_classes, cfg.feature_dim
        self.centers = root.normal(size=(C, D)) * cfg.class_sep
        self.scales = 0.5 + 1.5 * root.random(C)      # per-class difficulty
        self._class_cdf = np.cumsum(zipf_class_probs(C, cfg.imbalance))

    def spec(self) -> Dict[str, ArraySpec]:
        cfg = self.cfg
        B = cfg.local_batch
        return {
            "frame_embeds": ArraySpec((B, cfg.frames, cfg.embed_dim),
                                      np.dtype(np.float32)),
            "labels": ArraySpec((B, cfg.frames), np.dtype(np.int32)),
        }

    def _example(self, step: int, gidx: int) -> Tuple[np.ndarray, int, int]:
        """(features, clean class, observed label) for one GLOBAL example —
        per-example streams keep the batch byte-identical for any host
        count (elastic re-sharding)."""
        cfg = self.cfg
        g = np.random.default_rng((cfg.seed, self._STREAM, step, gidx))
        c = min(int(np.searchsorted(self._class_cdf, g.random())),
                cfg.num_classes - 1)
        x = self.centers[c] + g.normal(size=cfg.feature_dim) * \
            cfg.noise * self.scales[c]
        y = int(g.integers(cfg.num_classes)) if g.random() < cfg.label_noise \
            else c
        return x.astype(np.float32), c, y

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        B = cfg.local_batch
        start = step * cfg.global_batch + cfg.host_index * B
        frames = np.zeros((B, cfg.frames, cfg.embed_dim), np.float32)
        labels = np.empty((B, cfg.frames), np.int32)
        chunk = cfg.chunk
        for i in range(B):
            x, _, y = self._example(step, start + i)
            padded = np.zeros(cfg.frames * chunk, np.float32)
            padded[:cfg.feature_dim] = x
            frames[i, :, :chunk] = padded.reshape(cfg.frames, chunk)
            labels[i, :] = y
        return {"frame_embeds": frames, "labels": labels}

    def classes_at(self, step: int) -> np.ndarray:
        """CLEAN class ids (pre-label-noise) of the local batch — the
        ground truth for per-class selection-quality analysis."""
        cfg = self.cfg
        start = step * cfg.global_batch + cfg.host_index * cfg.local_batch
        return np.asarray([self._example(step, start + i)[1]
                           for i in range(cfg.local_batch)], np.int32)


def _classification_derive(mcfg, *, batch: int, seq: int,
                           seed: int) -> ClassificationConfig:
    return _finalize_embed_batch(ClassificationConfig(seed=seed), mcfg,
                                 batch=batch, seq=seq, seed=seed)


def _classification_validate(dcfg: ClassificationConfig, mcfg, batch: int,
                             seq: int) -> List[str]:
    out = _validate_embed_batch(dcfg, mcfg, batch)
    if dcfg.chunk > max(dcfg.embed_dim, 1):
        out.append(f"data.feature_dim={dcfg.feature_dim} over "
                   f"{dcfg.frames} frames needs chunk {dcfg.chunk} "
                   f"> embed_dim {dcfg.embed_dim}")
    return out


SYNTHETIC_CLASSIFICATION = register_source(SourceEntry(
    "synthetic_classification", ClassificationConfig,
    SyntheticClassificationSource,
    TaskAdapter(kind="classification",
                model_overrides=lambda d: {"vocab_size": d.num_classes,
                                           "frontend": "audio_frames"},
                derive=_classification_derive,
                validate=_classification_validate,
                finalize=_finalize_embed_batch)))


# ---------------------------------------------------------------------------
# synthetic_vision
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class VisionConfig:
    """Procedural vision stream: class-conditioned oriented gratings with
    per-class channel signatures, in CNN-compatible NHWC layout
    (``images_at``), patchified into the model's vision frontend
    (``patch_embeds`` + one class-query token). ``embed_dim``/
    ``global_batch`` of 0 mean "derive from model/train"."""
    num_classes: int = 10
    image_size: int = 16
    channels: int = 3
    patch_size: int = 4
    embed_dim: int = 0              # model d_model; 0 = derive
    noise: float = 0.3              # additive pixel noise std
    label_noise: float = 0.0
    imbalance: float = 0.0
    global_batch: int = 0           # 0 = derive from train.batch
    seed: int = 0
    num_hosts: int = 1
    host_index: int = 0

    @property
    def local_batch(self) -> int:
        assert self.global_batch % self.num_hosts == 0
        return self.global_batch // self.num_hosts

    @property
    def num_patches(self) -> int:
        assert self.image_size % self.patch_size == 0
        return (self.image_size // self.patch_size) ** 2

    @property
    def patch_dim(self) -> int:
        return self.patch_size * self.patch_size * self.channels


class SyntheticVisionSource(DataSourceBase):
    """Class-conditioned gratings → NHWC images → patchified model batches
    (``patch_embeds`` (B, P, embed_dim), ``tokens`` (B, 1) class query,
    ``labels`` (B, 1))."""

    _STREAM = 0xF1E1D

    def __init__(self, cfg: VisionConfig):
        super().__init__()
        if cfg.patch_dim > cfg.embed_dim:
            raise ValueError(f"patch_dim {cfg.patch_dim} exceeds "
                             f"embed_dim {cfg.embed_dim}")
        self.cfg = cfg
        root = np.random.default_rng(cfg.seed)
        C = cfg.num_classes
        # per-class grating signature: orientation, frequency, channel mix
        self.angles = np.pi * np.arange(C) / C
        self.freqs = 1.0 + (np.arange(C) % 4)
        self.channel_mix = 0.25 + 0.75 * root.random((C, cfg.channels))
        self._class_cdf = np.cumsum(zipf_class_probs(C, cfg.imbalance))
        grid = (np.arange(cfg.image_size) + 0.5) / cfg.image_size
        self._yy, self._xx = np.meshgrid(grid, grid, indexing="ij")

    def spec(self) -> Dict[str, ArraySpec]:
        cfg = self.cfg
        B = cfg.local_batch
        return {
            "patch_embeds": ArraySpec((B, cfg.num_patches, cfg.embed_dim),
                                      np.dtype(np.float32)),
            "tokens": ArraySpec((B, 1), np.dtype(np.int32)),
            "labels": ArraySpec((B, 1), np.dtype(np.int32)),
        }

    def _example(self, step: int, gidx: int) -> Tuple[np.ndarray, int, int]:
        """(image HWC, clean class, observed label) for one GLOBAL example."""
        cfg = self.cfg
        g = np.random.default_rng((cfg.seed, self._STREAM, step, gidx))
        c = min(int(np.searchsorted(self._class_cdf, g.random())),
                cfg.num_classes - 1)
        phase = g.random() * 2.0 * np.pi
        wave = np.cos(self.angles[c]) * self._xx + \
            np.sin(self.angles[c]) * self._yy
        base = np.sin(2.0 * np.pi * self.freqs[c] * wave + phase)
        img = base[..., None] * self.channel_mix[c][None, None, :]
        img = img + cfg.noise * g.normal(
            size=(cfg.image_size, cfg.image_size, cfg.channels))
        y = int(g.integers(cfg.num_classes)) if g.random() < cfg.label_noise \
            else c
        return img.astype(np.float32), c, y

    def _patchify(self, img: np.ndarray) -> np.ndarray:
        """(H, W, C) → (P, patch_size²·C) row-major patch grid."""
        p = self.cfg.patch_size
        H = self.cfg.image_size
        n = H // p
        patches = img.reshape(n, p, n, p, self.cfg.channels)
        return patches.transpose(0, 2, 1, 3, 4).reshape(
            self.cfg.num_patches, self.cfg.patch_dim)

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        B = cfg.local_batch
        start = step * cfg.global_batch + cfg.host_index * B
        embeds = np.zeros((B, cfg.num_patches, cfg.embed_dim), np.float32)
        labels = np.empty((B, 1), np.int32)
        for i in range(B):
            img, _, y = self._example(step, start + i)
            embeds[i, :, :cfg.patch_dim] = self._patchify(img)
            labels[i, 0] = y
        return {"patch_embeds": embeds,
                "tokens": np.zeros((B, 1), np.int32),   # class-query token
                "labels": labels}

    def images_at(self, step: int) -> Tuple[np.ndarray, np.ndarray]:
        """Raw (B, H, W, C) images + clean class ids — the CNN-compatible
        layout for external consumers and per-class analysis."""
        cfg = self.cfg
        start = step * cfg.global_batch + cfg.host_index * cfg.local_batch
        out = [self._example(step, start + i) for i in range(cfg.local_batch)]
        return (np.stack([img for img, _, _ in out]),
                np.asarray([c for _, c, _ in out], np.int32))


def _vision_derive(mcfg, *, batch: int, seq: int, seed: int) -> VisionConfig:
    return _finalize_embed_batch(VisionConfig(seed=seed), mcfg, batch=batch,
                                 seq=seq, seed=seed)


def _vision_validate(dcfg: VisionConfig, mcfg, batch: int,
                     seq: int) -> List[str]:
    out = _validate_embed_batch(dcfg, mcfg, batch)
    if dcfg.patch_dim > max(dcfg.embed_dim, 1):
        out.append(f"data.patch_size={dcfg.patch_size} needs patch_dim "
                   f"{dcfg.patch_dim} > embed_dim {dcfg.embed_dim}")
    return out


SYNTHETIC_VISION = register_source(SourceEntry(
    "synthetic_vision", VisionConfig, SyntheticVisionSource,
    TaskAdapter(kind="classification",
                model_overrides=lambda d: {"vocab_size": d.num_classes,
                                           "frontend": "vision_patches",
                                           "num_patches": d.num_patches},
                derive=_vision_derive,
                validate=_vision_validate,
                finalize=_finalize_embed_batch)))
