"""Divergence guard: host-side consumer of the on-device sentinel verdict.

``apply_sentinel`` (launch/steps.py) computes a fused health word inside the
jitted train step and already *contains* the blast: an unhealthy update is
skipped on device, bit-exactly. What remains for the host is the slow-burn
case — ``bad_streak`` growing past ``train.bad_step_patience`` means the run
is wedged (every step NaN, or a persistent loss spike), and the only way
forward is rolling back to the last checkpoint stamped healthy.

The guard reads the verdict WITHOUT adding host syncs on the healthy path:
``healthy``/``bad_streak`` ride the step's lazy ``MetricsFuture``, and the
guard only inspects rows some other drain boundary (JSONL flush, console
print, checkpoint save) has already materialized. Rows that outlive a full
``check_every`` window with no consumer draining them (no logger configured)
are force-drained here, under a sanctioned ``sync_allowed`` site — bounded
cadence, never per-step.
"""
from __future__ import annotations

from collections import deque
from typing import Any, Dict

from repro.analysis.sync_guard import sync_allowed
from repro.api.callbacks import Callback


class DivergenceGuardCallback(Callback):
    """Trips the trainer's rollback after ``patience`` consecutive bad steps.

    Priority 45: after the JSONL logger (30) — whose flush materializes
    rows the guard then reads for free — and before the checkpointer (90),
    so a tripped sentinel blocks the save of a poisoned state in the same
    step (``CheckpointCallback`` checks ``trainer.sentinel_tripped``).
    """
    priority = 45

    def __init__(self, patience: int = 10, check_every: int = 20):
        self.patience = max(1, patience)
        self.check_every = max(1, check_every)
        self.bad_steps = 0
        self.max_streak = 0
        self._pending: deque = deque()   # (step, MetricsFuture), oldest first

    # ------------------------------ hooks --------------------------------
    def on_step_end(self, trainer, step: int, metrics: Dict[str, Any]) -> None:
        if "bad_streak" not in metrics:   # sentinel disabled for this run
            return
        self._pending.append((step, metrics))
        # consume the already-materialized prefix — free, no device sync
        while self._pending and self._pending[0][1].materialized:
            if self._consume(trainer, *self._pending.popleft()):
                return
        # rows that aged past a full check window with no drain boundary
        # touching them: force the sync here, sanctioned and bounded
        while self._pending and step - self._pending[0][0] >= self.check_every:
            old_step, row = self._pending.popleft()
            with sync_allowed("divergence_guard"):
                row.materialize()                          # lint: allow
            if self._consume(trainer, old_step, row):
                return

    def on_train_end(self, trainer, report: Dict[str, Any]) -> None:
        with sync_allowed("divergence_guard"):
            while self._pending:
                step, row = self._pending.popleft()
                row.materialize()                          # lint: allow
                self._consume(trainer, step, row)
        res = report.setdefault("resilience", {})
        res.update({"bad_steps": self.bad_steps,
                    "max_bad_streak": self.max_streak,
                    "tripped": trainer.sentinel_tripped})

    # ----------------------------- internals -----------------------------
    def _consume(self, trainer, step: int, row) -> bool:
        """Inspect one materialized row; returns True when the guard trips
        (remaining pending rows belong to the abandoned trajectory)."""
        vals = row.materialize()                           # cached — no sync
        streak = int(vals.get("bad_streak", 0))
        if vals.get("healthy", 1.0) < 0.5:
            self.bad_steps += 1
        self.max_streak = max(self.max_streak, streak)
        if streak >= self.patience and not trainer.sentinel_tripped:
            trainer.sentinel_tripped = True
            self._pending.clear()
            trainer.request_rollback(
                f"bad_streak {streak} >= patience {self.patience} "
                f"at step {step}")
            return True
        return False


__all__ = ["DivergenceGuardCallback"]
