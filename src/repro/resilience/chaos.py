"""Deterministic fault injection for the training stack.

A :class:`FaultPlan` is a list of fault dicts, supplied either as inline
JSON (``train.fault_plan='[{"kind": "nan_batch", "step": 12}]'``), as an
``@``-free path to a JSON file, or via the ``REPRO_FAULT_PLAN`` environment
variable. Every fault is keyed on host-visible state (the trainer's step
counter, a named crash point) and fires **exactly once** — so a run that
rolls back and replays the same step range is NOT re-poisoned, and the
whole schedule replays bit-exactly across runs with the same plan.

Fault kinds:

``nan_batch``  — ``{"kind": "nan_batch", "step": k}``: poison the host
    batch dispatched at step ``k``. Float leaves become NaN; integer
    leaves become out-of-range ids, which ``jnp.take``'s default
    out-of-bounds ``fill`` mode turns into NaN embeddings — so even the
    int-only ``synthetic_lm`` workload produces a NaN loss/gradient.
``sigterm``    — ``{"kind": "sigterm", "step": k}``: deliver SIGTERM to
    this process right before step ``k`` is dispatched (preemption drill).
``crash``      — ``{"kind": "crash", "point": "checkpoint.mid_commit"}``:
    raise :class:`ChaosCrash` at a named :func:`crash_point` (the
    checkpoint writer declares ``pre_commit`` / ``mid_commit`` /
    ``post_commit``), simulating the process dying at exactly that
    filesystem state. ``"skip": N`` lets the first N hits of the point
    pass (crash the N+1-th save); ``"mode": "exit"`` hard-kills via
    ``os._exit(17)`` instead, for subprocess-based tests.
``stall``      — ``{"kind": "stall", "step": k, "seconds": s}``: delay the
    completion stamp of step ``k``'s DeviceClock marker by ``s`` seconds,
    exercising the watchdog (``train.device_timeout_s``).
``bit_flip``   — ``{"kind": "bit_flip", "leaf": substr}``: offline fault —
    the chaos CLI / tests apply it with :func:`flip_checkpoint_leaf`
    between runs; the trainer itself ignores it.
"""
from __future__ import annotations

import json
import os
import signal
import time
from contextlib import contextmanager
from typing import Any, Dict, List, Optional

import numpy as np

ENV_VAR = "REPRO_FAULT_PLAN"
KINDS = ("nan_batch", "sigterm", "crash", "stall", "bit_flip")

# out-of-range token id used to poison integer batches: far beyond any
# vocab, so the embedding gather's fill mode yields NaN rows
BAD_TOKEN_ID = 2 ** 30


class ChaosCrash(RuntimeError):
    """Injected crash — simulates the process dying at a crash point."""


class FaultPlan:
    """An ordered list of faults, each of which fires at most once."""

    def __init__(self, faults: List[Dict[str, Any]]):
        for f in faults:
            kind = f.get("kind")
            if kind not in KINDS:
                raise ValueError(f"unknown fault kind {kind!r} "
                                 f"(expected one of {KINDS})")
        self.faults = list(faults)
        self.fired: set = set()
        self._hits: Dict[int, int] = {}   # crash-point pass-throughs seen

    # ------------------------------ parsing ------------------------------
    @classmethod
    def from_spec(cls, spec) -> "FaultPlan":
        """Build from inline JSON text, an already-parsed list/dict, or a
        path to a JSON file."""
        if isinstance(spec, FaultPlan):
            return spec
        if isinstance(spec, str):
            text = spec.strip()
            if text.startswith("[") or text.startswith("{"):
                data = json.loads(text)
            else:
                path = text[1:] if text.startswith("@") else text
                with open(path) as f:
                    data = json.load(f)
        else:
            data = spec
        if isinstance(data, dict):
            data = data.get("faults", [data])
        return cls(data)

    # ----------------------------- injection -----------------------------
    def _take(self, **match) -> Optional[Dict[str, Any]]:
        """Return the first unfired fault matching ``match``, marking it
        fired — the once-only discipline that makes replay deterministic."""
        for i, f in enumerate(self.faults):
            if i in self.fired:
                continue
            if all(f.get(k) == v for k, v in match.items()):
                self.fired.add(i)
                return f
        return None

    def corrupt_batch(self, step: int, batch: Dict[str, Any]) -> Dict[str, Any]:
        """Poison every leaf of the host batch for a matching ``nan_batch``
        fault; returns the batch unchanged otherwise."""
        if self._take(kind="nan_batch", step=step) is None:
            return batch
        return {k: _poison(v) for k, v in batch.items()}

    def fire_signals(self, step: int) -> None:
        if self._take(kind="sigterm", step=step) is not None:
            signal.raise_signal(signal.SIGTERM)

    def crash_at(self, point: str) -> None:
        for i, f in enumerate(self.faults):
            if (i in self.fired or f.get("kind") != "crash"
                    or f.get("point") != point):
                continue
            hits = self._hits.get(i, 0)
            self._hits[i] = hits + 1
            if hits < int(f.get("skip", 0)):
                continue                    # let the first N saves commit
            self.fired.add(i)
            if f.get("mode") == "exit":
                os._exit(17)
            raise ChaosCrash(f"injected crash at '{point}'")

    def wrap_marker(self, step: int, marker: Any) -> Any:
        f = self._take(kind="stall", step=step)
        if f is None:
            return marker
        return StallMarker(marker, float(f.get("seconds", 1.0)))


def _poison(arr):
    a = np.asarray(arr)
    if np.issubdtype(a.dtype, np.floating):
        return np.full_like(a, np.nan)
    if np.issubdtype(a.dtype, np.integer):
        info = np.iinfo(a.dtype)
        return np.full_like(a, min(BAD_TOKEN_ID, int(info.max)))
    return a


class StallMarker:
    """Wraps a DeviceClock marker so its completion stamp arrives late —
    from the stamper thread's point of view this IS a wedged device."""

    def __init__(self, marker: Any, seconds: float):
        self._marker = marker
        self.seconds = seconds

    def block_until_ready(self):
        time.sleep(self.seconds)
        if hasattr(self._marker, "block_until_ready"):
            self._marker.block_until_ready()
        return self._marker


# ------------------------- module-global plumbing -------------------------
# The checkpoint writer (possibly on its writer thread) consults the active
# plan at its crash points; the Trainer activates the plan for the duration
# of fit(). Set-before-thread-start ordering makes this safe unread-locked.
_active: Optional[FaultPlan] = None


def activate(plan: FaultPlan) -> None:
    global _active
    _active = plan


def deactivate() -> None:
    global _active
    _active = None


@contextmanager
def active_plan(plan: FaultPlan):
    activate(plan)
    try:
        yield plan
    finally:
        deactivate()


def crash_point(name: str) -> None:
    """Declared at host-side commit boundaries (checkpoint writer); a no-op
    unless the active plan holds an unfired ``crash`` fault for ``name``."""
    if _active is not None:
        _active.crash_at(name)


def load_plan(config_spec: Optional[str] = None) -> Optional[FaultPlan]:
    """Resolve the fault plan from config or the environment (config wins);
    ``None`` when neither is set — the common, zero-overhead case."""
    spec = config_spec if config_spec else os.environ.get(ENV_VAR)
    if not spec:
        return None
    return FaultPlan.from_spec(spec)


# ------------------------------ offline faults ----------------------------
def flip_checkpoint_leaf(directory: str, step: int, leaf: str,
                         bit: int = 0) -> str:
    """Flip one bit in the payload of the first checkpoint leaf whose key
    contains ``leaf``. The manifest checksum is left intact, so a verified
    restore detects the corruption. Returns the corrupted key."""
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    for key, meta in sorted(manifest["leaves"].items()):
        if leaf in key:
            fpath = os.path.join(path, meta["file"])
            data = bytearray(open(fpath, "rb").read())
            # flip inside the array payload (the .npy header is ~128 bytes;
            # the last byte is always payload for non-empty arrays)
            idx = len(data) - 1 - (bit // 8)
            data[idx] ^= 1 << (bit % 8)
            with open(fpath, "wb") as f:
                f.write(bytes(data))
            return key
    raise KeyError(f"no checkpoint leaf matching '{leaf}' at step {step}")


__all__ = [
    "BAD_TOKEN_ID",
    "ChaosCrash",
    "ENV_VAR",
    "FaultPlan",
    "StallMarker",
    "activate",
    "active_plan",
    "crash_point",
    "deactivate",
    "flip_checkpoint_leaf",
    "load_plan",
]
