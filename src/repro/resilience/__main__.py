"""The chaos matrix: every recovery path exercised by an injected fault.

``python -m repro.resilience`` runs four end-to-end scenarios against a
small synthetic_lm cell (the CI ``chaos`` job):

  * **nan_rollback** — a NaN batch at step k: the sentinel skips the
    update on device, the guard trips (patience 1) at the next drain
    boundary, the trainer rolls back to the last healthy checkpoint and
    finishes — with a final loss BIT-IDENTICAL to an uninjected run
    resumed from that same checkpoint, and the poisoned JSONL row
    serialized as ``null`` + ``nonfinite_keys`` (valid JSON throughout);
  * **corrupt_leaf** — a bit flipped in the newest checkpoint's params:
    ``restore_latest_good`` quarantines it to ``corrupt.<step>`` and
    resumes from the prior step;
  * **sigterm** — SIGTERM mid-run: emergency checkpoint, clean stop,
    resume runs the remaining steps;
  * **kill_mid_save** — the async checkpoint writer dies pre-commit: the
    failure surfaces on the next save, abort cleanup releases handlers
    and files, and a restart recovers (stale tmp dropped, committed
    checkpoints intact).

Exit code 0 iff every scenario passes; ``--json PATH`` dumps the results.
"""
from __future__ import annotations

import argparse
import json
import os
import shutil
import sys
import tempfile
from typing import Callable, Dict, List

from repro.api import ExperimentConfig, Trainer
from repro.checkpoint import CheckpointManager
from repro.resilience import chaos

STEPS = 20


def _cell(td: str, *extra: str, fault_plan=None) -> ExperimentConfig:
    ck = os.path.join(td, "ck")
    overrides = [
        f"train.steps={STEPS}", "train.batch=8", "train.seq=16",
        "train.log_every=0", f"train.checkpoint_dir={ck}",
        "train.checkpoint_every=5", "train.metrics_flush_every=4",
        f"train.metrics_path={os.path.join(td, 'metrics.jsonl')}",
        "train.bad_step_patience=1", "graft.rset=[2,4]",
        "graft.refresh_every=3", *extra,
    ]
    if fault_plan is not None:
        overrides.append("train.fault_plan=" + json.dumps(fault_plan))
    return ExperimentConfig().apply_overrides(overrides)


def _require(cond: bool, msg: str) -> None:
    if not cond:
        raise AssertionError(msg)


def scenario_nan_rollback(td: str) -> Dict:
    cfg = _cell(td, fault_plan=[{"kind": "nan_batch", "step": 12}])
    report = Trainer(cfg).fit()
    rollbacks = report.get("resilience", {}).get("rollbacks", [])
    _require(len(rollbacks) == 1, f"expected one rollback, got {rollbacks}")
    to_step = rollbacks[0]["to_step"]

    # the acceptance bar: an uninjected run resumed from the SAME
    # checkpoint the rollback landed on finishes bit-identically
    twin_dir = os.path.join(td, "twin")
    os.makedirs(twin_dir)
    shutil.copytree(os.path.join(td, "ck", f"step_{to_step:08d}"),
                    os.path.join(twin_dir, f"step_{to_step:08d}"))
    twin = Trainer.from_checkpoint(twin_dir).fit()
    _require(twin["final_loss"] == report["final_loss"],
             f"final loss diverged: injected {report['final_loss']} vs "
             f"clean resume {twin['final_loss']}")

    # the poisoned step's telemetry row is valid JSON with null markers
    rows = [json.loads(line)
            for line in open(os.path.join(td, "metrics.jsonl"))]
    poisoned = [r for r in rows if r["step"] == 12 and r.get("loss") is None]
    _require(bool(poisoned), "no sanitized NaN row for the poisoned step")
    _require("loss" in poisoned[0].get("nonfinite_keys", []),
             "nonfinite_keys missing 'loss'")
    return {"rolled_back_to": to_step, "final_loss": report["final_loss"]}


def scenario_streaming_nan_rollback(td: str) -> Dict:
    """nan_rollback with a LIVE sketch reservoir: the streaming sampler's
    carry (frequent-directions sketch + stream-mean EMA) rides the train
    state, so the rollback must restore it and the replay must advance it
    identically — the bit-identical final loss proves the reservoir is
    checkpointed, rolled back, and resumed exactly."""
    cfg = _cell(td, "train.sampler=streaming_graft",
                fault_plan=[{"kind": "nan_batch", "step": 12}])
    report = Trainer(cfg).fit()
    rollbacks = report.get("resilience", {}).get("rollbacks", [])
    _require(len(rollbacks) == 1, f"expected one rollback, got {rollbacks}")
    to_step = rollbacks[0]["to_step"]

    twin_dir = os.path.join(td, "twin")
    os.makedirs(twin_dir)
    shutil.copytree(os.path.join(td, "ck", f"step_{to_step:08d}"),
                    os.path.join(twin_dir, f"step_{to_step:08d}"))
    twin = Trainer.from_checkpoint(twin_dir).fit()
    _require(twin["final_loss"] == report["final_loss"],
             f"final loss diverged with live reservoir: injected "
             f"{report['final_loss']} vs clean resume {twin['final_loss']}")
    return {"rolled_back_to": to_step, "final_loss": report["final_loss"]}


def scenario_corrupt_leaf(td: str) -> Dict:
    cfg = _cell(td)
    Trainer(cfg).fit()
    ck = os.path.join(td, "ck")
    steps = CheckpointManager(ck).all_steps()
    newest, prior = steps[-1], steps[-2]
    key = chaos.flip_checkpoint_leaf(ck, newest, "params")

    trainer = Trainer.from_checkpoint(ck)
    report = trainer.fit()
    _require(trainer.start_step == prior,
             f"resumed from {trainer.start_step}, wanted prior step {prior}")
    names = os.listdir(ck)
    _require(f"corrupt.{newest:08d}" in names,
             f"bit-flipped step {newest} not quarantined: {sorted(names)}")
    _require(newest not in CheckpointManager(ck).all_steps()
             or os.path.exists(os.path.join(ck, f"step_{newest:08d}")),
             "all_steps inconsistent after quarantine")
    return {"flipped": key, "quarantined": newest, "resumed_from": prior,
            "final_loss": report["final_loss"]}


def scenario_sigterm(td: str) -> Dict:
    cfg = _cell(td, "train.checkpoint_every=50",
                fault_plan=[{"kind": "sigterm", "step": 12}])
    first = Trainer(cfg).fit()
    _require(first.get("stopped") == "preempted",
             f"expected preempted stop, got {first.get('stopped')!r}")
    resumed = Trainer.from_checkpoint(os.path.join(td, "ck")).fit()
    total = first["host_loop"]["steps"] + resumed["host_loop"]["steps"]
    _require(total == STEPS, f"{total} steps across stop+resume, "
             f"wanted {STEPS}")
    return {"stopped_after": first["host_loop"]["steps"],
            "final_loss": resumed["final_loss"]}


def scenario_kill_mid_save(td: str) -> Dict:
    # the SECOND async save's writer dies before the commit rename; the
    # stored failure surfaces from wait() at the third save → fit aborts
    cfg = _cell(td, fault_plan=[{"kind": "crash", "skip": 1,
                                 "point": "checkpoint.pre_commit"}])
    try:
        Trainer(cfg).fit()
        raise AssertionError("injected writer crash never surfaced")
    except chaos.ChaosCrash:
        pass
    ck = os.path.join(td, "ck")
    survivors = CheckpointManager(ck).all_steps()   # init ran _recover()
    _require(survivors == [5], f"committed checkpoints after crash: "
             f"{survivors} (wanted [5])")
    report = Trainer.from_checkpoint(ck).fit()
    _require(report["host_loop"]["steps"] == STEPS - 5,
             f"restart ran {report['host_loop']['steps']} steps, "
             f"wanted {STEPS - 5}")
    return {"survivors": survivors, "final_loss": report["final_loss"]}


SCENARIOS: List[Callable[[str], Dict]] = [
    scenario_nan_rollback,
    scenario_streaming_nan_rollback,
    scenario_corrupt_leaf,
    scenario_sigterm,
    scenario_kill_mid_save,
]


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(description="run the chaos matrix")
    parser.add_argument("--json", default=None,
                        help="write the scenario results to this path")
    parser.add_argument("--only", default=None,
                        help="run a single scenario by name")
    args = parser.parse_args(argv)

    results: Dict[str, Dict] = {}
    failed = False
    for scenario in SCENARIOS:
        name = scenario.__name__.removeprefix("scenario_")
        if args.only and name != args.only:
            continue
        td = tempfile.mkdtemp(prefix=f"chaos_{name}_")
        try:
            results[name] = {"ok": True, **scenario(td)}
            print(f"[chaos] {name}: PASS {results[name]}")
        except Exception as e:                      # noqa: BLE001
            failed = True
            results[name] = {"ok": False, "error": f"{type(e).__name__}: {e}"}
            print(f"[chaos] {name}: FAIL {e}")
        finally:
            shutil.rmtree(td, ignore_errors=True)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(results, f, indent=1)
    print("[chaos] matrix:", "FAIL" if failed else "PASS")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
