"""Resilience: divergence sentinel plumbing, rollback, and chaos testing.

Three cooperating pieces (see README "Fault tolerance & chaos testing"):

  * the on-device divergence sentinel lives in ``launch/steps.py``
    (``apply_sentinel``) — a fused health word + skip-update computed inside
    the jitted train step, so a poisoned gradient never touches params and
    the verdict rides the existing lazy metrics row (zero new host syncs);
  * :class:`~repro.resilience.guard.DivergenceGuardCallback` consumes that
    verdict at drain boundaries and, after ``train.bad_step_patience``
    consecutive bad steps, asks the Trainer to roll back to the last
    checkpoint stamped healthy (``CheckpointManager.restore_latest_good``);
  * :mod:`~repro.resilience.chaos` is the deterministic fault-injection
    harness (NaN batch, SIGTERM, kill-mid-save, bit-flip, stalled step)
    driven by ``train.fault_plan`` / ``REPRO_FAULT_PLAN`` and replayed
    bit-exactly by tests and the CI chaos job
    (``python -m repro.resilience``).
"""
from repro.resilience.chaos import (ChaosCrash, FaultPlan, activate,
                                    active_plan, crash_point, deactivate,
                                    flip_checkpoint_leaf, load_plan)


def __getattr__(name):
    # guard pulls in the full api/callback stack (which itself imports the
    # checkpoint module, which imports chaos from here) — load it lazily so
    # `from repro.resilience import chaos` stays cycle-free and light
    if name == "DivergenceGuardCallback":
        from repro.resilience.guard import DivergenceGuardCallback
        return DivergenceGuardCallback
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

__all__ = [
    "ChaosCrash",
    "DivergenceGuardCallback",
    "FaultPlan",
    "activate",
    "active_plan",
    "crash_point",
    "deactivate",
    "flip_checkpoint_leaf",
    "load_plan",
]
