"""Learning-rate schedules: cosine, WSD (MiniCPM's warmup-stable-decay),
linear, constant. All are jit-safe ``f(step: int32) -> f32``."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    def f(step):
        return jnp.float32(lr)
    return f


def cosine(lr: float, total_steps: int, warmup_steps: int = 0,
           min_ratio: float = 0.1):
    def f(step):
        step = jnp.float32(step)
        warm = step / jnp.maximum(warmup_steps, 1)
        progress = jnp.clip((step - warmup_steps) /
                            jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0)
        cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * progress))
        return jnp.float32(lr) * jnp.where(step < warmup_steps, warm, cos)
    return f


def wsd(lr: float, total_steps: int, warmup_steps: int = 0,
        decay_fraction: float = 0.1, min_ratio: float = 0.01):
    """Warmup → Stable → Decay (MiniCPM §WSD): constant plateau, then a short
    exponential-ish (here: linear-in-log) decay over the final fraction."""
    decay_steps = max(int(total_steps * decay_fraction), 1)
    decay_start = total_steps - decay_steps

    def f(step):
        step = jnp.float32(step)
        warm = step / jnp.maximum(warmup_steps, 1)
        decay_progress = jnp.clip((step - decay_start) / decay_steps, 0.0, 1.0)
        decay = jnp.exp(jnp.log(jnp.maximum(min_ratio, 1e-6)) * decay_progress)
        scale = jnp.where(step < warmup_steps, warm,
                          jnp.where(step < decay_start, 1.0, decay))
        return jnp.float32(lr) * scale
    return f


def linear(lr: float, total_steps: int, warmup_steps: int = 0,
           min_ratio: float = 0.0):
    def f(step):
        step = jnp.float32(step)
        warm = step / jnp.maximum(warmup_steps, 1)
        progress = jnp.clip((step - warmup_steps) /
                            jnp.maximum(total_steps - warmup_steps, 1), 0.0, 1.0)
        lin = 1.0 - (1.0 - min_ratio) * progress
        return jnp.float32(lr) * jnp.where(step < warmup_steps, warm, lin)
    return f


SCHEDULES = {"constant": constant, "cosine": cosine, "wsd": wsd, "linear": linear}
