"""From-scratch optimizers + schedules (AdamW, SGD, Lion, factored Adafactor)."""
from repro.optim.optimizers import (Optimizer, OptimizerConfig,
                                    clip_by_global_norm, global_norm,
                                    make_optimizer)
from repro.optim.schedules import SCHEDULES

__all__ = ["Optimizer", "OptimizerConfig", "make_optimizer",
           "clip_by_global_norm", "global_norm", "SCHEDULES"]
