"""Optimizers built from scratch (no optax in this container): AdamW,
SGD+momentum, Lion, and Adafactor with factored second moments — the
factored state is what lets kimi-k2 (1T params) fit the 16 GB/chip HBM
budget (DESIGN.md §5). All states inherit the parameter sharding, i.e.
ZeRO-style fully sharded optimizer state under pjit.

API: ``opt = make_optimizer(cfg); state = opt.init(params);
new_params, new_state, metrics = opt.apply(params, grads, state, step)``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"             # adamw | sgd | lion | adafactor
    learning_rate: float = 1e-3
    schedule: str = "cosine"        # constant | cosine | wsd | linear
    total_steps: int = 1000
    warmup_steps: int = 100
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.01
    momentum: float = 0.9
    clip_norm: Optional[float] = 1.0
    state_dtype: str = "float32"    # bfloat16 halves m/v memory at scale


class Optimizer(NamedTuple):
    init: Callable[[PyTree], PyTree]
    apply: Callable[[PyTree, PyTree, PyTree, jax.Array],
                    Tuple[PyTree, PyTree, Dict[str, jax.Array]]]


def global_norm(tree: PyTree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in jax.tree_util.tree_leaves(tree)))


def clip_by_global_norm(grads: PyTree, max_norm: float) -> Tuple[PyTree, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree_util.tree_map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), grads), norm


def _lr_fn(cfg: OptimizerConfig):
    from repro.optim.schedules import SCHEDULES
    sched = SCHEDULES[cfg.schedule]
    if cfg.schedule == "constant":
        return sched(cfg.learning_rate)
    return sched(cfg.learning_rate, cfg.total_steps, cfg.warmup_steps)


def make_optimizer(cfg: OptimizerConfig) -> Optimizer:
    lr_fn = _lr_fn(cfg)
    sdt = jnp.dtype(cfg.state_dtype)

    def preprocess(grads):
        metrics = {}
        if cfg.clip_norm is not None:
            grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
            metrics["grad_norm"] = gnorm
        else:
            metrics["grad_norm"] = global_norm(grads)
        return grads, metrics

    # ----------------------------- AdamW ---------------------------------
    if cfg.name == "adamw":
        def init(params):
            z = lambda p: jnp.zeros(p.shape, sdt)
            return {"m": jax.tree_util.tree_map(z, params),
                    "v": jax.tree_util.tree_map(z, params)}

        def apply(params, grads, state, step):
            grads, metrics = preprocess(grads)
            lr = lr_fn(step)
            t = step.astype(jnp.float32) + 1.0
            bc1 = 1.0 - cfg.beta1 ** t
            bc2 = 1.0 - cfg.beta2 ** t

            def upd(p, g, m, v):
                gf = g.astype(jnp.float32)
                m_new = cfg.beta1 * m.astype(jnp.float32) + (1 - cfg.beta1) * gf
                v_new = cfg.beta2 * v.astype(jnp.float32) + (1 - cfg.beta2) * gf * gf
                update = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + cfg.eps)
                update = update + cfg.weight_decay * p.astype(jnp.float32)
                p_new = p.astype(jnp.float32) - lr * update
                return p_new.astype(p.dtype), m_new.astype(sdt), v_new.astype(sdt)

            flat = jax.tree_util.tree_map(upd, params, grads, state["m"], state["v"])
            params_new = jax.tree_util.tree_map(lambda x: x[0], flat,
                                                is_leaf=lambda x: isinstance(x, tuple))
            m_new = jax.tree_util.tree_map(lambda x: x[1], flat,
                                           is_leaf=lambda x: isinstance(x, tuple))
            v_new = jax.tree_util.tree_map(lambda x: x[2], flat,
                                           is_leaf=lambda x: isinstance(x, tuple))
            metrics["lr"] = lr
            return params_new, {"m": m_new, "v": v_new}, metrics

        return Optimizer(init, apply)

    # ------------------------- SGD + momentum -----------------------------
    if cfg.name == "sgd":
        def init(params):
            return {"m": jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, sdt), params)}

        def apply(params, grads, state, step):
            grads, metrics = preprocess(grads)
            lr = lr_fn(step)

            def upd(p, g, m):
                m_new = cfg.momentum * m.astype(jnp.float32) + g.astype(jnp.float32)
                p_new = p.astype(jnp.float32) - lr * (
                    m_new + cfg.weight_decay * p.astype(jnp.float32))
                return p_new.astype(p.dtype), m_new.astype(sdt)

            flat = jax.tree_util.tree_map(upd, params, grads, state["m"])
            params_new = jax.tree_util.tree_map(lambda x: x[0], flat,
                                                is_leaf=lambda x: isinstance(x, tuple))
            m_new = jax.tree_util.tree_map(lambda x: x[1], flat,
                                           is_leaf=lambda x: isinstance(x, tuple))
            metrics["lr"] = lr
            return params_new, {"m": m_new}, metrics

        return Optimizer(init, apply)

    # ------------------------------ Lion ----------------------------------
    if cfg.name == "lion":
        def init(params):
            return {"m": jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, sdt), params)}

        def apply(params, grads, state, step):
            grads, metrics = preprocess(grads)
            lr = lr_fn(step)

            def upd(p, g, m):
                gf, mf = g.astype(jnp.float32), m.astype(jnp.float32)
                update = jnp.sign(cfg.beta1 * mf + (1 - cfg.beta1) * gf)
                m_new = cfg.beta2 * mf + (1 - cfg.beta2) * gf
                p_new = p.astype(jnp.float32) - lr * (
                    update + cfg.weight_decay * p.astype(jnp.float32))
                return p_new.astype(p.dtype), m_new.astype(sdt)

            flat = jax.tree_util.tree_map(upd, params, grads, state["m"])
            params_new = jax.tree_util.tree_map(lambda x: x[0], flat,
                                                is_leaf=lambda x: isinstance(x, tuple))
            m_new = jax.tree_util.tree_map(lambda x: x[1], flat,
                                           is_leaf=lambda x: isinstance(x, tuple))
            metrics["lr"] = lr
            return params_new, {"m": m_new}, metrics

        return Optimizer(init, apply)

    # ---------------------------- Adafactor -------------------------------
    if cfg.name == "adafactor":
        def init(params):
            def state_for(p):
                if p.ndim >= 2:
                    # factor over the two largest dims (trailing two)
                    return {"vr": jnp.zeros(p.shape[:-1], sdt),
                            "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:], sdt)}
                return {"v": jnp.zeros(p.shape, sdt)}
            return {"v": jax.tree_util.tree_map(
                state_for, params, is_leaf=lambda x: hasattr(x, "ndim"))}

        def apply(params, grads, state, step):
            grads, metrics = preprocess(grads)
            lr = lr_fn(step)
            t = step.astype(jnp.float32) + 1.0
            beta2t = 1.0 - t ** -0.8       # Adafactor's increasing decay

            def upd(p, g, s):
                gf = g.astype(jnp.float32)
                g2 = gf * gf + 1e-30
                if p.ndim >= 2:
                    vr = beta2t * s["vr"].astype(jnp.float32) + \
                        (1 - beta2t) * jnp.mean(g2, axis=-1)
                    vc = beta2t * s["vc"].astype(jnp.float32) + \
                        (1 - beta2t) * jnp.mean(g2, axis=-2)
                    denom = (vr[..., None] * vc[..., None, :]) / (
                        jnp.mean(vr, axis=-1, keepdims=True)[..., None] + 1e-30)
                    update = gf / (jnp.sqrt(denom) + 1e-30)
                    s_new = {"vr": vr.astype(sdt), "vc": vc.astype(sdt)}
                else:
                    v = beta2t * s["v"].astype(jnp.float32) + (1 - beta2t) * g2
                    update = gf / (jnp.sqrt(v) + 1e-30)
                    s_new = {"v": v.astype(sdt)}
                # update clipping (Adafactor d=1.0)
                rms = jnp.sqrt(jnp.mean(update * update) + 1e-30)
                update = update / jnp.maximum(1.0, rms)
                p_new = p.astype(jnp.float32) - lr * (
                    update + cfg.weight_decay * p.astype(jnp.float32))
                return p_new.astype(p.dtype), s_new

            flat = jax.tree_util.tree_map(
                upd, params, grads, state["v"],
                is_leaf=lambda x: isinstance(x, dict) and ("vr" in x or "v" in x))
            params_new = jax.tree_util.tree_map(lambda x: x[0], flat,
                                                is_leaf=lambda x: isinstance(x, tuple))
            v_new = jax.tree_util.tree_map(lambda x: x[1], flat,
                                           is_leaf=lambda x: isinstance(x, tuple))
            metrics["lr"] = lr
            return params_new, {"v": v_new}, metrics

        return Optimizer(init, apply)

    raise ValueError(f"unknown optimizer '{cfg.name}'")
