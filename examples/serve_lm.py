"""Serve a small model with batched requests (wave-scheduled static batching
over a KV-cache decode loop).

Usage:  PYTHONPATH=src python examples/serve_lm.py --requests 12 --slots 4
"""
import argparse, json, os, sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.serve import serve


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minicpm-2b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=24)
    args = ap.parse_args()
    report = serve(arch=args.arch, slots=args.slots, requests=args.requests,
                   max_new_tokens=args.max_new, max_seq=128)
    print(json.dumps({k: v for k, v in report.items() if k != "results"},
                     indent=1))
    print(f"sample output tokens (request 0): {report['results'][0]['tokens'][:10]}")


if __name__ == "__main__":
    main()
