"""Paper Table 2 analog: fine-tuning with GRAFT vs GRAFT-Warm vs full data.

BERT/IMDB is approximated by a frozen 'pretrained' feature encoder (trained
on held-out synthetic data) + classification head fine-tuned on GRAFT-
selected subsets. Reproduces the Table-2 pattern: Warm ≈ full accuracy at
35% data; cold GRAFT cheapest at moderate accuracy.

Usage:  PYTHONPATH=src python examples/finetune_classifier.py
"""
import json, os, sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (accuracy, init_mlp, mlp_loss, sgd_step,
                               train_flops_per_example)
from repro.core.grad_features import per_sample_grads_full
from repro.core.maxvol import fast_maxvol
from repro.data import SyntheticClassification
from repro.selection import resolve_features

DIM, HIDDEN, CLASSES = 64, 64, 4          # sentiment-ish low class count
BATCH, STEPS, LR = 100, 120, 0.2          # paper: batch 100

# the same feature-extractor registry the LM train step resolves from
# (swap for "pca_sketch" / "pooled_raw" to reproduce the ablations)
FEATURES = resolve_features("svd")


def pretrain_encoder(xtr, ytr):
    """The 'pretrained BERT': an MLP trained on a disjoint synthetic split."""
    p = init_mlp(jax.random.PRNGKey(7), DIM, HIDDEN, CLASSES)
    step = jax.jit(lambda p, xs, ys: sgd_step(p, jax.grad(mlp_loss)(p, xs, ys), LR))
    g = np.random.default_rng(7)
    for _ in range(150):
        idx = g.choice(len(ytr), BATCH, replace=False)
        p = step(p, jnp.asarray(xtr[idx]), jnp.asarray(ytr[idx]))
    return p


def finetune(method, frac, xtr, ytr, xte, yte, warm):
    p = init_mlp(jax.random.PRNGKey(0), DIM, HIDDEN, CLASSES)
    r = max(2, int(BATCH * frac))
    step = jax.jit(lambda p, xs, ys: sgd_step(p, jax.grad(mlp_loss)(p, xs, ys), LR))
    g = np.random.default_rng(0)
    flops = 0.0
    fe = train_flops_per_example(DIM, HIDDEN, CLASSES)
    piv = None
    for s in range(STEPS):
        idx = g.choice(len(ytr), BATCH, replace=False)
        xb, yb = jnp.asarray(xtr[idx]), jnp.asarray(ytr[idx])
        if s % 10 == 0 or piv is None:                    # paper: every 10
            if method == "full":
                piv = jnp.arange(BATCH)
            else:
                probe = warm if method == "graft_warm" else p
                def ex_loss(q, ex):
                    x1, y1 = ex
                    return mlp_loss(q, x1[None], y1[None])
                G, _ = per_sample_grads_full(ex_loss, probe, (xb, yb))
                src = G.T if method == "graft_warm" else xb
                rf = min(r, src.shape[1])
                V = FEATURES(src, rf)
                piv, _ = fast_maxvol(V, rf)
                if r > rf:
                    rest = np.setdiff1d(np.arange(BATCH), np.asarray(piv))
                    piv = jnp.concatenate([piv, jnp.asarray(
                        np.random.default_rng(s).choice(rest, r - rf, replace=False),
                        dtype=jnp.int32)])
                flops += fe * BATCH / 3.0
        p = step(p, xb[piv], yb[piv])
        flops += fe * len(piv)
    return accuracy(p, jnp.asarray(xte), jnp.asarray(yte)), flops


def main():
    ds = SyntheticClassification(n=4096, dim=DIM, num_classes=CLASSES, seed=1,
                                 noise=2.5, label_noise=0.03, imbalance=0.8)
    (x, y), (xte, yte) = ds.split(0.25)
    half = len(y) // 2
    warm = pretrain_encoder(x[:half], y[:half])          # disjoint pretraining
    xtr, ytr = x[half:], y[half:]

    rows = {}
    full_acc, full_flops = finetune("full", 1.0, xtr, ytr, xte, yte, warm)
    rows["full"] = {"acc": full_acc, "flops": full_flops}
    for frac in (0.10, 0.35):
        for m in ("graft", "graft_warm"):
            acc, fl = finetune(m, frac, xtr, ytr, xte, yte, warm)
            rows[f"{m}@{int(frac*100)}%"] = {
                "acc": round(acc, 4), "flops": fl,
                "flops_vs_full": round(fl / full_flops, 3)}
    print(json.dumps(rows, indent=1))
    print("\nTable-2 pattern check: warm@35% within 1% of full accuracy:",
          rows["graft_warm@35%"]["acc"] >= rows["full"]["acc"] - 0.01)


if __name__ == "__main__":
    main()
