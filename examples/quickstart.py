"""Quickstart: GRAFT subset selection inside a tiny LM training loop.

Runs in ~1 minute on CPU. Shows the three-line public API:
  1. build a model config + train config with GraftConfig
  2. make_train_step() — selection fused into the jitted step
  3. watch rank/alignment/loss evolve.

Usage:  PYTHONPATH=src python examples/quickstart.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.train import RunConfig, train


def main():
    run = RunConfig(
        arch="minicpm-2b",        # smoke-sized variant of the assigned arch
        steps=40, batch=16, seq=64,
        use_graft=True,
        graft_rset=(4, 8),        # candidate subset sizes (25% / 50% of batch)
        graft_eps=0.3,            # projection-error threshold
        graft_refresh=5,          # re-select every S=5 steps (paper: 20-50)
        lr=3e-3, log_every=5,
    )
    report = train(run)
    print(f"\nfinal loss: {report['final_loss']:.4f}  "
          f"wall: {report['wall_s']:.1f}s")
    ranks = [h["rank"] for h in report["history"]]
    print(f"selected ranks over training: min={min(ranks):.0f} "
          f"max={max(ranks):.0f}")
    print("GRAFT trained on ≤50% of each batch while tracking the full-batch "
          "gradient direction.")


if __name__ == "__main__":
    main()
