"""Quickstart: GRAFT subset selection through the Experiment API.

Runs in ~1 minute on CPU. The whole public API is three moves:
  1. declare an ExperimentConfig (model / train / graft / optimizer sections)
  2. Trainer(cfg).fit() — selection fused into the jitted step, while
     checkpointing/eval/telemetry run as Callback plugins
  3. read the report (or add your own Callback for live metrics).

Usage:  PYTHONPATH=src python examples/quickstart.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.api import ExperimentConfig, GraftConfig, TrainConfig, Trainer


def main():
    cfg = ExperimentConfig(
        train=TrainConfig(steps=40, batch=16, seq=64, log_every=5),
        graft=GraftConfig(
            rset=(4, 8),          # candidate subset sizes (25% / 50% of batch)
            eps=0.3,              # projection-error threshold
            refresh_every=5,      # re-select every S=5 steps (paper: 20-50)
            feature_mode="svd",   # try pca_sketch | pooled_raw
            grad_mode="probe"),   # try logit_embed
    ).apply_overrides(["optimizer.learning_rate=3e-3"])   # flat CLI-style
    report = Trainer(cfg).fit()

    print(f"\nconfig {report['config_hash']}  "
          f"final loss: {report['final_loss']:.4f}  "
          f"wall: {report['wall_s']:.1f}s")
    ranks = [h["rank"] for h in report["history"]]
    print(f"selected ranks over training: min={min(ranks):.0f} "
          f"max={max(ranks):.0f}")
    print("GRAFT trained on ≤50% of each batch while tracking the full-batch "
          "gradient direction.")


if __name__ == "__main__":
    main()
