"""Paper Table 5 analog: Fast MaxVol for channel pruning.

Prune 50% of an MLP's hidden channels by running Fast MaxVol on the hidden
activation matrix (channels = columns → select the most volumetric ones)
and compare accuracy/FLOPs against the unpruned net and magnitude pruning.

Usage:  PYTHONPATH=src python examples/channel_pruning.py
"""
import json, os, sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import accuracy, init_mlp, mlp_logits, mlp_loss, sgd_step
from repro.core.features import svd_features
from repro.core.maxvol import fast_maxvol
from repro.data import SyntheticClassification

DIM, HIDDEN, CLASSES = 64, 128, 10


def main():
    ds = SyntheticClassification(n=4096, dim=DIM, num_classes=CLASSES,
                                 seed=0, noise=1.5)
    (xtr, ytr), (xte, yte) = ds.split(0.2)
    p = init_mlp(jax.random.PRNGKey(0), DIM, HIDDEN, CLASSES)
    step = jax.jit(lambda p, xs, ys: sgd_step(p, jax.grad(mlp_loss)(p, xs, ys), 0.25))
    g = np.random.default_rng(0)
    for _ in range(250):
        idx = g.choice(len(ytr), 200, replace=False)
        p = step(p, jnp.asarray(xtr[idx]), jnp.asarray(ytr[idx]))
    base_acc = accuracy(p, jnp.asarray(xte), jnp.asarray(yte))

    # activations on a probe batch: (K, HIDDEN); channels are columns → run
    # Fast MaxVol on the transposed feature matrix (channels as rows)
    probe = jnp.asarray(xtr[:512])
    H = jnp.tanh(probe @ p["w1"] + p["b1"])              # (512, HIDDEN)
    keep = HIDDEN // 2
    V = svd_features(H.T, keep)                          # channels × features
    piv, _ = fast_maxvol(V, keep)
    piv = np.sort(np.asarray(piv))

    def pruned_params(sel):
        return {"w1": p["w1"][:, sel], "b1": p["b1"][sel],
                "w2": p["w2"][sel, :], "b2": p["b2"]}

    maxvol_acc = accuracy(pruned_params(piv), jnp.asarray(xte), jnp.asarray(yte))
    mag = np.argsort(-np.linalg.norm(np.asarray(p["w1"]), axis=0))[:keep]
    mag_acc = accuracy(pruned_params(np.sort(mag)), jnp.asarray(xte), jnp.asarray(yte))
    rnd = np.sort(np.random.default_rng(0).choice(HIDDEN, keep, replace=False))
    rnd_acc = accuracy(pruned_params(rnd), jnp.asarray(xte), jnp.asarray(yte))

    flops_full = 2 * (DIM * HIDDEN + HIDDEN * CLASSES)
    flops_half = 2 * (DIM * keep + keep * CLASSES)
    print(json.dumps({
        "baseline": {"acc": round(base_acc, 4), "flops": flops_full},
        "maxvol_pruned_50%": {"acc": round(maxvol_acc, 4), "flops": flops_half},
        "magnitude_pruned_50%": {"acc": round(mag_acc, 4), "flops": flops_half},
        "random_pruned_50%": {"acc": round(rnd_acc, 4), "flops": flops_half},
    }, indent=1))


if __name__ == "__main__":
    main()
