"""End-to-end driver: train a ~100M-param LM with GRAFT vs full-batch
baseline, with checkpoint/restart fault tolerance.

The full 100M preset is sized for a real accelerator; ``--preset cpu`` (the
default here) runs a faithful scaled-down version in a few minutes on CPU.

Usage:
  PYTHONPATH=src python examples/train_lm_graft.py --preset cpu
  PYTHONPATH=src python examples/train_lm_graft.py --preset 100m --steps 300
"""
import argparse, json, os, sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import dataclasses
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager, EmergencySaver
from repro.selection import GraftConfig
from repro.data import DataConfig, SyntheticLM
from repro.distributed import sharding as sh
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_host_mesh
from repro.models.model import ModelConfig
from repro.optim import OptimizerConfig

PRESETS = {
    # ~100M params: 12L d768 12H — the paper-scale LM target
    "100m": dict(num_layers=12, d_model=768, num_heads=12, num_kv_heads=12,
                 head_dim=64, d_ff=3072, vocab_size=32000,
                 batch=64, seq=512),
    # CPU-friendly faithful miniature (~8M params)
    "cpu": dict(num_layers=4, d_model=256, num_heads=8, num_kv_heads=4,
                head_dim=32, d_ff=1024, vocab_size=2048,
                batch=16, seq=128),
}


def build(preset: str, use_graft: bool, steps: int, sampler: str = "graft"):
    p = dict(PRESETS[preset])
    batch, seq = p.pop("batch"), p.pop("seq")
    mcfg = ModelConfig(name=f"lm-{preset}", family="dense",
                       mlp_activation="silu", remat="none", **p)
    graft = GraftConfig(rset=(batch // 8, batch // 4, batch // 2), eps=0.3,
                        refresh_every=10, grad_mode="probe") if use_graft else None
    tcfg = steps_lib.TrainConfig(
        optimizer=OptimizerConfig(name="adamw", learning_rate=3e-4,
                                  schedule="cosine", total_steps=steps,
                                  warmup_steps=max(steps // 20, 1)),
        graft=graft, sampler=sampler, probe_positions=64)
    data = SyntheticLM(DataConfig(vocab_size=mcfg.vocab_size, seq_len=seq,
                                  global_batch=batch, seed=0))
    return mcfg, tcfg, data, batch


def run(preset: str, steps: int, use_graft: bool, ckpt_dir, sampler: str = "graft"):
    mcfg, tcfg, data, batch = build(preset, use_graft, steps, sampler)
    mesh = make_host_mesh()
    step_fn = jax.jit(steps_lib.make_train_step(mcfg, tcfg), donate_argnums=(0,))
    ckpt = CheckpointManager(ckpt_dir, keep_last_n=2, async_save=True) if ckpt_dir else None
    saver = EmergencySaver()
    with sh.sharding_rules(mesh):
        state = steps_lib.init_train_state(mcfg, tcfg, jax.random.PRNGKey(0), batch)
        start = 0
        if ckpt and ckpt.latest_step() is not None:
            s = ckpt.latest_step()
            state = ckpt.restore(s, state)
            start = ckpt.manifest(s)["extra"]["train_step"]
            data.load_state_dict(ckpt.manifest(s)["extra"]["data"])
            print(f"[resume] from step {start}")
        data.load_state_dict({"step": start})
        it = iter(data)
        losses = []
        for step in range(start, steps):
            batch_np = next(it)
            state, metrics = step_fn(state, {k: jnp.asarray(v) for k, v in batch_np.items()})
            losses.append(float(metrics["loss"]))
            if step % 10 == 0:
                extra = f" rank={float(metrics.get('rank', 0)):.0f}" if use_graft else ""
                print(f"step {step:4d} loss {losses[-1]:.4f}{extra}", flush=True)
            if ckpt and ((step + 1) % 50 == 0 or saver.should_stop):
                ckpt.save(step + 1, state, extra={"train_step": step + 1,
                                                  "data": data.state_dict()})
                if saver.should_stop:
                    print("[preempted] emergency checkpoint saved")
                    break
        if ckpt:
            ckpt.wait()
    return losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="cpu", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--sampler", default="graft",
                    help="subset strategy from the repro.selection registry "
                         "(graft | random | loss_topk | el2n | ...)")
    ap.add_argument("--compare", action="store_true",
                    help="also run the full-batch baseline for comparison")
    args = ap.parse_args()
    graft_losses = run(args.preset, args.steps, True, args.ckpt_dir,
                       sampler=args.sampler)
    out = {"graft_final": graft_losses[-1], "graft_first": graft_losses[0]}
    if args.compare:
        base_losses = run(args.preset, args.steps, False, None)
        out.update(baseline_final=base_losses[-1])
    print(json.dumps(out, indent=1))


if __name__ == "__main__":
    main()
