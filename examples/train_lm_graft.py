"""End-to-end driver: train a ~100M-param LM with GRAFT vs full-batch
baseline, with checkpoint/restart fault tolerance — all through the
Experiment API. The Trainer owns resume/preemption via its
CheckpointCallback plugin: kill the process mid-run and rerun with the same
``--ckpt-dir`` to continue from the last manifest.

The full 100M preset is sized for a real accelerator; ``--preset cpu`` (the
default here) runs a faithful scaled-down version in a few minutes on CPU.

Usage:
  PYTHONPATH=src python examples/train_lm_graft.py --preset cpu
  PYTHONPATH=src python examples/train_lm_graft.py --preset 100m --steps 300
"""
import argparse, json, os, sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.api import (ExperimentConfig, GraftConfig, ModelConfig,
                       OptimizerConfig, TrainConfig, Trainer)

PRESETS = {
    # ~100M params: 12L d768 12H — the paper-scale LM target
    "100m": {"num_layers": 12, "d_model": 768, "num_heads": 12,
             "num_kv_heads": 12, "head_dim": 64, "d_ff": 3072,
             "vocab_size": 32000, "batch": 64, "seq": 512},
    # CPU-friendly faithful miniature (~8M params)
    "cpu": {"num_layers": 4, "d_model": 256, "num_heads": 8,
            "num_kv_heads": 4, "head_dim": 32, "d_ff": 1024,
            "vocab_size": 2048, "batch": 16, "seq": 128},
}


def experiment(preset: str, use_graft: bool, steps: int, ckpt_dir,
               sampler: str = "graft") -> ExperimentConfig:
    p = dict(PRESETS[preset])
    batch, seq = p.pop("batch"), p.pop("seq")
    # minicpm's smoke config ties embeddings; these presets always carried a
    # separate lm_head (the 100m param count includes the 768×32000 head)
    p.update(remat="none", mlp_activation="silu", tie_embeddings=False)
    graft = GraftConfig(rset=(batch // 8, batch // 4, batch // 2), eps=0.3,
                        refresh_every=10, grad_mode="probe") if use_graft else None
    return ExperimentConfig(
        model=ModelConfig(arch="minicpm-2b", smoke=True, overrides=p),
        train=TrainConfig(steps=steps, batch=batch, seq=seq, seed=0,
                          sampler=sampler, probe_positions=64, log_every=10,
                          checkpoint_dir=ckpt_dir, checkpoint_every=50),
        graft=graft,
        optimizer=OptimizerConfig(name="adamw", learning_rate=3e-4,
                                  schedule="cosine", total_steps=steps,
                                  warmup_steps=max(steps // 20, 1)))


def run(preset: str, steps: int, use_graft: bool, ckpt_dir,
        sampler: str = "graft"):
    cfg = experiment(preset, use_graft, steps, ckpt_dir, sampler)
    report = Trainer(cfg).fit()
    return [h["loss"] for h in report["history"]]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="cpu", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--sampler", default="graft",
                    help="subset strategy from the repro.selection registry "
                         "(graft | random | loss_topk | el2n | ...)")
    ap.add_argument("--compare", action="store_true",
                    help="also run the full-batch baseline for comparison")
    args = ap.parse_args()
    graft_losses = run(args.preset, args.steps, True, args.ckpt_dir,
                       sampler=args.sampler)
    out = {"graft_final": graft_losses[-1], "graft_first": graft_losses[0]}
    if args.compare:
        base_losses = run(args.preset, args.steps, False, None)
        out.update(baseline_final=base_losses[-1])
    print(json.dumps(out, indent=1))


if __name__ == "__main__":
    main()
