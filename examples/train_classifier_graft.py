"""GRAFT vs random / loss_topk on the synthetic_classification workload —
the data-source registry demo: the SAME Experiment API, Trainer, samplers,
and selection forward as the LM pipeline, pointed at a non-LM task with one
override (``data.source=synthetic_classification``).

The source is an imbalanced Gaussian-mixture stream with label noise — the
regime where the samplers actually rank differently: random subsets miss
rare classes, loss-topk chases flipped labels, GRAFT's MaxVol pivots chase
feature diversity.

Usage:  PYTHONPATH=src python examples/train_classifier_graft.py
        PYTHONPATH=src python examples/train_classifier_graft.py \
            --steps 120 --samplers graft random loss_topk full
"""
import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.api import ExperimentConfig, Trainer
from repro.launch.evaluate import make_eval_fn_for


def run_one(sampler: str, args) -> dict:
    cfg = ExperimentConfig().apply_overrides([
        f"train.steps={args.steps}",
        f"train.batch={args.batch}",
        "train.log_every=0",
        f"train.sampler={sampler}",
        f"optimizer.learning_rate={args.lr}",
        "data.source=synthetic_classification",
        f"data.num_classes={args.classes}",
        f"data.imbalance={args.imbalance}",
        f"data.label_noise={args.label_noise}",
    ])
    trainer = Trainer(cfg)
    report = trainer.fit()
    evaluate = make_eval_fn_for(trainer.config, trainer.mcfg, num_batches=8)
    metrics = evaluate(trainer.state["params"])
    losses = [h["loss"] for h in report["history"]]
    return {
        "final_loss": round(report["final_loss"], 4),
        "loss_drop": round(sum(losses[:5]) / 5 - sum(losses[-5:]) / 5, 4),
        "eval_acc": round(metrics["eval_acc"], 4),
        "eval_loss": round(metrics["eval_loss"], 4),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--classes", type=int, default=10)
    ap.add_argument("--imbalance", type=float, default=1.0)
    ap.add_argument("--label-noise", type=float, default=0.1)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--samplers", nargs="+",
                    default=["graft", "random", "loss_topk"])
    args = ap.parse_args()

    rows = {}
    for sampler in args.samplers:
        rows[sampler] = run_one(sampler, args)
        print(f"[{sampler:>9s}] {rows[sampler]}", flush=True)
    print(json.dumps(rows, indent=1))
    best = max(rows, key=lambda s: rows[s]["eval_acc"])
    print(f"\nbest eval accuracy: {best} ({rows[best]['eval_acc']})")


if __name__ == "__main__":
    main()
