"""Per-arch smoke tests (reduced configs): one forward/train step on CPU,
output shapes + no NaNs; decode parity; chunked-path equivalence; MoE
routing invariants."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.models import decode as Dec
from repro.models import layers as L
from repro.models import model as M

ARCHS = list(configs.CANONICAL_IDS)


def make_batch(cfg, rng, B=4, Seq=32):
    if cfg.family == "audio":
        return {
            "frame_embeds": jnp.asarray(
                rng.normal(size=(B, Seq, cfg.d_model)).astype(np.float32)),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, Seq)),
                                  dtype=jnp.int32)}
    if cfg.family == "vlm":
        st = Seq - cfg.num_patches
        return {
            "patch_embeds": jnp.asarray(
                rng.normal(size=(B, cfg.num_patches, cfg.d_model)).astype(np.float32)),
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, st)),
                                  dtype=jnp.int32),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, st)),
                                  dtype=jnp.int32)}
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, Seq)), dtype=jnp.int32)
    return {"tokens": toks, "labels": toks}


@pytest.mark.parametrize("arch", ARCHS)
class TestArchSmoke:
    def test_forward_loss_grad(self, arch, rng):
        cfg = configs.get_smoke_config(arch)
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        batch = make_batch(cfg, rng)
        loss, aux = jax.jit(lambda p, b: M.loss_fn(cfg, p, b))(params, batch)
        assert np.isfinite(float(loss))
        grads = jax.grad(lambda p: M.loss_fn(cfg, p, batch)[0])(params)
        for path, g in jax.tree_util.tree_flatten_with_path(grads)[0]:
            assert np.isfinite(np.asarray(g)).all(), path

    def test_full_config_exactness(self, arch):
        """The registered full config carries the exact published dims."""
        cfg = configs.get_config(arch)
        expected = {
            "stablelm-12b": (40, 5120, 32, 8, 13824, 100352),
            "gemma2-27b": (46, 4608, 32, 16, 36864, 256000),
            "qwen1.5-32b": (64, 5120, 40, 40, 27392, 152064),
            "minicpm-2b": (40, 2304, 36, 36, 5760, 122753),
            "qwen3-moe-235b-a22b": (94, 4096, 64, 4, 1536, 151936),
            "kimi-k2-1t-a32b": (61, 7168, 64, 8, 2048, 163840),
            "rwkv6-7b": (32, 4096, 64, 64, 14336, 65536),
            "musicgen-medium": (48, 1536, 24, 24, 6144, 2048),
            "internvl2-26b": (48, 6144, 48, 8, 16384, 92553),
            "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
        }[arch]
        got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
               cfg.d_ff, cfg.vocab_size)
        assert got == expected

    def test_pooled_features_and_per_example_loss(self, arch, rng):
        cfg = configs.get_smoke_config(arch)
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        batch = make_batch(cfg, rng)
        f = M.pooled_features(cfg, params, batch)
        pel = M.per_example_loss(cfg, params, batch)
        assert f.shape == (4, cfg.d_model) and pel.shape == (4,)
        assert np.isfinite(np.asarray(f)).all()

    def test_params_logical_structure_matches(self, arch):
        cfg = configs.get_smoke_config(arch)
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        lg = M.params_logical(cfg, params)
        flat_p = jax.tree_util.tree_flatten_with_path(params)[0]
        is_lg = lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x)
        flat_l = jax.tree_util.tree_flatten_with_path(lg, is_leaf=is_lg)[0]
        assert len(flat_p) == len(flat_l)
        for (pp, leaf), (_lp, logical) in zip(flat_p, flat_l):
            assert len(logical) == leaf.ndim, (pp, logical, leaf.shape)


class TestDecodeParity:
    @pytest.mark.parametrize("arch", ["stablelm-12b", "gemma2-27b",
                                      "rwkv6-7b", "hymba-1.5b"])
    def test_decode_matches_teacher_forcing(self, arch, rng):
        cfg = configs.get_smoke_config(arch)
        params = M.init_params(cfg, jax.random.PRNGKey(1))
        B, Seq, P = 2, 24, 8
        tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, Seq)),
                             dtype=jnp.int32)
        batch = {"tokens": tokens, "labels": tokens}
        h, _ = M.forward_hiddens(cfg, params, batch)
        ref = M.logits_from_hiddens(cfg, params, h)[:, P - 1:, :]
        logits_p, cache = Dec.prefill(
            cfg, params, {"tokens": tokens[:, :P], "labels": tokens[:, :P]},
            max_seq=Seq)
        outs = [logits_p[:, 0]]
        step = jax.jit(lambda p, c, t: Dec.decode_step(cfg, p, c, t))
        for t in range(P, Seq):
            lg, cache = step(params, cache, tokens[:, t:t + 1])
            outs.append(lg[:, 0])
        dec = jnp.stack(outs, axis=1)
        err = float(jnp.max(jnp.abs(dec.astype(jnp.float32) -
                                    ref.astype(jnp.float32))))
        scale = float(jnp.max(jnp.abs(ref.astype(jnp.float32))))
        assert err < 0.02 * max(scale, 1.0) + 1e-3, (arch, err, scale)

    def test_moe_decode_dropless(self, rng):
        """Single-token decode is batching-invariant (dropless capacity)."""
        cfg = configs.get_smoke_config("qwen3-moe-235b-a22b",
                                       moe_capacity_factor=4.0)
        params = M.init_params(cfg, jax.random.PRNGKey(1))
        B, Seq, P = 2, 16, 8
        tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, Seq)),
                             dtype=jnp.int32)
        h, _ = M.forward_hiddens(cfg, params, {"tokens": tokens, "labels": tokens})
        ref = M.logits_from_hiddens(cfg, params, h)[:, P - 1:, :]
        logits_p, cache = Dec.prefill(
            cfg, params, {"tokens": tokens[:, :P], "labels": tokens[:, :P]},
            max_seq=Seq)
        outs = [logits_p[:, 0]]
        for t in range(P, Seq):
            lg, cache = Dec.decode_step(cfg, params, cache, tokens[:, t:t + 1])
            outs.append(lg[:, 0])
        dec = jnp.stack(outs, axis=1)
        err = float(jnp.max(jnp.abs(dec.astype(jnp.float32) -
                                    ref.astype(jnp.float32))))
        assert err < 0.1, err


class TestChunkedPaths:
    @pytest.mark.parametrize("arch", ["stablelm-12b", "gemma2-27b"])
    def test_chunked_attention_and_loss_match_dense(self, arch, rng):
        cfg0 = configs.get_smoke_config(arch, param_dtype="float32")
        cfg1 = dataclasses.replace(cfg0, attn_chunk=16, loss_chunk=16)
        params = M.init_params(cfg0, jax.random.PRNGKey(0))
        batch = make_batch(cfg0, rng, B=2, Seq=64)
        l0, _ = M.loss_fn(cfg0, params, batch)
        l1, _ = M.loss_fn(cfg1, params, batch)
        np.testing.assert_allclose(float(l0), float(l1), rtol=1e-5)
        g0 = jax.grad(lambda p: M.loss_fn(cfg0, p, batch)[0])(params)
        g1 = jax.grad(lambda p: M.loss_fn(cfg1, p, batch)[0])(params)
        num = sum(float(jnp.sum((a - b) ** 2)) for a, b in zip(
            jax.tree_util.tree_leaves(g0), jax.tree_util.tree_leaves(g1)))
        den = sum(float(jnp.sum(a ** 2)) for a in jax.tree_util.tree_leaves(g0))
        assert (num / den) ** 0.5 < 1e-4

    def test_sliding_window_chunked(self, rng):
        """Window masking must survive the chunked path (gemma2 local layers)."""
        cfg0 = configs.get_smoke_config("gemma2-27b", param_dtype="float32",
                                        sliding_window=8)
        cfg1 = dataclasses.replace(cfg0, attn_chunk=16)
        params = M.init_params(cfg0, jax.random.PRNGKey(0))
        batch = make_batch(cfg0, rng, B=2, Seq=64)
        h0, _ = M.forward_hiddens(cfg0, params, batch)
        h1, _ = M.forward_hiddens(cfg1, params, batch)
        np.testing.assert_allclose(np.asarray(h0), np.asarray(h1),
                                   atol=1e-4, rtol=1e-4)


class TestAttnBackends:
    """``attn_backend`` routing: the Pallas flash path (interpret on CPU)
    must agree with the jnp chunked/dense paths in forward AND gradient,
    fall back cleanly on shapes the kernel refuses, and dispatch exactly
    one ``pallas_call`` per layer when it does run."""

    @staticmethod
    def _grad_rel_err(cfg0, cfg1, params, batch):
        g0 = jax.grad(lambda p: M.loss_fn(cfg0, p, batch)[0])(params)
        g1 = jax.grad(lambda p: M.loss_fn(cfg1, p, batch)[0])(params)
        num = sum(float(jnp.sum((a - b) ** 2)) for a, b in zip(
            jax.tree_util.tree_leaves(g0), jax.tree_util.tree_leaves(g1)))
        den = sum(float(jnp.sum(a ** 2))
                  for a in jax.tree_util.tree_leaves(g0))
        return (num / den) ** 0.5

    @pytest.mark.parametrize("arch,extra", [
        ("stablelm-12b", {}),                        # GQA, no window
        ("gemma2-27b", {"sliding_window": 8}),       # GQA + local/global
    ])                                               #   pattern + softcap
    @pytest.mark.parametrize("backend", ["flash", "chunked"])
    def test_backend_matches_dense_fwd_and_grad(self, arch, extra, backend,
                                                rng):
        cfg_d = configs.get_smoke_config(arch, param_dtype="float32",
                                         attn_backend="dense", **extra)
        cfg_b = dataclasses.replace(cfg_d, attn_backend=backend,
                                    attn_chunk=16)
        params = M.init_params(cfg_d, jax.random.PRNGKey(0))
        batch = make_batch(cfg_d, rng, B=2, Seq=64)
        l_d, _ = M.loss_fn(cfg_d, params, batch)
        l_b, _ = M.loss_fn(cfg_b, params, batch)
        np.testing.assert_allclose(float(l_d), float(l_b), rtol=2e-5)
        assert self._grad_rel_err(cfg_d, cfg_b, params, batch) < 5e-4

    def test_non_divisible_seq_falls_back_to_jnp(self, rng):
        """S=60 fits no kernel block size — explicit flash must silently
        take the jnp path and match dense EXACTLY (same code path)."""
        cfg_d = configs.get_smoke_config("stablelm-12b",
                                         param_dtype="float32",
                                         attn_backend="dense",
                                         attn_chunk=None)
        cfg_f = dataclasses.replace(cfg_d, attn_backend="flash")
        assert L.resolve_attn_backend(cfg_f, 60, 60) == "dense"
        params = M.init_params(cfg_d, jax.random.PRNGKey(0))
        batch = make_batch(cfg_d, rng, B=2, Seq=60)
        l_d, _ = M.loss_fn(cfg_d, params, batch)
        l_f, _ = M.loss_fn(cfg_f, params, batch)
        assert float(l_d) == float(l_f)

    def test_routing_dispatch_counts(self, rng):
        """flash traces to exactly one pallas_call per layer; auto stays on
        the jnp paths off-TPU (zero pallas_call on CPU)."""
        def count_pallas(fn, *args):
            n = 0
            def walk(jp):
                nonlocal n
                for eqn in jp.eqns:
                    n += eqn.primitive.name == "pallas_call"
                    for v in eqn.params.values():
                        for sub in (v if isinstance(v, (list, tuple))
                                    else [v]):
                            if isinstance(sub, jax.core.ClosedJaxpr):
                                walk(sub.jaxpr)
                            elif isinstance(sub, jax.core.Jaxpr):
                                walk(sub)
            walk(jax.make_jaxpr(fn)(*args).jaxpr)
            return n

        def mk(backend):
            return M.ModelConfig(
                family="dense", num_layers=2, d_model=64, num_heads=4,
                num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=64,
                param_dtype="float32", scan_layers=False,
                attn_backend=backend)

        cfg = mk("flash")
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        batch = make_batch(cfg, rng, B=2, Seq=32)
        assert count_pallas(
            lambda p, b: M.loss_fn(cfg, p, b)[0], params, batch) == 2
        cfg_auto = mk("auto")
        n_auto = count_pallas(
            lambda p, b: M.loss_fn(cfg_auto, p, b)[0], params, batch)
        assert n_auto == (2 if jax.default_backend() == "tpu" else 0)

    def test_chunked_fully_masked_row_is_zero(self, rng):
        """Regression for the masked-tile bug in the jnp online softmax: a
        query row whose ENTIRE mask row is false must produce exactly 0,
        not the renormalized mean of V."""
        cfg = configs.get_smoke_config("stablelm-12b", attn_chunk=8)
        B, S, Hkv, g, Dh = 1, 16, 2, 2, 8
        qg = jnp.asarray(rng.normal(size=(B, S, Hkv, g, Dh)).astype(np.float32))
        k = jnp.asarray(rng.normal(size=(B, S, Hkv, Dh)).astype(np.float32))
        v = jnp.asarray(rng.normal(size=(B, S, Hkv, Dh)).astype(np.float32))
        mask = np.tril(np.ones((S, S), bool))[None]
        mask[:, 0, :] = False                       # row 0: no visible keys
        out = np.asarray(L._chunked_attention(cfg, qg, k, v,
                                              jnp.asarray(mask)))
        assert np.array_equal(out[0, 0], np.zeros_like(out[0, 0]))
        assert np.isfinite(out).all()
        assert np.abs(out[0, 1:]).max() > 0


class TestMoEInvariants:
    def _setup(self, rng, cf=8.0):
        cfg = configs.get_smoke_config("qwen3-moe-235b-a22b",
                                       moe_capacity_factor=cf)
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        p = jax.tree_util.tree_map(lambda x: x[0], params["blocks"]["moe"])
        x = jnp.asarray(rng.normal(size=(2, 32, cfg.d_model)).astype(np.float32))
        return cfg, p, x

    def test_combine_mass_conservation_no_drops(self, rng):
        """With generous capacity, Σ_e,c combine[t] == 1 for every token."""
        cfg, p, x = self._setup(rng, cf=8.0)
        B, S, D = x.shape
        E, k = cfg.num_experts, cfg.num_experts_per_tok
        gs = min(cfg.moe_group_size, B * S)
        xt = x.reshape(-1, gs, D)
        logits = jnp.einsum("gsd,de->gse", xt, p["router"])
        probs = jax.nn.softmax(logits, axis=-1)
        gate, ids = jax.lax.top_k(probs, k)
        # run the layer and check output is a convex-ish combination: use the
        # public API — mass conservation shows as output magnitude stability
        out = L.moe(cfg, p, x.astype(cfg.dtype))
        assert np.isfinite(np.asarray(out)).all()
        out_dropless = L.moe(cfg, p, x.astype(cfg.dtype), dropless=True)
        np.testing.assert_allclose(np.asarray(out).astype(np.float32),
                                   np.asarray(out_dropless).astype(np.float32),
                                   atol=2e-2)

    def test_capacity_drops_reduce_output(self, rng):
        """Tiny capacity must drop tokens (outputs differ from dropless)."""
        cfg, p, x = self._setup(rng, cf=0.25)
        out_small = np.asarray(L.moe(cfg, p, x.astype(cfg.dtype))).astype(np.float32)
        out_free = np.asarray(L.moe(cfg, p, x.astype(cfg.dtype),
                                    dropless=True)).astype(np.float32)
        assert np.abs(out_small - out_free).max() > 1e-4


class TestLayerPatterns:
    def test_gemma2_local_global_pattern(self):
        cfg = configs.get_config("gemma2-27b")
        pat = cfg.is_local_pattern()
        assert pat[0] and not pat[1] and pat[2] and len(pat) == 46

    def test_hymba_global_islands(self):
        cfg = configs.get_config("hymba-1.5b")
        pat = cfg.is_local_pattern()
        assert not pat[0] and not pat[15] and not pat[31]
        assert pat[1] and pat[30]

    def test_sliding_window_blocks_long_range(self, rng):
        """A token beyond the window must not influence a local-only model."""
        cfg = configs.get_smoke_config(
            "gemma2-27b", layer_pattern=("local",), sliding_window=4,
            param_dtype="float32")
        params = M.init_params(cfg, jax.random.PRNGKey(0))
        toks = rng.integers(2, cfg.vocab_size, (1, 24)).astype(np.int32)
        toks2 = toks.copy()
        toks2[0, 0] = (toks2[0, 0] + 1) % cfg.vocab_size   # perturb far past
        h1, _ = M.forward_hiddens(cfg, params, {"tokens": jnp.asarray(toks),
                                                "labels": jnp.asarray(toks)})
        h2, _ = M.forward_hiddens(cfg, params, {"tokens": jnp.asarray(toks2),
                                                "labels": jnp.asarray(toks2)})
        # with 2 local layers of window 4, position 23 sees back to ~16 > 0
        np.testing.assert_allclose(np.asarray(h1)[0, -1], np.asarray(h2)[0, -1],
                                   atol=1e-5)
