"""Per-kernel allclose sweeps vs the pure-jnp oracles (interpret mode runs
the exact TPU kernel body on CPU)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ops, ref
from repro.kernels.fast_maxvol import fast_maxvol_pallas
from repro.kernels.projection_sweep import projection_sweep_pallas
from repro.kernels.rwkv_scan import rwkv_scan_pallas


class TestFastMaxvolKernel:
    @pytest.mark.parametrize("K,R,rank", [
        (16, 4, 4), (64, 16, 16), (128, 32, 8), (256, 64, 64),
        (100, 12, 12), (33, 7, 5),
    ])
    def test_matches_ref(self, rng, K, R, rank):
        V = jnp.asarray(rng.normal(size=(K, R)).astype(np.float32))
        piv_k, lv_k = fast_maxvol_pallas(V, rank, interpret=True)
        piv_r, lv_r = ref.fast_maxvol_ref(V, rank)
        assert np.array_equal(np.asarray(piv_k), np.asarray(piv_r))
        np.testing.assert_allclose(float(lv_k), float(lv_r), rtol=1e-5)

    @pytest.mark.parametrize("dtype", [np.float32, np.float64, np.float16])
    def test_dtype_sweep(self, rng, dtype):
        V = jnp.asarray(rng.normal(size=(64, 8)).astype(dtype))
        piv_k, _ = fast_maxvol_pallas(V, 8, interpret=True)
        piv_r, _ = ref.fast_maxvol_ref(V.astype(jnp.float32), 8)
        assert np.array_equal(np.asarray(piv_k), np.asarray(piv_r))

    def test_vmem_budget_guard(self, rng):
        V = jnp.zeros((4096, 1024), jnp.float32)      # 16 MB > budget
        with pytest.raises(ValueError, match="VMEM"):
            fast_maxvol_pallas(V, 16, interpret=True)


class TestProjectionSweepKernel:
    @pytest.mark.parametrize("d,R", [(32, 4), (50, 16), (512, 32),
                                     (2048, 64), (999, 13)])
    def test_matches_ref(self, rng, d, R):
        G = jnp.asarray(rng.normal(size=(d, R)).astype(np.float32))
        g = jnp.asarray(rng.normal(size=(d,)).astype(np.float32))
        e_k = projection_sweep_pallas(G, g, interpret=True)
        e_r = ref.projection_sweep_ref(G, g)
        np.testing.assert_allclose(np.asarray(e_k), np.asarray(e_r), atol=1e-5)

    def test_monotone(self, rng):
        G = jnp.asarray(rng.normal(size=(128, 24)).astype(np.float32))
        g = jnp.asarray(rng.normal(size=(128,)).astype(np.float32))
        errs = np.asarray(projection_sweep_pallas(G, g, interpret=True))
        assert np.all(np.diff(errs) <= 1e-5)


class TestRwkvScanKernel:
    @pytest.mark.parametrize("BH,T,D,chunk", [
        (1, 32, 16, 8), (4, 64, 32, 16), (2, 128, 64, 32), (3, 96, 48, 32),
    ])
    def test_matches_ref(self, rng, BH, T, D, chunk):
        r = rng.normal(size=(BH, T, D)).astype(np.float32) * 0.3
        k = rng.normal(size=(BH, T, D)).astype(np.float32) * 0.3
        v = rng.normal(size=(BH, T, D)).astype(np.float32) * 0.3
        w = (0.4 + 0.59 * rng.random(size=(BH, T, D))).astype(np.float32)
        u = rng.normal(size=(BH, D)).astype(np.float32) * 0.1
        o_k = rwkv_scan_pallas(*map(jnp.asarray, (r, k, v, w, u)),
                               chunk=chunk, interpret=True)
        o_r = np.stack([np.asarray(ref.rwkv_chunk_ref(
            jnp.asarray(r[i]), jnp.asarray(k[i]), jnp.asarray(v[i]),
            jnp.asarray(w[i]), jnp.asarray(u[i]))) for i in range(BH)])
        np.testing.assert_allclose(np.asarray(o_k), o_r, atol=2e-4)

    def test_chunk_invariance(self, rng):
        """Output must not depend on the chunk size (state carried exactly)."""
        BH, T, D = 2, 64, 32
        args = (rng.normal(size=(BH, T, D)).astype(np.float32) * 0.3,
                rng.normal(size=(BH, T, D)).astype(np.float32) * 0.3,
                rng.normal(size=(BH, T, D)).astype(np.float32) * 0.3,
                (0.5 + 0.49 * rng.random((BH, T, D))).astype(np.float32),
                rng.normal(size=(BH, D)).astype(np.float32) * 0.1)
        outs = [np.asarray(rwkv_scan_pallas(*map(jnp.asarray, args),
                                            chunk=c, interpret=True))
                for c in (8, 16, 64)]
        np.testing.assert_allclose(outs[0], outs[1], atol=1e-5)
        np.testing.assert_allclose(outs[0], outs[2], atol=1e-5)

    def test_indivisible_chunk_raises(self, rng):
        with pytest.raises(ValueError, match="divisible"):
            rwkv_scan_pallas(jnp.zeros((1, 30, 8)), jnp.zeros((1, 30, 8)),
                             jnp.zeros((1, 30, 8)), jnp.ones((1, 30, 8)),
                             jnp.zeros((1, 8)), chunk=16, interpret=True)


class TestOpsDispatch:
    def test_ops_cpu_uses_interpret(self, rng):
        V = jnp.asarray(rng.normal(size=(32, 8)).astype(np.float32))
        piv = ops.fast_maxvol(V, 8)
        piv_r, _ = ref.fast_maxvol_ref(V, 8)
        assert np.array_equal(np.asarray(piv), np.asarray(piv_r))

    def test_graft_select_with_pallas_kernels(self, rng):
        """GraftConfig(use_pallas=True) must agree with the jnp path."""
        from repro.core import graft
        from repro.core.features import svd_features
        K, d = 32, 24
        A = jnp.asarray(rng.normal(size=(K, d)).astype(np.float32))
        G = jnp.asarray(rng.normal(size=(d, K)).astype(np.float32))
        gb = jnp.asarray(np.asarray(G).mean(1))
        V = svd_features(A, 8)
        cfg_j = graft.GraftConfig(rset=(2, 4, 8), eps=0.3, use_pallas=False)
        cfg_p = graft.GraftConfig(rset=(2, 4, 8), eps=0.3, use_pallas=True)
        s_j = graft.graft_select(cfg_j, V, G, gb, jnp.int32(0))
        s_p = graft.graft_select(cfg_p, V, G, gb, jnp.int32(0))
        assert np.array_equal(np.asarray(s_j.pivots), np.asarray(s_p.pivots))
        assert int(s_j.rank) == int(s_p.rank)
        np.testing.assert_allclose(float(s_j.last_error),
                                   float(s_p.last_error), atol=1e-5)


class TestFlashAttentionKernel:
    @pytest.mark.parametrize("BH,S,Dh,bq,bk,causal,window,softcap", [
        (2, 256, 64, 128, 128, True, None, None),
        (1, 256, 128, 64, 64, True, None, 50.0),      # gemma2-style softcap
        (3, 128, 32, 64, 32, True, 48, None),          # sliding window
        (2, 256, 64, 128, 64, False, None, None),      # bidirectional
        (1, 128, 64, 128, 128, True, None, None),      # single tile
    ])
    def test_matches_dense_oracle(self, rng, BH, S, Dh, bq, bk, causal,
                                  window, softcap):
        from repro.kernels.flash_attention import flash_attention_pallas
        q = jnp.asarray(rng.normal(size=(BH, S, Dh)).astype(np.float32))
        k = jnp.asarray(rng.normal(size=(BH, S, Dh)).astype(np.float32))
        v = jnp.asarray(rng.normal(size=(BH, S, Dh)).astype(np.float32))
        o_k = flash_attention_pallas(q, k, v, block_q=bq, block_k=bk,
                                     causal=causal, window=window,
                                     softcap=softcap, interpret=True)
        o_r = ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                      softcap=softcap)
        np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r),
                                   atol=2e-5)

    def test_block_size_invariance(self, rng):
        from repro.kernels.flash_attention import flash_attention_pallas
        q = jnp.asarray(rng.normal(size=(2, 256, 64)).astype(np.float32))
        k = jnp.asarray(rng.normal(size=(2, 256, 64)).astype(np.float32))
        v = jnp.asarray(rng.normal(size=(2, 256, 64)).astype(np.float32))
        outs = [np.asarray(flash_attention_pallas(
            q, k, v, block_q=bq, block_k=bk, interpret=True))
            for bq, bk in ((256, 256), (128, 64), (64, 128))]
        np.testing.assert_allclose(outs[0], outs[1], atol=1e-5)
        np.testing.assert_allclose(outs[0], outs[2], atol=1e-5)

    def test_vmem_budget_guard(self):
        from repro.kernels.flash_attention import flash_attention_pallas
        big = jnp.zeros((1, 65536, 128), jnp.float32)
        with pytest.raises(ValueError, match="VMEM"):
            flash_attention_pallas(big, big, big, interpret=True)

    def test_bf16_inputs(self, rng):
        from repro.kernels.flash_attention import flash_attention_pallas
        q = jnp.asarray(rng.normal(size=(1, 128, 64))).astype(jnp.bfloat16)
        k = jnp.asarray(rng.normal(size=(1, 128, 64))).astype(jnp.bfloat16)
        v = jnp.asarray(rng.normal(size=(1, 128, 64))).astype(jnp.bfloat16)
        o_k = flash_attention_pallas(q, k, v, block_q=64, block_k=64,
                                     interpret=True)
        o_r = ref.flash_attention_ref(q, k, v)
        assert o_k.dtype == jnp.bfloat16
        np.testing.assert_allclose(np.asarray(o_k, np.float32),
                                   np.asarray(o_r, np.float32), atol=3e-2)

    def test_fully_masked_rows_are_zero(self, rng):
        """Regression for the masked-tile bug: a window that masks EVERY
        key for a q row must yield exactly 0, not exp(-1e30 − (−1e30)) = 1
        renormalized into the mean of V (the pre-fix garbage)."""
        from repro.kernels.flash_attention import flash_attention_pallas
        q = jnp.asarray(rng.normal(size=(2, 128, 32)).astype(np.float32))
        k = jnp.asarray(rng.normal(size=(2, 128, 32)).astype(np.float32))
        v = jnp.asarray(rng.normal(size=(2, 128, 32)).astype(np.float32))
        o = flash_attention_pallas(q, k, v, block_q=64, block_k=64,
                                   causal=True, window=0, interpret=True)
        assert np.array_equal(np.asarray(o), np.zeros_like(np.asarray(o)))

    @pytest.mark.parametrize("causal,window", [(True, None), (True, 48)])
    def test_bounded_loop_bit_parity(self, rng, causal, window):
        """The causal/window KV loop bound must be a pure skip: every tile
        it skips is fully masked, so bounded vs exhaustive is BITWISE
        identical (skipped tiles contribute alpha=1, p=0 exactly)."""
        from repro.kernels.flash_attention import flash_attention_pallas
        q = jnp.asarray(rng.normal(size=(2, 256, 32)).astype(np.float32))
        k = jnp.asarray(rng.normal(size=(2, 256, 32)).astype(np.float32))
        v = jnp.asarray(rng.normal(size=(2, 256, 32)).astype(np.float32))
        kw = {"block_q": 64, "block_k": 64, "causal": causal,
              "window": window, "interpret": True}
        o_b = flash_attention_pallas(q, k, v, bound_loop=True, **kw)
        o_u = flash_attention_pallas(q, k, v, bound_loop=False, **kw)
        assert np.array_equal(np.asarray(o_b), np.asarray(o_u))

    @pytest.mark.parametrize("group", [2, 4])
    def test_gqa_matches_repeated_kv(self, rng, group):
        """group > 1 folds GQA into the BH axis (kv stream = bh // group)
        without materializing repeated K/V — must match the repeat."""
        from repro.kernels.flash_attention import flash_attention_pallas
        BHkv, S, Dh = 2, 128, 32
        q = jnp.asarray(
            rng.normal(size=(BHkv * group, S, Dh)).astype(np.float32))
        k = jnp.asarray(rng.normal(size=(BHkv, S, Dh)).astype(np.float32))
        v = jnp.asarray(rng.normal(size=(BHkv, S, Dh)).astype(np.float32))
        o_g = flash_attention_pallas(q, k, v, block_q=64, block_k=64,
                                     group=group, interpret=True)
        o_r = ref.flash_attention_ref(q, jnp.repeat(k, group, axis=0),
                                      jnp.repeat(v, group, axis=0))
        np.testing.assert_allclose(np.asarray(o_g), np.asarray(o_r),
                                   atol=2e-5)

    @pytest.mark.parametrize("window,softcap,group", [
        (None, None, 1), (48, None, 1), (None, 30.0, 1), (48, 30.0, 2),
    ])
    def test_grad_matches_ref(self, rng, window, softcap, group):
        """custom_vjp backward (recompute dq/dk/dv kernels) vs autodiff
        through the dense oracle."""
        from repro.kernels.flash_attention import flash_attention_pallas
        BHkv, S, Dh = 2, 128, 32
        q = jnp.asarray(
            rng.normal(size=(BHkv * group, S, Dh)).astype(np.float32))
        k = jnp.asarray(rng.normal(size=(BHkv, S, Dh)).astype(np.float32))
        v = jnp.asarray(rng.normal(size=(BHkv, S, Dh)).astype(np.float32))
        dout = jnp.asarray(
            rng.normal(size=(BHkv * group, S, Dh)).astype(np.float32))

        def loss_k(q, k, v):
            o = flash_attention_pallas(q, k, v, block_q=64, block_k=64,
                                       window=window, softcap=softcap,
                                       group=group, interpret=True)
            return jnp.sum(o * dout)

        def loss_r(q, k, v):
            kk = jnp.repeat(k, group, axis=0)
            vv = jnp.repeat(v, group, axis=0)
            o = ref.flash_attention_ref(q, kk, vv, window=window,
                                        softcap=softcap)
            return jnp.sum(o * dout)

        g_k = jax.grad(loss_k, argnums=(0, 1, 2))(q, k, v)
        g_r = jax.grad(loss_r, argnums=(0, 1, 2))(q, k, v)
        for a, b, name in zip(g_k, g_r, ("dq", "dk", "dv")):
            np.testing.assert_allclose(
                np.asarray(a), np.asarray(b), atol=5e-4,
                err_msg=f"{name} mismatch (window={window}, "
                        f"softcap={softcap}, group={group})")

    def test_dynamic_window_matches_static(self, rng):
        """window as a TRACED int (the model's scan-carried is_local) must
        match the python-int window bit for bit."""
        from repro.kernels.flash_attention import flash_attention_pallas
        q = jnp.asarray(rng.normal(size=(2, 128, 32)).astype(np.float32))
        k = jnp.asarray(rng.normal(size=(2, 128, 32)).astype(np.float32))
        v = jnp.asarray(rng.normal(size=(2, 128, 32)).astype(np.float32))

        @jax.jit
        def dyn(q, k, v, w):
            return flash_attention_pallas(q, k, v, block_q=64, block_k=64,
                                          window=w, interpret=True)

        o_d = dyn(q, k, v, jnp.int32(48))
        o_s = flash_attention_pallas(q, k, v, block_q=64, block_k=64,
                                     window=48, interpret=True)
        assert np.array_equal(np.asarray(o_d), np.asarray(o_s))
