"""Int8 KV-cache quantization: roundtrip bounds, decode-attention parity,
HBM accounting."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis_compat import given, settings, st  # skips, not collection errors, without hypothesis

from repro.models import kv_quant as KQ
from repro.kernels import ref as kernel_ref


class TestQuantization:
    def test_roundtrip_error_bound(self, rng):
        x = jnp.asarray(rng.normal(size=(2, 16, 4, 32)).astype(np.float32) * 3)
        q, s = KQ.quantize_kv(x)
        back = KQ.dequantize_kv(q, s, jnp.float32)
        # per-(token, head) bound: |err| ≤ absmax/127 (half-step = /254)
        absmax = np.abs(np.asarray(x)).max(-1)
        err = np.abs(np.asarray(back) - np.asarray(x)).max(-1)
        assert (err <= absmax / 127.0 + 1e-6).all()

    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 999), scale=st.floats(1e-3, 1e2))
    def test_property_bound_across_scales(self, seed, scale):
        g = np.random.default_rng(seed)
        x = jnp.asarray((g.normal(size=(1, 8, 2, 16)) * scale).astype(np.float32))
        q, s = KQ.quantize_kv(x)
        back = KQ.dequantize_kv(q, s, jnp.float32)
        absmax = np.abs(np.asarray(x)).max(-1) + 1e-12
        err = np.abs(np.asarray(back) - np.asarray(x)).max(-1)
        assert (err <= absmax / 127.0 + 1e-9 * scale).all()

    def test_update_and_read(self, rng):
        cache = KQ.init_quant_cache(2, 32, 4, 16)
        k1 = jnp.asarray(rng.normal(size=(2, 8, 4, 16)).astype(np.float32))
        v1 = jnp.asarray(rng.normal(size=(2, 8, 4, 16)).astype(np.float32))
        cache = KQ.update_quant_cache(cache, k1, v1, 0)
        k2 = jnp.asarray(rng.normal(size=(2, 1, 4, 16)).astype(np.float32))
        cache = KQ.update_quant_cache(cache, k2, k2, 8)
        k, v = KQ.read_quant_cache(cache, jnp.float32)
        np.testing.assert_allclose(np.asarray(k[:, :8]), np.asarray(k1),
                                   atol=np.abs(np.asarray(k1)).max() / 100)
        np.testing.assert_allclose(np.asarray(k[:, 8:9]), np.asarray(k2),
                                   atol=np.abs(np.asarray(k2)).max() / 100)
        assert np.abs(np.asarray(k[:, 9:])).max() == 0


class TestAttentionParity:
    def test_decode_attention_with_quantized_cache(self, rng):
        """Attention over an int8 cache ≈ attention over the exact cache —
        the end-to-end accuracy statement for the decode-cell lever."""
        BH, T, Dh = 4, 64, 64
        q = jnp.asarray(rng.normal(size=(BH, 1, Dh)).astype(np.float32))
        k = jnp.asarray(rng.normal(size=(BH, T, Dh)).astype(np.float32))
        v = jnp.asarray(rng.normal(size=(BH, T, Dh)).astype(np.float32))
        # exact
        o_ref = kernel_ref.flash_attention_ref(q, k, v, causal=False)
        # quantized cache path
        k4 = k.reshape(BH, T, 1, Dh)
        v4 = v.reshape(BH, T, 1, Dh)
        kq, ks = KQ.quantize_kv(k4)
        vq, vs = KQ.quantize_kv(v4)
        k_deq = KQ.dequantize_kv(kq, ks, jnp.float32).reshape(BH, T, Dh)
        v_deq = KQ.dequantize_kv(vq, vs, jnp.float32).reshape(BH, T, Dh)
        o_q = kernel_ref.flash_attention_ref(q, k_deq, v_deq, causal=False)
        rel = float(jnp.max(jnp.abs(o_q - o_ref)) /
                    (jnp.max(jnp.abs(o_ref)) + 1e-9))
        assert rel < 0.02, rel                       # <2 % of output range

    def test_hbm_accounting(self):
        # kimi-k2 decode_32k per layer: bf16 vs int8 at-rest bytes
        bf16 = KQ.cache_bytes(128, 32768, 8, 112, quantized=False)
        int8 = KQ.cache_bytes(128, 32768, 8, 112, quantized=True)
        assert bf16 / int8 == pytest.approx(1.93, abs=0.05)
