"""Distributed machinery: logical-axis rules, dry-run smoke (8 fake devices
via subprocess — the 512-device override belongs only to dryrun), collective
parsing, multi-device compression."""
import numpy as np
import jax
import pytest

from jax.sharding import Mesh, PartitionSpec as P

from conftest import run_forced_devices
from repro.distributed import sharding as sh


def run_py(code: str, devices: int = 8, timeout: int = 480) -> str:
    return run_forced_devices(code, devices=devices, timeout=timeout)


class TestShardingRules:
    def _mesh(self):
        dev = np.array(jax.devices()[:1]).reshape(1, 1)
        return Mesh(dev, ("data", "model"))

    def test_logical_to_spec_filters_missing_axes(self):
        mesh = self._mesh()
        spec = sh.logical_to_spec(("act_batch", None, "act_heads"), mesh)
        # "pod" axis not in mesh → filtered from the tuple rule
        assert spec == P(("data",), None, "model")

    def test_drop_indivisible(self):
        dev = np.array(jax.devices()[:1]).reshape(1, 1)
        mesh = Mesh(dev, ("data", "model"))
        # sizes are 1 so everything divides; use a fake larger mesh via spec
        spec = sh.drop_indivisible(P("data", "model"), (4, 4), mesh)
        assert spec == P("data", "model")

    def test_duplicate_axis_dedup(self):
        mesh = self._mesh()
        spec = sh.drop_indivisible(P("data", ("data", "model")), (4, 8), mesh)
        # first dim claims "data"; second keeps only "model"
        assert spec == P("data", "model")

    def test_constrain_noop_outside_context(self):
        import jax.numpy as jnp
        x = jnp.ones((4, 4))
        y = sh.constrain(x, ("act_batch", None))
        assert y is x


class TestCollectiveParsing:
    def test_parse_known_ops(self):
        from repro.launch.dryrun import parse_collective_bytes
        hlo = """
          %ag = bf16[4,1024]{1,0} all-gather(bf16[1,1024]{1,0} %x), replica_groups={}
          %ar = f32[2048]{0} all-reduce(f32[2048]{0} %y), to_apply=%sum
          %rs = f32[512]{0} reduce-scatter(f32[2048]{0} %z), dimensions={0}
          %cp = u8[100]{0} collective-permute(u8[100]{0} %w), source_target_pairs={{0,1}}
          %a2a = s32[64]{0} all-to-all(s32[64]{0} %v), dimensions={0}
          %dot = f32[8,8]{1,0} dot(f32[8,8] %a, f32[8,8] %b)
        """
        res = parse_collective_bytes(hlo)
        assert res["bytes_by_op"]["all-gather"] == 1 * 1024 * 2
        assert res["bytes_by_op"]["all-reduce"] == 2048 * 4
        assert res["bytes_by_op"]["reduce-scatter"] == 2048 * 4
        assert res["bytes_by_op"]["collective-permute"] == 100
        assert res["bytes_by_op"]["all-to-all"] == 64 * 4
        assert res["total_count"] == 5


@pytest.mark.slow
class TestDryrunSmoke:
    def test_train_cell_compiles_on_2x4(self):
        out = run_py("""
            from repro.launch import dryrun
            from repro.launch.mesh import make_mesh
            mesh = make_mesh((2, 4), ("data", "model"))
            res = dryrun.run_cell("minicpm-2b", "train_4k", "t", "graft",
                                  with_deltas=False, smoke=True,
                                  mesh_override=mesh)
            print("FLOPS", res["full"]["flops"] > 0)
            print("COLL", res["full"]["collectives"]["total_count"] > 0)
        """)
        assert "FLOPS True" in out and "COLL True" in out

    def test_decode_cell_compiles_multipod_2x2x2(self):
        out = run_py("""
            from repro.launch import dryrun
            from repro.launch.mesh import make_mesh
            mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
            res = dryrun.run_cell("hymba-1.5b", "decode_32k", "t", "serve",
                                  with_deltas=False, smoke=True,
                                  mesh_override=mesh)
            print("OK", res["full"]["flops"] >= 0)
        """)
        assert "OK True" in out

    def test_production_mesh_shapes(self):
        out = run_py("""
            from repro.launch.mesh import make_production_mesh
            m1 = make_production_mesh()
            m2 = make_production_mesh(multi_pod=True)
            print(m1.devices.shape, m1.axis_names)
            print(m2.devices.shape, m2.axis_names)
        """, devices=512)
        assert "(16, 16) ('data', 'model')" in out
        assert "(2, 16, 16) ('pod', 'data', 'model')" in out


@pytest.mark.slow
class TestCompressionMultiDevice:
    def test_ef_psum_across_8_shards(self):
        out = run_py("""
            import jax, jax.numpy as jnp, numpy as np
            from jax.experimental.shard_map import shard_map
            from jax.sharding import Mesh, PartitionSpec as P
            from repro.distributed import compression
            mesh = Mesh(np.array(jax.devices()).reshape(8), ("pod",))
            g = jnp.asarray(np.random.default_rng(0).normal(
                size=(8, 512)).astype(np.float32))
            e = jnp.zeros((8, 512))
            def f(g, e):
                out, ne = compression.ef_compressed_psum(g[0], e[0], "pod", 8)
                return out[None], ne[None]
            out, _ = shard_map(f, mesh=mesh, in_specs=(P("pod"), P("pod")),
                               out_specs=(P("pod"), P("pod")))(g, e)
            ref = np.asarray(g).mean(0)
            err = np.abs(np.asarray(out)[0] - ref).max()
            print("ERR_OK", err < 0.05, float(err))
        """)
        assert "ERR_OK True" in out


class TestElasticRestore:
    def test_checkpoint_restores_onto_different_sharding(self, tmp_path):
        """Save on 1 device, restore with an explicit sharding tree."""
        import jax.numpy as jnp
        from repro.checkpoint import CheckpointManager
        cm = CheckpointManager(str(tmp_path))
        tree = {"w": jnp.arange(16.0).reshape(4, 4)}
        cm.save(1, tree)
        mesh = Mesh(np.array(jax.devices()[:1]).reshape(1), ("data",))
        shard = jax.sharding.NamedSharding(mesh, P("data", None))
        out = cm.restore(1, tree, sharding_tree={"w": shard})
        assert out["w"].sharding.is_equivalent_to(shard, 2)
        np.testing.assert_array_equal(np.asarray(out["w"]),
                                      np.asarray(tree["w"]))


@pytest.mark.slow
class TestPipelineParallel:
    def test_gpipe_matches_sequential(self):
        out = run_py("""
            import numpy as np, jax, jax.numpy as jnp
            from jax.sharding import Mesh
            from repro.distributed.pipeline import pipeline_forward
            mesh = Mesh(np.array(jax.devices()[:4]), ("pod",))
            S, M, mb, D = 4, 3, 2, 8
            rng = np.random.default_rng(0)
            Ws = jnp.asarray(rng.normal(size=(S, D, D)).astype(np.float32) * 0.3)
            x = jnp.asarray(rng.normal(size=(M, mb, D)).astype(np.float32))
            out = pipeline_forward(lambda W, h: jnp.tanh(h @ W), Ws, x, mesh)
            ref = x
            for s in range(S):
                ref = jnp.tanh(ref @ Ws[s])
            print("ERR_OK", float(jnp.max(jnp.abs(out - ref))) < 1e-5)
        """)
        assert "ERR_OK True" in out

    def test_bubble_fraction(self):
        from repro.distributed.pipeline import pipeline_bubble_fraction
        assert pipeline_bubble_fraction(2, 1) == 0.5
        assert pipeline_bubble_fraction(4, 13) == 3 / 16
        assert pipeline_bubble_fraction(1, 8) == 0.0
