"""Fast MaxVol properties: greedy volume maximization, prefix consistency,
classical-MaxVol dominance condition, Cross-2D baseline sanity (paper §3.1,
Table 4)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis_compat import given, settings, st  # skips, not collection errors, without hypothesis

from repro.core import maxvol


def _random_V(rng, K, R):
    return jnp.asarray(rng.normal(size=(K, R)).astype(np.float32))


class TestFastMaxvol:
    def test_pivots_unique_and_valid(self, rng):
        V = _random_V(rng, 100, 16)
        piv, _ = maxvol.fast_maxvol(V, 16)
        piv = np.asarray(piv)
        assert len(set(piv.tolist())) == 16
        assert piv.min() >= 0 and piv.max() < 100

    def test_logvol_matches_slogdet(self, rng):
        V = _random_V(rng, 64, 8)
        piv, logvol = maxvol.fast_maxvol(V, 8)
        _, ref = np.linalg.slogdet(np.asarray(V)[np.asarray(piv), :8])
        np.testing.assert_allclose(float(logvol), ref, rtol=1e-4)

    def test_beats_random_volume(self, rng):
        """The greedy selection must dominate random subsets (paper's point)."""
        V = _random_V(rng, 128, 12)
        piv, _ = maxvol.fast_maxvol(V, 12)
        _, sel = np.linalg.slogdet(np.asarray(V)[np.asarray(piv), :12])
        rand = []
        for _ in range(500):
            idx = rng.choice(128, 12, replace=False)
            _, ld = np.linalg.slogdet(np.asarray(V)[idx, :12])
            rand.append(ld)
        assert sel > np.max(rand) - 1e-6

    def test_prefix_consistency(self, rng):
        """fast_maxvol(V, r) pivots == first r pivots of fast_maxvol(V, R) —
        the property that lets one sweep evaluate every candidate rank."""
        V = _random_V(rng, 80, 16)
        full, _ = maxvol.fast_maxvol(V, 16)
        for r in (1, 4, 9, 15):
            pref, _ = maxvol.fast_maxvol(V, r)
            assert np.array_equal(np.asarray(pref), np.asarray(full)[:r])

    def test_greedy_stepwise_optimal(self, rng):
        """Each pivot maximizes |det| of the extended submatrix over all
        remaining rows (Eq. 1 in the paper)."""
        V = np.asarray(_random_V(rng, 40, 6))
        piv = np.asarray(maxvol.fast_maxvol(jnp.asarray(V), 6)[0])
        for j in range(1, 6):
            base = list(piv[:j])
            best_det, best_i = -1.0, None
            for i in range(40):
                if i in base:
                    continue
                d = abs(np.linalg.det(V[np.ix_(base + [i], list(range(j + 1)))]))
                if d > best_det:
                    best_det, best_i = d, i
            chosen = abs(np.linalg.det(V[np.ix_(base + [piv[j]], list(range(j + 1)))]))
            np.testing.assert_allclose(chosen, best_det, rtol=1e-4)

    @settings(max_examples=25, deadline=None)
    @given(K=st.integers(8, 64), R=st.integers(1, 8), seed=st.integers(0, 10_000))
    def test_property_random_matrices(self, K, R, seed):
        g = np.random.default_rng(seed)
        R = min(R, K)
        V = jnp.asarray(g.normal(size=(K, R)).astype(np.float32))
        piv, logvol = maxvol.fast_maxvol(V, R)
        piv = np.asarray(piv)
        assert len(set(piv.tolist())) == R
        assert np.isfinite(float(logvol))

    def test_degenerate_rank_one_matrix(self):
        """Rank-deficient input must not produce duplicate pivots or NaNs."""
        u = np.linspace(1, 2, 32)[:, None].astype(np.float32)
        V = jnp.asarray(u @ np.ones((1, 4), np.float32))
        piv, logvol = maxvol.fast_maxvol(V, 4)
        assert len(set(np.asarray(piv).tolist())) == 4
        assert np.isfinite(float(logvol))


class TestClassicMaxvol:
    def test_dominance_condition(self, rng):
        """After convergence every |B_ij| ≤ tol (Goreinov's criterion)."""
        V = _random_V(rng, 64, 8)
        piv = np.asarray(maxvol.maxvol_classic(V, 8, tol=1.05))
        B = np.asarray(V)[:, :8] @ np.linalg.inv(np.asarray(V)[piv, :8])
        assert np.abs(B).max() <= 1.05 + 1e-3

    def test_at_least_fast_maxvol_volume(self, rng):
        V = _random_V(rng, 64, 8)
        fast, _ = maxvol.fast_maxvol(V, 8)
        classic = maxvol.maxvol_classic(V, 8)
        _, lv_fast = np.linalg.slogdet(np.asarray(V)[np.asarray(fast), :8])
        _, lv_classic = np.linalg.slogdet(np.asarray(V)[np.asarray(classic), :8])
        assert lv_classic >= lv_fast - 1e-5


class TestCross2D:
    def test_shapes_and_uniqueness(self, rng):
        X = jnp.asarray(rng.normal(size=(60, 40)).astype(np.float32))
        rows, cols = maxvol.cross2d_maxvol(X, 8)
        assert len(set(np.asarray(rows).tolist())) == 8
        assert len(set(np.asarray(cols).tolist())) == 8

    def test_fast_maxvol_subspace_similarity_vs_cross2d(self, rng):
        """Paper Table 4: Fast MaxVol matches-or-beats Cross-2D subspace
        similarity ON AVERAGE (per-draw dominance is not guaranteed — the
        benchmark reports the actual Table-4 numbers; here we gate on the
        mean not regressing by more than 5%)."""
        from repro.core.features import svd_features
        sims_f, sims_c = [], []
        for t in range(10):
            g = np.random.default_rng(t)
            # low-rank-ish data like real features
            A = (g.normal(size=(80, 6)) @ g.normal(size=(6, 30)) +
                 0.3 * g.normal(size=(80, 30))).astype(np.float32)
            R = 6
            V = svd_features(jnp.asarray(A), R)
            piv_f, _ = maxvol.fast_maxvol(V, R)
            rows_c, _ = maxvol.cross2d_maxvol(jnp.asarray(A), R)

            def sim(rows):
                sub = np.asarray(A)[np.asarray(rows)]
                q1, _ = np.linalg.qr(sub.T)
                full = np.linalg.svd(np.asarray(A).T, full_matrices=False)[0][:, :R]
                s = np.linalg.svd(q1[:, :R].T @ full)[1]
                return float(np.sum(s ** 2))

            sims_f.append(sim(piv_f))
            sims_c.append(sim(rows_c))
        assert np.mean(sims_f) >= np.mean(sims_c) * 0.95, (
            f"fast {np.mean(sims_f):.3f} vs cross2d {np.mean(sims_c):.3f}")
