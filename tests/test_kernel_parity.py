"""Pallas kernel parity on the awkward inputs: non-square and rank-deficient
feature/gradient matrices (interpret mode vs kernels/ref.py), plus the
``select_rank`` eps-fallback contract and the fused selection kernel vs the
unfused three-dispatch chain."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import projection
from repro.kernels import ref
from repro.kernels.fast_maxvol import fast_maxvol_pallas
from repro.kernels.graft_select import (fused_graft_select_batched_pallas,
                                        fused_graft_select_pallas)
from repro.kernels.projection_sweep import projection_sweep_pallas


def _low_rank(rng, K, R, true_rank, noise=0.0):
    A = rng.normal(size=(K, true_rank)).astype(np.float32)
    B = rng.normal(size=(true_rank, R)).astype(np.float32)
    X = A @ B
    if noise:
        X = X + noise * rng.normal(size=(K, R)).astype(np.float32)
    return jnp.asarray(X.astype(np.float32))


class TestFastMaxvolParity:
    @pytest.mark.parametrize("K,R,rank", [
        (96, 12, 12),     # tall non-square
        (20, 16, 10),     # nearly square, partial rank
        (17, 5, 3),       # odd shapes off the 8x128 lane grid
    ])
    def test_non_square(self, rng, K, R, rank):
        V = jnp.asarray(rng.normal(size=(K, R)).astype(np.float32))
        piv_k, lv_k = fast_maxvol_pallas(V, rank, interpret=True)
        piv_r, lv_r = ref.fast_maxvol_ref(V, rank)
        np.testing.assert_array_equal(np.asarray(piv_k), np.asarray(piv_r))
        np.testing.assert_allclose(float(lv_k), float(lv_r), rtol=1e-5)

    @pytest.mark.parametrize("true_rank,rank", [(3, 6), (2, 8), (4, 4)])
    def test_rank_deficient(self, rng, true_rank, rank):
        """Requested rank exceeds matrix rank: the eliminated residual columns
        go ~0 and the eps pivot guard kicks in. Kernel and reference must
        agree on the pivots (same guard, same tie-break) without NaNs."""
        V = _low_rank(rng, 64, 8, true_rank)
        piv_k, lv_k = fast_maxvol_pallas(V, rank, interpret=True)
        piv_r, lv_r = ref.fast_maxvol_ref(V, rank)
        np.testing.assert_array_equal(np.asarray(piv_k), np.asarray(piv_r))
        assert np.isfinite(float(lv_k)) and np.isfinite(float(lv_r))
        piv = np.asarray(piv_k)
        assert len(set(piv.tolist())) == rank, "pivots must stay distinct"

    def test_duplicated_rows(self, rng):
        base = rng.normal(size=(8, 6)).astype(np.float32)
        V = jnp.asarray(np.concatenate([base, base, base], axis=0))
        piv_k, _ = fast_maxvol_pallas(V, 6, interpret=True)
        piv_r, _ = ref.fast_maxvol_ref(V, 6)
        np.testing.assert_array_equal(np.asarray(piv_k), np.asarray(piv_r))


class TestProjectionSweepParity:
    @pytest.mark.parametrize("d,R", [
        (8, 16),      # wide: more candidates than gradient dims
        (100, 7),     # tall odd
        (16, 16),     # square
    ])
    def test_non_square(self, rng, d, R):
        G = jnp.asarray(rng.normal(size=(d, R)).astype(np.float32))
        g = jnp.asarray(rng.normal(size=(d,)).astype(np.float32))
        e_k = projection_sweep_pallas(G, g, interpret=True)
        e_r = ref.projection_sweep_ref(G, g)
        np.testing.assert_allclose(np.asarray(e_k), np.asarray(e_r), atol=1e-5)

    def test_rank_deficient_columns(self, rng):
        """Duplicated gradient columns hit the zero-norm MGS branch; both
        paths must emit the same (finite, monotone) error sweep."""
        col = rng.normal(size=(32, 1)).astype(np.float32)
        rest = rng.normal(size=(32, 4)).astype(np.float32)
        G = jnp.asarray(np.concatenate([col, col, rest, col], axis=1))
        g = jnp.asarray(rng.normal(size=(32,)).astype(np.float32))
        e_k = np.asarray(projection_sweep_pallas(G, g, interpret=True))
        e_r = np.asarray(ref.projection_sweep_ref(G, g))
        np.testing.assert_allclose(e_k, e_r, atol=1e-5)
        assert np.all(np.isfinite(e_k))
        assert np.all(np.diff(e_k) <= 1e-5)

    def test_wide_sweep_past_full_rank_is_flat(self, rng):
        """Once the basis spans R^d (at r = d) the remaining prefix errors
        must be ~0, not garbage from degenerate orthogonalization."""
        d, R = 6, 12
        G = jnp.asarray(rng.normal(size=(d, R)).astype(np.float32))
        g = jnp.asarray(rng.normal(size=(d,)).astype(np.float32))
        e = np.asarray(projection_sweep_pallas(G, g, interpret=True))
        assert np.all(e[d:] < 1e-4)


class TestFusedSelectParity:
    """The fused refresh kernel (MaxVol + gather + MGS sweep in ONE
    ``pallas_call``) vs the unfused ``fast_maxvol`` → ``take`` →
    ``projection_sweep`` chain: pivots must be bit-identical and prefix
    errors within 1e-5, including non-square and rank-deficient inputs."""

    @staticmethod
    def _chain(V, G, g_bar, rank):
        piv, lv = fast_maxvol_pallas(V, rank, interpret=True)
        G_sel = jnp.take(G, piv, axis=1)
        errs = projection_sweep_pallas(G_sel, g_bar, interpret=True)
        return piv, errs, lv, G_sel

    @pytest.mark.parametrize("K,R,d,rank", [
        (96, 12, 40, 12),    # tall non-square
        (20, 16, 64, 10),    # nearly square, partial rank
        (17, 5, 9, 3),       # odd shapes off the 8x128 lane grid
    ])
    def test_non_square(self, rng, K, R, d, rank):
        V = jnp.asarray(rng.normal(size=(K, R)).astype(np.float32))
        G = jnp.asarray(rng.normal(size=(d, K)).astype(np.float32))
        gb = jnp.mean(G, axis=1)
        piv_f, err_f, lv_f, gsel_f = fused_graft_select_pallas(
            V, G, gb, rank, interpret=True)
        piv_u, err_u, lv_u, gsel_u = self._chain(V, G, gb, rank)
        np.testing.assert_array_equal(np.asarray(piv_f), np.asarray(piv_u))
        np.testing.assert_allclose(np.asarray(err_f), np.asarray(err_u),
                                   atol=1e-5)
        np.testing.assert_allclose(float(lv_f), float(lv_u), rtol=1e-5)
        # the one-hot-matmul gather is exact, not approximate
        np.testing.assert_array_equal(np.asarray(gsel_f), np.asarray(gsel_u))

    def test_rank_deficient(self, rng):
        """Requested rank beyond the true rank of V AND duplicated gradient
        columns: the safe-pivot guard and the zero-norm MGS branch must fire
        identically in both paths, with finite monotone errors."""
        A = rng.normal(size=(64, 3)).astype(np.float32)
        B = rng.normal(size=(3, 8)).astype(np.float32)
        V = jnp.asarray(A @ B)                      # true rank 3, ask for 6
        col = rng.normal(size=(32, 1)).astype(np.float32)
        G = jnp.asarray(np.concatenate(
            [col, col, rng.normal(size=(32, 62)).astype(np.float32)], axis=1))
        gb = jnp.mean(G, axis=1)
        piv_f, err_f, _, _ = fused_graft_select_pallas(
            V, G, gb, 6, interpret=True)
        piv_u, err_u, _, _ = self._chain(V, G, gb, 6)
        np.testing.assert_array_equal(np.asarray(piv_f), np.asarray(piv_u))
        np.testing.assert_allclose(np.asarray(err_f), np.asarray(err_u),
                                   atol=1e-5)
        e = np.asarray(err_f)
        assert np.all(np.isfinite(e)) and np.all(np.diff(e) <= 1e-5)
        assert len(set(np.asarray(piv_f).tolist())) == 6

    def test_batched_matches_single(self, rng):
        """grid=(B,) variant: every batch row identical to the grid=()
        kernel on that row."""
        B, K, R, d, rank = 5, 40, 10, 24, 8
        Vs = jnp.asarray(rng.normal(size=(B, K, R)).astype(np.float32))
        Gs = jnp.asarray(rng.normal(size=(B, d, K)).astype(np.float32))
        gbs = jnp.mean(Gs, axis=2)
        piv_b, err_b, lv_b, gsel_b = fused_graft_select_batched_pallas(
            Vs, Gs, gbs, rank, interpret=True)
        assert piv_b.shape == (B, rank) and gsel_b.shape == (B, d, rank)
        for b in range(B):
            piv_s, err_s, lv_s, gsel_s = fused_graft_select_pallas(
                Vs[b], Gs[b], gbs[b], rank, interpret=True)
            np.testing.assert_array_equal(np.asarray(piv_b[b]),
                                          np.asarray(piv_s))
            np.testing.assert_allclose(np.asarray(err_b[b]),
                                       np.asarray(err_s), atol=1e-6)
            np.testing.assert_allclose(float(lv_b[b]), float(lv_s), rtol=1e-6)
            np.testing.assert_array_equal(np.asarray(gsel_b[b]),
                                          np.asarray(gsel_s))

    def test_shape_validation(self, rng):
        V = jnp.zeros((16, 8), jnp.float32)
        G = jnp.zeros((4, 12), jnp.float32)          # K mismatch
        with pytest.raises(ValueError, match="columns"):
            fused_graft_select_pallas(V, G, jnp.zeros((4,)), 4, interpret=True)
        with pytest.raises(ValueError, match="rank"):
            fused_graft_select_pallas(V, jnp.zeros((4, 16)), jnp.zeros((4,)),
                                      12, interpret=True)


class TestSelectRankFallback:
    def test_no_candidate_meets_eps_falls_back_to_r_max(self):
        errs = jnp.asarray([0.9, 0.8, 0.7, 0.6])
        rank, err = projection.select_rank(errs, (1, 2, 4), eps=0.1)
        assert int(rank) == 4
        np.testing.assert_allclose(float(err), 0.6, atol=1e-6)

    def test_flat_error_plateau_does_not_collapse_rank(self):
        """Regression: with tied errors an argmin fallback picks the SMALLEST
        candidate — the fallback must be r_max, never a silent shrink."""
        errs = jnp.full((8,), 0.5)
        rank, err = projection.select_rank(errs, (1, 2, 8), eps=0.1)
        assert int(rank) == 8
        np.testing.assert_allclose(float(err), 0.5, atol=1e-6)

    def test_smallest_satisfying_rank_still_wins(self):
        errs = jnp.asarray([0.9, 0.5, 0.2, 0.05])
        rank, err = projection.select_rank(errs, (1, 2, 3, 4), eps=0.3)
        assert int(rank) == 3
        np.testing.assert_allclose(float(err), 0.2, atol=1e-6)

    def test_all_satisfying_picks_first(self):
        errs = jnp.asarray([0.01, 0.005, 0.001, 0.0])
        rank, _ = projection.select_rank(errs, (1, 2, 4), eps=0.25)
        assert int(rank) == 1
