"""repro.analysis: each checker must catch its deliberately-broken fixture
with the right rule id, and the real Trainer probe config must pass clean.
"""
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import jaxpr_audit, lint, vmem
from repro.analysis.recompile import RecompileWatcher
from repro.analysis.report import RULES, Finding, Report, rule_table
from repro.analysis.sync_guard import (SyncGuard, SyncGuardError,
                                       sync_allowed)


# ---------------------------------------------------------------------------
# report format
# ---------------------------------------------------------------------------

def test_finding_defaults_severity_from_registry():
    f = Finding(rule="VM003", location="x", message="m")
    assert f.severity == "info"
    assert Finding(rule="JX001", location="x", message="m").severity == "error"


def test_report_accounting_and_json():
    r = Report([Finding(rule="JX001", location="a", message="bad"),
                Finding(rule="VM003", location="b", message="note")])
    assert not r.ok and len(r.errors) == 1
    assert r.by_rule("VM003")[0].location == "b"
    assert '"ok": false' in r.to_json()
    assert all(rid in rule_table() for rid in RULES)


# ---------------------------------------------------------------------------
# jaxpr_audit
# ---------------------------------------------------------------------------

def test_count_primitives_recurses_into_pjit():
    def fn(x):
        return jax.jit(lambda y: y * 2)(x) + 1

    counts = jaxpr_audit.count_primitives(fn, jnp.ones(3))
    assert counts.get("mul", 0) >= 1          # found inside the pjit body


def test_forbidden_callback_primitive_flagged():
    def fn(x):
        return jax.pure_callback(
            lambda v: np.asarray(v), jax.ShapeDtypeStruct((3,), jnp.float32),
            x)

    report = jaxpr_audit.audit_step(fn, (jnp.ones(3),), label="fixture")
    assert [f.rule for f in report.errors] == ["JX001"]
    assert "pure_callback" in report.errors[0].message


def test_f64_op_flagged():
    from jax.experimental import enable_x64

    def fn(x):
        return x.astype(jnp.float64) * 2.0

    with enable_x64():
        report = jaxpr_audit.audit_dtypes(fn, jnp.ones(3, jnp.float32),
                                          label="fixture")
    assert any(f.rule == "JX002" and f.severity == "error" for f in report)


def test_clean_step_passes():
    report = jaxpr_audit.audit_step(lambda x: x * 2 + 1, (jnp.ones(3),))
    assert report.ok and len(report) == 0


def test_fused_selection_rules_catch_unfused_shape():
    # 0 pallas_call + a gather = the unfused chain → JX003 and JX004
    def unfused(v, idx):
        return jnp.take(v, idx, axis=0)

    report = jaxpr_audit.audit_counts(
        unfused, (jnp.ones((8, 4)), jnp.arange(2)),
        jaxpr_audit.fused_selection_rules(), label="fixture")
    assert {f.rule for f in report.errors} == {"JX003", "JX004"}


def test_monotone_count_rows():
    rows, problems = jaxpr_audit.monotone_count_rows(
        "d", {"pallas_call": 1, "gather": 0}, {"pallas_call": 2, "gather": 0},
        ("pallas_call", "gather"), "count increased")
    assert ("d.pallas_call", 1.0, 2.0, True) in rows
    assert len(problems) == 1 and "d.pallas_call" in problems[0]
    _, ok = jaxpr_audit.monotone_count_rows(
        "d", {"pallas_call": 2}, {"pallas_call": 1}, ("pallas_call",), "w")
    assert ok == []                          # decrease is an improvement


# ---------------------------------------------------------------------------
# sync_guard
# ---------------------------------------------------------------------------

def test_sync_guard_strict_raises_on_float():
    x = jnp.ones(())
    with pytest.raises(SyncGuardError, match="unsanctioned"), \
            SyncGuard(strict=True):
        float(x)


def test_sync_guard_records_and_reports_sy001():
    x = jnp.ones(())
    with SyncGuard() as g:
        float(x)                             # violation
        with sync_allowed("probe"):
            jax.device_get(x)                # sanctioned
    kinds = [(e.kind, e.site) for e in g.events]
    assert ("__float__", None) in kinds and ("device_get", "probe") in kinds
    report = g.report()
    assert [f.rule for f in report.errors] == ["SY001"]
    assert "test_analysis.py" in report.errors[0].location


def test_sync_guard_sanctioned_sites_pass_strict():
    x = jnp.ones(())
    with SyncGuard(strict=True) as g, sync_allowed("flush"):
        jax.block_until_ready(x)
        float(x)
    assert g.violations == [] and len(g.events) == 2


def test_sync_guard_is_thread_local():
    x = jnp.ones(())
    errors = []

    def other_thread():
        try:
            jax.block_until_ready(x)         # unguarded thread: free
        except Exception as e:               # pragma: no cover
            errors.append(e)

    with SyncGuard(strict=True) as g:
        t = threading.Thread(target=other_thread)
        t.start()
        t.join()
    assert errors == [] and g.events == []


def test_sync_guard_restores_patches():
    x = jnp.ones(()) * 3
    orig = jax.block_until_ready
    with SyncGuard():
        assert jax.block_until_ready is not orig
    assert jax.block_until_ready is orig
    assert float(x) == 3.0                   # dunder restored


# ---------------------------------------------------------------------------
# recompile
# ---------------------------------------------------------------------------

def test_recompile_watcher_names_drifting_arg():
    w = RecompileWatcher(label="step")
    assert w.observe(step=0, batch={"x": jnp.ones((8, 16))}) == []
    assert w.observe(step=1, batch={"x": jnp.ones((8, 16))}) == []
    drift = w.observe(step=2, batch={"x": jnp.ones((8, 32))})
    assert [f.rule for f in drift] == ["RC001"]
    assert "batch['x']" in drift[0].message
    assert "float32[8,16]" in drift[0].message
    assert "float32[8,32]" in drift[0].message
    assert not w.ok


def test_recompile_watcher_dtype_and_static_drift():
    w = RecompileWatcher()
    w.observe(x=jnp.ones(3, jnp.float32), n=4)
    drift = w.observe(x=jnp.ones(3, jnp.bfloat16), n=5)
    msgs = " ".join(f.message for f in drift)
    assert "bfloat16" in msgs and "'n'" in msgs


def test_recompile_cache_watch():
    @jax.jit
    def f(x):
        return x * 2

    f(jnp.ones(3))
    f(jnp.ones(5))                           # second specialization
    w = RecompileWatcher(label="probe")
    w.watch("f", f, expected_specializations=1)
    findings = w.check_caches()
    assert [x.rule for x in findings] == ["RC001"]
    assert "2 specializations" in findings[0].message


# ---------------------------------------------------------------------------
# vmem
# ---------------------------------------------------------------------------

def test_vmem_overflow_flagged():
    est = vmem.flash_forward_vmem(T=65536, head_dim=128, block_q=128)
    assert not est.fits
    report = est.report()
    assert [f.rule for f in report.errors] == ["VM001"]


def test_vmem_divisibility_flagged():
    report = vmem.flash_attention_report(S=100, T=64, head_dim=16,
                                         block_q=64, block_k=64)
    assert any(f.rule == "VM002" for f in report.errors)


def test_vmem_formulas_match_kernel_guards():
    # flash: the wrapper guard formula, bit-exact
    T, Dh, bq = 512, 64, 128
    assert vmem.flash_forward_vmem(T, Dh, bq).total == \
        (2 * T * Dh + 3 * bq * Dh) * 4
    # fused selection: graft_select._check_budget's word count, bit-exact
    K, R, d, rank = 256, 32, 1024, 16
    assert vmem.fused_select_vmem(K, R, d, rank).total == \
        (K * R + d * K + 2 * d * rank + K * rank) * 4
    assert vmem.VMEM_BUDGET_BYTES == 12 * 1024 * 1024


def test_vmem_feasible_agrees_with_attn_router():
    from repro.models import layers as layers_lib

    class Cfg:
        head_dim = 64

    for S, T in ((64, 64), (128, 4096), (128, 65536)):
        bq, bk = layers_lib._flash_blocks(S, T)
        expect = layers_lib._flash_feasible(Cfg, S, T)
        got = (bq is not None and bk is not None and
               vmem.flash_feasible(S, T, Cfg.head_dim, bq, bk))
        assert got == expect, (S, T)


def test_vmem_headroom_reported():
    report = vmem.fast_maxvol_vmem(1024, 64).report()
    assert report.ok
    assert [f.rule for f in report.findings] == ["VM003"]


# ---------------------------------------------------------------------------
# lint
# ---------------------------------------------------------------------------

_BAD_HOT_PATH = """
import time
import numpy as np
import jax

def f(x):
    t = time.perf_counter()
    return float(x), np.asarray(x), jax.device_get(x)
"""

_BAD_PALLAS = """
from jax.experimental import pallas as pl

def launch(k, x):
    return pl.pallas_call(k)(x)
"""


def test_lint_flags_host_sync_in_hot_path():
    findings = lint.lint_source(_BAD_HOT_PATH, "launch/steps.py")
    rules = sorted(f.rule for f in findings)
    assert rules == ["LN001", "LN001", "LN001", "LN002"]


def test_lint_scopes_rules_by_module():
    # same source in a non-hot-path module: only the wall clock is illegal
    findings = lint.lint_source(_BAD_HOT_PATH, "kernels/somekernel.py")
    assert sorted(f.rule for f in findings) == ["LN002"]
    assert lint.lint_source(_BAD_HOT_PATH, "core/maxvol.py") == []


def test_lint_flags_pallas_call_outside_kernels():
    findings = lint.lint_source(_BAD_PALLAS, "selection/graft.py")
    assert [f.rule for f in findings] == ["LN003"]
    assert lint.lint_source(_BAD_PALLAS, "kernels/mine.py") == []


_BAD_TOPOLOGY = """
import jax
from jax.sharding import Mesh

def f(devices):
    jax.distributed.initialize("127.0.0.1:1", 2, 0)
    m1 = jax.make_mesh((2,), ("data",))
    m2 = Mesh(devices, ("data",))
    return jax.process_index(), jax.process_count()
"""


def test_lint_flags_topology_outside_backend():
    findings = lint.lint_source(_BAD_TOPOLOGY, "api/trainer.py")
    assert [f.rule for f in findings] == ["LN004"] * 5
    # the backend package and the mesh helpers own topology
    assert lint.lint_source(_BAD_TOPOLOGY, "backend/multiprocess.py") == []
    assert lint.lint_source(_BAD_TOPOLOGY, "launch/mesh.py") == []


def test_lint_allow_marker_whitelists_line():
    src = ("import jax\n"
           "def f(x):\n"
           "    # lint: allow drain point\n"
           "    return jax.device_get(x)\n")
    assert lint.lint_source(src, "launch/metrics.py") == []


def test_lint_tree_clean_on_repo():
    report = lint.lint_tree()
    assert report.ok, report.format()


# ---------------------------------------------------------------------------
# trainer integration: the train.audit knob
# ---------------------------------------------------------------------------

def _tiny_cfg(**overrides):
    from repro.api import ExperimentConfig
    pairs = ["train.steps=3", "train.batch=4", "train.seq=16",
             "train.log_every=0", "train.audit=true",
             "graft.rset=[2,4]", "graft.refresh_every=2"]
    pairs += [f"{k}={v}" for k, v in overrides.items()]
    return ExperimentConfig().apply_overrides(pairs)


def test_audit_knob_does_not_change_config_hash():
    from repro.api import ExperimentConfig
    base = ExperimentConfig()
    assert base.config_hash() == \
        base.apply_overrides(["train.audit=true"]).config_hash()


def test_trainer_audit_catches_per_step_sync():
    from repro.api import Trainer
    from repro.api.callbacks import Callback

    class PerStepSync(Callback):
        def on_step_end(self, trainer, step, metrics):
            _ = metrics["loss"]              # float() inside the step loop

    with pytest.raises(SyncGuardError, match="unsanctioned"):
        Trainer(_tiny_cfg(), callbacks=[PerStepSync()]).fit()


def test_trainer_audit_clean_run_reports_sites():
    from repro.api import Trainer
    report = Trainer(_tiny_cfg()).fit()
    audit = report["audit"]
    assert audit["unsanctioned"] == 0
    assert audit["recompiles"] == 0
    assert report["final_loss"] is not None


def test_runner_probe_config_passes_clean(tmp_path):
    """The acceptance criterion: the full probe config (async loop, eval
    side stream, checkpointing, console) under strict audit — clean."""
    from repro.analysis import runner
    report = runner.check_runtime()
    assert report.ok, report.format()
    assert any(f.rule == "SY001" and f.severity == "info"
               for f in report.findings)


def test_runner_rules_flag():
    from repro.analysis import runner
    assert runner.main(["--rules"]) == 0
