"""Unified Experiment API: config round-trips, CLI overrides, hashing,
Trainer lifecycle hooks, checkpoint-before-stop ordering, resume from the
manifest-embedded config alone, and legacy-shim equivalence."""
import json

import numpy as np
import pytest

from repro.api import (Callback, ExperimentConfig, GraftConfig, HookRecorder,
                       ModelConfig, TrainConfig, Trainer, resume)
from repro.api.config import apply_overrides
from repro.launch.metrics import read_metrics
from repro.launch.train import RunConfig, to_experiment, train

SMALL = {"steps": 6, "batch": 8, "seq": 16, "seed": 3, "log_every": 0}


def small_cfg(**train_kw):
    kw = dict(SMALL, **train_kw)
    return ExperimentConfig(train=TrainConfig(**kw),
                            graft=GraftConfig(rset=(2, 4), refresh_every=3))


class TestExperimentConfig:
    def test_json_round_trip_equality(self):
        cfg = ExperimentConfig(
            model=ModelConfig(arch="stablelm-12b", smoke=True,
                              overrides={"num_layers": 2}),
            train=TrainConfig(steps=12, batch=4, seq=32, sampler="loss_topk",
                              metrics_path="/tmp/m.jsonl"),
            graft=GraftConfig(rset=(2, 4), eps=0.3, feature_mode="pca_sketch",
                              grad_mode="logit_embed"))
        assert ExperimentConfig.from_json(cfg.to_json()) == cfg
        # finalized configs round-trip too (the manifest-embedded form)
        fin = cfg.finalized()
        assert ExperimentConfig.from_json(fin.to_json()) == fin

    def test_round_trip_preserves_none_graft(self):
        cfg = ExperimentConfig(graft=None)
        back = ExperimentConfig.from_json(cfg.to_json())
        assert back.graft is None and back == cfg

    def test_rset_round_trips_as_tuple(self):
        cfg = ExperimentConfig(graft=GraftConfig(rset=(2, 4)))
        back = ExperimentConfig.from_json(cfg.to_json())
        assert back.graft.rset == (2, 4)
        assert isinstance(back.graft.rset, tuple)

    def test_finalized_derives_and_is_idempotent(self):
        cfg = ExperimentConfig(train=TrainConfig(steps=40, seq=32))
        fin = cfg.finalized()
        assert fin.optimizer.total_steps == 40
        assert fin.optimizer.warmup_steps == 2
        assert fin.train.probe_positions == 32
        assert fin.data is not None and fin.data.seq_len == 32
        assert fin.finalized() == fin

    def test_cli_override_parsing(self):
        cfg = ExperimentConfig().apply_overrides([
            "train.steps=7", "graft.eps=0.5", "graft.rset=[2,4]",
            "model.arch=stablelm-12b", "optimizer.name=lion",
            "train.metrics_path=/tmp/x.jsonl", "graft.feature_mode=pca_sketch",
        ])
        assert cfg.train.steps == 7
        assert cfg.graft.eps == 0.5
        assert cfg.graft.rset == (2, 4)
        assert cfg.model.arch == "stablelm-12b"
        assert cfg.optimizer.name == "lion"
        assert cfg.train.metrics_path == "/tmp/x.jsonl"
        assert cfg.graft.feature_mode == "pca_sketch"
        # comma shorthand for tuples
        assert apply_overrides(cfg, ["graft.rset=2,4,8"]).graft.rset == (2, 4, 8)

    def test_data_override_derives_from_model_and_train(self):
        """Regression: a data.* override on the default (data=None) config
        must derive the section from model/train — raw DataConfig defaults
        would silently train on mismatched vocab/batch/seq (NaN loss)."""
        cfg = ExperimentConfig().apply_overrides(
            ["train.batch=8", "train.seq=16", "data.seed=5"])
        assert cfg.data.seed == 5
        assert cfg.data.global_batch == 8 and cfg.data.seq_len == 16
        assert cfg.data.vocab_size == cfg.model.build().vocab_size

    def test_override_disable_and_reenable_graft(self):
        cfg = ExperimentConfig().apply_overrides(["graft=none"])
        assert cfg.graft is None
        cfg = cfg.apply_overrides(["graft.eps=0.4"])   # re-enables from defaults
        assert cfg.graft is not None and cfg.graft.eps == 0.4

    def test_override_errors(self):
        with pytest.raises(KeyError, match="unknown config section"):
            ExperimentConfig().apply_overrides(["nope.steps=1"])
        with pytest.raises(KeyError, match="unknown field"):
            ExperimentConfig().apply_overrides(["train.bogus=1"])
        with pytest.raises(ValueError, match="key=value"):
            ExperimentConfig().apply_overrides(["train.steps"])

    def test_steps_override_on_finalized_rederives_schedule(self):
        """Regression: overriding train.steps on a previously-finalized
        config (the --dump-config / manifest form) must re-derive the LR
        horizon — not keep cosine total_steps at the old value and train
        the tail at ~zero LR."""
        dumped = ExperimentConfig(train=TrainConfig(steps=5)).finalized()
        assert dumped.optimizer.total_steps == 5
        big = dumped.apply_overrides(["train.steps=500"]).finalized()
        assert big.optimizer.total_steps == 500
        assert big.optimizer.warmup_steps == 25
        # data + probe_positions re-derive too
        wide = dumped.apply_overrides(["train.batch=8", "train.seq=32"])
        fin = wide.finalized()
        assert fin.data.global_batch == 8 and fin.data.seq_len == 32
        assert fin.train.probe_positions == 32
        # explicitly-set optimizer fields survive a steps override
        explicit = ExperimentConfig().apply_overrides(
            ["optimizer.total_steps=10000", "train.steps=500"])
        assert explicit.optimizer.total_steps == 10000

    def test_mismatched_data_section_errors_loudly(self):
        """An explicit data section that disagrees with model/train must
        raise in build() (a vocab mismatch otherwise NaNs silently)."""
        from repro.api import DataConfig
        cfg = ExperimentConfig(
            train=TrainConfig(steps=2, batch=8, seq=16),
            data=DataConfig(vocab_size=999, seq_len=16, global_batch=8))
        with pytest.raises(ValueError, match="vocab_size"):
            cfg.build()
        # order-dependent override (data derived before train changed)
        stale = ExperimentConfig().apply_overrides(
            ["data.num_clusters=4", "train.batch=8"])
        with pytest.raises(ValueError, match="global_batch"):
            stale.build()

    def test_config_hash_ignores_run_environment(self):
        a = small_cfg()
        b = small_cfg(stop_after=3, checkpoint_dir="/tmp/ck",
                      metrics_path="/tmp/m.jsonl", log_every=2)
        c = small_cfg(steps=7)                       # trajectory-shaping
        assert a.config_hash() == b.config_hash()
        assert a.config_hash() != c.config_hash()
        assert a.config_hash() != small_cfg(seed=4).config_hash()


class TestTrainerLifecycle:
    def test_checkpoint_before_stop_on_preemption(self, tmp_path):
        """Simulated preemption (stop_after): the emergency checkpoint hook
        must fire before the loop exits and before on_train_end."""
        rec = HookRecorder()
        cfg = small_cfg(stop_after=4, checkpoint_dir=str(tmp_path / "ck"),
                        checkpoint_every=100)        # only the stop triggers it
        report = Trainer(cfg, callbacks=[rec]).fit()
        events = rec.events
        assert ("on_checkpoint", 3) in events
        assert events.index(("on_checkpoint", 3)) < \
            events.index(("on_train_end", None))
        assert events[0] == ("on_train_start", None)
        assert events[-1] == ("on_train_end", None)
        assert report["stopped"] == "stop_after"
        assert len(report["history"]) == 4

    def test_callback_priority_ordering(self):
        order = []

        class A(Callback):
            priority = 5

            def on_step_end(self, trainer, step, metrics):
                order.append("A")

        class B(Callback):
            priority = 80

            def on_step_end(self, trainer, step, metrics):
                order.append("B")

        Trainer(small_cfg(steps=1), callbacks=[B(), A()]).fit()
        assert order == ["A", "B"]

    def test_default_priority_user_stop_is_checkpointed(self, tmp_path):
        """A user callback at the DEFAULT priority calling request_stop must
        still get its stop checkpointed in the same step (default priority
        sorts before the checkpointer)."""
        class Stopper(Callback):
            def on_step_end(self, trainer, step, metrics):
                if step == 1:
                    trainer.request_stop("custom")

        rec = HookRecorder()
        cfg = small_cfg(checkpoint_dir=str(tmp_path / "ck"),
                        checkpoint_every=100)
        report = Trainer(cfg, callbacks=[Stopper(), rec]).fit()
        assert report["stopped"] == "custom"
        assert ("on_checkpoint", 1) in rec.events
        assert len(report["history"]) == 2

    def test_on_checkpoint_fires_after_commit(self, tmp_path):
        """The on_checkpoint contract is 'after the checkpoint commits' —
        with async saves the manifest must already be on disk when the hook
        fires (a listener uploading `path` must not race the writer)."""
        import os
        seen = []

        class Uploader(Callback):
            def on_checkpoint(self, trainer, step, path):
                seen.append(os.path.exists(
                    os.path.join(path, "manifest.json")))

        cfg = small_cfg(steps=4, checkpoint_dir=str(tmp_path / "ck"),
                        checkpoint_every=2)
        Trainer(cfg, callbacks=[Uploader()]).fit()
        assert len(seen) == 2 and all(seen)

    def test_eval_metrics_reach_jsonl_stream(self, tmp_path):
        """Regression (legacy bug): telemetry logged before eval merged, so
        eval_loss never hit the JSONL stream. One row per step, eval rows
        carrying eval_loss/eval_ppl."""
        mpath = str(tmp_path / "metrics.jsonl")
        report = Trainer(small_cfg(eval_every=3, metrics_path=mpath)).fit()
        rows = read_metrics(mpath)
        assert len(rows) == 6                        # exactly one row per step
        eval_rows = [r for r in rows if "eval_loss" in r]
        assert [r["step"] for r in eval_rows] == [2, 5]
        assert all("eval_ppl" in r for r in eval_rows)
        assert any("eval_ppl" in h for h in report["history"])


# JSONL fields that legitimately differ between two otherwise-identical
# runs: wall clocks and everything derived from them (device completion
# stamps included — mfu_source only because a slow CI flush can time out
# waiting on the clock and fall back to the dispatch value)
_TIMING_FIELDS = ("time", "step_time_s", "tokens_per_s", "mfu",
                  "host_overhead_s", "device_step_time_s", "mfu_source")


def _strip_timing(rows):
    return [{k: v for k, v in r.items() if k not in _TIMING_FIELDS}
            for r in rows]


class TestAsyncHostLoop:
    """The async host loop (deferred metrics + side-stream eval) must be a
    pure dispatch-schedule change: bit-identical trajectory and JSONL
    stream (modulo timing fields) vs the synchronous escape hatches."""

    def test_async_trajectory_and_jsonl_match_sync(self, tmp_path):
        """The full async stack (graft.overlap dispatch schedule +
        side-stream eval + deferred metrics drain) vs the fully synchronous
        loop (sequential dispatch, blocking eval, per-row flush)."""
        ap, sp = str(tmp_path / "async.jsonl"), str(tmp_path / "sync.jsonl")
        r_async = Trainer(small_cfg(eval_every=3, metrics_path=ap,
                                    metrics_flush_every=4)
                          .apply_overrides(["graft.overlap=true"])).fit()
        r_sync = Trainer(small_cfg(eval_every=3, metrics_path=sp,
                                   sync_eval=True,
                                   metrics_flush_every=1)).fit()
        assert r_async["final_loss"] == r_sync["final_loss"]
        assert [h["loss"] for h in r_async["history"]] == \
            [h["loss"] for h in r_sync["history"]]
        assert _strip_timing(read_metrics(ap)) == \
            _strip_timing(read_metrics(sp))

    def test_deferred_eval_rows_tagged_with_dispatch_step(self, tmp_path):
        """Side-stream eval results land on the row of the step they were
        DISPATCHED at, even though they are collected at the next boundary
        (or close)."""
        mpath = str(tmp_path / "m.jsonl")
        Trainer(small_cfg(eval_every=3, metrics_path=mpath,
                          metrics_flush_every=100)).fit()  # drain only at close
        rows = read_metrics(mpath)
        assert [r["step"] for r in rows if "eval_loss" in r] == [2, 5]
        assert all(isinstance(r["eval_ppl"], float)
                   for r in rows if "eval_loss" in r)

    def test_flush_drains_on_preemption_stop(self, tmp_path):
        """A stop_after kill with a flush cadence longer than the run must
        still land EVERY queued row on disk — the clean-stop path drains
        the lazy buffer through close."""
        mpath = str(tmp_path / "m.jsonl")
        report = Trainer(small_cfg(steps=8, stop_after=4, eval_every=2,
                                   metrics_path=mpath,
                                   metrics_flush_every=100)).fit()
        assert report["stopped"] == "stop_after"
        rows = read_metrics(mpath)
        assert [r["step"] for r in rows] == [0, 1, 2, 3]
        assert all(np.isfinite(r["loss"]) for r in rows)

    def test_host_dispatches_ahead_of_materialization(self):
        """With deferred metrics the loop must issue step N+1 while step
        N's metrics are still device futures (the dispatch accounting the
        bench gates)."""
        report = Trainer(small_cfg(metrics_flush_every=100)).fit()
        assert report["host_loop"]["steps"] == 6
        assert report["host_loop"]["dispatched_ahead"] >= 4

    def test_history_cap_keeps_first_and_tail(self):
        report = Trainer(small_cfg(steps=8, history_cap=3)).fit()
        hist = report["history"]
        assert len(hist) == 4                        # first + tail window
        assert report["history_dropped"] == 4
        full = Trainer(small_cfg(steps=8)).fit()
        assert [h["loss"] for h in hist] == \
            [full["history"][i]["loss"] for i in (0, 5, 6, 7)]
        assert report["final_loss"] == full["final_loss"]


class TestResumeFromManifest:
    def test_resume_reconstructs_config_and_metrics(self, tmp_path):
        """Kill via stop_after → resume from the manifest-embedded config
        ALONE (no flags) → same config hash and same final loss as an
        uninterrupted run."""
        full = Trainer(small_cfg(steps=8)).fit()
        ck = str(tmp_path / "ck")
        interrupted = small_cfg(steps=8, stop_after=4, checkpoint_dir=ck,
                                checkpoint_every=100)
        Trainer(interrupted).fit()

        resumed_trainer = Trainer.from_checkpoint(ck)
        assert resumed_trainer.config.train.stop_after is None
        assert resumed_trainer.config.config_hash() == \
            interrupted.config_hash() == full["config_hash"]
        report = resumed_trainer.fit()
        np.testing.assert_allclose(full["final_loss"], report["final_loss"],
                                   rtol=1e-6)
        assert len(report["history"]) == 4           # steps 4..7 only

    def test_resume_helper(self, tmp_path):
        ck = str(tmp_path / "ck")
        Trainer(small_cfg(steps=4, stop_after=2, checkpoint_dir=ck,
                          checkpoint_every=100)).fit()
        report = resume(ck)
        assert len(report["history"]) == 2

    def test_resume_restores_tokens_seen(self, tmp_path):
        """Regression: the resumed run's fresh MetricsLogger restarted
        tokens_seen at zero, corrupting cumulative-token and MFU history —
        it must continue from start_step × tokens_per_step."""
        ck = str(tmp_path / "ck")
        tokens_per_step = SMALL["batch"] * SMALL["seq"]
        mpath = str(tmp_path / "metrics.jsonl")
        Trainer(small_cfg(steps=8, stop_after=4, checkpoint_dir=ck,
                          checkpoint_every=100, metrics_path=mpath)).fit()
        assert read_metrics(mpath)[-1]["tokens_seen"] == 4 * tokens_per_step
        # the manifest carries metrics_path: the resumed run appends to the
        # same JSONL stream, and the cumulative counter must pick up at
        # start_step × tokens_per_step, not restart at zero
        Trainer.from_checkpoint(ck).fit()
        rows = read_metrics(mpath)
        assert [r["step"] for r in rows] == [0, 1, 2, 3, 4, 5, 6, 7]
        assert [r["tokens_seen"] for r in rows] == \
            [t * tokens_per_step for t in range(1, 9)]

    def test_resume_dump_config_does_not_train(self, tmp_path, capsys):
        from repro.api.cli import main
        ck = str(tmp_path / "ck")
        Trainer(small_cfg(steps=4, stop_after=2, checkpoint_dir=ck,
                          checkpoint_every=100)).fit()
        capsys.readouterr()
        assert main(["--resume", ck, "--dump-config"]) == 0
        dumped = json.loads(capsys.readouterr().out)
        cfg = ExperimentConfig.from_dict(dumped)
        assert cfg.train.stop_after is None          # consumed by the kill
        assert cfg.train.checkpoint_dir == ck

    def test_resume_without_checkpoint_raises(self, tmp_path):
        from repro.checkpoint import load_experiment
        with pytest.raises(FileNotFoundError):
            load_experiment(str(tmp_path / "empty"))


class TestLegacyShim:
    def test_run_config_translation_and_identical_loss(self):
        run = RunConfig(**SMALL, graft_rset=(2, 4), graft_refresh=3)
        cfg = to_experiment(run)
        assert cfg.graft.rset == (2, 4)
        r_legacy = train(run)
        r_api = Trainer(cfg).fit()
        assert r_legacy["final_loss"] == r_api["final_loss"]
        assert "straggler" in r_legacy

    def test_legacy_function_callbacks_still_fire(self):
        seen = []
        train(RunConfig(steps=2, batch=8, seq=16, log_every=0,
                        graft_rset=(2, 4)),
              callbacks=[lambda step, state, metrics: seen.append(step)])
        assert seen == [0, 1]


class TestApiCli:
    def test_dump_config_round_trips(self, capsys):
        from repro.api.cli import main
        rc = main(["--train.steps=3", "--graft.eps=0.4", "--dump-config"])
        assert rc == 0
        dumped = json.loads(capsys.readouterr().out)
        cfg = ExperimentConfig.from_dict(dumped)
        assert cfg.train.steps == 3 and cfg.graft.eps == 0.4
        assert cfg.finalized() == cfg                # dump emits finalized form

    def test_bad_override_is_an_error(self, capsys):
        from repro.api.cli import main
        with pytest.raises(SystemExit):
            main(["positional"])
