"""Degrade gracefully when ``hypothesis`` is not installed.

Import ``given``/``settings``/``st`` from here instead of from hypothesis:
with hypothesis present they ARE hypothesis; without it the property-based
cases collect as skips (never as collection errors), and every
example-based test in the module still runs.
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*_args, **_kwargs):
        def deco(fn):
            return fn
        return deco

    class _StrategyStub:
        """Accepts any strategy constructor call (the arguments of a skipped
        ``@given`` still evaluate at collection time)."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _StrategyStub()
