"""Selection engine: registry resolution, sampler contracts, vmapped
multi-batch == single-batch loop, shard_map data-parallel == single-device
reference, core.graft compatibility shim."""
import dataclasses

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from conftest import run_forced_devices
from repro.selection import (GraftConfig, Sampler, SelectionInputs,
                             SelectionState, available, engine, get_sampler,
                             init_state, register)

CFG = GraftConfig(rset=(2, 4, 8), eps=0.25)


def _inputs(rng, K=32, d=24, r=8):
    V = jnp.asarray(rng.normal(size=(K, r)).astype(np.float32))
    G = jnp.asarray(rng.normal(size=(d, K)).astype(np.float32))
    return V, G, jnp.mean(G, axis=1)


class TestRegistry:
    def test_default_samplers_registered(self):
        names = available()
        for expected in ("graft", "random", "loss_topk", "full",
                         "el2n", "gradmatch", "craig", "glister"):
            assert expected in names

    def test_resolution_returns_sampler(self):
        smp = get_sampler("graft")
        assert isinstance(smp, Sampler) and smp.name == "graft"
        # a Sampler instance passes through unchanged
        assert get_sampler(smp) is smp

    def test_unknown_sampler_error_lists_available(self):
        with pytest.raises(KeyError, match="unknown sampler 'bogus'"):
            get_sampler("bogus")
        with pytest.raises(KeyError, match="graft"):
            get_sampler("bogus")

    def test_duplicate_registration_rejected(self):
        with pytest.raises(ValueError, match="already registered"):
            register(get_sampler("graft"))

    def test_custom_registration(self):
        def fn(cfg, inputs, step):
            return init_state(cfg, inputs.V.shape[0])._replace(step=step)
        try:
            register(Sampler("custom_test_only", fn))
            st, _ = engine.select_batch(CFG, "custom_test_only",
                                        *_inputs(np.random.default_rng(0)))
            assert int(st.rank) == CFG.r_max
        finally:
            from repro.selection import registry as reg
            reg._REGISTRY.pop("custom_test_only", None)


class TestSamplerContracts:
    @pytest.mark.parametrize("name", ["graft", "random", "loss_topk", "full",
                                      "el2n", "gradmatch", "craig", "glister",
                                      "streaming_graft"])
    def test_state_invariants(self, rng, name):
        K = 32
        V, G, gb = _inputs(rng, K=K)
        scores = jnp.asarray(rng.random(K).astype(np.float32))
        st, _ = engine.select_batch(CFG, name, V, G, gb, scores=scores)
        assert isinstance(st, SelectionState)
        piv = np.asarray(st.pivots)
        w = np.asarray(st.weights)
        assert piv.shape == (CFG.r_max,) and w.shape == (CFG.r_max,)
        assert piv.min() >= 0 and piv.max() < K
        active = piv[w > 0]
        assert len(set(active.tolist())) == len(active), "active pivots repeat"
        np.testing.assert_allclose(w.sum(), 1.0, atol=1e-5)
        assert 1 <= int(st.rank) <= CFG.r_max
        assert 0.0 <= float(st.last_error) <= 1.0 + 1e-6

    @pytest.mark.parametrize("name", ["loss_topk", "el2n"])
    def test_score_samplers_require_scores(self, rng, name):
        """Score-consuming samplers fail LOUDLY without scores — via the
        engine AND via Sampler.select directly — instead of silently
        selecting on a zeros placeholder."""
        V, G, gb = _inputs(rng)
        with pytest.raises(ValueError, match=f"sampler '{name}' requires "
                                             "SelectionInputs.scores"):
            engine.select_batch(CFG, name, V, G, gb)
        with pytest.raises(ValueError, match="scores"):
            get_sampler(name).select(CFG, SelectionInputs(V, G, gb))

    def test_declared_requirements_enforced(self, rng):
        """Every registered sampler's declared optional-input requirements
        (needs_scores AND needs_key) must actually be validated by
        Sampler.select — not just documented."""
        V, G, gb = _inputs(rng)
        scores = jnp.asarray(rng.random(V.shape[0]).astype(np.float32))
        key = jax.random.PRNGKey(0)
        for name in available():
            smp = get_sampler(name)
            if smp.needs_scores:
                with pytest.raises(ValueError, match="scores"):
                    smp.select(CFG, SelectionInputs(V, G, gb, None, key))
            if smp.needs_key:
                with pytest.raises(ValueError, match="key"):
                    smp.select(CFG, SelectionInputs(V, G, gb, scores, None))
            # with both supplied, every sampler must select
            st, _ = smp.select(CFG, SelectionInputs(V, G, gb, scores, key))
            assert isinstance(st, SelectionState)

    def test_random_requires_key_via_select(self, rng):
        V, G, gb = _inputs(rng)
        assert get_sampler("random").needs_key
        with pytest.raises(ValueError, match="key"):
            get_sampler("random").select(CFG, SelectionInputs(V, G, gb))

    def test_loss_topk_picks_highest_scores(self, rng):
        K = 16
        V, G, gb = _inputs(rng, K=K)
        scores = jnp.asarray(np.arange(K, dtype=np.float32))
        st, _ = engine.select_batch(CFG, "loss_topk", V, G, gb, scores=scores)
        assert set(np.asarray(st.pivots).tolist()) == set(range(K - CFG.r_max, K))

    def test_full_is_identity_prefix(self, rng):
        V, G, gb = _inputs(rng)
        st, _ = engine.select_batch(CFG, "full", V, G, gb)
        assert np.array_equal(np.asarray(st.pivots), np.arange(CFG.r_max))

    def test_random_deterministic_in_key(self, rng):
        V, G, gb = _inputs(rng)
        key = jax.random.PRNGKey(7)
        a, _ = engine.select_batch(CFG, "random", V, G, gb, key=key)
        b, _ = engine.select_batch(CFG, "random", V, G, gb, key=key)
        assert np.array_equal(np.asarray(a.pivots), np.asarray(b.pivots))

    def test_masked_weight_error_matches_active_subspace(self, rng):
        """Regression: gradmatch clips some weights to 0; last_error must be
        the projection error over ONLY the active columns, not a QR of the
        zero-masked matrix (whose completion directions fake extra span)."""
        K, d = 64, 16
        cfg = GraftConfig(rset=(4, 8, 16), eps=0.25)
        V = jnp.asarray(rng.normal(size=(K, 16)).astype(np.float32))
        G = jnp.asarray(rng.normal(size=(d, K)).astype(np.float32))
        gb = jnp.mean(G, axis=1)
        st, _ = engine.select_batch(cfg, "gradmatch", V, G, gb)
        w = np.asarray(st.weights)
        assert (w == 0).any(), "seed no longer exercises clipped weights"
        act = np.asarray(st.pivots)[w > 0]
        q, _ = np.linalg.qr(np.asarray(G)[:, act])
        g = np.asarray(gb)
        true_err = np.clip(1 - ((q.T @ g) ** 2).sum() / (g * g).sum(), 0, 1)
        np.testing.assert_allclose(float(st.last_error), true_err, atol=2e-3)

    def test_graft_matches_direct_call(self, rng):
        from repro.selection.graft import graft_select
        V, G, gb = _inputs(rng)
        via_engine, _ = engine.select_batch(CFG, "graft", V, G, gb)
        direct = graft_select(CFG, V, G, gb, jnp.int32(0))
        assert np.array_equal(np.asarray(via_engine.pivots), np.asarray(direct.pivots))
        assert int(via_engine.rank) == int(direct.rank)


class TestVmappedMultiBatch:
    @pytest.mark.parametrize("name", ["graft", "el2n", "random", "loss_topk"])
    def test_equals_python_loop(self, rng, name):
        B, K, d = 4, 24, 16
        Vs = jnp.asarray(rng.normal(size=(B, K, CFG.r_max)).astype(np.float32))
        Gs = jnp.asarray(rng.normal(size=(B, d, K)).astype(np.float32))
        gbs = jnp.mean(Gs, axis=2)
        scores = jnp.asarray(rng.random((B, K)).astype(np.float32))
        keys = jax.random.split(jax.random.PRNGKey(3), B)
        multi, _ = engine.select_multi_batch(CFG, name, Vs, Gs, gbs,
                                             scores=scores, keys=keys)
        assert multi.pivots.shape == (B, CFG.r_max)
        for b in range(B):
            single, _ = engine.select_batch(CFG, name, Vs[b], Gs[b], gbs[b],
                                            scores=scores[b], key=keys[b])
            np.testing.assert_array_equal(np.asarray(multi.pivots[b]),
                                          np.asarray(single.pivots))
            np.testing.assert_allclose(np.asarray(multi.weights[b]),
                                       np.asarray(single.weights), atol=1e-6)
            assert int(multi.rank[b]) == int(single.rank)
            np.testing.assert_allclose(float(multi.last_error[b]),
                                       float(single.last_error), atol=1e-5)

    @pytest.mark.parametrize("use_pallas", [False, True])
    def test_graft_use_pallas_matches_jnp_reference(self, rng, use_pallas):
        """Regression: use_pallas under the multi-batch engine used to vmap
        a grid=() pallas_call (no Mosaic lowering); it now dispatches ONE
        grid=(B,) fused kernel. Both settings must agree with the jnp
        single-batch loop."""
        B, K, d = 3, 24, 16
        cfg = GraftConfig(rset=(2, 4, 8), eps=0.25, use_pallas=use_pallas)
        Vs = jnp.asarray(rng.normal(size=(B, K, cfg.r_max)).astype(np.float32))
        Gs = jnp.asarray(rng.normal(size=(B, d, K)).astype(np.float32))
        gbs = jnp.mean(Gs, axis=2)
        multi, _ = engine.select_multi_batch(cfg, "graft", Vs, Gs, gbs)
        for b in range(B):
            single, _ = engine.select_batch(CFG, "graft", Vs[b], Gs[b], gbs[b])
            np.testing.assert_array_equal(np.asarray(multi.pivots[b]),
                                          np.asarray(single.pivots))
            assert int(multi.rank[b]) == int(single.rank)
            np.testing.assert_allclose(np.asarray(multi.weights[b]),
                                       np.asarray(single.weights), atol=1e-6)
            np.testing.assert_allclose(float(multi.last_error[b]),
                                       float(single.last_error), atol=1e-5)
            np.testing.assert_allclose(float(multi.alignment[b]),
                                       float(single.alignment), atol=1e-5)

    def test_microbatch_stack_feeds_vmapped_path(self, rng):
        from repro.data import DataConfig, SyntheticLM
        data = SyntheticLM(DataConfig(vocab_size=64, seq_len=8, global_batch=4))
        stack = data.microbatch_stack(step=3, num_micro=5)
        assert stack["tokens"].shape == (5, 4, 8)
        np.testing.assert_array_equal(stack["tokens"][2], data.batch_at(5)["tokens"])


class TestShardedSelection:
    def test_single_device_mesh_matches_reference(self, rng):
        V, G, gb = _inputs(rng)
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        sharded, _ = engine.select_sharded(CFG, mesh, V, G)
        single, _ = engine.select_batch(CFG, "graft", V, G, gb)
        np.testing.assert_array_equal(np.asarray(sharded.pivots),
                                      np.asarray(single.pivots))
        assert int(sharded.rank) == int(single.rank)
        np.testing.assert_allclose(float(sharded.last_error),
                                   float(single.last_error), atol=1e-5)
        np.testing.assert_allclose(float(sharded.alignment),
                                   float(single.alignment), atol=1e-5)

    def test_selector_is_cached(self):
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        a = engine.make_sharded_selector(CFG, mesh)
        b = engine.make_sharded_selector(CFG, mesh)
        assert a is b, "sharded selector must not re-trace per call"

    def test_input_validation(self, rng):
        V, G, _ = _inputs(rng, K=6, r=8)
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        with pytest.raises(ValueError, match="r_max"):
            engine.select_sharded(CFG, mesh, V, G)

    def test_no_data_axis_rejected(self, rng):
        V, G, _ = _inputs(rng)
        mesh = jax.make_mesh((1,), ("model",))
        with pytest.raises(ValueError, match="no axis"):
            engine.select_sharded(CFG, mesh, V, G)

    def test_multi_device_mesh_matches_reference(self):
        """4 forced CPU devices (fresh subprocess — device count is fixed at
        backend init): every shard holds a replica of the same batch; the
        sharded path must reproduce the single-device pivots per shard and
        the psum'd global rank decision must equal the single-device one."""
        code = """
            import numpy as np, jax, jax.numpy as jnp
            from repro.selection import GraftConfig, engine
            assert len(jax.devices()) == 4
            rng = np.random.default_rng(0)
            K, d, n = 24, 16, 4
            cfg = GraftConfig(rset=(2, 4, 8), eps=0.2)
            V1 = jnp.asarray(rng.normal(size=(K, 8)).astype(np.float32))
            G1 = jnp.asarray(rng.normal(size=(d, K)).astype(np.float32))
            single, _ = engine.select_batch(cfg, "graft", V1, G1, jnp.mean(G1, axis=1))
            mesh = jax.make_mesh((2, 2), ("data", "model"))  # 2-way data sharding
            n_sh = 2
            sharded, _ = engine.select_sharded(cfg, mesh,
                                               jnp.tile(V1, (n_sh, 1)),
                                               jnp.tile(G1, (1, n_sh)))
            piv = np.asarray(sharded.pivots).reshape(n_sh, cfg.r_max)
            for s in range(n_sh):
                assert np.array_equal(piv[s] - s * K, np.asarray(single.pivots)), s
            assert int(sharded.rank) == int(single.rank)
            np.testing.assert_allclose(float(sharded.last_error),
                                       float(single.last_error), atol=1e-5)
            np.testing.assert_allclose(float(sharded.alignment),
                                       float(single.alignment), atol=1e-5)
            np.testing.assert_allclose(np.asarray(sharded.weights).sum(), 1.0,
                                       atol=1e-5)
            print("SHARDED_OK")
        """
        assert "SHARDED_OK" in run_forced_devices(code, devices=4)


class TestSamplerV2Conformance:
    """Protocol conformance for EVERY registered sampler: init_carry/select
    round-trip on the single-batch, vmapped multi-batch, and forced-4-device
    shard_map paths, plus bit-identity of legacy (stateless) samplers with
    their pre-v2 ``fn``."""

    def _spec_inputs(self, rng, K=24, d=16):
        V = jnp.asarray(rng.normal(size=(K, CFG.r_max)).astype(np.float32))
        G = jnp.asarray(rng.normal(size=(d, K)).astype(np.float32))
        scores = jnp.asarray(rng.random(K).astype(np.float32))
        key = jax.random.PRNGKey(11)
        return V, G, jnp.mean(G, axis=1), scores, key

    @pytest.mark.parametrize("name", sorted(available()))
    def test_select_roundtrips_carry(self, rng, name):
        from repro.selection import CarrySpec
        smp = get_sampler(name)
        V, G, gb, scores, key = self._spec_inputs(rng)
        spec = CarrySpec(batch_size=int(V.shape[0]), grad_dim=int(G.shape[0]))
        carry0 = smp.init_carry(CFG, spec)
        st, carry1 = smp.select(CFG, SelectionInputs(V, G, gb, scores, key),
                                carry0)
        assert isinstance(st, SelectionState)
        assert (jax.tree_util.tree_structure(carry1)
                == jax.tree_util.tree_structure(carry0))
        for a, b in zip(jax.tree_util.tree_leaves(carry0),
                        jax.tree_util.tree_leaves(carry1)):
            assert a.shape == b.shape and a.dtype == b.dtype
        if not smp.stateful:
            assert not jax.tree_util.tree_leaves(carry1), (
                f"stateless sampler '{name}' returned a non-empty carry")
        # second hop: the returned carry feeds straight back in
        st2, _ = smp.select(CFG, SelectionInputs(V, G, gb, scores, key),
                            carry1, step=1)
        assert isinstance(st2, SelectionState)

    @pytest.mark.parametrize("name", sorted(available()))
    def test_legacy_samplers_bit_identical_to_fn(self, rng, name):
        """The v2 protocol is a pure superset: a stateless sampler routed
        through select/engine must reproduce its pre-v2 ``fn`` output
        bit-for-bit."""
        smp = get_sampler(name)
        if smp.stateful:
            pytest.skip("stateful sampler has no pre-v2 fn")
        V, G, gb, scores, key = self._spec_inputs(rng)
        inputs = SelectionInputs(V, G, gb, scores, key)
        # eager vs eager: Sampler.select is a zero-cost shim around fn
        direct = smp.fn(CFG, inputs, jnp.int32(0))
        via_select, carry = smp.select(CFG, inputs)
        # jitted vs jitted: the carry-threading engine compiles to the same
        # program as a bare jit of fn (the {} carry is leafless)
        direct_jit = jax.jit(smp.fn, static_argnums=0)(CFG, inputs,
                                                       jnp.int32(0))
        via_engine, _ = engine.select_batch(CFG, name, V, G, gb,
                                            scores=scores, key=key)
        for field in SelectionState._fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(direct, field)),
                np.asarray(getattr(via_select, field)), err_msg=field)
            np.testing.assert_array_equal(
                np.asarray(getattr(direct_jit, field)),
                np.asarray(getattr(via_engine, field)), err_msg=field)
        assert not jax.tree_util.tree_leaves(carry)

    @pytest.mark.parametrize("name", sorted(available()))
    def test_vmapped_path_all_samplers(self, rng, name):
        B, K, d = 3, 24, 16
        Vs = jnp.asarray(rng.normal(size=(B, K, CFG.r_max)).astype(np.float32))
        Gs = jnp.asarray(rng.normal(size=(B, d, K)).astype(np.float32))
        gbs = jnp.mean(Gs, axis=2)
        scores = jnp.asarray(rng.random((B, K)).astype(np.float32))
        keys = jax.random.split(jax.random.PRNGKey(5), B)
        multi, carry = engine.select_multi_batch(CFG, name, Vs, Gs, gbs,
                                                 scores=scores, keys=keys)
        assert multi.pivots.shape == (B, CFG.r_max)
        assert multi.weights.shape == (B, CFG.r_max)
        for leaf in jax.tree_util.tree_leaves(carry):
            assert leaf.shape[0] == B, "carry must stack along the batch axis"
        # round-trip: the stacked carry feeds the next refresh
        multi2, _ = engine.select_multi_batch(CFG, name, Vs, Gs, gbs,
                                              scores=scores, keys=keys,
                                              carry=carry, step=1)
        assert multi2.pivots.shape == (B, CFG.r_max)

    def test_forced_4device_shardmap_all_samplers(self):
        """Every registered sampler runs under the sharded selector on a
        forced-4-device CPU mesh and round-trips its carry (fresh subprocess:
        device count is fixed at backend init)."""
        code = """
            import numpy as np, jax, jax.numpy as jnp
            from repro.selection import GraftConfig, available, engine, get_sampler
            assert len(jax.devices()) == 4
            rng = np.random.default_rng(0)
            K, d = 16, 12
            cfg = GraftConfig(rset=(2, 4), eps=0.2)
            mesh = jax.make_mesh((2, 2), ("data", "model"))
            n_sh = 2
            V = jnp.asarray(rng.normal(size=(n_sh * K, cfg.r_max)).astype(np.float32))
            G = jnp.asarray(rng.normal(size=(d, n_sh * K)).astype(np.float32))
            scores = jnp.asarray(rng.random(n_sh * K).astype(np.float32))
            for name in available():
                state, carry = engine.select_sharded(cfg, mesh, V, G,
                                                     sampler=name, scores=scores)
                piv = np.asarray(state.pivots)
                assert piv.shape == (n_sh * cfg.r_max,), (name, piv.shape)
                assert piv.min() >= 0 and piv.max() < n_sh * K, name
                np.testing.assert_allclose(np.asarray(state.weights).sum(),
                                           1.0, atol=1e-5, err_msg=name)
                smp = get_sampler(name)
                state2, carry2 = engine.select_sharded(cfg, mesh, V, G,
                                                       sampler=name,
                                                       scores=scores,
                                                       carry=carry, step=1)
                assert (jax.tree_util.tree_structure(carry2)
                        == jax.tree_util.tree_structure(carry)), name
                if not smp.stateful:
                    assert not jax.tree_util.tree_leaves(carry), name
            print("CONFORMANCE_OK")
        """
        assert "CONFORMANCE_OK" in run_forced_devices(code, devices=4)


class TestStreamingGraft:
    """The frequent-directions sketch reservoir behind ``streaming_graft``."""

    def _inputs(self, rng, K=24, d=16):
        V = jnp.asarray(rng.normal(size=(K, CFG.r_max)).astype(np.float32))
        G = jnp.asarray(rng.normal(size=(d, K)).astype(np.float32))
        return V, G, jnp.mean(G, axis=1)

    def test_carry_shapes_and_footprint(self):
        from repro.selection import CarrySpec
        from repro.selection.streaming import SketchCarry, init_sketch_carry
        cfg = dataclasses.replace(CFG, sketch_rows=8)
        carry = init_sketch_carry(cfg, CarrySpec(batch_size=24, grad_dim=16))
        assert isinstance(carry, SketchCarry)
        assert carry.sketch.shape == (8, 16)      # fixed (L, d), K-independent
        assert carry.g_ema.shape == (16,)
        assert carry.count.shape == () and carry.agreement.shape == ()
        assert all(leaf.dtype == jnp.float32 for leaf in carry)

    def test_first_refresh_matches_per_batch_graft(self, rng):
        """Empty reservoir ⇒ agreement 0 ⇒ the blended target is exactly the
        per-batch mean gradient: refresh #1 is bit-identical to plain
        GRAFT."""
        V, G, gb = self._inputs(rng)
        stream, carry = engine.select_batch(CFG, "streaming_graft", V, G, gb)
        plain, _ = engine.select_batch(CFG, "graft", V, G, gb)
        for field in ("pivots", "weights", "rank", "last_error"):
            np.testing.assert_array_equal(
                np.asarray(getattr(stream, field)),
                np.asarray(getattr(plain, field)), err_msg=field)
        assert float(carry.count) == 1.0

    def test_reservoir_evolves_and_modulates_selection(self, rng):
        """Feeding the same batch twice drives agreement → 1 (the sketch
        spans the batch gradients); a live reservoir may change the blended
        target while the selection stays well-formed."""
        V, G, gb = self._inputs(rng)
        smp = get_sampler("streaming_graft")
        _, c1 = engine.select_batch(CFG, "streaming_graft", V, G, gb)
        st2, c2 = engine.select_batch(CFG, "streaming_graft", V, G, gb,
                                      carry=c1, step=1)
        assert float(c2.count) == 2.0
        assert float(c2.agreement) > 0.9, (
            "repeated batch must be captured by the sketch")
        assert 0.0 <= float(c2.agreement) <= 1.0
        assert smp.stateful
        w = np.asarray(st2.weights)
        np.testing.assert_allclose(w.sum(), 1.0, atol=1e-5)

    def test_sketch_rows_bound_holds_under_many_updates(self, rng):
        """The reservoir footprint is CONSTANT: 20 refreshes over random
        batches never grow the carry beyond (sketch_rows, d)."""
        cfg = dataclasses.replace(CFG, sketch_rows=4)
        V, G, gb = self._inputs(rng)
        carry = None
        for step in range(20):
            G = jnp.asarray(rng.normal(size=G.shape).astype(np.float32))
            _, carry = engine.select_batch(cfg, "streaming_graft", V, G,
                                           jnp.mean(G, axis=1),
                                           carry=carry, step=step)
        assert carry.sketch.shape == (4, 16)
        assert float(carry.count) == 20.0
        assert bool(jnp.all(jnp.isfinite(carry.sketch)))

    def test_carry_checkpoint_roundtrip_bit_exact(self, rng, tmp_path):
        """The reservoir survives a save/restore cycle bit-exactly — the
        invariant the chaos ``streaming_nan_rollback`` scenario leans on."""
        from repro.checkpoint import CheckpointManager
        V, G, gb = self._inputs(rng)
        _, c1 = engine.select_batch(CFG, "streaming_graft", V, G, gb)
        _, c2 = engine.select_batch(CFG, "streaming_graft", V, G, gb,
                                    carry=c1, step=1)
        state = {"step": jnp.int32(2), "sampler_carry": c2}
        mgr = CheckpointManager(str(tmp_path))
        mgr.save(2, state)
        mgr.wait()
        restored = mgr.restore(2, state)
        for a, b in zip(jax.tree_util.tree_leaves(c2),
                        jax.tree_util.tree_leaves(restored["sampler_carry"])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_streaming_via_graft_train_step(self, rng):
        """End to end: ``--train.sampler=streaming_graft`` threads the carry
        through the jitted train step — it advances ONLY on refresh steps."""
        from repro import configs
        from repro.launch import steps as steps_lib
        from repro.launch.specs import default_train_config
        mcfg = configs.get_smoke_config("minicpm-2b")
        tcfg = default_train_config("minicpm-2b", batch=8)
        tcfg = dataclasses.replace(
            tcfg, sampler="streaming_graft",
            graft=dataclasses.replace(tcfg.graft, refresh_every=2))
        toks = jnp.asarray(rng.integers(0, mcfg.vocab_size, (8, 16)),
                           dtype=jnp.int32)
        batch = {"tokens": toks, "labels": toks}
        state = steps_lib.init_train_state(mcfg, tcfg, jax.random.PRNGKey(2),
                                           batch_size=8)
        assert float(state["sampler_carry"].count) == 0.0
        state, metrics = steps_lib.graft_train_step(mcfg, tcfg, state, batch)
        assert np.isfinite(metrics["loss"])
        assert float(state["sampler_carry"].count) == 1.0   # step 0 refreshes
        state, _ = steps_lib.graft_train_step(mcfg, tcfg, state, batch)
        assert float(state["sampler_carry"].count) == 1.0   # step 1 does not


class TestCompatShim:
    def test_core_graft_reexports_selection(self):
        from repro.core import graft as core_graft
        from repro.selection import base as sel_base
        from repro.selection import graft as sel_graft
        assert core_graft.GraftConfig is sel_base.GraftConfig
        assert core_graft.GraftState is sel_base.SelectionState
        assert core_graft.graft_select is sel_graft.graft_select
        assert core_graft.init_state is sel_base.init_state
        assert core_graft.maybe_refresh is sel_graft.maybe_refresh

    def test_core_package_still_exports_graft_names(self):
        import repro.core as core
        assert core.GraftConfig is GraftConfig
        cfg = core.GraftConfig(rset=(2, 4))
        assert cfg.r_max == 4


class TestSourcesRegistry:
    """Feature-extractor / gradient-source registries (selection inputs)."""

    def test_builtins_registered(self):
        from repro.selection import available_features, available_grad_sources
        for f in ("svd", "sketch_svd", "pca_sketch", "pooled_raw", "ica"):
            assert f in available_features()
        for g in ("probe", "logit_embed", "full"):
            assert g in available_grad_sources()

    def test_unknown_names_error_with_available(self):
        from repro.selection import resolve_features, resolve_grad_source
        with pytest.raises(KeyError, match="unknown feature extractor"):
            resolve_features("bogus")
        with pytest.raises(KeyError, match="unknown grad source"):
            resolve_grad_source("bogus")

    @pytest.mark.parametrize("name", ["svd", "sketch_svd", "pca_sketch",
                                      "pooled_raw"])
    def test_feature_extractors_shapes_and_order(self, rng, name):
        from repro.selection import resolve_features
        K, M, R = 16, 48, 4
        A = jnp.asarray(rng.normal(size=(K, M)).astype(np.float32))
        V = resolve_features(name)(A, R)
        assert V.shape == (K, R)
        assert bool(jnp.all(jnp.isfinite(V)))
        # relevance ordering: column energy must be non-increasing
        energy = np.asarray(jnp.sum(V * V, axis=0))
        assert np.all(energy[:-1] >= energy[1:] - 1e-4), energy

    def test_pooled_raw_pads_when_narrow(self, rng):
        from repro.selection import resolve_features
        A = jnp.asarray(rng.normal(size=(8, 3)).astype(np.float32))
        V = resolve_features("pooled_raw")(A, 6)
        assert V.shape == (8, 6)
        assert bool(jnp.all(V[:, 3:] == 0.0))

    def test_grad_sources_through_selection_inputs(self, rng):
        """selection_inputs resolves feature/grad modes from GraftConfig —
        every registered combination must produce well-shaped V/G/scores."""
        import dataclasses as dc
        from repro import configs
        from repro.launch import steps as steps_lib
        from repro.launch.specs import default_train_config
        from repro.models import model as M
        mcfg = configs.get_smoke_config("minicpm-2b")
        params = M.init_params(mcfg, jax.random.PRNGKey(0))
        toks = jnp.asarray(rng.integers(0, mcfg.vocab_size, (8, 32)),
                           dtype=jnp.int32)
        batch = {"tokens": toks, "labels": toks}
        for fm in ("svd", "sketch_svd", "pca_sketch", "pooled_raw"):
            for gm in ("probe", "logit_embed"):
                tcfg = default_train_config("minicpm-2b", batch=8,
                                            feature_mode=fm, grad_mode=gm)
                assert tcfg.graft.feature_mode == fm
                V, G, gbar, scores = steps_lib.selection_inputs(
                    mcfg, tcfg, params, batch)
                assert V.shape == (8, tcfg.graft.r_max)
                assert G.shape[1] == 8 and gbar.shape == (G.shape[0],)
                assert scores.shape == (8,)
                assert bool(jnp.all(jnp.isfinite(V)))
                assert bool(jnp.all(jnp.isfinite(G)))
        del dc

    def test_custom_registration_and_overwrite_guard(self):
        from repro.selection import sources
        fx = sources.FeatureExtractor("custom_feat_test",
                                      lambda A, r: A[:, :r])
        try:
            sources.register_features(fx)
            assert sources.resolve_features("custom_feat_test") is fx
            with pytest.raises(ValueError, match="already registered"):
                sources.register_features(fx)
        finally:
            sources._FEATURES.pop("custom_feat_test", None)

    def test_ica_mode_reachable_from_graft_config(self, rng):
        """ROADMAP gap closed: feature_mode='ica' resolves through the
        registry and selection_inputs, with kurtosis-ordered columns."""
        from repro import configs
        from repro.launch import steps as steps_lib
        from repro.launch.specs import default_train_config
        from repro.models import model as M
        from repro.selection import resolve_features
        K, M_, R = 16, 48, 4
        A = jnp.asarray(rng.normal(size=(K, M_)).astype(np.float32))
        V = resolve_features("ica")(A, R)
        assert V.shape == (K, R) and bool(jnp.all(jnp.isfinite(V)))
        mcfg = configs.get_smoke_config("minicpm-2b")
        params = M.init_params(mcfg, jax.random.PRNGKey(0))
        toks = jnp.asarray(rng.integers(0, mcfg.vocab_size, (8, 16)),
                           dtype=jnp.int32)
        tcfg = default_train_config("minicpm-2b", batch=8,
                                    feature_mode="ica")
        V, G, gbar, scores = steps_lib.selection_inputs(
            mcfg, tcfg, params, {"tokens": toks, "labels": toks})
        assert V.shape == (8, tcfg.graft.r_max)
        assert bool(jnp.all(jnp.isfinite(V)))

    def test_full_grad_source_exact_parity(self, rng):
        """grad_mode='full' (per_sample_grads_full behind the GradSource
        protocol) on a tiny f32 model: the mean per-sample gradient must
        equal the batch-loss gradient (linearity of the mean-CE loss), and
        the per-sample rows restricted to the lm_head leaf must match the
        analytic head gradient (1/S)·Σ_s h_s (p−y)_sᵀ."""
        from repro import configs
        from repro.models import model as M
        from repro.selection import sources
        mcfg = configs.get_smoke_config("stablelm-12b")
        assert not mcfg.tie_embeddings
        mcfg = dataclasses.replace(mcfg, param_dtype="float32", num_layers=1)
        params = M.init_params(mcfg, jax.random.PRNGKey(1))
        K, S = 4, 8
        toks = jnp.asarray(rng.integers(0, mcfg.vocab_size, (K, S)),
                           dtype=jnp.int32)
        batch = {"tokens": toks, "labels": toks}
        h, _ = M.forward_hiddens(mcfg, params, batch)
        logits = M.logits_from_hiddens(mcfg, params, h)
        src = sources.resolve_grad_source("full")
        assert src.needs_params and src.needs_batch
        with pytest.raises(ValueError, match="requires GradSourceInputs.batch"):
            src(sources.GradSourceInputs(logits=logits, labels=toks,
                                         hiddens=h, mcfg=mcfg, params=params))
        emb = src(sources.GradSourceInputs(
            logits=logits, labels=toks, hiddens=h, mcfg=mcfg, params=params,
            batch=batch))                                   # (K, |Θ|)
        num_params = sum(int(np.prod(l.shape)) for l in
                         jax.tree_util.tree_leaves(params))
        assert emb.shape == (K, num_params)
        # 1) mean of per-sample grads == batch gradient
        gref = jax.grad(lambda p: M.loss_fn(mcfg, p, batch)[0])(params)
        flat_ref = jnp.concatenate([jnp.ravel(l).astype(jnp.float32)
                                    for l in jax.tree_util.tree_leaves(gref)])
        np.testing.assert_allclose(np.asarray(jnp.mean(emb, axis=0)),
                                   np.asarray(flat_ref), atol=1e-5)
        # 2) per-sample lm_head rows == analytic (1/S)·h (p−y)ᵀ
        leaves = jax.tree_util.tree_flatten_with_path(params)[0]
        offset = 0
        head_slice = None
        for path, leaf in leaves:
            n = int(np.prod(leaf.shape))
            if "lm_head" in "/".join(str(getattr(p, "key", p)) for p in path):
                head_slice = (offset, offset + n, leaf.shape)
            offset += n
        assert head_slice is not None
        lo, hi, shape = head_slice
        p_soft = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
        onehot = jax.nn.one_hot(toks, mcfg.vocab_size, dtype=jnp.float32)
        analytic = jnp.einsum("ksd,ksv->kdv", h.astype(jnp.float32),
                              p_soft - onehot) / S
        np.testing.assert_allclose(
            np.asarray(emb[:, lo:hi]).reshape((K,) + shape),
            np.asarray(analytic), atol=2e-4)

    def test_full_grad_source_selects_through_train_step(self, rng):
        """grad_mode='full' end to end: selection_inputs → a GRAFT train
        step on a tiny model (the small-model oracle path)."""
        from repro import configs
        from repro.launch import steps as steps_lib
        from repro.launch.specs import default_train_config
        from repro.models import model as M
        mcfg = configs.get_smoke_config("minicpm-2b")
        params = M.init_params(mcfg, jax.random.PRNGKey(0))
        toks = jnp.asarray(rng.integers(0, mcfg.vocab_size, (8, 16)),
                           dtype=jnp.int32)
        batch = {"tokens": toks, "labels": toks}
        tcfg = default_train_config("minicpm-2b", batch=8, grad_mode="full")
        V, G, gbar, scores = steps_lib.selection_inputs(
            mcfg, tcfg, params, batch)
        num_params = sum(int(np.prod(l.shape)) for l in
                         jax.tree_util.tree_leaves(params))
        assert G.shape == (num_params, 8) and gbar.shape == (num_params,)
        assert bool(jnp.all(jnp.isfinite(G)))
        state = steps_lib.init_train_state(mcfg, tcfg, jax.random.PRNGKey(2),
                                           batch_size=8)
        state, metrics = steps_lib.graft_train_step(mcfg, tcfg, state, batch)
        assert np.isfinite(metrics["loss"])
