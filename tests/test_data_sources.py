"""Task/data-source registry: protocol contracts (spec/one-int state/host
sharding), tagged ExperimentConfig.data section (JSON round-trip, CLI
overrides, source swap + rederivation), checkpoint-manifest resume restores
the right source + step, selection engine on classification batches
(vmapped == loop), and end-to-end training on every registered workload."""
import dataclasses
import json

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.api import ExperimentConfig, Trainer
from repro.data import (ClassificationConfig, DataConfig, VisionConfig,
                        available_sources, build_source, derive_config,
                        entry_for_config, get_source, source_name_of)

SOURCES = ("synthetic_lm", "synthetic_classification", "synthetic_vision")


def small_overrides(source, **extra):
    ov = ["train.steps=6", "train.batch=8", "train.seq=16", "train.seed=3",
          "train.log_every=0", "graft.rset=[2,4]", "graft.refresh_every=3",
          f"data.source={source}"]
    ov += [f"{k}={v}" for k, v in extra.items()]
    return ov


def small_cfg(source, **extra):
    return ExperimentConfig().apply_overrides(small_overrides(source, **extra))


@pytest.fixture
def smoke_mcfg():
    from repro import configs
    return configs.get_smoke_config("minicpm-2b")


class TestRegistry:
    def test_builtin_sources_registered(self):
        assert set(SOURCES) <= set(available_sources())

    def test_unknown_source_errors_with_available(self):
        with pytest.raises(KeyError, match="unknown data source"):
            get_source("bogus")
        with pytest.raises(KeyError, match="no registered data source"):
            entry_for_config(object())

    def test_config_classes_are_uniquely_tagged(self):
        assert source_name_of(DataConfig()) == "synthetic_lm"
        assert source_name_of(ClassificationConfig()) == \
            "synthetic_classification"
        assert source_name_of(VisionConfig()) == "synthetic_vision"

    @pytest.mark.parametrize("source", SOURCES)
    def test_spec_matches_produced_batches(self, source, smoke_mcfg):
        dcfg = derive_config(source, smoke_mcfg, batch=8, seq=16, seed=0)
        data = build_source(dcfg)
        spec = data.spec()
        batch = data.batch_at(2)
        assert set(spec) == set(batch)
        for k, s in spec.items():
            assert batch[k].shape == s.shape, k
            assert batch[k].dtype == s.dtype, k

    @pytest.mark.parametrize("source", SOURCES)
    def test_state_is_one_integer_and_resumes(self, source, smoke_mcfg):
        dcfg = derive_config(source, smoke_mcfg, batch=4, seq=8, seed=1)
        data = build_source(dcfg)
        it = iter(data)
        for _ in range(3):
            next(it)
        state = data.state_dict()
        assert state == {"step": 3}
        fresh = build_source(dcfg)
        fresh.load_state_dict(json.loads(json.dumps(state)))  # manifest trip
        a, b = next(iter(fresh)), next(it)
        for k in a:
            np.testing.assert_array_equal(a[k], b[k])

    @pytest.mark.parametrize("source", SOURCES)
    def test_host_sharding_is_byte_exact(self, source, smoke_mcfg):
        """Per-GLOBAL-example streams: any host count yields the same global
        batch (elastic re-sharding invariant, same as the LM pipeline)."""
        dcfg = derive_config(source, smoke_mcfg, batch=8, seq=8, seed=2)
        full = build_source(dcfg).batch_at(4)
        shards = [build_source(dataclasses.replace(
            dcfg, num_hosts=2, host_index=h)).batch_at(4) for h in (0, 1)]
        for k in full:
            np.testing.assert_array_equal(
                full[k], np.concatenate([s[k] for s in shards]))

    def test_classification_imbalance_and_label_noise(self, smoke_mcfg):
        dcfg = dataclasses.replace(
            derive_config("synthetic_classification", smoke_mcfg,
                          batch=64, seq=8, seed=0),
            imbalance=1.5, label_noise=0.25, num_classes=8)
        data = build_source(dcfg)
        classes = np.concatenate([data.classes_at(s) for s in range(8)])
        counts = np.bincount(classes, minlength=8)
        # Zipf skew: the head class must dominate the tail class clearly
        assert counts[0] > 2 * max(counts[-1], 1), counts
        labels = np.concatenate(
            [data.batch_at(s)["labels"][:, 0] for s in range(8)])
        flipped = np.mean(labels != classes)
        assert 0.05 < flipped < 0.5, flipped   # ~label_noise·(C-1)/C

    def test_vision_images_layout_and_patch_round_trip(self, smoke_mcfg):
        dcfg = derive_config("synthetic_vision", smoke_mcfg,
                             batch=4, seq=8, seed=0)
        data = build_source(dcfg)
        imgs, classes = data.images_at(1)
        assert imgs.shape == (4, dcfg.image_size, dcfg.image_size,
                              dcfg.channels)
        assert imgs.dtype == np.float32 and classes.shape == (4,)
        # the model batch's patch rows are exactly the patchified image
        batch = data.batch_at(1)
        np.testing.assert_allclose(
            batch["patch_embeds"][0, 0, :dcfg.patch_dim],
            imgs[0, :dcfg.patch_size, :dcfg.patch_size, :].reshape(-1),
            rtol=1e-6)
        assert np.all(batch["patch_embeds"][..., dcfg.patch_dim:] == 0.0)


class TestTaggedConfigSection:
    @pytest.mark.parametrize("source", SOURCES)
    def test_json_round_trip(self, source):
        cfg = small_cfg(source)
        assert ExperimentConfig.from_json(cfg.to_json()) == cfg
        fin = cfg.finalized()
        assert ExperimentConfig.from_json(fin.to_json()) == fin
        if source == "synthetic_lm":
            # the default source stays UNTAGGED so pre-registry configs
            # keep their config_hash (a missing tag reads as LM)
            assert "source" not in fin.to_dict()["data"]
        else:
            assert fin.to_dict()["data"]["source"] == source

    def test_untagged_data_dict_reads_as_lm(self):
        """Pre-registry manifests have no 'source' key — they must still
        load as the LM pipeline, and the default LM config_hash must not
        have changed with the introduction of the tag."""
        d = ExperimentConfig().finalized().to_dict()
        assert "source" not in d["data"]
        cfg = ExperimentConfig.from_dict(d)
        assert isinstance(cfg.data, DataConfig)

    def test_per_source_field_overrides(self):
        cfg = small_cfg("synthetic_classification", **{
            "data.num_classes": 4, "data.imbalance": 0.7,
            "data.label_noise": 0.1})
        assert cfg.data.num_classes == 4
        assert cfg.data.imbalance == 0.7
        mcfg, _, _ = cfg.build()
        assert mcfg.vocab_size == 4                  # task-pinned head
        assert mcfg.frontend == "audio_frames"
        cfg = small_cfg("synthetic_vision", **{"data.patch_size": 2})
        assert cfg.data.patch_size == 2
        assert cfg.build()[0].num_patches == 64

    def test_unknown_field_error_lists_source_fields(self):
        with pytest.raises(KeyError, match="patch_size"):
            small_cfg("synthetic_vision", **{"data.bogus": 1})
        with pytest.raises(KeyError, match="unknown data source"):
            small_cfg("nope")

    def test_source_swap_derives_from_model_and_train(self):
        cfg = small_cfg("synthetic_classification")
        assert cfg.data.global_batch == 8
        assert cfg.data.embed_dim == cfg.model.build().d_model
        assert cfg.data.seed == 3                    # train.seed flows in

    def test_untouched_section_rederives_on_later_train_override(self):
        cfg = ExperimentConfig().apply_overrides(
            ["data.source=synthetic_vision", "train.batch=8"])
        assert cfg.data.global_batch == 8
        cfg.build()                                  # no mismatch raise

    def test_touched_section_errors_loudly_on_later_train_override(self):
        """Same contract as the LM section: explicitly-edited data + a later
        conflicting train override must raise, not silently rederive."""
        cfg = ExperimentConfig().apply_overrides(
            ["data.source=synthetic_classification", "data.noise=0.5",
             "train.batch=4"])
        with pytest.raises(ValueError, match="global_batch"):
            cfg.build()

    def test_explicit_mismatched_embed_dim_errors_loudly(self):
        cfg = ExperimentConfig(
            data=ClassificationConfig(embed_dim=999, global_batch=16))
        with pytest.raises(ValueError, match="embed_dim"):
            cfg.build()

    def test_sentinel_fields_finalize_from_model_and_train(self):
        cfg = ExperimentConfig(data=ClassificationConfig())   # all sentinels
        fin = cfg.finalized()
        assert fin.data.embed_dim == cfg.model.build().d_model
        assert fin.data.global_batch == cfg.train.batch
        assert fin.finalized() == fin                # idempotent

    def test_config_hash_separates_sources(self):
        hashes = {small_cfg(s).config_hash() for s in SOURCES}
        assert len(hashes) == 3
        # run-environment fields still don't affect the hash
        a = small_cfg("synthetic_classification")
        b = a.apply_overrides(["train.log_every=7"])
        assert a.config_hash() == b.config_hash()


class TestTrainAndResume:
    def test_classification_resume_restores_source_and_step(self, tmp_path):
        """Kill → resume from the manifest alone: the resumed Trainer must
        carry the SAME source config (not the LM default), restart at the
        right step, and land on the uninterrupted final loss."""
        full = Trainer(small_cfg("synthetic_classification")).fit()
        ck = str(tmp_path / "ck")
        interrupted = small_cfg(
            "synthetic_classification",
            **{"train.stop_after": 3, "train.checkpoint_dir": ck,
               "train.checkpoint_every": 100})
        Trainer(interrupted).fit()

        resumed = Trainer.from_checkpoint(ck)
        assert isinstance(resumed.config.data, ClassificationConfig)
        assert resumed.config.config_hash() == full["config_hash"]
        report = resumed.fit()
        assert resumed.start_step == 3
        assert len(report["history"]) == 3           # steps 3..5 only
        np.testing.assert_allclose(full["final_loss"], report["final_loss"],
                                   rtol=1e-6)

    def test_vision_trains_and_reports_accuracy(self):
        report = Trainer(small_cfg(
            "synthetic_vision", **{"train.eval_every": 3})).fit()
        eval_rows = [h for h in report["history"] if "eval_acc" in h]
        assert len(eval_rows) == 2
        assert all(0.0 <= h["eval_acc"] <= 1.0 for h in eval_rows)
        assert np.isfinite(report["final_loss"])

    def test_classification_loss_decreases(self):
        """Acceptance: a 50-step classification run must learn."""
        cfg = ExperimentConfig().apply_overrides(
            ["train.steps=50", "train.batch=16", "train.log_every=0",
             "optimizer.learning_rate=0.003",
             "data.source=synthetic_classification"])
        losses = [h["loss"] for h in Trainer(cfg).fit()["history"]]
        assert np.mean(losses[-10:]) < np.mean(losses[:10]) - 0.3, \
            (np.mean(losses[:10]), np.mean(losses[-10:]))


class TestMaskedSelectionInputs:
    def test_vision_scores_ignore_unlabeled_patch_positions(self):
        """Regression: probe CE scores and grad embeddings must be computed
        over LABELED positions only — on vision batches the 16 unlabeled
        patch positions (padded label 0) would otherwise dominate the
        1-position class signal 16:1."""
        from repro.launch import steps as steps_lib
        from repro.models import model as M
        cfg = small_cfg("synthetic_vision").finalized()
        mcfg, tcfg, data = cfg.build()
        params = M.init_params(mcfg, jax.random.PRNGKey(0))
        batch = {k: jnp.asarray(v) for k, v in data.batch_at(0).items()}
        V, G, gbar, scores = steps_lib.selection_inputs(
            mcfg, tcfg, params, batch)
        h, mask = M.forward_hiddens(mcfg, params, batch)
        logits = M.logits_from_hiddens(mcfg, params, h)
        logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
        lab = M._pad_labels(batch["labels"], h.shape[1])
        nll = -jnp.take_along_axis(logp, lab[..., None], axis=-1)[..., 0]
        ref = jnp.sum(nll * mask, 1) / jnp.maximum(jnp.sum(mask, 1), 1.0)
        np.testing.assert_allclose(np.asarray(scores), np.asarray(ref),
                                   rtol=1e-5)

    def test_masked_probe_embeddings_match_labeled_only_slice(self, rng):
        """logit_error_embeddings with a mask == the unmasked call on just
        the labeled positions (and the all-ones mask is a no-op)."""
        from repro.core.grad_features import logit_error_embeddings
        K, S, V, E = 4, 6, 8, 5
        logits = jnp.asarray(rng.normal(size=(K, S, V)).astype(np.float32))
        labels = jnp.asarray(rng.integers(0, V, (K, S)), dtype=jnp.int32)
        hiddens = jnp.asarray(rng.normal(size=(K, S, E)).astype(np.float32))
        ones = jnp.ones((K, S), jnp.float32)
        np.testing.assert_allclose(
            np.asarray(logit_error_embeddings(logits, labels, hiddens)),
            np.asarray(logit_error_embeddings(logits, labels, hiddens,
                                              mask=ones)), rtol=1e-6)
        # mask off the first 4 positions == slicing them away
        m = ones.at[:, :4].set(0.0)
        np.testing.assert_allclose(
            np.asarray(logit_error_embeddings(logits, labels, hiddens,
                                              mask=m)),
            np.asarray(logit_error_embeddings(logits[:, 4:], labels[:, 4:],
                                              hiddens[:, 4:])), rtol=1e-5)


class TestSelectionOnClassificationBatches:
    def test_vmapped_engine_equals_loop(self, smoke_mcfg):
        """The multi-batch engine on REAL classification selection inputs
        (microbatch stack → selection_inputs per microbatch) must equal a
        Python loop of single-batch selections."""
        from repro.launch import steps as steps_lib
        from repro.models import model as M
        from repro.selection import GraftConfig, engine

        cfg = small_cfg("synthetic_classification").finalized()
        entry = get_source("synthetic_classification")
        mcfg = cfg.model.build(
            extra_overrides=entry.task.model_overrides(cfg.data))
        data = build_source(cfg.data)
        tcfg = steps_lib.TrainConfig(graft=cfg.graft,
                                     probe_positions=cfg.train.probe_positions)
        params = M.init_params(mcfg, jax.random.PRNGKey(0))

        B = 3
        stack = data.microbatch_stack(step=0, num_micro=B)
        per_batch = [steps_lib.selection_inputs(
            mcfg, tcfg, params,
            {k: jnp.asarray(v[b]) for k, v in stack.items()})
            for b in range(B)]
        Vs = jnp.stack([p[0] for p in per_batch])
        Gs = jnp.stack([p[1] for p in per_batch])
        gbs = jnp.stack([p[2] for p in per_batch])
        scores = jnp.stack([p[3] for p in per_batch])

        gcfg = GraftConfig(rset=(2, 4), eps=0.25)
        keys = jax.random.split(jax.random.PRNGKey(7), B)
        multi, _ = engine.select_multi_batch(gcfg, "graft", Vs, Gs, gbs,
                                             scores=scores, keys=keys)
        for b in range(B):
            single, _ = engine.select_batch(gcfg, "graft", Vs[b], Gs[b],
                                            gbs[b], scores=scores[b],
                                            key=keys[b])
            np.testing.assert_array_equal(np.asarray(multi.pivots[b]),
                                          np.asarray(single.pivots))
            assert int(multi.rank[b]) == int(single.rank)
            np.testing.assert_allclose(np.asarray(multi.weights[b]),
                                       np.asarray(single.weights), atol=1e-6)
