"""Optimizers, schedules, data pipeline, checkpointing, compression,
accumulation, straggler monitor."""
import os

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis_compat import given, settings, st  # skips, not collection errors, without hypothesis

from repro.checkpoint import CheckpointManager
from repro.data import DataConfig, SyntheticClassification, SyntheticLM
from repro.distributed import accumulate, compression
from repro.distributed.straggler import StragglerConfig, StragglerMonitor
from repro.optim import OptimizerConfig, make_optimizer
from repro.optim.schedules import cosine, wsd


class TestOptimizers:
    @pytest.mark.parametrize("name,lr", [("adamw", 0.05), ("sgd", 0.05),
                                         ("lion", 0.005), ("adafactor", 0.1)])
    def test_converges_on_quadratic(self, name, lr):
        cfg = OptimizerConfig(name=name, learning_rate=lr, schedule="constant",
                              weight_decay=0.0)
        opt = make_optimizer(cfg)
        W = {"a": jnp.ones((6, 6)), "b": jnp.ones((6,))}
        state = opt.init(W)

        @jax.jit
        def step(W, state, i):
            loss, g = jax.value_and_grad(
                lambda w: sum(jnp.sum(w[k] ** 2) for k in w))(W)
            W, state, m = opt.apply(W, g, state, i)
            return W, state, loss

        for i in range(300):
            W, state, loss = step(W, state, jnp.int32(i))
        assert float(loss) < 0.3

    def test_adamw_matches_reference_numpy(self):
        """One AdamW step vs a hand-written numpy reference."""
        cfg = OptimizerConfig(name="adamw", learning_rate=0.1,
                              schedule="constant", weight_decay=0.01,
                              beta1=0.9, beta2=0.95, eps=1e-8, clip_norm=None)
        opt = make_optimizer(cfg)
        w0 = np.array([1.0, -2.0, 3.0], np.float32)
        g = np.array([0.5, 0.25, -1.0], np.float32)
        params = {"w": jnp.asarray(w0)}
        state = opt.init(params)
        new_p, _, _ = opt.apply(params, {"w": jnp.asarray(g)}, state, jnp.int32(0))
        m = 0.1 * g
        v = 0.05 * g * g
        mh, vh = m / (1 - 0.9), v / (1 - 0.95)
        ref = w0 - 0.1 * (mh / (np.sqrt(vh) + 1e-8) + 0.01 * w0)
        np.testing.assert_allclose(np.asarray(new_p["w"]), ref, rtol=1e-5)

    def test_adafactor_state_is_factored(self):
        cfg = OptimizerConfig(name="adafactor")
        opt = make_optimizer(cfg)
        params = {"w": jnp.zeros((32, 16)), "b": jnp.zeros((16,))}
        state = opt.init(params)
        assert state["v"]["w"]["vr"].shape == (32,)
        assert state["v"]["w"]["vc"].shape == (16,)
        assert state["v"]["b"]["v"].shape == (16,)

    def test_grad_clipping(self):
        from repro.optim import clip_by_global_norm
        g = {"a": jnp.full((10,), 100.0)}
        clipped, norm = clip_by_global_norm(g, 1.0)
        assert abs(float(jnp.linalg.norm(clipped["a"])) - 1.0) < 1e-4
        assert float(norm) > 100


class TestSchedules:
    def test_wsd_phases(self):
        f = wsd(2.0, 1000, warmup_steps=100, decay_fraction=0.1)
        assert abs(float(f(50)) - 1.0) < 1e-5          # mid-warmup
        assert abs(float(f(500)) - 2.0) < 1e-5         # stable plateau
        assert float(f(999)) < 0.1                     # decayed
        assert float(f(950)) < 2.0                     # inside decay window

    def test_cosine_endpoints(self):
        f = cosine(1.0, 100, warmup_steps=10, min_ratio=0.1)
        assert float(f(0)) == 0.0
        assert abs(float(f(10)) - 1.0) < 1e-5
        assert abs(float(f(100)) - 0.1) < 1e-5


class TestDataPipeline:
    def test_determinism(self):
        d = SyntheticLM(DataConfig(global_batch=4, seq_len=16))
        b1, b2 = d.batch_at(7), d.batch_at(7)
        assert np.array_equal(b1["tokens"], b2["tokens"])

    def test_labels_are_shifted_tokens(self):
        d = SyntheticLM(DataConfig(global_batch=2, seq_len=16))
        b = d.batch_at(0)
        assert b["tokens"].shape == b["labels"].shape == (2, 16)
        assert not np.array_equal(b["tokens"], b["labels"])

    def test_elastic_host_sharding(self):
        """Same global stream regardless of host count (elastic restarts)."""
        whole = SyntheticLM(DataConfig(global_batch=8, seq_len=8)).batch_at(5)
        parts = [SyntheticLM(DataConfig(global_batch=8, seq_len=8,
                                        num_hosts=4, host_index=i)).batch_at(5)
                 for i in range(4)]
        np.testing.assert_array_equal(
            whole["tokens"], np.concatenate([p["tokens"] for p in parts]))

    def test_resume_state(self):
        d = SyntheticLM(DataConfig(global_batch=2, seq_len=8))
        it = iter(d)
        next(it); next(it); next(it)
        state = d.state_dict()
        d2 = SyntheticLM(DataConfig(global_batch=2, seq_len=8))
        d2.load_state_dict(state)
        np.testing.assert_array_equal(next(iter(d2))["tokens"],
                                      d.batch_at(3)["tokens"])

    def test_markov_structure_is_learnable(self):
        """Bigram statistics must beat unigram (the stream has structure)."""
        d = SyntheticLM(DataConfig(global_batch=32, seq_len=64, vocab_size=64,
                                   num_clusters=4))
        toks = np.concatenate([d.batch_at(i)["tokens"].ravel() for i in range(4)])
        uni = np.bincount(toks, minlength=64) / len(toks)
        h_uni = -np.sum(uni * np.log(uni + 1e-12))
        pairs = np.stack([toks[:-1], toks[1:]])
        joint = np.zeros((64, 64))
        np.add.at(joint, (pairs[0], pairs[1]), 1)
        joint /= joint.sum()
        cond = joint / (joint.sum(1, keepdims=True) + 1e-12)
        h_bi = -np.sum(joint * np.log(cond + 1e-12))
        assert h_bi < h_uni - 0.05, (h_bi, h_uni)

    def test_classification_split(self):
        ds = SyntheticClassification(n=512, dim=16, num_classes=5)
        (xtr, ytr), (xte, yte) = ds.split(0.25)
        assert len(ytr) == 384 and len(yte) == 128
        assert set(np.unique(ytr)) <= set(range(5))


class TestCheckpoint:
    def test_roundtrip_and_gc(self, tmp_path):
        cm = CheckpointManager(str(tmp_path), keep_last_n=2)
        tree = {"w": jnp.arange(6.0).reshape(2, 3), "s": jnp.int32(3)}
        for s in (10, 20, 30):
            cm.save(s, tree, extra={"k": s})
        assert cm.all_steps() == [20, 30]
        out = cm.restore(30, jax.tree_util.tree_map(jnp.zeros_like, tree))
        np.testing.assert_array_equal(np.asarray(out["w"]),
                                      np.asarray(tree["w"]))
        assert cm.manifest(20)["extra"]["k"] == 20

    def test_corruption_detected(self, tmp_path):
        cm = CheckpointManager(str(tmp_path))
        tree = {"w": jnp.ones((4,))}
        path = cm.save(1, tree)
        # corrupt the leaf file
        fname = [f for f in os.listdir(path) if f.endswith(".npy")][0]
        arr = np.load(os.path.join(path, fname))
        np.save(os.path.join(path, fname), arr + 1)
        with pytest.raises(IOError, match="checksum"):
            cm.restore(1, tree)

    def test_interrupted_save_never_corrupts_latest(self, tmp_path):
        """A tmp dir from a crashed save must not count as a checkpoint."""
        cm = CheckpointManager(str(tmp_path))
        cm.save(1, {"w": jnp.ones((2,))})
        os.makedirs(os.path.join(str(tmp_path), "tmp.2.999"))  # simulated crash
        assert cm.latest_step() == 1

    def test_async_save(self, tmp_path):
        cm = CheckpointManager(str(tmp_path), async_save=True)
        cm.save(5, {"w": jnp.ones((8,))})
        cm.wait()
        assert cm.latest_step() == 5

    def test_shape_mismatch_rejected(self, tmp_path):
        cm = CheckpointManager(str(tmp_path))
        cm.save(1, {"w": jnp.ones((4,))})
        with pytest.raises(ValueError, match="shape"):
            cm.restore(1, {"w": jnp.ones((5,))})


class TestCompression:
    def test_quantization_error_bound(self, rng):
        """|x − deq(q(x))| ≤ absmax/254 per block (int8 step/2)."""
        x = jnp.asarray(rng.normal(size=(1000,)).astype(np.float32) * 5)
        q, s = compression.quantize_int8(x)
        back = compression.dequantize_int8(q, s, x.shape, jnp.float32)
        blocks = np.asarray(x)[: (1000 // 256) * 256].reshape(-1, 256)
        err = np.abs(np.asarray(back) - np.asarray(x))
        assert err.max() <= np.abs(np.asarray(x)).max() / 127.0 + 1e-6

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 999), scale=st.floats(1e-3, 1e3))
    def test_property_roundtrip_bounded(self, seed, scale):
        g = np.random.default_rng(seed)
        x = jnp.asarray((g.normal(size=(300,)) * scale).astype(np.float32))
        q, s = compression.quantize_int8(x)
        back = compression.dequantize_int8(q, s, x.shape, jnp.float32)
        # per-block bound: |err| <= block_absmax/127 (half-step would be /254)
        xb, _ = compression._pad_to_block(x)
        blocks = np.asarray(xb).reshape(-1, 256)
        bound = np.abs(blocks).max(1) / 127.0 + 1e-9
        errb = np.abs(np.asarray(back) - np.asarray(x))
        errb = np.pad(errb, (0, blocks.size - errb.size)).reshape(-1, 256)
        assert (errb.max(1) <= bound + 1e-6).all()

    def test_error_feedback_converges_on_quadratic(self):
        """EF-compressed gradient descent reaches the optimum (bias cancels)."""
        target = jnp.asarray(np.linspace(-1, 1, 64).astype(np.float32))
        w = jnp.zeros((64,))
        err = jnp.zeros((64,))
        for _ in range(200):
            g = 2 * (w - target)
            comp = g.astype(jnp.float32) + err
            q, s = compression.quantize_int8(comp)
            gq = compression.dequantize_int8(q, s, g.shape, jnp.float32)
            err = comp - gq
            w = w - 0.05 * gq
        assert float(jnp.max(jnp.abs(w - target))) < 1e-2

    def test_ef_compressed_psum_under_shard_map(self):
        """Single-device shard_map sanity: reduces to identity mean."""
        from jax.experimental.shard_map import shard_map
        from jax.sharding import Mesh, PartitionSpec as P
        mesh = Mesh(np.array(jax.devices()[:1]), ("pod",))
        g = jnp.asarray(np.random.default_rng(0).normal(size=(256,)).astype(np.float32))
        e = jnp.zeros((256,))

        def f(g, e):
            return compression.ef_compressed_psum(g, e, "pod", 1)

        out, new_e = shard_map(f, mesh=mesh, in_specs=(P(), P()),
                               out_specs=(P(), P()))(g, e)
        np.testing.assert_allclose(np.asarray(out), np.asarray(g), atol=0.05)


class TestAccumulation:
    def test_matches_full_batch_grads(self, rng):
        params = {"w": jnp.asarray(rng.normal(size=(8, 4)).astype(np.float32))}
        batch = {"x": jnp.asarray(rng.normal(size=(16, 8)).astype(np.float32)),
                 "y": jnp.asarray(rng.normal(size=(16, 4)).astype(np.float32))}

        def loss_fn(p, b):
            return jnp.mean((b["x"] @ p["w"] - b["y"]) ** 2)

        loss_full, g_full = jax.value_and_grad(loss_fn)(params, batch)
        loss_acc, g_acc = accumulate.accumulated_grads(loss_fn, params, batch, 4)
        np.testing.assert_allclose(float(loss_full), float(loss_acc), rtol=1e-5)
        np.testing.assert_allclose(np.asarray(g_full["w"]),
                                   np.asarray(g_acc["w"]), rtol=1e-4)

    def test_indivisible_raises(self):
        with pytest.raises(ValueError, match="divisible"):
            accumulate.split_microbatches({"x": jnp.zeros((10, 2))}, 3)


class TestStraggler:
    def test_flags_outliers_and_keeps_ema_clean(self):
        mon = StragglerMonitor(StragglerConfig(min_history=3, threshold=1.5))
        flagged = [mon.record(t) for t in
                   [1.0, 1.0, 1.0, 1.0, 1.0, 5.0, 1.0, 1.0]]
        assert flagged[5] is True and sum(flagged) == 1
        assert abs(mon.ema - 1.0) < 0.05          # outlier didn't poison EMA
        assert mon.summary()["flagged"] == 1

    def test_rank_backoff(self):
        mon = StragglerMonitor()
        assert mon.suggested_rank(64, True) == 32
        assert mon.suggested_rank(64, False) == 64


class TestSampling:
    def test_greedy_matches_argmax(self, rng):
        from repro.launch.sampling import sample_tokens
        logits = jnp.asarray(rng.normal(size=(4, 50)).astype(np.float32))
        out = sample_tokens(jax.random.PRNGKey(0), logits, temperature=0.0)
        np.testing.assert_array_equal(np.asarray(out),
                                      np.asarray(jnp.argmax(logits, -1)))

    def test_top_k_restricts_support(self, rng):
        from repro.launch.sampling import sample_tokens
        logits = jnp.asarray(rng.normal(size=(1, 100)).astype(np.float32))
        top5 = set(np.argsort(-np.asarray(logits)[0])[:5].tolist())
        draws = {int(sample_tokens(jax.random.PRNGKey(i), logits,
                                   temperature=1.0, top_k=5)[0])
                 for i in range(50)}
        assert draws <= top5

    def test_top_p_keeps_minimal_nucleus(self):
        from repro.launch.sampling import sample_tokens
        # one dominant token (p≈0.97) → nucleus at 0.9 is a single token
        logits = jnp.asarray([[10.0, 1.0, 0.0, -1.0]])
        draws = {int(sample_tokens(jax.random.PRNGKey(i), logits,
                                   temperature=1.0, top_p=0.9)[0])
                 for i in range(20)}
        assert draws == {0}


class TestMetricsAndEval:
    def test_jsonl_roundtrip_and_throughput(self, tmp_path):
        from repro.launch.metrics import MetricsLogger, read_metrics
        path = str(tmp_path / "m.jsonl")
        lg = MetricsLogger(path, num_chips=2, flops_per_step=1e12)
        lg.log(0, {"loss": 2.0}, tokens=100)
        lg.log(1, {"loss": 1.5}, tokens=100)
        lg.close()
        rows = read_metrics(path)
        assert rows[0]["loss"] == 2.0
        assert "tokens_per_s" in rows[1] and "mfu" in rows[1]
        assert rows[1]["tokens_seen"] == 200

    def test_jsonl_rows_buffered_until_flush(self, tmp_path):
        """One logical row per step, but host writes only every
        ``flush_every`` rows and on close — the step loop never pays a
        per-step file syscall."""
        from repro.launch.metrics import MetricsLogger, read_metrics
        path = str(tmp_path / "buffered.jsonl")
        lg = MetricsLogger(path, flush_every=3)
        lg.log(0, {"loss": 1.0})
        lg.log(1, {"loss": 2.0})
        assert read_metrics(path) == []               # still buffered
        lg.log(2, {"loss": 3.0})                      # hits the boundary
        assert [r["step"] for r in read_metrics(path)] == [0, 1, 2]
        lg.log(3, {"loss": 4.0})
        lg.close()                                    # close drains the tail
        rows = read_metrics(path)
        assert [r["step"] for r in rows] == [0, 1, 2, 3]
        assert rows[3]["loss"] == 4.0

    def test_step_time_isolated_from_host_pauses(self, tmp_path):
        """Regression: step_time_s/mfu/tokens_per_s came from the wall gap
        between log calls, so an eval/checkpoint pause between steps
        cratered the NEXT step's MFU. With step_time passed, throughput is
        computed from the dispatch clock and the pause lands in
        host_overhead_s instead."""
        import time as time_mod
        from repro.launch.metrics import MetricsLogger, read_metrics
        path = str(tmp_path / "t.jsonl")
        lg = MetricsLogger(path, num_chips=1, flops_per_step=1e12,
                           flush_every=1)
        lg.log(0, {"loss": 2.0}, tokens=100, step_time=0.01)
        time_mod.sleep(0.08)                  # simulated eval pause
        lg.log(1, {"loss": 1.9}, tokens=100, step_time=0.01)
        lg.close()
        rows = read_metrics(path)
        for r in rows:
            assert r["step_time_s"] == pytest.approx(0.01)
            assert r["tokens_per_s"] == pytest.approx(100 / 0.01)
        assert rows[1]["host_overhead_s"] >= 0.05   # the pause, separated
        assert rows[1]["mfu"] == pytest.approx(
            1e12 / (0.01 * 197e12), rel=1e-6)

    def test_lazy_rows_materialize_at_flush(self, tmp_path):
        """MetricsFuture rows queue without a device sync; the flush
        boundary is the one materialization point."""
        import jax.numpy as jnp
        from repro.launch.metrics import (MetricsFuture, MetricsLogger,
                                          read_metrics)
        path = str(tmp_path / "lazy.jsonl")
        lg = MetricsLogger(path, flush_every=3)
        futs = [MetricsFuture({"loss": jnp.float32(i)}) for i in range(3)]
        lg.log(0, futs[0])
        lg.log(1, futs[1])
        assert not futs[0].materialized and not futs[1].materialized
        assert "loss" in futs[0]              # key checks never sync
        assert not futs[0].materialized
        lg.log(2, futs[2])                    # flush boundary drains all
        assert all(f.materialized for f in futs)
        assert [r["loss"] for r in read_metrics(path)] == [0.0, 1.0, 2.0]
        lg.close()

    def test_eval_stream_disjoint_and_ppl(self):
        from repro import configs
        from repro.launch.evaluate import make_eval_fn
        from repro.models import model as M
        mcfg = configs.get_smoke_config("minicpm-2b")
        params = M.init_params(mcfg, jax.random.PRNGKey(0))
        ev = make_eval_fn(mcfg, batch=4, seq=16, num_batches=2)
        out = ev(params)
        assert out["eval_ppl"] == pytest.approx(
            np.exp(out["eval_loss"]), rel=1e-5)
        assert 0 < out["eval_loss"] < 20

    def test_train_loop_with_metrics_and_eval(self, tmp_path):
        from repro.launch.train import RunConfig, train
        from repro.launch.metrics import read_metrics
        mpath = str(tmp_path / "metrics.jsonl")
        run = RunConfig(arch="minicpm-2b", steps=6, batch=8, seq=32,
                        graft_rset=(2, 4), log_every=100,
                        metrics_path=mpath, eval_every=3)
        report = train(run)
        rows = read_metrics(mpath)
        assert len(rows) == 6
        assert any("eval_ppl" in h for h in report["history"])

    def test_train_step_flops_attention_term(self):
        """Regression for the mfu denominator: 6·N·tokens misses the
        O(S²·Dh·H) attention work, so at fixed tokens the param-only
        estimate is flat in S while compiled FLOPs grow. The
        attention-aware estimate must track that growth better."""
        from repro.compat import cost_analysis_dict
        from repro.launch.metrics import (attention_train_flops,
                                          train_step_flops)
        from repro.models import model as M

        def mk(S):
            cfg = M.ModelConfig(
                family="dense", num_layers=2, d_model=64, num_heads=4,
                num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=64,
                param_dtype="float32", scan_layers=False)
            params = M.init_params(cfg, jax.random.PRNGKey(0))
            B = 512 // S                       # fixed tokens per step
            toks = jnp.zeros((B, S), jnp.int32)
            batch = {"tokens": toks, "labels": toks}

            def step(p, b):
                return jax.grad(lambda pp: M.loss_fn(cfg, pp, b)[0])(p)

            compiled = jax.jit(step).lower(params, batch).compile()
            measured = cost_analysis_dict(compiled).get("flops", 0.0)
            n = sum(int(np.prod(l.shape))
                    for l in jax.tree_util.tree_leaves(params))
            return (measured,
                    train_step_flops(n, B * S, remat=False),
                    train_step_flops(n, B * S, remat=False, mcfg=cfg, seq=S))

        m1, p1, a1 = mk(128)
        m2, p2, a2 = mk(512)
        assert p1 == p2                        # param-only: blind to S
        assert a2 > a1 > p1                    # attention term grows with S
        measured_ratio = m2 / m1
        assert measured_ratio > 1.05           # XLA sees the S² work too
        # attention-aware ratio lands closer to the measured growth
        assert abs(a2 / a1 - measured_ratio) < abs(1.0 - measured_ratio)

    def test_attention_flops_window_and_pattern_aware(self):
        """A sliding window caps the per-query KV horizon, and only LOCAL
        layers in the pattern get the cap."""
        from repro.launch.metrics import attention_train_flops
        from repro.models import model as M
        import dataclasses

        base = M.ModelConfig(
            family="dense", num_layers=4, d_model=64, num_heads=4,
            num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=64)
        full = attention_train_flops(base, seq=1024, tokens_per_step=1024)
        local = dataclasses.replace(base, sliding_window=64,
                                    layer_pattern=("local",))
        capped = attention_train_flops(local, seq=1024, tokens_per_step=1024)
        assert capped < full / 4               # horizon 512 → ~64
        mixed = dataclasses.replace(base, sliding_window=64,
                                    layer_pattern=("local", "global"))
        half = attention_train_flops(mixed, seq=1024, tokens_per_step=1024)
        assert capped < half < full

    def test_device_clock_times_all_but_first_step(self):
        """N observed steps yield exactly N−1 timings, delivered to both
        poll() (straggler feed) and device_time() (logger)."""
        from repro.launch.metrics import DeviceClock
        clock = DeviceClock()
        for step in range(5):
            clock.observe(step, jnp.float32(step))
        clock.drain()
        assert clock.timed_steps == 4
        assert clock.device_time(0, timeout=0.1) is None   # no predecessor
        for step in range(1, 5):
            assert clock.device_time(step, timeout=1.0) >= 0.0
        assert clock.total_device_s >= 0.0
        assert len(clock.poll()) == 4
        assert clock.poll() == []              # fresh list drained once
        clock.close()
