"""GRAFT selection pipeline: features, projection errors, dynamic rank,
Lemma 1 / Remark 1 numerical checks, baselines."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis_compat import given, settings, st  # skips, not collection errors, without hypothesis

from repro.core import baselines, features, graft, grad_features, projection


class TestFeatures:
    def test_svd_features_ordered(self, rng):
        A = jnp.asarray(rng.normal(size=(32, 100)).astype(np.float32))
        V = features.svd_features(A, 8)
        norms = np.linalg.norm(np.asarray(V), axis=0)
        assert np.all(np.diff(norms) <= 1e-3), "columns not relevance-ordered"

    def test_svd_spans_dominant_subspace(self, rng):
        A = np.asarray(rng.normal(size=(24, 64)).astype(np.float32))
        V = np.asarray(features.svd_features(jnp.asarray(A), 4))
        U = np.linalg.svd(A, full_matrices=False)[0][:, :4]
        # V should span the same subspace as top-4 left singular vectors
        q, _ = np.linalg.qr(V)
        s = np.linalg.svd(q.T @ U)[1]
        np.testing.assert_allclose(np.sum(s ** 2), 4.0, atol=1e-3)

    def test_gram_path_equals_svd_path(self, rng):
        A = rng.normal(size=(16, 40)).astype(np.float32)   # M > K → gram path
        B = A.T.copy()                                      # M < K → svd path
        VA = np.asarray(features.svd_features(jnp.asarray(A), 4))
        U, s, _ = np.linalg.svd(A, full_matrices=False)
        ref = U[:, :4] * s[:4]
        # columns defined up to sign
        for j in range(4):
            err = min(np.linalg.norm(VA[:, j] - ref[:, j]),
                      np.linalg.norm(VA[:, j] + ref[:, j]))
            assert err < 1e-2

    def test_pca_centers(self, rng):
        A = jnp.asarray((rng.normal(size=(32, 20)) + 100.0).astype(np.float32))
        V = features.pca_features(A, 4)
        assert np.isfinite(np.asarray(V)).all()

    def test_ica_shapes_and_determinism(self, rng):
        A = jnp.asarray(rng.normal(size=(40, 30)).astype(np.float32))
        V1 = np.asarray(features.ica_features(A, 6))
        V2 = np.asarray(features.ica_features(A, 6))
        assert V1.shape == (40, 6)
        np.testing.assert_allclose(V1, V2)

    @staticmethod
    def _excess_kurtosis(S):
        return (np.mean(S ** 4, axis=0)
                / np.clip(np.mean(S ** 2, axis=0) ** 2, 1e-12, None) - 3.0)

    @pytest.mark.parametrize("K", [32, 64])
    def test_ica_kurtosis_ordering_at_probe_batch_sizes(self, rng, K):
        """Rel-ordering precondition at GRAFT probe batch sizes: ICA columns
        must come out sorted by descending |excess kurtosis| (the ICA
        relevance measure), and the ordering must be non-degenerate when the
        batch genuinely mixes heavy-tailed, sub-Gaussian and Gaussian
        sources."""
        sources = np.stack([
            rng.laplace(size=K),                      # heavy tail: kurt ≈ +3
            rng.uniform(-1, 1, size=K),               # sub-Gaussian: ≈ −1.2
            rng.normal(size=K),                       # Gaussian: ≈ 0
        ], axis=1).astype(np.float32)                 # (K, 3)
        mix = rng.normal(size=(3, 256)).astype(np.float32)
        A = jnp.asarray(sources @ mix)                # (K, 256) mixed batch
        V = np.asarray(features.ica_features(A, 3))
        assert V.shape == (K, 3) and np.all(np.isfinite(V))
        k = np.abs(self._excess_kurtosis(V))
        assert np.all(np.diff(k) <= 1e-4), f"|kurtosis| not descending: {k}"
        # ordering must be real, not a tie: the recovered heavy-tailed
        # source separates clearly from the least non-Gaussian one
        assert k[0] > k[-1] + 0.3, k

    def test_sketch_svd_ordered_and_spans_svd_subspace(self, rng):
        """sketch_svd must match svd_features up to sketching error: same
        relevance ordering, near-zero principal angles on a matrix with a
        decaying spectrum, and matching singular-value scales."""
        K, M, R = 128, 512, 8
        U = np.linalg.qr(rng.normal(size=(K, K)))[0]
        Vt = np.linalg.qr(rng.normal(size=(M, M)))[0][:K]
        s = 10.0 * (0.7 ** np.arange(K))
        A = jnp.asarray(((U * s) @ Vt).astype(np.float32))
        Vs = np.asarray(features.svd_features(A, R))
        Vk = np.asarray(features.sketch_svd_features(A, R))
        assert Vk.shape == (K, R)
        norms = np.linalg.norm(Vk, axis=0)
        assert np.all(np.diff(norms) <= 1e-3), "columns not relevance-ordered"
        np.testing.assert_allclose(norms, np.linalg.norm(Vs, axis=0),
                                   rtol=5e-2)
        qs, _ = np.linalg.qr(Vs)
        qk, _ = np.linalg.qr(Vk)
        cosines = np.linalg.svd(qs.T @ qk, compute_uv=False)
        assert cosines.min() > 0.98, f"principal angles too wide: {cosines}"

    def test_sketch_svd_deterministic_across_calls(self, rng):
        A = jnp.asarray(rng.normal(size=(48, 96)).astype(np.float32))
        np.testing.assert_array_equal(
            np.asarray(features.sketch_svd_features(A, 6)),
            np.asarray(features.sketch_svd_features(A, 6)))


class TestProjection:
    def test_lemma1_identity(self, rng):
        """Lemma 1: ‖ḡ − QQᵀḡ‖² = ‖ḡ‖²(1 − ‖Qᵀĝ‖²)."""
        G = rng.normal(size=(50, 8)).astype(np.float32)
        g = rng.normal(size=(50,)).astype(np.float32)
        q, _ = np.linalg.qr(G)
        lhs = np.linalg.norm(g - q @ (q.T @ g)) ** 2
        ghat = g / np.linalg.norm(g)
        rhs = np.linalg.norm(g) ** 2 * (1 - np.linalg.norm(q.T @ ghat) ** 2)
        np.testing.assert_allclose(lhs, rhs, rtol=1e-4)
        # and our normalized prefix error at full rank equals lhs/‖g‖²
        errs = projection.prefix_projection_errors(jnp.asarray(G), jnp.asarray(g))
        np.testing.assert_allclose(float(errs[-1]),
                                   lhs / np.linalg.norm(g) ** 2, atol=1e-4)

    def test_prefix_errors_monotone_nonincreasing(self, rng):
        G = jnp.asarray(rng.normal(size=(64, 16)).astype(np.float32))
        g = jnp.asarray(rng.normal(size=(64,)).astype(np.float32))
        errs = np.asarray(projection.prefix_projection_errors(G, g))
        assert np.all(np.diff(errs) <= 1e-5)

    def test_full_rank_error_zero(self, rng):
        """When span(G) = R^d the projection error must vanish."""
        G = jnp.asarray(rng.normal(size=(8, 8)).astype(np.float32))
        g = jnp.asarray(rng.normal(size=(8,)).astype(np.float32))
        errs = np.asarray(projection.prefix_projection_errors(G, g))
        assert errs[-1] < 1e-5

    @settings(max_examples=20, deadline=None)
    @given(d=st.integers(4, 64), R=st.integers(1, 12), seed=st.integers(0, 9999))
    def test_property_sweep_matches_qr_oracle(self, d, R, seed):
        g_ = np.random.default_rng(seed)
        R = min(R, d)
        G = jnp.asarray(g_.normal(size=(d, R)).astype(np.float32))
        gb = jnp.asarray(g_.normal(size=(d,)).astype(np.float32))
        errs = np.asarray(projection.prefix_projection_errors(G, gb))
        for r in (1, R):
            oracle = float(projection.projection_error(G[:, :r], gb))
            np.testing.assert_allclose(errs[r - 1], oracle, atol=2e-4)

    def test_select_rank_smallest_satisfying(self):
        errs = jnp.asarray([0.9, 0.5, 0.2, 0.05])
        rank, err = projection.select_rank(errs, (1, 2, 3, 4), eps=0.3)
        assert int(rank) == 3 and abs(float(err) - 0.2) < 1e-6

    def test_select_rank_fallback_argmin(self):
        errs = jnp.asarray([0.9, 0.8, 0.7, 0.6])
        rank, err = projection.select_rank(errs, (1, 2, 4), eps=0.1)
        assert int(rank) == 4 and abs(float(err) - 0.6) < 1e-6


class TestRemark1:
    def test_gradient_approximation_with_interpolation_weights(self, rng):
        """Remark 1 (as its proof actually establishes): with MaxVol
        interpolation weights T = V·V_S⁻¹ the weighted subset gradient
        reconstructs the full-batch mean gradient with error O(L_g·σ_{R+1})
        for a linear (hence Lipschitz) gradient map. The paper states the
        bound for unweighted means, which does not hold even at σ_{R+1}=0 —
        deviation recorded in EXPERIMENTS.md §Paper-claims. The bound is
        exact in the rank-R limit, which is what we gate on."""
        K, M, R = 32, 20, 8
        W = rng.normal(size=(M, M)).astype(np.float32)
        W = W @ W.T / M                                # PSD, grad map g(x) = Wx
        L_g = float(np.linalg.eigvalsh(W).max())

        def recon_error(noise):
            A = (rng.normal(size=(K, R)) @ rng.normal(size=(R, M)) +
                 noise * rng.normal(size=(K, M))).astype(np.float32)
            from repro.core.features import svd_features
            from repro.core.maxvol import fast_maxvol
            V = np.asarray(svd_features(jnp.asarray(A), R))
            piv, _ = fast_maxvol(jnp.asarray(V), R)
            piv = np.asarray(piv)
            T = V @ np.linalg.inv(V[piv])              # (K, R) interpolation
            c = T.mean(0)                              # weighted-mean coeffs
            g_full = (A @ W).mean(0)
            g_sub = (A[piv] @ W).T @ c                 # Σ_j c_j g(A_j)
            sigma = np.linalg.svd(A, full_matrices=False)[1]
            return np.linalg.norm(g_full - g_sub), sigma[R] if R < len(sigma) else 0.0

        err_clean, sig_clean = recon_error(1e-5)
        err_noisy, sig_noisy = recon_error(0.3)
        # exact in the rank-R limit…
        assert err_clean < 1e-3, err_clean
        # …and the error tracks σ_{R+1} with a modest Lipschitz-sized factor
        assert err_noisy <= 5.0 * L_g * K / R * sig_noisy, (err_noisy, sig_noisy)


class TestGraftSelect:
    def test_end_to_end_state(self, rng):
        cfg = graft.GraftConfig(rset=(2, 4, 8), eps=0.3, grad_mode="full")
        A = jnp.asarray(rng.normal(size=(32, 40)).astype(np.float32))
        target = jnp.asarray(rng.normal(size=(40,)).astype(np.float32))

        def loss_fn(params, x):
            return jnp.mean((x @ params) ** 2)

        st_ = graft.select_from_batch(cfg, A, loss_fn=loss_fn,
                                      params=target)
        assert int(st_.rank) in (2, 4, 8)
        assert len(set(np.asarray(st_.pivots).tolist())) == 8
        np.testing.assert_allclose(float(jnp.sum(st_.weights)), 1.0, atol=1e-5)
        active = int(jnp.sum(st_.weights > 0))
        assert active == int(st_.rank)

    def test_low_rank_gradients_choose_small_rank(self, rng):
        """If all per-sample gradients live in a 2-D subspace, GRAFT must
        pick the smallest candidate rank ≥ 2."""
        cfg = graft.GraftConfig(rset=(2, 4, 8, 16), eps=1e-3)
        d, K = 30, 32
        basis = rng.normal(size=(d, 2)).astype(np.float32)
        coeffs = rng.normal(size=(2, K)).astype(np.float32)
        G = jnp.asarray(basis @ coeffs)
        g_bar = jnp.asarray(G.mean(axis=1))
        V = features.svd_features(G.T, cfg.r_max)
        state = graft.graft_select(cfg, V, G, g_bar, jnp.int32(0))
        assert int(state.rank) == 2, f"picked {int(state.rank)}"
        assert float(state.last_error) < 1e-3

    def test_maybe_refresh_period(self, rng):
        cfg = graft.GraftConfig(rset=(2, 4), eps=0.5, refresh_every=5)
        K, d = 16, 10
        state0 = graft.init_state(cfg, K)
        V = jnp.asarray(rng.normal(size=(K, 4)).astype(np.float32))
        G = jnp.asarray(rng.normal(size=(d, K)).astype(np.float32))
        gb = jnp.asarray(rng.normal(size=(d,)).astype(np.float32))
        s1 = graft.maybe_refresh(cfg, state0, jnp.int32(3), V, G, gb)
        assert np.array_equal(np.asarray(s1.pivots), np.asarray(state0.pivots))
        s2 = graft.maybe_refresh(cfg, state0, jnp.int32(5), V, G, gb)
        assert int(s2.step) == 5 and float(s2.last_error) <= 1.0


class TestGradFeatures:
    def test_per_sample_grads_full(self, rng):
        params = {"w": jnp.asarray(rng.normal(size=(5,)).astype(np.float32))}
        X = jnp.asarray(rng.normal(size=(8, 5)).astype(np.float32))

        def loss_fn(p, x):
            return jnp.sum((x @ p["w"]) ** 2)

        G, gbar = grad_features.per_sample_grads_full(loss_fn, params, X)
        assert G.shape == (5, 8)
        np.testing.assert_allclose(np.asarray(gbar), np.asarray(G).mean(1), rtol=1e-5)
        # analytic: ∇_w = 2 (xᵀw) x
        x0 = np.asarray(X)[0]
        ref = 2 * (x0 @ np.asarray(params["w"])) * x0
        np.testing.assert_allclose(np.asarray(G[:, 0]), ref, rtol=1e-4)

    def test_logit_error_embeddings_shapes(self, rng):
        K, S, V, E = 6, 12, 50, 16
        logits = jnp.asarray(rng.normal(size=(K, S, V)).astype(np.float32))
        labels = jnp.asarray(rng.integers(0, V, size=(K, S)), dtype=jnp.int32)
        hid = jnp.asarray(rng.normal(size=(K, S, E)).astype(np.float32))
        emb = grad_features.logit_error_embeddings(logits, labels, hid)
        assert emb.shape == (K, E)
        assert np.isfinite(np.asarray(emb)).all()

    def test_perfect_predictions_give_small_embeddings(self, rng):
        """Zero loss ⇒ zero error signal ⇒ tiny gradient embedding."""
        K, S, V, E = 4, 8, 20, 8
        labels = jnp.asarray(rng.integers(0, V, size=(K, S)), dtype=jnp.int32)
        logits = 100.0 * jax.nn.one_hot(labels, V)
        hid = jnp.asarray(rng.normal(size=(K, S, E)).astype(np.float32))
        emb = grad_features.logit_error_embeddings(logits, labels, hid)
        assert float(jnp.max(jnp.abs(emb))) < 1e-3


class TestBaselines:
    def _G(self, rng, d=30, K=40):
        return jnp.asarray(rng.normal(size=(d, K)).astype(np.float32))

    def test_gradmatch_reduces_residual(self, rng):
        G = self._G(rng)
        gbar = jnp.asarray(np.asarray(G).mean(1))
        piv, w = baselines.gradmatch_omp(G, gbar, 8)
        recon = np.asarray(G)[:, np.asarray(piv)] @ np.asarray(w)
        base = np.linalg.norm(np.asarray(gbar))
        assert np.linalg.norm(np.asarray(gbar) - recon) < base

    def test_craig_weights_sum_to_one(self, rng):
        G = self._G(rng)
        piv, w = baselines.craig_greedy(G, 8)
        assert len(set(np.asarray(piv).tolist())) == 8
        np.testing.assert_allclose(float(jnp.sum(w)), 1.0, atol=1e-5)

    def test_el2n_picks_largest_norms(self, rng):
        G = np.asarray(self._G(rng))
        piv, _ = baselines.el2n_topk(jnp.asarray(G), 5)
        norms = np.linalg.norm(G, axis=0)
        assert set(np.asarray(piv).tolist()) == set(np.argsort(-norms)[:5].tolist())

    def test_random_subset_deterministic_per_key(self):
        p1, _ = baselines.random_subset(jax.random.PRNGKey(7), 32, 8)
        p2, _ = baselines.random_subset(jax.random.PRNGKey(7), 32, 8)
        assert np.array_equal(np.asarray(p1), np.asarray(p2))


class TestGlister:
    def test_greedy_prefers_val_aligned_gradients(self, rng):
        """Samples whose gradients align with the validation gradient must be
        picked first (the GLISTER objective)."""
        from repro.core.baselines import glister_greedy
        d, K = 20, 32
        g_val = rng.normal(size=(d,)).astype(np.float32)
        G = 0.1 * rng.normal(size=(d, K)).astype(np.float32)
        aligned = [3, 17, 29]
        for i in aligned:
            G[:, i] = g_val + 0.01 * rng.normal(size=d)
        piv, w = glister_greedy(jnp.asarray(G), jnp.asarray(g_val), 3)
        assert set(np.asarray(piv).tolist()) == set(aligned)
        np.testing.assert_allclose(float(jnp.sum(w)), 1.0, atol=1e-6)

    def test_diminishing_returns_via_eta(self, rng):
        """The Taylor correction makes the second pick η-dependent: small η
        duplicates the aligned direction, large η flips its residual sign so
        even an orthogonal sample beats partially-aligned duplicates."""
        from repro.core.baselines import glister_greedy
        d = 10
        g_val = np.zeros(d, np.float32); g_val[0] = 1.0
        G = np.zeros((d, 4), np.float32)
        G[0, 0] = 1.0                     # perfectly aligned
        G[0, 1] = 0.95                    # nearly identical direction
        G[1, 2] = 0.5; G[0, 2] = 0.4      # partially aligned, novel direction
        G[2, 3] = 1.0                     # orthogonal
        pick2 = {}
        for eta in (0.5, 2.0):
            piv, _ = glister_greedy(jnp.asarray(G), jnp.asarray(g_val), 2,
                                    eta=eta)
            piv = np.asarray(piv).tolist()
            assert piv[0] == 0, piv
            pick2[eta] = piv[1]
        assert pick2[0.5] == 1            # duplicate still profitable
        assert pick2[2.0] == 3            # over-corrected: novelty wins
