"""Execution-backend subsystem: registry/config plumbing, LocalBackend
bit-identity with the pre-backend trainer loop, batch staging, topology
stamps + elastic (resharded) checkpoint restore across device counts."""
import dataclasses
import json
import os
import shutil
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import SRC, run_forced_devices

from repro import backend as backend_lib
from repro.api.config import ExperimentConfig
from repro.checkpoint import CheckpointManager
from repro.data import sources as data_sources
from repro.distributed.pipeline import BatchStager, assemble_global_batch
from repro.launch.mesh import make_host_mesh


# ---------------------------------------------------------------------------
# registry + config section
# ---------------------------------------------------------------------------

def test_registry_lists_both_backends():
    names = backend_lib.available_backends()
    assert "local" in names and "multiprocess" in names


def test_resolve_none_is_local():
    be = backend_lib.resolve(None)
    assert be.name == "local"
    assert be.process_index == 0 and be.process_count == 1
    assert be.is_primary
    assert be.data_shard() == (1, 0)
    assert be.staging_depth == 0


def test_resolve_passes_backend_instances_through():
    be = backend_lib.resolve(backend_lib.LocalBackendConfig())
    assert backend_lib.resolve(be) is be


def test_entry_for_config_and_name():
    mcfg = backend_lib.MultiProcessBackendConfig()
    assert backend_lib.backend_name_of(mcfg) == "multiprocess"
    assert backend_lib.backend_name_of(backend_lib.LocalBackendConfig()) \
        == "local"
    with pytest.raises(KeyError):
        backend_lib.entry_for_config(object())


def test_one_config_class_per_backend():
    with pytest.raises(ValueError):
        backend_lib.register_backend(backend_lib.BackendEntry(
            "imposter", backend_lib.LocalBackendConfig, lambda c: None))


def test_backend_section_round_trips_tagged():
    cfg = ExperimentConfig().apply_overrides([
        "backend.kind=multiprocess",
        "backend.coordinator=10.0.0.1:5555",
        "backend.num_processes=4",
    ])
    assert isinstance(cfg.backend, backend_lib.MultiProcessBackendConfig)
    assert cfg.backend.coordinator == "10.0.0.1:5555"
    d = cfg.to_dict()
    assert d["backend"]["kind"] == "multiprocess"
    back = ExperimentConfig.from_dict(d)
    assert back.backend == cfg.backend


def test_backend_section_is_hash_neutral():
    base = ExperimentConfig()
    multi = ExperimentConfig().apply_overrides([
        "backend.kind=multiprocess", "backend.num_processes=2"])
    assert base.config_hash() == multi.config_hash()


def test_backend_field_override_requires_kind_first():
    # default backend is None (= local); per-backend fields only exist
    # after backend.kind selects the section type
    with pytest.raises(KeyError, match="num_processes"):
        ExperimentConfig().apply_overrides(["backend.num_processes=2"])


def test_backend_kind_swap_back_to_local():
    cfg = ExperimentConfig().apply_overrides([
        "backend.kind=multiprocess", "backend.kind=local"])
    assert isinstance(cfg.backend, backend_lib.LocalBackendConfig)
    # local serializes untagged — kind only appears for non-default backends
    assert cfg.to_dict().get("backend") in (None, {})


# ---------------------------------------------------------------------------
# data-pipeline host sharding
# ---------------------------------------------------------------------------

class _FakeShardBackend(backend_lib.Backend):
    name = "fake2of4"

    def __init__(self):
        super().__init__(None)

    def data_shard(self):
        return 4, 1


def test_shard_for_backend_local_is_noop():
    dcfg = data_sources.DataConfig()
    out = data_sources.shard_for_backend(dcfg, backend_lib.resolve(None))
    assert out is dcfg


def test_shard_for_backend_splits_hosts():
    dcfg = dataclasses.replace(data_sources.DataConfig(), global_batch=16)
    out = data_sources.shard_for_backend(dcfg, _FakeShardBackend())
    assert (out.num_hosts, out.host_index) == (4, 1)
    assert out.global_batch == 16


def test_shard_for_backend_rejects_indivisible_batch():
    dcfg = dataclasses.replace(data_sources.DataConfig(), global_batch=6)
    with pytest.raises(ValueError):
        data_sources.shard_for_backend(dcfg, _FakeShardBackend())


# ---------------------------------------------------------------------------
# batch staging
# ---------------------------------------------------------------------------

class _CountingSource:
    """Yields {"x": [i]} forever; state is the number of batches pulled."""

    def __init__(self):
        self.pulled = 0

    def __iter__(self):
        return self

    def __next__(self):
        batch = {"x": np.asarray([self.pulled], dtype=np.int64)}
        self.pulled += 1
        return batch

    def state_dict(self):
        return {"pos": self.pulled}

    def load_state_dict(self, state):
        self.pulled = int(state["pos"])


def test_stager_depth0_is_inline_and_ordered():
    src = _CountingSource()
    stager = BatchStager(src, lambda b: {"x": b["x"] * 10}, depth=0)
    assert stager.consumed_state() == {"pos": 0}
    for i in range(3):
        out = next(stager)
        assert out["x"][0] == i * 10
        # inline: source advances exactly one pull per next()
        assert src.pulled == i + 1
        assert stager.consumed_state() == {"pos": i + 1}
    stager.close()


def test_stager_lookahead_accounts_consumed_not_pulled():
    src = _CountingSource()
    stager = BatchStager(src, lambda b: b, depth=2)
    first = next(stager)
    assert first["x"][0] == 0
    # depth=2 keeps 3 staged ahead: source ran ahead of consumption
    assert src.pulled >= 3
    assert stager.consumed_state() == {"pos": 1}
    second = next(stager)
    assert second["x"][0] == 1
    assert stager.consumed_state() == {"pos": 2}
    stager.close()


def test_stager_reset_drops_stale_lookahead():
    src = _CountingSource()
    stager = BatchStager(src, lambda b: b, depth=2)
    next(stager), next(stager)
    # external rewind (restore/rollback) then reset: staged batches from
    # the pre-rewind position must never reach the loop
    src.load_state_dict({"pos": 0})
    stager.reset()
    assert stager.consumed_state() == {"pos": 0}
    assert next(stager)["x"][0] == 0
    stager.close()


def test_assemble_global_batch_single_process_identity():
    mesh = make_host_mesh()
    batch = {"tokens": np.arange(12, dtype=np.int32).reshape(4, 3),
             "y": np.ones((4,), dtype=np.float32)}
    out = assemble_global_batch(mesh, batch)
    for k in batch:
        np.testing.assert_array_equal(np.asarray(out[k]), batch[k])
    assert out["tokens"].sharding.spec == jax.sharding.PartitionSpec(
        "data", None)


def test_local_backend_shard_batch_matches_asarray():
    be = backend_lib.resolve(None)
    batch = {"x": np.arange(6).reshape(2, 3)}
    out = be.shard_batch(batch)
    np.testing.assert_array_equal(np.asarray(out["x"]), batch["x"])
    # all_reduce/check_consistent are identities on the local backend
    assert be.all_reduce({"a": 1.5})["a"] == 1.5
    be.check_consistent("anything")
    spec = be.all_reduce_spec()
    assert spec.num_shards == 1 and not spec.compressed


# ---------------------------------------------------------------------------
# LocalBackend trainer bit-identity with the pre-backend loop
# ---------------------------------------------------------------------------

_FAST = ["train.steps=3", "train.batch=8", "train.seq=16",
         "train.log_every=0", "train.checkpoint_every=0",
         "graft.refresh_every=2"]


def test_local_backend_trainer_matches_handrolled_loop():
    from repro.api import Trainer
    from repro.distributed import sharding as sh
    from repro.launch import steps as steps_lib

    cfg = ExperimentConfig().apply_overrides(_FAST).finalized()

    # hand-rolled pre-backend loop: host mesh + init + jnp.asarray batches
    mcfg, tcfg, data = cfg.build()
    mesh = make_host_mesh()
    run_step = steps_lib.make_run_step(mcfg, tcfg)
    ref_losses = []
    with sh.sharding_rules(mesh):
        state = steps_lib.init_train_state(
            mcfg, tcfg, jax.random.PRNGKey(cfg.train.seed), cfg.train.batch)
        it = iter(data)
        for step in range(cfg.train.steps):
            batch = {k: jnp.asarray(v) for k, v in next(it).items()}
            state, metrics = run_step(state, batch, step)
            ref_losses.append(float(np.asarray(metrics["loss"])))

    report = Trainer(cfg).fit()
    got = [row["loss"] for row in report["history"]]
    assert got == ref_losses, f"backend loop diverged: {got} vs {ref_losses}"


_PHASE_REDUCE = """
import numpy as np
from repro.backend.base import MultiProcessBackendConfig
from repro.backend.multiprocess import MultiProcessBackend

# single process, 4 forced devices: the mesh/shard_map machinery of
# all_reduce runs without jax.distributed (setup() skipped on purpose)
tree = {'a': np.float32(2.5), 'b': np.linspace(-1, 1, 7, dtype=np.float32)}
plain = MultiProcessBackend(MultiProcessBackendConfig()).all_reduce(tree)
comp_be = MultiProcessBackend(
    MultiProcessBackendConfig(compress_reduce=True))
comp = comp_be.all_reduce(tree)
assert comp_be.all_reduce_spec().compressed
# replicated inputs: the mean is the value itself; int8 quantization adds
# <1% error which the EF accumulator carries to the next call
np.testing.assert_allclose(plain['a'], 2.5, rtol=1e-6)
np.testing.assert_allclose(comp['a'], 2.5, rtol=2e-2)
np.testing.assert_allclose(comp['b'], tree['b'], atol=2e-2)
assert comp_be._ef_errors is not None
print('REDUCE_OK')
"""


def test_all_reduce_plain_and_compressed_forced_devices():
    assert "REDUCE_OK" in run_forced_devices(_PHASE_REDUCE, devices=4)


def test_straggler_merge_summaries_names_worst_process():
    from repro.distributed.straggler import merge_summaries
    merged = merge_summaries([
        {"process_index": 0, "ema_s": 0.10, "max_s": 0.2, "flagged": 0},
        {"process_index": 1, "ema_s": 0.45, "max_s": 0.9, "flagged": 3},
    ])
    assert merged["processes"] == 2
    assert merged["worst_process"] == 1
    assert merged["worst_ema_s"] == pytest.approx(0.45)
    assert merged["flagged_total"] == 3
    assert merged["max_s"] == pytest.approx(0.9)
    empty = merge_summaries([])
    assert empty["processes"] == 0 and empty["worst_process"] == -1


# ---------------------------------------------------------------------------
# topology stamp + elastic restore
# ---------------------------------------------------------------------------

def _tiny_tree():
    return {"params": {"w": np.arange(6, dtype=np.float32).reshape(2, 3)},
            "step": np.asarray(0, dtype=np.int32)}


def test_restore_matching_topology_needs_no_backend(tmp_path):
    be = backend_lib.resolve(None)
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _tiny_tree(), topology=be.topology())
    out = mgr.restore(1, _tiny_tree())
    np.testing.assert_array_equal(np.asarray(out["params"]["w"]),
                                  _tiny_tree()["params"]["w"])


def test_restore_mismatched_topology_raises_actionable(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _tiny_tree(),
             topology={"process_count": 8, "device_count": 64,
                       "shard_layout": "replicated"})
    with pytest.raises(ValueError, match="reshard elastically"):
        mgr.restore(1, _tiny_tree())


def test_restore_mismatched_topology_reshards_with_backend(tmp_path,
                                                           capsys):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _tiny_tree(),
             topology={"process_count": 8, "device_count": 64,
                       "shard_layout": "replicated"})
    out = mgr.restore(1, _tiny_tree(), backend=backend_lib.resolve(None))
    np.testing.assert_array_equal(np.asarray(out["params"]["w"]),
                                  _tiny_tree()["params"]["w"])
    assert "resharding" in capsys.readouterr().out


def test_unstamped_checkpoint_restores_everywhere(tmp_path):
    # pre-backend checkpoints carry no topology — they must keep restoring
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, _tiny_tree())
    out = mgr.restore(1, _tiny_tree())
    np.testing.assert_array_equal(np.asarray(out["params"]["w"]),
                                  _tiny_tree()["params"]["w"])


# ---------------------------------------------------------------------------
# elastic resume across device counts (forced-device subprocesses)
# ---------------------------------------------------------------------------

_ELASTIC_OVERRIDES = ("'train.steps=6', 'train.batch=8', 'train.seq=16', "
                      "'train.log_every=0', 'train.checkpoint_every=3', "
                      "'train.metrics_flush_every=1', "
                      "'graft.refresh_every=2', 'graft.streaming=true'")

_PHASE_A = """
import json
import shutil
import numpy as np
from repro.api import ExperimentConfig, Trainer

overrides = [{overrides}]
ref = Trainer(ExperimentConfig().apply_overrides(
    overrides + ['train.checkpoint_dir={work}/ref_ckpt'])).fit()
ref_losses = [r['loss'] for r in ref['history']]

interrupted = Trainer(ExperimentConfig().apply_overrides(
    overrides + ['train.checkpoint_dir={work}/ckpt',
                 'train.stop_after=3'])).fit()
assert interrupted.get('stopped') == 'stop_after', interrupted.get('stopped')

# resume from a COPY — the resumed run checkpoints into its directory,
# and later phases need the pristine mid-run checkpoint
shutil.copytree('{work}/ckpt', '{work}/ckpt_same')
resumed = Trainer.from_checkpoint('{work}/ckpt_same').fit()
res_losses = [r['loss'] for r in resumed['history']]
# same device count + byte-exact restore + data replay → bit-exact tail
assert res_losses == ref_losses[3:], (res_losses, ref_losses)
print('SAMECOUNT_OK')
print(json.dumps({{'ref': ref_losses}}))
"""

_PHASE_RESUME = """
import json
from repro.api import Trainer

report = Trainer.from_checkpoint('{ckpt}').fit()
assert report['history'], 'resume ran no steps'
losses = [r['loss'] for r in report['history']]
print('RESUME_OK')
print(json.dumps({{'losses': losses}}))
"""

_PHASE_RESHARD = """
import numpy as np
from repro import backend as backend_lib
from repro.checkpoint import CheckpointManager
from repro.api import ExperimentConfig

mgr = CheckpointManager('{ckpt}')
step = mgr.latest_step()
manifest = mgr.manifest(step)
saved_topo = manifest['topology']
be = backend_lib.resolve(None)
assert saved_topo != be.topology(), (saved_topo, be.topology())
# target skeleton: zeros shaped like the stored leaves
import os, json as _json
tree = {{}}
for key, meta in manifest['leaves'].items():
    arr = np.load(os.path.join('{ckpt}', f'step_{{step:08d}}', meta['file']))
    tree[key] = np.zeros_like(arr)
out = mgr.restore(step, tree, backend=be)
for key, meta in manifest['leaves'].items():
    got = np.asarray(out[key])
    want = np.load(os.path.join('{ckpt}', f'step_{{step:08d}}',
                                meta['file']))
    assert got.dtype == want.dtype or meta['dtype'] == 'bfloat16'
    np.testing.assert_array_equal(got.view(want.dtype), want)
print('RESHARD_OK')
"""


def _last_json(stdout: str) -> dict:
    lines = [l for l in stdout.strip().splitlines() if l.startswith("{")]
    return json.loads(lines[-1])


def test_elastic_resume_across_device_counts(tmp_path):
    work = str(tmp_path)
    out = run_forced_devices(
        _PHASE_A.format(overrides=_ELASTIC_OVERRIDES, work=work), devices=4)
    assert "SAMECOUNT_OK" in out
    ref_losses = _last_json(out)["ref"]
    assert len(ref_losses) == 6

    # the 4-device checkpoint resumes on 1 and 2 devices; losses track the
    # 4-device reference (not bit-exact: batch-axis reductions reassociate
    # across device counts — observed drift ~1e-4..5e-4 by step 5)
    for ndev in (1, 2):
        ckpt = os.path.join(work, f"ckpt_{ndev}dev")
        shutil.copytree(os.path.join(work, "ckpt"), ckpt)
        out = run_forced_devices(_PHASE_RESUME.format(ckpt=ckpt),
                                 devices=ndev)
        assert "RESUME_OK" in out
        losses = _last_json(out)["losses"]
        assert len(losses) == 3
        np.testing.assert_allclose(losses, ref_losses[3:], rtol=3e-3,
                                   err_msg=f"{ndev}-device resume diverged")

    # vice versa: the resumed 1-device run wrote its own (1-device-stamped)
    # checkpoint — restore it onto 4 devices through the backend
    out = run_forced_devices(
        _PHASE_RESHARD.format(ckpt=os.path.join(work, "ckpt_1dev")),
        devices=4)
    assert "RESHARD_OK" in out


# ---------------------------------------------------------------------------
# real 2-process jax.distributed smoke (the CI multihost job's entry point)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_multiprocess_harness_end_to_end(tmp_path):
    env = dict(os.environ, PYTHONPATH=SRC, JAX_PLATFORMS="cpu")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.backend", "--workdir", str(tmp_path)],
        capture_output=True, text=True, env=env, timeout=900)
    assert proc.returncode == 0, \
        f"stdout:\n{proc.stdout[-3000:]}\nstderr:\n{proc.stderr[-3000:]}"
    assert "loss parity OK" in proc.stdout
    assert "elastic resume OK" in proc.stdout
