import os
import subprocess
import sys
import textwrap

# tests see the real (single) CPU device — the 512-device override belongs
# ONLY to repro.launch.dryrun (see that module's header).
SRC = os.path.join(os.path.dirname(__file__), "..", "src")
sys.path.insert(0, SRC)
sys.path.insert(0, os.path.dirname(__file__))   # hypothesis_compat import

import numpy as np
import pytest


def run_forced_devices(code: str, devices: int = 4, timeout: int = 480,
                       env_extra=None) -> str:
    """Run ``code`` in a FRESH python with N forced CPU devices.

    The XLA device count is fixed at backend init, so multi-device CPU
    tests cannot run in the pytest process — this is the one shared
    subprocess recipe (selection shard_map, sampler-v2 conformance,
    dry-run, and backend elastic-resume tests all use it). Returns stdout;
    asserts a zero exit with the subprocess stderr tail on failure."""
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH=SRC)
    if env_extra:
        env.update(env_extra)
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def pytest_addoption(parser):
    parser.addoption("--run-slow", action="store_true", default=False,
                     help="run tests marked @pytest.mark.slow "
                          "(multi-device subprocess smokes; minutes each)")


def pytest_collection_modifyitems(config, items):
    if config.getoption("--run-slow"):
        return
    deselected = [i for i in items if "slow" in i.keywords]
    if deselected:
        config.hook.pytest_deselected(items=deselected)
        items[:] = [i for i in items if "slow" not in i.keywords]


@pytest.fixture
def rng():
    return np.random.default_rng(0)
