import os
import sys

# tests see the real (single) CPU device — the 512-device override belongs
# ONLY to repro.launch.dryrun (see that module's header).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))   # hypothesis_compat import

import numpy as np
import pytest


def pytest_addoption(parser):
    parser.addoption("--run-slow", action="store_true", default=False,
                     help="run tests marked @pytest.mark.slow "
                          "(multi-device subprocess smokes; minutes each)")


def pytest_collection_modifyitems(config, items):
    if config.getoption("--run-slow"):
        return
    deselected = [i for i in items if "slow" in i.keywords]
    if deselected:
        config.hook.pytest_deselected(items=deselected)
        items[:] = [i for i in items if "slow" not in i.keywords]


@pytest.fixture
def rng():
    return np.random.default_rng(0)
