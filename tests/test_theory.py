"""Numerical checks of the paper's theory (Lemma 1, Remark 1, Thm 1/2
convergence behavior, Corollary 1 dynamic-rank safety)."""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import graft, projection
from repro.core.features import svd_features
from repro.core.maxvol import fast_maxvol


class TestTheorem1Convergence:
    def test_projected_gd_converges_when_error_bounded(self, rng):
        """GD with gradient projected onto a subspace containing most of ḡ
        converges to a small-gradient region (Thm 1: ‖∇L‖ ≤ εG)."""
        d = 30
        A = rng.normal(size=(d, d)).astype(np.float32)
        Q_ = A @ A.T / d + np.eye(d, dtype=np.float32)   # strongly convex
        w_star = rng.normal(size=(d,)).astype(np.float32)

        def grad(w):
            return Q_ @ (w - w_star)

        w = np.zeros(d, np.float32)
        for _ in range(400):
            g = grad(w)
            # projection basis: top-8 directions of recent gradients + noise
            basis = np.stack([grad(w + 0.01 * rng.normal(size=d))
                              for _ in range(8)], 1)
            q, _ = np.linalg.qr(basis)
            g_proj = q @ (q.T @ g)
            w = w - 0.05 * g_proj
        assert np.linalg.norm(grad(w)) < 0.1 * np.linalg.norm(grad(np.zeros(d)))

    def test_unbounded_error_stalls(self, rng):
        """Projecting onto a near-orthogonal subspace must NOT converge —
        the ε in the bound is real, not slack."""
        d = 30
        w_star = np.ones(d, np.float32)

        def grad(w):
            return w - w_star

        w = np.zeros(d, np.float32)
        # fixed basis orthogonal to the gradient direction 1/√d
        ones = np.ones((d, 1)) / np.sqrt(d)
        B = np.linalg.qr(rng.normal(size=(d, 5)) -
                         ones @ (ones.T @ rng.normal(size=(d, 5))))[0]
        for _ in range(200):
            g = grad(w)
            w = w - 0.1 * B @ (B.T @ g)
        # gradient norm stays large: projection killed the descent direction
        assert np.linalg.norm(grad(w)) > 0.5 * np.linalg.norm(grad(np.zeros(d)))


class TestCorollary1:
    def test_rank_grows_until_error_below_eps(self, rng):
        """Dynamic rank adjustment: for gradients with r-dim structure the
        selected rank tracks r as eps tightens."""
        d, K = 40, 64
        for true_rank in (2, 6):
            basis = rng.normal(size=(d, true_rank)).astype(np.float32)
            G = (basis @ rng.normal(size=(true_rank, K))).astype(np.float32)
            G += 1e-4 * rng.normal(size=(d, K)).astype(np.float32)
            gb = jnp.asarray(G.mean(1))
            V = svd_features(jnp.asarray(G).T, 16)
            cfg = graft.GraftConfig(rset=(1, 2, 4, 6, 8, 16), eps=1e-3)
            st = graft.graft_select(cfg, V, jnp.asarray(G), gb, jnp.int32(0))
            assert int(st.rank) <= max(true_rank, 1) + 2
            assert float(st.last_error) <= 1e-3 + 1e-4


class TestAlignmentFigure2:
    def test_alignment_improves_with_rank(self, rng):
        """cos(subset ḡ, batch ḡ) grows with subset size (Fig 2b trend)."""
        d, K = 32, 64
        G = rng.normal(size=(d, K)).astype(np.float32)
        G[:, : K // 2] += 3 * rng.normal(size=(d, 1)).astype(np.float32)
        gb = jnp.asarray(G.mean(1))
        V = svd_features(jnp.asarray(G).T, 16)
        piv, _ = fast_maxvol(V, 16)
        aligns = []
        for r in (2, 8, 16):
            sub = jnp.asarray(G)[:, np.asarray(piv)[:r]].mean(1)
            aligns.append(float(projection.cosine_alignment(sub, gb)))
        assert aligns[-1] >= aligns[0] - 0.05


class TestComplexityScaling:
    def test_fast_maxvol_quadratic_in_R(self, rng):
        """Operation-count proxy: FLOP estimate of the jitted fast_maxvol
        scales ~O(K·R²) (paper Table 7)."""
        import jax
        K = 512

        def flops(R):
            from repro.compat import cost_analysis_dict
            V = jnp.zeros((K, R), jnp.float32)
            c = jax.jit(lambda v: fast_maxvol(v, R)).lower(V).compile()
            return cost_analysis_dict(c).get("flops", 0.0)

        f8, f16, f32 = flops(8), flops(16), flops(32)
        # growth ratio between successive doublings should be ≲ 4 (R² term)
        # and ≳ 1.6 (definitely superlinear)
        assert 1.6 < f32 / f16 < 5.0, (f8, f16, f32)
