"""End-to-end training integration: GRAFT step vs baseline, convergence,
checkpoint resume byte-exactness, serving driver."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.launch import serve as serve_lib
from repro.launch import steps as steps_lib
from repro.launch.train import RunConfig, train


class TestTrainLoop:
    def test_graft_training_reduces_loss(self, tmp_path):
        run = RunConfig(arch="minicpm-2b", steps=30, batch=16, seq=32,
                        use_graft=True, graft_rset=(4, 8), graft_refresh=5,
                        lr=3e-3, log_every=100)
        report = train(run)
        losses = [h["loss"] for h in report["history"]]
        assert losses[-1] < losses[0] - 0.1, (losses[0], losses[-1])
        ranks = {h["rank"] for h in report["history"]}
        assert ranks <= {4.0, 8.0}

    def test_baseline_training_reduces_loss(self):
        run = RunConfig(arch="minicpm-2b", steps=25, batch=8, seq=32,
                        use_graft=False, lr=3e-3, log_every=100)
        report = train(run)
        losses = [h["loss"] for h in report["history"]]
        assert losses[-1] < losses[0] - 0.1

    def test_checkpoint_resume_is_exact(self, tmp_path):
        """Train 20; vs train 10 → restart → 10 more: identical final loss."""
        # NOTE: the interrupted leg must keep steps=20 — the LR schedule is
        # a function of the TOTAL step budget, so "train 10 of 20" is
        # expressed via stop_after (preemption), not by shrinking steps.
        common = {"arch": "minicpm-2b", "batch": 8, "seq": 32,
                  "use_graft": True, "graft_rset": (2, 4), "graft_refresh": 4,
                  "lr": 1e-3, "log_every": 100, "checkpoint_every": 10,
                  "seed": 3}
        r_full = train(RunConfig(steps=20, **common))
        ck = str(tmp_path / "ck")
        train(RunConfig(steps=20, stop_after=10, checkpoint_dir=ck, **common))
        r_resumed = train(RunConfig(steps=20, checkpoint_dir=ck, **common))
        np.testing.assert_allclose(r_full["final_loss"],
                                   r_resumed["final_loss"], rtol=1e-4)

    def test_graft_metrics_present(self):
        run = RunConfig(arch="stablelm-12b", steps=6, batch=8, seq=32,
                        graft_rset=(2, 4), graft_refresh=2, log_every=100)
        report = train(run)
        h = report["history"][0]
        for key in ("loss", "grad_norm", "rank", "proj_error", "alignment"):
            assert key in h


class TestOverlappedSelector:
    """graft.overlap=True splits the refresh into its own dispatch
    (double-buffered, pipelined against the train stream) — the trajectory
    must be IDENTICAL to the sequential lax.cond path."""

    @staticmethod
    def _cfg(overrides=()):
        from repro.api import ExperimentConfig
        base = ["train.steps=8", "train.batch=8", "train.seq=16",
                "train.log_every=0", "graft.rset=[2,4]",
                "graft.refresh_every=3"]
        return ExperimentConfig().apply_overrides(base + list(overrides))

    def test_trajectory_matches_sequential(self):
        from repro.api import Trainer
        seq_cfg = self._cfg()
        ov_cfg = self._cfg(["graft.overlap=true"])
        # overlap is a dispatch schedule, not an experiment: hashes agree
        assert seq_cfg.config_hash() == ov_cfg.config_hash()
        r_seq = Trainer(seq_cfg, use_default_callbacks=False).fit()
        r_ov = Trainer(ov_cfg, use_default_callbacks=False).fit()
        np.testing.assert_allclose(
            [h["loss"] for h in r_seq["history"]],
            [h["loss"] for h in r_ov["history"]], rtol=1e-6)
        assert [h["rank"] for h in r_seq["history"]] == \
            [h["rank"] for h in r_ov["history"]]
        np.testing.assert_allclose(r_seq["final_loss"], r_ov["final_loss"],
                                   rtol=1e-6)

    def test_overlap_metrics_match_sequential_keys(self):
        from repro.api import Trainer
        report = Trainer(self._cfg(["graft.overlap=true", "train.steps=4"]),
                         use_default_callbacks=False).fit()
        h = report["history"][0]
        for key in ("loss", "grad_norm", "rank", "proj_error", "alignment"):
            assert key in h

    def test_refresh_cadence_respected(self):
        """The selector refreshes exactly at step % S == 0: pivots may only
        change at refresh boundaries."""
        import jax.numpy as jnp
        from repro.selection.overlap import OverlappedSelector
        from repro.api import Trainer
        cfg = self._cfg(["train.steps=1"])
        tr = Trainer(cfg, use_default_callbacks=False)
        tr.fit()                                      # builds mcfg/tcfg/state
        sel = OverlappedSelector(tr.mcfg, tr.tcfg, donate=False)
        state = steps_lib.init_train_state(
            tr.mcfg, tr.tcfg, jax.random.PRNGKey(0), 8)
        batch = {k: jnp.asarray(v) for k, v in tr.data.batch_at(0).items()}
        pivots = []
        for step in range(6):
            state, _ = sel.step(state, batch, step)
            pivots.append(np.asarray(state["graft"].pivots).tolist())
        assert pivots[0] == pivots[1] == pivots[2]    # refresh at 0, hold
        assert pivots[3] == pivots[4] == pivots[5]    # refresh at 3, hold


class TestFlashBackendTraining:
    def test_flash_selection_pivots_match_dense(self):
        """attn_backend is a kernel schedule, not an experiment: GRAFT's
        discrete selection (pivots, ranks) must be IDENTICAL under the
        flash and dense attention paths on synthetic_lm."""
        from repro.api import ExperimentConfig, Trainer

        def run(backend):
            cfg = ExperimentConfig().apply_overrides([
                "train.steps=5", "train.batch=8", "train.seq=32",
                "train.log_every=0",
                'model.overrides={"attn_backend": "%s", '
                '"param_dtype": "float32"}' % backend,
                "graft.rset=[2,4]", "graft.refresh_every=2",
            ])
            tr = Trainer(cfg, use_default_callbacks=False)
            report = tr.fit()
            return report, np.asarray(tr.state["graft"].pivots)

        r_f, piv_f = run("flash")
        r_d, piv_d = run("dense")
        assert np.array_equal(piv_f, piv_d)
        assert [h["rank"] for h in r_f["history"]] == \
            [h["rank"] for h in r_d["history"]]
        np.testing.assert_allclose(r_f["final_loss"], r_d["final_loss"],
                                   rtol=1e-4)


class TestGraftVsRandomSubset:
    def test_graft_selects_better_than_random_on_skewed_batch(self, rng):
        """On a batch with a few dominant directions, GRAFT's projection
        error at rank R must beat random selection's (averaged)."""
        from repro.core import graft
        from repro.core.features import svd_features
        from repro.core.projection import projection_error
        d, K, R = 40, 64, 8
        basis = rng.normal(size=(d, 3)).astype(np.float32)
        G = (basis @ rng.normal(size=(3, K)) +
             0.1 * rng.normal(size=(d, K))).astype(np.float32)
        g_bar = jnp.asarray(G.mean(1))
        Gj = jnp.asarray(G)
        V = svd_features(Gj.T, R)
        cfg = graft.GraftConfig(rset=(R,), eps=0.5)
        state = graft.graft_select(cfg, V, Gj, g_bar, jnp.int32(0))
        graft_err = float(state.last_error)
        rand_errs = []
        for t in range(30):
            idx = np.random.default_rng(t).choice(K, R, replace=False)
            rand_errs.append(float(projection_error(Gj[:, idx], g_bar)))
        assert graft_err <= np.mean(rand_errs) + 1e-3, \
            (graft_err, np.mean(rand_errs))


class TestServe:
    def test_wave_serving_completes_all_requests(self):
        report = serve_lib.serve(arch="minicpm-2b", slots=3, requests=7,
                                 max_new_tokens=6, max_seq=64)
        assert report["requests"] == 7
        ids = sorted(r["request_id"] for r in report["results"])
        assert ids == list(range(7))
        for r in report["results"]:
            assert 1 <= len(r["tokens"]) <= 6

    def test_serving_is_deterministic(self):
        r1 = serve_lib.serve(arch="minicpm-2b", slots=2, requests=3,
                             max_new_tokens=5, max_seq=64, seed=11)
        r2 = serve_lib.serve(arch="minicpm-2b", slots=2, requests=3,
                             max_new_tokens=5, max_seq=64, seed=11)
        assert [r["tokens"] for r in r1["results"]] == \
            [r["tokens"] for r in r2["results"]]


class TestTrainStepUnits:
    def test_train_state_logical_covers_state(self):
        from repro import configs
        from repro.launch.specs import default_train_config
        mcfg = configs.get_smoke_config("qwen3-moe-235b-a22b")
        tcfg = default_train_config("qwen3-moe-235b-a22b", batch=8)
        abstract = jax.eval_shape(
            lambda key: steps_lib.init_train_state(mcfg, tcfg, key, 8),
            jax.ShapeDtypeStruct((2,), jnp.uint32))
        logical = steps_lib.train_state_logical(mcfg, tcfg, abstract)
        is_lg = lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x)
        flat_a = jax.tree_util.tree_flatten(abstract)[0]
        flat_l = jax.tree_util.tree_flatten(logical, is_leaf=is_lg)[0]
        assert len(flat_a) == len(flat_l)

    def test_adafactor_state_logical_drops_axis(self):
        from repro import configs
        from repro.launch.specs import default_train_config
        mcfg = configs.get_smoke_config("kimi-k2-1t-a32b")
        tcfg = default_train_config("kimi-k2-1t-a32b", batch=8)
        assert tcfg.optimizer.name == "adafactor"
        abstract = jax.eval_shape(
            lambda key: steps_lib.init_train_state(mcfg, tcfg, key, 8),
            jax.ShapeDtypeStruct((2,), jnp.uint32))
        logical = steps_lib.train_state_logical(mcfg, tcfg, abstract)
        # vr for a stacked (L, E, D, F) weight must have 3 entries
        vr = logical["opt"]["v"]["blocks"]["moe"]["w_gate"]["vr"]
        assert len(vr) == 4 - 1

    def test_selection_inputs_shapes(self, rng):
        from repro import configs
        from repro.launch.specs import default_train_config
        mcfg = configs.get_smoke_config("minicpm-2b")
        tcfg = default_train_config("minicpm-2b", batch=8)
        from repro.models import model as M
        params = M.init_params(mcfg, jax.random.PRNGKey(0))
        toks = jnp.asarray(rng.integers(0, mcfg.vocab_size, (8, 32)),
                           dtype=jnp.int32)
        V, G, gbar, scores = steps_lib.selection_inputs(
            mcfg, tcfg, params, {"tokens": toks, "labels": toks})
        assert V.shape == (8, tcfg.graft.r_max)
        assert G.shape == (mcfg.d_model, 8)
        assert gbar.shape == (mcfg.d_model,)
        assert scores.shape == (8,) and bool(jnp.all(scores > 0))
