"""Resilience subsystem: sentinel, rollback, chaos harness, watchdog.

End-to-end recovery is exercised by ``python -m repro.resilience`` (the CI
chaos matrix); these tests pin the unit-level contracts each piece rides on
— fault-plan determinism, the checkpoint crash window, quarantine walks,
health-aware GC, JSONL sanitization, signal-handler hygiene, and the
DeviceClock stall watchdog.
"""
import dataclasses
import json
import os
import shutil
import signal
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import ExperimentConfig, Trainer
from repro.api.callbacks import CheckpointCallback
from repro.checkpoint import CheckpointManager, EmergencySaver
from repro.launch import steps as steps_lib
from repro.launch.metrics import (DeviceClock, MetricsFuture, MetricsLogger,
                                  sanitize_row)
from repro.resilience import chaos
from repro.resilience.guard import DivergenceGuardCallback


# ---------------------------------------------------------------------------
# fault plans
# ---------------------------------------------------------------------------

def test_fault_plan_parsing_inline_dict_and_file(tmp_path):
    inline = chaos.FaultPlan.from_spec('[{"kind": "sigterm", "step": 3}]')
    assert inline.faults[0]["step"] == 3
    single = chaos.FaultPlan.from_spec('{"kind": "nan_batch", "step": 1}')
    assert single.faults[0]["kind"] == "nan_batch"
    parsed = chaos.FaultPlan.from_spec([{"kind": "stall", "step": 2}])
    assert parsed.faults[0]["kind"] == "stall"
    p = tmp_path / "plan.json"
    p.write_text('{"faults": [{"kind": "crash", "point": "x"}]}')
    from_file = chaos.FaultPlan.from_spec(str(p))
    assert from_file.faults[0]["point"] == "x"


def test_fault_plan_rejects_unknown_kind():
    with pytest.raises(ValueError, match="unknown fault kind"):
        chaos.FaultPlan([{"kind": "meteor", "step": 1}])


def test_fault_plan_env_fallback(monkeypatch):
    monkeypatch.setenv(chaos.ENV_VAR, '[{"kind": "sigterm", "step": 9}]')
    plan = chaos.load_plan(None)
    assert plan is not None and plan.faults[0]["step"] == 9
    # explicit config wins over the environment
    plan = chaos.load_plan('[{"kind": "sigterm", "step": 1}]')
    assert plan.faults[0]["step"] == 1
    monkeypatch.delenv(chaos.ENV_VAR)
    assert chaos.load_plan(None) is None


def test_nan_batch_fault_fires_exactly_once():
    plan = chaos.FaultPlan([{"kind": "nan_batch", "step": 4}])
    clean = {"tokens": np.arange(6).reshape(2, 3),
             "x": np.ones((2, 3), np.float32)}
    assert plan.corrupt_batch(3, clean) is clean
    poisoned = plan.corrupt_batch(4, clean)
    assert np.all(poisoned["tokens"] >= chaos.BAD_TOKEN_ID) or \
        np.all(poisoned["tokens"] == np.iinfo(clean["tokens"].dtype).max // 1)
    assert np.all(np.isnan(poisoned["x"]))
    # replaying the same step after a rollback must NOT re-poison
    assert plan.corrupt_batch(4, clean) is clean


def test_crash_point_skip_counter():
    plan = chaos.FaultPlan([{"kind": "crash", "point": "p", "skip": 2}])
    with chaos.active_plan(plan):
        chaos.crash_point("p")      # pass 1
        chaos.crash_point("other")  # different point: not counted
        chaos.crash_point("p")      # pass 2
        with pytest.raises(chaos.ChaosCrash):
            chaos.crash_point("p")  # third hit fires
        chaos.crash_point("p")      # fired already — inert
    chaos.crash_point("p")          # no active plan — inert


# ---------------------------------------------------------------------------
# the on-device sentinel
# ---------------------------------------------------------------------------

def _sentinel_tcfg(**kw):
    return steps_lib.TrainConfig(sentinel=True, **kw)


def test_apply_sentinel_spike_z_detection():
    tcfg = _sentinel_tcfg(spike_z=6.0)
    health = {"ema_mean": jnp.float32(2.0), "ema_var": jnp.float32(0.01),
              "count": jnp.int32(steps_lib.SENTINEL_WARMUP),
              "bad_streak": jnp.int32(0)}
    state = {"step": jnp.int32(5), "params": {"w": jnp.ones(3)},
             "health": health}
    new_state = {"step": jnp.int32(6), "params": {"w": jnp.zeros(3)}}

    # a 100-sigma loss spike is unhealthy even though it is finite
    _, m = steps_lib.apply_sentinel(tcfg, state, dict(new_state),
                                    {"loss": jnp.float32(100.0)})
    assert float(m["healthy"]) == 0.0
    # a loss inside the band passes
    sel, m = steps_lib.apply_sentinel(tcfg, state, dict(new_state),
                                      {"loss": jnp.float32(2.01)})
    assert float(m["healthy"]) == 1.0
    assert float(sel["params"]["w"][0]) == 0.0      # update applied


def test_apply_sentinel_skip_update_restores_fallback():
    tcfg = _sentinel_tcfg(spike_z=0.0)
    state = {"step": jnp.int32(5), "params": {"w": jnp.ones(3)},
             "health": steps_lib.init_health()}
    new_state = {"step": jnp.int32(6), "params": {"w": jnp.zeros(3)}}
    sel, m = steps_lib.apply_sentinel(tcfg, state, dict(new_state),
                                      {"loss": jnp.float32(float("nan"))})
    assert float(m["healthy"]) == 0.0
    np.testing.assert_array_equal(np.asarray(sel["params"]["w"]),
                                  np.ones(3))       # update skipped
    assert int(sel["step"]) == 6                    # but the step advances
    assert int(sel["health"]["bad_streak"]) == 1


def test_sentinel_state_round_trips_through_checkpoint(tmp_path):
    """Pre-sentinel checkpoints (no health/ leaves) restore into the new
    state layout — the fresh health leaves are kept, nothing raises."""
    mgr = CheckpointManager(str(tmp_path))
    old_tree = {"params": {"w": np.arange(4.0)}}
    mgr.save(1, old_tree, extra={"train_step": 1})
    target = {"params": {"w": jnp.zeros(4)},
              "health": steps_lib.init_health()}
    got = mgr.restore(1, target)
    np.testing.assert_array_equal(np.asarray(got["params"]["w"]),
                                  np.arange(4.0))
    assert int(got["health"]["count"]) == 0


# ---------------------------------------------------------------------------
# checkpoint crash window + recovery
# ---------------------------------------------------------------------------

def test_resave_crash_between_renames_keeps_committed_step(tmp_path):
    """The PR-8 regression test for checkpoint.py's old rmtree-before-rename
    window: killing the writer between the two commit renames must not lose
    the committed checkpoint for that step."""
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(5, {"w": np.arange(8.0)}, extra={"train_step": 5})
    plan = chaos.FaultPlan([{"kind": "crash",
                             "point": "checkpoint.mid_commit"}])
    with chaos.active_plan(plan), \
            pytest.raises(chaos.ChaosCrash):
        mgr.save(5, {"w": np.arange(8.0) * 2}, extra={"train_step": 5})
    # the directory holds only breadcrumbs now; a fresh manager recovers
    mgr2 = CheckpointManager(str(tmp_path))
    assert mgr2.all_steps() == [5]
    got = mgr2.restore(5, {"w": np.zeros(8)})
    np.testing.assert_array_equal(np.asarray(got["w"]), np.arange(8.0))


def test_recover_drops_stale_tmp_and_redundant_old(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(3, {"w": np.ones(2)}, extra={})
    os.makedirs(tmp_path / "tmp.9.123")
    final = tmp_path / "step_00000003"
    shutil.copytree(final, tmp_path / "step_00000003.old")
    mgr2 = CheckpointManager(str(tmp_path))
    names = sorted(os.listdir(tmp_path))
    assert "tmp.9.123" not in names
    assert "step_00000003.old" not in names
    assert mgr2.all_steps() == [3]


def test_async_writer_failure_surfaces_on_wait(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=True)
    plan = chaos.FaultPlan([{"kind": "crash",
                             "point": "checkpoint.pre_commit"}])
    with chaos.active_plan(plan):
        mgr.save(1, {"w": np.ones(2)}, extra={})
        with pytest.raises(chaos.ChaosCrash):
            mgr.wait()
    mgr.wait()                       # exception is one-shot
    assert CheckpointManager(str(tmp_path)).all_steps() == []


# ---------------------------------------------------------------------------
# restore_latest_good / quarantine / GC
# ---------------------------------------------------------------------------

def _save_steps(mgr, steps, health=None):
    for s in steps:
        extra = {"train_step": s}
        if health and s in health:
            extra["health"] = health[s]
        mgr.save(s, {"w": np.full(4, float(s))}, extra=extra)


def test_restore_latest_good_quarantines_corrupt_intermediate(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_last_n=0, async_save=False)
    _save_steps(mgr, [1, 2, 3])
    chaos.flip_checkpoint_leaf(str(tmp_path), 3, "w")
    step, tree, manifest = mgr.restore_latest_good({"w": np.zeros(4)})
    assert step == 2 and manifest["extra"]["train_step"] == 2
    np.testing.assert_array_equal(np.asarray(tree["w"]), np.full(4, 2.0))
    assert "corrupt.00000003" in os.listdir(tmp_path)
    # quarantined dirs are invisible to the step walk
    assert mgr.all_steps() == [1, 2]


def test_restore_latest_good_skips_unhealthy_stamp(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_last_n=0, async_save=False)
    _save_steps(mgr, [1, 2, 3],
                health={3: {"healthy": False, "bad_streak": 4}})
    step, tree, _ = mgr.restore_latest_good({"w": np.zeros(4)})
    assert step == 2
    # unhealthy-but-intact checkpoints are skipped, NOT quarantined
    assert mgr.all_steps() == [1, 2, 3]


def test_restore_latest_good_exhausted_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_last_n=0, async_save=False)
    _save_steps(mgr, [1])
    chaos.flip_checkpoint_leaf(str(tmp_path), 1, "w")
    with pytest.raises(FileNotFoundError):
        mgr.restore_latest_good({"w": np.zeros(4)})


def test_restore_onto_different_keep_last_n_with_corrupt_step(tmp_path):
    """Elastic-restore edge: a manager with different retention policy
    reads the same directory, falls over the corrupt newest step, and
    restores the prior one."""
    writer = CheckpointManager(str(tmp_path), keep_last_n=5,
                               async_save=False)
    _save_steps(writer, [1, 2, 3, 4])
    chaos.flip_checkpoint_leaf(str(tmp_path), 4, "w")
    reader = CheckpointManager(str(tmp_path), keep_last_n=1,
                               async_save=False)
    step, tree, _ = reader.restore_latest_good({"w": np.zeros(4)})
    assert step == 3
    np.testing.assert_array_equal(np.asarray(tree["w"]), np.full(4, 3.0))


def test_gc_preserves_newest_healthy_ancestor(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_last_n=2, async_save=False)
    _save_steps(mgr, [1, 2, 3, 4],
                health={1: {"healthy": True},
                        2: {"healthy": True},
                        3: {"healthy": False, "bad_streak": 2},
                        4: {"healthy": False, "bad_streak": 3}})
    # keep-last-2 would retain only {3, 4} — both unhealthy; the GC must
    # also keep step 2, the newest healthy state rollback can land on
    assert mgr.all_steps() == [2, 3, 4]


def test_manifest_rejects_bare_nan(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    with pytest.raises(ValueError):
        mgr.save(1, {"w": np.ones(2)},
                 extra={"metrics": {"loss": float("nan")}})
    # the sanitized form (what CheckpointCallback writes) goes through
    mgr.save(1, {"w": np.ones(2)},
             extra={"metrics": sanitize_row({"loss": float("nan")})})
    m = CheckpointManager(str(tmp_path)).manifest(1)
    assert m["extra"]["metrics"]["loss"] is None
    assert m["extra"]["metrics"]["nonfinite_keys"] == ["loss"]


# ---------------------------------------------------------------------------
# JSONL telemetry sanitization
# ---------------------------------------------------------------------------

def test_sanitize_row_nonfinite_to_null():
    row = {"step": 3, "loss": float("nan"), "mfu": float("inf"),
           "ok": 1.5, "name": "x"}
    out = sanitize_row(row)
    assert out["loss"] is None and out["mfu"] is None
    assert out["ok"] == 1.5 and out["name"] == "x"
    assert out["nonfinite_keys"] == ["loss", "mfu"]
    assert "nonfinite_keys" not in sanitize_row({"loss": 1.0})


def test_metrics_logger_rows_round_trip_json(tmp_path):
    path = str(tmp_path / "m.jsonl")
    logger = MetricsLogger(path, flush_every=2)
    logger.log(0, MetricsFuture({"loss": jnp.float32(1.5)}), tokens=8)
    logger.log(1, MetricsFuture({"loss": jnp.float32(float("nan")),
                                 "grad_norm": jnp.float32(float("inf"))}),
               tokens=8)
    logger.close()
    rows = [json.loads(line) for line in open(path)]   # raises on bare NaN
    assert rows[0]["loss"] == 1.5
    assert rows[1]["loss"] is None
    assert set(rows[1]["nonfinite_keys"]) == {"loss", "grad_norm"}


# ---------------------------------------------------------------------------
# DeviceClock stall watchdog
# ---------------------------------------------------------------------------

class _StuckMarker:
    """Marker whose completion never arrives until released."""

    def __init__(self):
        self.release = threading.Event()

    def block_until_ready(self):
        self.release.wait(10.0)


def test_device_clock_watchdog_unblocks_consumers():
    clock = DeviceClock(stall_timeout_s=0.2)
    stuck = _StuckMarker()
    clock.observe(0, stuck)
    t0 = time.time()
    clock.drain(timeout=8.0)
    waited = time.time() - t0
    assert waited < 4.0, f"drain blocked {waited:.1f}s despite watchdog"
    assert clock.stalled
    assert clock.device_time(0, timeout=5.0) is None
    stuck.release.set()              # let the stamper thread finish
    deadline = time.time() + 5.0
    while clock.stalled and time.time() < deadline:
        time.sleep(0.05)
    assert not clock.stalled         # completion clears the stall flag
    clock.close()


def test_device_clock_without_timeout_unaffected():
    clock = DeviceClock()
    for s in range(3):
        clock.observe(s, jnp.float32(s))
    clock.drain(timeout=5.0)
    assert clock.timed_steps == 2    # N observed → N−1 deltas
    assert not clock.stalled
    clock.close()


def test_stall_fault_marks_dispatch_fallback(tmp_path):
    """A chaos-stalled step trips the watchdog; telemetry for that window
    keeps the dispatch clock (mfu_source: dispatch), and the run is not
    blocked."""
    plan = json.dumps([{"kind": "stall", "step": 2, "seconds": 3.0}])
    cfg = ExperimentConfig().apply_overrides([
        "train.steps=6", "train.batch=4", "train.seq=16",
        "train.log_every=0", "train.metrics_flush_every=2",
        f"train.metrics_path={tmp_path / 'm.jsonl'}",
        "train.device_timeout_s=0.3", "graft=none",
        "train.sampler=random", f"train.fault_plan={plan}"])
    t0 = time.time()
    report = Trainer(cfg).fit()
    assert time.time() - t0 < 60
    assert report["host_loop"].get("device_stalled") is True
    rows = [json.loads(line) for line in open(tmp_path / "m.jsonl")]
    stalled_window = [r for r in rows if r["step"] >= 2
                      and r.get("mfu_source") == "dispatch"]
    assert stalled_window, "no dispatch-sourced row in the stalled window"


# ---------------------------------------------------------------------------
# signal-handler hygiene: two trainers, one process
# ---------------------------------------------------------------------------

def test_two_trainers_one_process_no_stale_handlers(tmp_path):
    before_term = signal.getsignal(signal.SIGTERM)
    before_int = signal.getsignal(signal.SIGINT)
    plan = json.dumps([{"kind": "sigterm", "step": 2}])
    common = ["train.steps=4", "train.batch=4", "train.seq=16",
              "train.log_every=0", "graft=none", "train.sampler=random"]
    cfg1 = ExperimentConfig().apply_overrides(
        common + [f"train.fault_plan={plan}",
                  f"train.checkpoint_dir={tmp_path / 'ck'}"])
    rep1 = Trainer(cfg1).fit()
    assert rep1.get("stopped") == "preempted"
    # handlers unwound → process defaults back in place
    assert signal.getsignal(signal.SIGTERM) is before_term
    assert signal.getsignal(signal.SIGINT) is before_int
    # a second fit in the same process must not inherit the stop flag
    cfg2 = ExperimentConfig().apply_overrides(common)
    rep2 = Trainer(cfg2).fit()
    assert "stopped" not in rep2
    assert rep2["host_loop"]["steps"] == 4
    assert signal.getsignal(signal.SIGTERM) is before_term


def test_emergency_saver_restore_is_idempotent():
    before = signal.getsignal(signal.SIGTERM)
    saver = EmergencySaver(signals=(signal.SIGTERM,))
    saver.restore_handlers()
    saver.restore_handlers()         # second call is a no-op, not a stale
    assert signal.getsignal(signal.SIGTERM) is before


def test_abort_releases_handlers_and_flushes_metrics(tmp_path):
    """A chaos crash aborts fit() before on_train_end — the abort hooks
    must still unwind signal handlers and flush the JSONL tail."""
    before = signal.getsignal(signal.SIGTERM)
    plan = json.dumps([{"kind": "crash", "point": "checkpoint.pre_commit"}])
    cfg = ExperimentConfig().apply_overrides([
        "train.steps=6", "train.batch=4", "train.seq=16",
        "train.log_every=0", "graft=none", "train.sampler=random",
        f"train.checkpoint_dir={tmp_path / 'ck'}",
        "train.checkpoint_every=2", "train.metrics_flush_every=100",
        f"train.metrics_path={tmp_path / 'm.jsonl'}",
        f"train.fault_plan={plan}"])
    with pytest.raises(chaos.ChaosCrash):
        Trainer(cfg).fit()
    assert signal.getsignal(signal.SIGTERM) is before
    rows = [json.loads(line) for line in open(tmp_path / "m.jsonl")]
    assert rows, "buffered metrics were lost on abort"


# ---------------------------------------------------------------------------
# guard + rollback semantics
# ---------------------------------------------------------------------------

class _FakeTrainer:
    def __init__(self):
        self.sentinel_tripped = False
        self.rollback_reasons = []

    def request_rollback(self, reason):
        self.rollback_reasons.append(reason)


def test_guard_consumes_materialized_rows_for_free():
    guard = DivergenceGuardCallback(patience=2, check_every=100)
    tr = _FakeTrainer()
    for step in range(3):
        row = MetricsFuture({"healthy": jnp.float32(1.0),
                             "bad_streak": jnp.float32(0.0),
                             "loss": jnp.float32(1.0)})
        row.materialize()
        guard.on_step_end(tr, step, row)
    assert not tr.rollback_reasons and guard.bad_steps == 0
    bad = MetricsFuture({"healthy": jnp.float32(0.0),
                         "bad_streak": jnp.float32(2.0),
                         "loss": jnp.float32(float("nan"))})
    bad.materialize()
    guard.on_step_end(tr, 3, bad)
    assert tr.sentinel_tripped
    assert tr.rollback_reasons and "bad_streak 2" in tr.rollback_reasons[0]


def test_guard_force_drains_aged_rows():
    guard = DivergenceGuardCallback(patience=1, check_every=2)
    tr = _FakeTrainer()
    rows = [MetricsFuture({"healthy": jnp.float32(1.0),
                           "bad_streak": jnp.float32(0.0)})
            for _ in range(4)]
    for step, row in enumerate(rows):
        guard.on_step_end(tr, step, row)
    # rows older than check_every steps were drained even though no other
    # consumer materialized them
    assert rows[0].materialized and rows[1].materialized
    assert not tr.rollback_reasons


def test_guard_ignores_runs_without_sentinel():
    guard = DivergenceGuardCallback(patience=1, check_every=1)
    tr = _FakeTrainer()
    guard.on_step_end(tr, 0, MetricsFuture({"loss": jnp.float32(1.0)}))
    assert not guard._pending and not tr.rollback_reasons


def test_checkpoint_callback_refuses_save_while_tripped(tmp_path):
    cb = CheckpointCallback(str(tmp_path / "ck"), every=1)

    class _T:
        pass

    t = _T()
    t.sentinel_tripped = True
    t.should_stop = False
    t.config = ExperimentConfig().apply_overrides(["train.steps=4"])
    from repro import backend as backend_lib
    t.backend = backend_lib.resolve(None)
    cb.on_step_end(t, 0, MetricsFuture({"loss": jnp.float32(1.0)}))
    assert cb.manager.all_steps() == []


def test_rollback_replay_is_bit_exact_for_three_steps(tmp_path):
    """Resume-after-rollback lands on the exact pre-fault trajectory: the
    three steps after the restore point match a clean resume from the same
    checkpoint bit-for-bit."""
    ck = tmp_path / "ck"
    # fault at step 10: rows 10-11 flush at step 11, the guard trips and
    # rolls back to checkpoint 9 — which keep-last-2 still retains at the
    # end of the run (unlike an early checkpoint, which GC would drop)
    plan = json.dumps([{"kind": "nan_batch", "step": 10}])
    cfg = ExperimentConfig().apply_overrides([
        "train.steps=12", "train.batch=8", "train.seq=16",
        "train.log_every=0", f"train.checkpoint_dir={ck}",
        "train.checkpoint_every=3", "train.metrics_flush_every=2",
        f"train.metrics_path={tmp_path / 'm.jsonl'}",
        "train.bad_step_patience=1", "graft.rset=[2,4]",
        "graft.refresh_every=3", f"train.fault_plan={plan}"])
    report = Trainer(cfg).fit()
    rollbacks = report["resilience"]["rollbacks"]
    assert len(rollbacks) == 1
    to_step = rollbacks[0]["to_step"]

    # per-step losses after the rollback (the LAST occurrence of each step
    # in the stream is the replayed, healthy one)
    rows = [json.loads(line) for line in open(tmp_path / "m.jsonl")]
    replayed = {}
    for r in rows:
        replayed[r["step"]] = r["loss"]

    twin_dir = tmp_path / "twin"
    os.makedirs(twin_dir)
    shutil.copytree(ck / f"step_{to_step:08d}",
                    twin_dir / f"step_{to_step:08d}")
    twin_metrics = tmp_path / "twin.jsonl"
    from repro.checkpoint import load_experiment
    twin_cfg = load_experiment(str(twin_dir))
    twin_cfg = dataclasses.replace(twin_cfg, train=dataclasses.replace(
        twin_cfg.train, stop_after=None, fault_plan=None,
        checkpoint_dir=str(twin_dir), metrics_path=str(twin_metrics),
        metrics_flush_every=2))
    twin_report = Trainer(twin_cfg).fit()
    twin_rows = {r["step"]: r["loss"]
                 for r in (json.loads(line) for line in open(twin_metrics))}
    for step in range(to_step, min(to_step + 3, 12)):
        assert replayed[step] == twin_rows[step], \
            f"step {step}: {replayed[step]} != {twin_rows[step]}"
    assert report["final_loss"] == twin_report["final_loss"]


def test_rollback_without_checkpoints_stops_run(tmp_path):
    plan = json.dumps([{"kind": "nan_batch", "step": 2}])
    cfg = ExperimentConfig().apply_overrides([
        "train.steps=8", "train.batch=4", "train.seq=16",
        "train.log_every=0", "graft=none", "train.sampler=random",
        "train.bad_step_patience=1", "train.metrics_flush_every=1",
        f"train.metrics_path={tmp_path / 'm.jsonl'}",
        f"train.fault_plan={plan}"])
    report = Trainer(cfg).fit()
    assert report.get("stopped") == "diverged"
    assert report["host_loop"]["steps"] < 8
